//! Scalability walk (paper Figure 4 in miniature): sweep matrix sizes and
//! print how fill ratio, factorization time, and ordering time evolve per
//! method — showing the paper's qualitative claim that score-sorting
//! (learned) methods hold their ordering cost flat while eigen/partition
//! methods grow.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use pfm_reorder::coordinator::Method;
use pfm_reorder::gen::{ProblemClass, TestMatrix};
use pfm_reorder::harness::runner::evaluate_one;
use pfm_reorder::order::Classical;
use pfm_reorder::runtime::{Learned, PfmRuntime};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rt = PfmRuntime::new("artifacts")?;
    let methods = [
        Method::Classical(Classical::Amd),
        Method::Classical(Classical::Metis),
        Method::Classical(Classical::Fiedler),
        Method::Learned(Learned::Pfm),
    ];
    println!(
        "{:<8} {:<10} {:>8} {:>12} {:>12}",
        "n", "method", "fill", "factor (ms)", "order (ms)"
    );
    for &n in &[128usize, 256, 512, 1024, 2048] {
        let tm = TestMatrix {
            name: format!("sweep_n{n}"),
            class: ProblemClass::TwoDThreeD,
            matrix: ProblemClass::TwoDThreeD.generate(n, 99),
        };
        for &m in &methods {
            let r = evaluate_one(&tm, m, &mut rt, 5)?;
            println!(
                "{:<8} {:<10} {:>8.2} {:>12.2} {:>12.2}{}",
                r.n,
                r.method,
                r.fill_ratio,
                r.factor_time * 1e3,
                r.ordering_time * 1e3,
                match r.provenance {
                    Some(pfm_reorder::runtime::Provenance::SpectralFallback) => "  (fallback)",
                    Some(pfm_reorder::runtime::Provenance::NativeOptimizer) => "  (native)",
                    _ => "",
                }
            );
        }
        println!();
    }
    Ok(())
}
