//! End-to-end direct solver: assemble an FEM system on an unstructured
//! Delaunay mesh (one of the paper's training geometries), reorder with
//! every method, factorize, solve Ax = b, and verify the residual — then
//! do the same on an unsymmetric convection–diffusion system, where the
//! solver dispatches to the Gilbert–Peierls LU engine automatically.
//!
//! This is the "downstream user" workflow the paper motivates: the
//! ordering quality shows up directly as factor size and solve speed.
//!
//! ```bash
//! cargo run --release --example direct_solver
//! ```

use pfm_reorder::coordinator::Method;
use pfm_reorder::factor::DirectSolver;
use pfm_reorder::gen::grid::convection_diffusion_2d;
use pfm_reorder::gen::mesh::{delaunay_mesh, fem_stiffness, Geometry};
use pfm_reorder::runtime::PfmRuntime;
use pfm_reorder::util::rng::Pcg64;
use pfm_reorder::util::timer::time_once;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // FEM stiffness matrix on a plate with 6 holes, ~700 nodes
    let mut rng = Pcg64::new(2026);
    let mesh = delaunay_mesh(Geometry::Hole6, 700, &mut rng);
    let a = fem_stiffness(&mesh, 1.0);
    println!(
        "FEM system: {} nodes, {} triangles, nnz = {}",
        a.nrows(),
        mesh.tris.len(),
        a.nnz()
    );

    // manufactured solution → rhs
    let xtrue: Vec<f64> = (0..a.nrows()).map(|_| rng.next_gaussian()).collect();
    let b = a.matvec(&xtrue);

    let mut rt = PfmRuntime::new("artifacts")?;
    println!(
        "\n{:<10} {:>8} {:>10} {:>12} {:>12} {:>10}",
        "method", "fill", "nnz(L)", "order (ms)", "factor (ms)", "residual"
    );
    for method in Method::table2() {
        let (order, order_t) = time_once(|| match method {
            Method::Classical(c) => Ok(c.order(&a)),
            Method::Learned(l) => l.order(&mut rt, &a, 3).map(|(o, _)| o),
        });
        let order = order?;
        let solver = DirectSolver::prepare(&a, order, order_t)?;
        let x = solver.solve(&b);
        let resid = DirectSolver::residual(&a, &x, &b);
        let s = &solver.stats;
        println!(
            "{:<10} {:>8.2} {:>10} {:>12.2} {:>12.2} {:>10.2e}",
            method.label(),
            s.fill_ratio,
            s.lnnz,
            s.ordering_time * 1e3,
            s.factor_time * 1e3,
            resid
        );
        assert!(resid < 1e-8, "{}: residual too large", method.label());
    }
    println!("\nall methods solved the system to < 1e-8 relative residual");

    // ---- unsymmetric system: the solver dispatches to LU on its own ----
    let cd = convection_diffusion_2d(28, 24, 2.0, &mut rng);
    let xtrue: Vec<f64> = (0..cd.nrows()).map(|_| rng.next_gaussian()).collect();
    let b = cd.matvec(&xtrue);
    println!(
        "\nconvection–diffusion system: {} nodes, nnz = {} (value-unsymmetric)",
        cd.nrows(),
        cd.nnz()
    );
    println!(
        "{:<10} {:>6} {:>10} {:>12} {:>12} {:>10}",
        "method", "kind", "nnz(L+U)", "LU fill", "factor (ms)", "residual"
    );
    for method in Method::unsymmetric() {
        let (order, order_t) = time_once(|| match method {
            Method::Classical(c) => Ok::<_, String>(c.order(&cd)),
            Method::Learned(_) => unreachable!("unsymmetric set is classical"),
        });
        let solver = DirectSolver::prepare(&cd, order?, order_t)?;
        let x = solver.solve(&b);
        let resid = DirectSolver::residual(&cd, &x, &b);
        let s = &solver.stats;
        println!(
            "{:<10} {:>6} {:>10} {:>12.2} {:>12.2} {:>10.2e}",
            method.label(),
            s.factor_kind,
            s.lnnz,
            s.fill_ratio,
            s.factor_time * 1e3,
            resid
        );
        assert_eq!(s.factor_kind, "lu", "unsymmetric input must take the LU engine");
        assert!(resid < 1e-8, "{}: LU residual too large", method.label());
    }
    println!("\nLU path solved the unsymmetric system to < 1e-8 relative residual");
    Ok(())
}
