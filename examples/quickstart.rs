//! Quickstart: generate a sparse SPD system, reorder it with PFM (network
//! artifact if built, the native in-Rust ADMM optimizer otherwise),
//! factorize, and compare fill against the natural ordering.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use pfm_reorder::factor::{analyze, fill_ratio};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::order::Classical;
use pfm_reorder::runtime::{Learned, PfmRuntime};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. a workload: 2D/3D discretized problem, ~400 unknowns
    let a = ProblemClass::TwoDThreeD.generate(400, 42);
    println!("matrix: {}x{}, {} nonzeros", a.nrows(), a.ncols(), a.nnz());

    // 2. the PFM reordering network (native ADMM optimizer if no artifact)
    let mut rt = PfmRuntime::new("artifacts")?;
    let (order, provenance) = Learned::Pfm.order(&mut rt, &a, 7)?;
    println!("PFM ordering via {provenance:?}");

    // 3. fill-in accounting (paper Eq. 15)
    let natural = {
        let sym = analyze(&a);
        fill_ratio(&a, &sym)
    };
    let pap = a.permute_sym(&order);
    let sym = analyze(&pap);
    let pfm_fill = fill_ratio(&pap, &sym);
    println!("fill ratio: natural {natural:.2} -> PFM {pfm_fill:.2}");

    // 4. classical baselines for context
    for method in [Classical::Rcm, Classical::Amd, Classical::Metis, Classical::Fiedler] {
        let o = method.order(&a);
        let p = a.permute_sym(&o);
        let s = analyze(&p);
        println!("  {:<8} {:.2}", method.label(), fill_ratio(&p, &s));
    }

    // 5. numeric factorization of the reordered system
    let factor = pfm_reorder::factor::cholesky_with(&pap, &sym)?;
    println!(
        "numeric Cholesky: nnz(L) = {} (l1 norm = {:.1})",
        factor.lnnz(),
        factor.l1_norm()
    );
    Ok(())
}
