//! Debug probe for a single HLO artifact. Uses the PJRT compatibility
//! layer: with the offline stub it exits with the backend-unavailable
//! error; with the real `xla` crate linked it executes the artifact.

use pfm_reorder::runtime::xla_compat as xla;

fn main() {
    let path = std::env::args().nth(1).unwrap();
    let n = 16usize;
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(&path).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let mut a = vec![0f32; n*n];
    for i in 0..11 { a[i*n+i+1] = -1.0; a[(i+1)*n+i] = -1.0; }
    for i in 0..12 { a[i*n+i] = 2.0; }
    let x0: Vec<f32> = (0..n).map(|i| (i as f32)/(n as f32) - 0.5).collect();
    let mut mask = vec![0f32; n]; for m in mask.iter_mut().take(12) { *m = 1.0; }
    let al = xla::Literal::vec1(&a).reshape(&[16,16]).unwrap();
    let r = exe.execute::<xla::Literal>(&[al, xla::Literal::vec1(&x0), xla::Literal::vec1(&mask)]).unwrap()[0][0].to_literal_sync().unwrap();
    let out = r.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    println!("rust: {:?}", out.iter().map(|x| (x*10000.0).round()/10000.0).collect::<Vec<_>>());
}
