//! **End-to-end serving driver** (the system-prompt-mandated E2E
//! validation): starts the coordinator service, submits a sustained
//! mixed-method workload across all six problem classes and several
//! sizes, and reports latency/throughput plus batching metrics.
//!
//! Proves all three layers compose under concurrency: L3 routing/batching
//! → PJRT execution of the L2 network → whose hot ops are L1 Pallas
//! kernels — while the classical pool runs in parallel threads.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_reorder
//! ```

use std::time::Instant;

use pfm_reorder::coordinator::{Method, ReorderService, ServiceConfig};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::order::Classical;
use pfm_reorder::runtime::{Learned, PfmRuntime};
use pfm_reorder::util::check::check_permutation;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // verify artifacts exist up front so the learned rows use the network
    let rt = PfmRuntime::new("artifacts")?;
    let has_artifacts = !rt.variants().is_empty();
    println!(
        "artifacts: {} ({} variants)",
        if has_artifacts { "found" } else { "MISSING (learned -> fallback)" },
        rt.variants().len()
    );
    drop(rt);

    let service = ReorderService::start(ServiceConfig {
        workers: 4,
        max_batch: 8,
        artifact_dir: "artifacts".into(),
        ..Default::default()
    });

    // workload: 3 waves x 6 classes x 3 sizes x 4 methods = 216 requests
    let methods = [
        Method::Learned(Learned::Pfm),
        Method::Learned(Learned::Udno),
        Method::Classical(Classical::Amd),
        Method::Classical(Classical::Metis),
    ];
    let sizes = [128usize, 256, 420];
    let t0 = Instant::now();
    let mut inflight = Vec::new();
    let mut submitted = 0u64;
    for wave in 0..3u64 {
        for &n in &sizes {
            for &class in &ProblemClass::ALL {
                let a = class.generate(n, wave * 1000 + n as u64);
                for &m in &methods {
                    inflight.push((a.nrows(), m, service.submit(a.clone(), m, submitted)));
                    submitted += 1;
                }
            }
        }
    }
    let submit_wall = t0.elapsed().as_secs_f64();

    let mut ok = 0u64;
    for (n, m, rx) in inflight {
        let resp = rx.recv()?;
        let result = resp.result.map_err(|e| format!("{}: {e}", m.label()))?;
        assert_eq!(result.order.len(), n);
        check_permutation(&result.order).map_err(|e| format!("{}: {e}", m.label()))?;
        ok += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\nsubmitted {submitted} requests in {submit_wall:.2}s; all {ok} completed in {wall:.2}s"
    );
    println!("throughput: {:.1} req/s", ok as f64 / wall);
    println!("\nper-method latency (log-bucketed histograms, O(1) memory):");
    for (name, h) in service.metrics.latency_histograms() {
        println!(
            "  {:<22} n={:<4} mean {:>8.2} ms   p95 {:>8.2} ms   p99 {:>8.2} ms   max {:>8.2} ms",
            name,
            h.count(),
            h.mean() * 1e3,
            h.quantile(0.95) * 1e3,
            h.quantile(0.99) * 1e3,
            h.max() * 1e3
        );
    }
    println!(
        "\nnetwork batching: mean batch occupancy {:.2}, fallbacks {}",
        service.metrics.mean_batch(),
        service.metrics.fallbacks()
    );
    println!("metrics json: {}", service.metrics.to_json().to_string());
    Ok(())
}
