use pfm_reorder::runtime::PfmRuntime;
fn main() {
    let mut rt = PfmRuntime::new("artifacts").unwrap();
    let exe = rt.executable(&std::env::args().nth(1).unwrap_or("pfm".into()), 64).unwrap();
    // deterministic inputs: adj = 7x7 grid laplacian padded, x0 = linspace, mask
    let mut adj = vec![0f32; 64*64];
    let (nx, ny) = (7usize, 7usize);
    let idx = |x: usize, y: usize| y*nx + x;
    for y in 0..ny { for x in 0..nx {
        let i = idx(x,y); adj[i*64+i] = 4.0;
        if x+1<nx { let j = idx(x+1,y); adj[i*64+j]=-1.0; adj[j*64+i]=-1.0; }
        if y+1<ny { let j = idx(x,y+1); adj[i*64+j]=-1.0; adj[j*64+i]=-1.0; }
    }}
    let x0: Vec<f32> = (0..64).map(|i| (i as f32)/64.0 - 0.5).collect();
    let mut mask = vec![0f32; 64]; for m in mask.iter_mut().take(49) { *m = 1.0; }
    let s = exe.run(&adj, &x0, &mask).unwrap();
    println!("scores[0..8] = {:?}", &s[0..8]);
    println!("scores[45..52] = {:?}", &s[45..52]);
}
