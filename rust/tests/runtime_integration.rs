//! Integration: load real AOT artifacts through the PJRT CPU client and
//! verify the full inference path — the critical L3↔L2↔L1 composition
//! check. Skipped (with a message) when `make artifacts` hasn't run.

use pfm_reorder::factor::fill_ratio_of_order;
use pfm_reorder::gen::grid::laplacian_2d;
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::order::{order_from_scores_f32, Classical};
use pfm_reorder::runtime::{Learned, PfmRuntime, Provenance};
use pfm_reorder::util::check::check_permutation;

fn runtime() -> Option<PfmRuntime> {
    let rt = PfmRuntime::new("artifacts").expect("PJRT client");
    if rt.variants().is_empty() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(rt)
}

#[test]
fn pfm_artifact_executes_and_orders() {
    let Some(mut rt) = runtime() else { return };
    let a = laplacian_2d(7, 7); // n=49 → bucket 64
    let scores = rt.scores("pfm", &a, 42).expect("network run");
    assert_eq!(scores.len(), 49);
    assert!(scores.iter().all(|s| s.is_finite()), "non-finite scores");
    // scores must not be constant (the network must discriminate nodes)
    let min = scores.iter().cloned().fold(f32::INFINITY, f32::min);
    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert!(max - min > 1e-9, "constant scores: {min}..{max}");
    let order = order_from_scores_f32(&scores);
    check_permutation(&order).unwrap();
}

#[test]
fn all_variants_execute_on_bucket64() {
    let Some(mut rt) = runtime() else { return };
    let a = ProblemClass::TwoDThreeD.generate(49, 7);
    for variant in ["pfm", "se", "gpce", "udno", "pfm_randinit", "pfm_gunet"] {
        let scores = rt.scores(variant, &a, 1).unwrap_or_else(|e| panic!("{variant}: {e}"));
        assert_eq!(scores.len(), a.nrows(), "{variant}");
        assert!(scores.iter().all(|s| s.is_finite()), "{variant}: non-finite");
    }
}

#[test]
fn network_provenance_and_fallback() {
    let Some(mut rt) = runtime() else { return };
    let small = laplacian_2d(6, 6);
    let (order, prov) = Learned::Pfm.order(&mut rt, &small, 3).unwrap();
    assert_eq!(prov, Provenance::Network);
    check_permutation(&order).unwrap();

    // way above the largest bucket → the PFM variants now run the native
    // in-Rust optimizer instead of the spectral fallback
    let big = laplacian_2d(40, 40); // n=1600 > 512
    let (order, prov) = Learned::Pfm.order(&mut rt, &big, 3).unwrap();
    assert_eq!(prov, Provenance::NativeOptimizer);
    check_permutation(&order).unwrap();

    // surrogate-objective methods keep the spectral fallback
    let (order, prov) = Learned::Udno.order(&mut rt, &big, 3).unwrap();
    assert_eq!(prov, Provenance::SpectralFallback);
    check_permutation(&order).unwrap();
}

#[test]
fn se_artifact_matches_rust_spectral_quality() {
    // The S_e artifact (power-iteration Fiedler in the network) and the
    // Rust Lanczos Fiedler ordering should land in the same fill-ratio
    // ballpark on a grid — they estimate the same vector.
    let Some(mut rt) = runtime() else { return };
    let a = laplacian_2d(8, 8);
    let (order_net, prov) = Learned::Se.order(&mut rt, &a, 5).unwrap();
    assert_eq!(prov, Provenance::Network);
    let fill_net = fill_ratio_of_order(&a, &order_net);
    let fill_rust = fill_ratio_of_order(&a, &Classical::Fiedler.order(&a));
    assert!(
        fill_net <= fill_rust * 1.5 + 0.5,
        "network spectral {fill_net} vs rust lanczos {fill_rust}"
    );
}

#[test]
fn pfm_scores_deterministic_per_seed() {
    let Some(mut rt) = runtime() else { return };
    let a = laplacian_2d(6, 6);
    let s1 = rt.scores("pfm", &a, 9).unwrap();
    let s2 = rt.scores("pfm", &a, 9).unwrap();
    assert_eq!(s1, s2);
}

#[test]
fn larger_bucket_also_works() {
    let Some(mut rt) = runtime() else { return };
    let a = ProblemClass::TwoDThreeD.generate(100, 3); // bucket 128
    let scores = rt.scores("pfm", &a, 11).unwrap();
    assert_eq!(scores.len(), a.nrows());
    let order = order_from_scores_f32(&scores);
    check_permutation(&order).unwrap();
}
