//! System-level integration: the coordinator service under load, failure
//! injection, and cross-layer consistency between the service path and the
//! direct API path.

use std::sync::Arc;

use pfm_reorder::coordinator::{Method, ReorderService, ServiceConfig};
use pfm_reorder::factor::{fill_ratio_of_order, DirectSolver};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::order::Classical;
use pfm_reorder::runtime::{Learned, PfmRuntime};
use pfm_reorder::util::check::check_permutation;
use pfm_reorder::util::rng::Pcg64;

fn service() -> Arc<ReorderService> {
    ReorderService::start(ServiceConfig {
        workers: 3,
        artifact_dir: "artifacts".into(),
        ..Default::default()
    })
}

#[test]
fn service_and_direct_api_agree_on_classical_orders() {
    let svc = service();
    let a = ProblemClass::Sp.generate(216, 5);
    for method in [Classical::Rcm, Classical::Amd, Classical::Metis] {
        let via_service = svc
            .reorder_blocking(a.clone(), Method::Classical(method), 1)
            .unwrap();
        let direct = method.order(&a);
        assert_eq!(via_service.order, direct, "{}", method.label());
    }
}

#[test]
fn service_survives_burst_larger_than_queue_window() {
    let svc = service();
    let mut rxs = Vec::new();
    // 60 mixed requests, more than max_batch and worker count
    for i in 0..60u64 {
        let class = ProblemClass::ALL[(i % 6) as usize];
        let a = class.generate(80 + (i % 5) as usize * 30, i);
        let m = if i % 2 == 0 {
            Method::Learned(Learned::Pfm)
        } else {
            Method::Classical(Classical::Amd)
        };
        rxs.push((a.nrows(), svc.submit(a, m, i)));
    }
    for (n, rx) in rxs {
        let resp = rx.recv().expect("service response");
        let res = resp.result.expect("ok result");
        assert_eq!(res.order.len(), n);
        check_permutation(&res.order).unwrap();
    }
    assert_eq!(svc.metrics.total_completed(), 60);
    assert_eq!(svc.metrics.errors(), 0);
}

#[test]
fn learned_method_without_artifacts_serves_native_or_fallback_not_fails() {
    // failure injection: empty artifact dir → PFM is served by the native
    // optimizer, surrogate methods by the spectral fallback — never an
    // error, and the provenance counters tell the two apart
    use pfm_reorder::runtime::Provenance;
    let dir = std::env::temp_dir().join(format!("pfm_noart_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let svc = ReorderService::start(ServiceConfig {
        workers: 1,
        artifact_dir: dir.to_string_lossy().to_string(),
        ..Default::default()
    });
    let a = ProblemClass::TwoDThreeD.generate(100, 1);
    let res = svc
        .reorder_blocking(a.clone(), Method::Learned(Learned::Pfm), 1)
        .expect("native result");
    check_permutation(&res.order).unwrap();
    assert_eq!(res.provenance, Some(Provenance::NativeOptimizer));
    assert_eq!(svc.metrics.native_optimized(), 1);
    assert_eq!(svc.metrics.fallbacks(), 0);

    let res = svc
        .reorder_blocking(a, Method::Learned(Learned::Se), 1)
        .expect("fallback result");
    check_permutation(&res.order).unwrap();
    assert_eq!(res.provenance, Some(Provenance::SpectralFallback));
    assert_eq!(svc.metrics.fallbacks(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_artifact_reports_error_gracefully() {
    // failure injection: garbage HLO file → the request errors, the
    // service keeps serving other requests
    let dir = std::env::temp_dir().join(format!("pfm_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("pfm_n64.hlo.txt"), "this is not hlo").unwrap();
    let svc = ReorderService::start(ServiceConfig {
        workers: 1,
        artifact_dir: dir.to_string_lossy().to_string(),
        ..Default::default()
    });
    let a = ProblemClass::TwoDThreeD.generate(49, 1);
    let res = svc.reorder_blocking(a, Method::Learned(Learned::Pfm), 1);
    assert!(res.is_err(), "corrupt artifact must surface as request error");
    // service still alive for classical work
    let b = ProblemClass::TwoDThreeD.generate(49, 2);
    let ok = svc
        .reorder_blocking(b, Method::Classical(Classical::Amd), 1)
        .unwrap();
    check_permutation(&ok.order).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shutdown_mid_burst_answers_every_inflight_request() {
    // regression for the PR 1 dispatcher-drop bug, now under the batched
    // native-PFM path: a burst larger than one drain window is in flight
    // when shutdown fires — every receiver must still get *a* response
    // (success for requests already past the dispatcher, an explicit
    // shutdown error for the rest), never a silent drop
    use pfm_reorder::pfm::OptBudget;
    use std::time::Duration;
    let dir = std::env::temp_dir().join(format!("pfm_shutmid_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let svc = ReorderService::start(ServiceConfig {
        workers: 1,
        artifact_dir: dir.to_string_lossy().to_string(),
        ..Default::default()
    });
    let a = ProblemClass::TwoDThreeD.generate(324, 3);
    let budget = OptBudget { outer: 1, refine: 4, level_refine: 2, ..OptBudget::default() };
    let mut rxs = Vec::new();
    for i in 0..16u64 {
        rxs.push(svc.submit_with_budget(
            a.clone(),
            Method::Learned(Learned::Pfm),
            i,
            false,
            None,
            Some(budget),
        ));
    }
    svc.shutdown();
    let mut served = 0usize;
    let mut refused = 0usize;
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(resp) => match resp.result {
                Ok(res) => {
                    check_permutation(&res.order).unwrap();
                    served += 1;
                }
                Err(e) => {
                    assert!(e.contains("shut"), "unexpected error: {e}");
                    refused += 1;
                }
            },
            Err(e) => panic!("an in-flight request was dropped without a response: {e}"),
        }
    }
    assert_eq!(served + refused, 16, "every request must be answered");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn full_pipeline_order_factor_solve_all_methods() {
    // the complete downstream workflow on a mid-size FEM-like system
    let a = ProblemClass::Cfd.generate(300, 9);
    let n = a.nrows();
    let mut rng = Pcg64::new(10);
    let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    let b = a.matvec(&xt);

    let mut rt = PfmRuntime::new("artifacts").unwrap();
    for method in Method::table2() {
        let order = match method {
            Method::Classical(c) => c.order(&a),
            Method::Learned(l) => l.order(&mut rt, &a, 1).unwrap().0,
        };
        let solver = DirectSolver::prepare(&a, order, 0.0)
            .unwrap_or_else(|e| panic!("{}: {e}", method.label()));
        let x = solver.solve(&b);
        let resid = DirectSolver::residual(&a, &x, &b);
        assert!(
            resid < 1e-8,
            "{}: residual {resid}",
            method.label()
        );
    }
}

#[test]
fn full_pipeline_unsymmetric_order_factor_solve() {
    // the same downstream workflow on the unsymmetric classes: the service
    // computes the ordering + LU fill, the direct API factors through the
    // Gilbert–Peierls engine and solves to machine accuracy
    let svc = service();
    let mut rng = Pcg64::new(12);
    for &class in &ProblemClass::UNSYMMETRIC {
        let a = class.generate(220, 4);
        let n = a.nrows();
        let res = svc
            .reorder_blocking_with_fill(a.clone(), Method::Classical(Classical::Amd), 1)
            .unwrap();
        check_permutation(&res.order).unwrap();
        assert_eq!(res.factor_kind, Some("lu"), "{class:?}");
        let lu_fill = res.fill_ratio.expect("fill requested");
        assert!(lu_fill >= 1.0, "{class:?}: nnz(L+U)/nnz(A) = {lu_fill}");

        let solver = DirectSolver::prepare(&a, res.order, 0.0)
            .unwrap_or_else(|e| panic!("{class:?}: {e}"));
        assert_eq!(solver.stats.factor_kind, "lu");
        assert!(
            (solver.stats.fill_ratio - lu_fill).abs() < 1e-12,
            "{class:?}: service fill {lu_fill} vs solver fill {}",
            solver.stats.fill_ratio
        );
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        let resid = DirectSolver::residual(&a, &x, &b);
        assert!(resid < 1e-9, "{class:?}: residual {resid}");
    }
}

#[test]
fn reordering_improves_over_shuffled_natural_everywhere() {
    // sanity across classes: AMD ordering never loses to a random shuffle
    let mut rng = Pcg64::new(77);
    for &class in &ProblemClass::ALL {
        let a = class.generate(200, 3);
        let n = a.nrows();
        let shuffled = fill_ratio_of_order(&a, &rng.permutation(n));
        let ordered = fill_ratio_of_order(&a, &Classical::Amd.order(&a));
        assert!(
            ordered <= shuffled + 1e-9,
            "{:?}: amd {ordered} vs shuffled {shuffled}",
            class
        );
    }
}
