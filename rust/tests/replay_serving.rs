//! Integration tests for the traffic-replay load driver
//! (`harness::replay`): deterministic trace generation over the public
//! API, an end-to-end in-process run against a persisting service (warm
//! bursts must land as warm-store hits on the second pass), and the
//! committed `BENCH_serving.json` document shape.

use std::time::Duration;

use pfm_reorder::coordinator::{ReorderService, ServiceConfig};
use pfm_reorder::harness::replay::{
    self, ReplaySpec, SloRule, TraceKind, BASE_INTERARRIVAL_S, BENCH_SCHEMA,
};
use pfm_reorder::persist::PersistConfig;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pfm_replay_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn public_trace_generation_is_deterministic_across_calls() {
    for kind in [TraceKind::Mixed, TraceKind::Warm, TraceKind::ColdStorm] {
        let spec = ReplaySpec { kind, speed: 50.0, requests: 40, seed: 1234 };
        let a = replay::generate(&spec);
        let b = replay::generate(&spec);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix, y.matrix, "{kind:?} trace must be reproducible");
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.method.label(), y.method.label());
        }
        // open-loop schedule at the compressed inter-arrival gap
        let gap = a[1].at_s - a[0].at_s;
        assert!((gap - BASE_INTERARRIVAL_S / 50.0).abs() < 1e-12, "{kind:?} gap {gap}");
        // a different seed reorders/remints the work
        let other = replay::generate(&ReplaySpec { seed: 99, ..spec });
        assert!(
            kind == TraceKind::Warm || a.iter().zip(&other).any(|(x, y)| x.matrix != y.matrix),
            "{kind:?}: seed must matter"
        );
    }
}

#[test]
fn slo_rules_round_trip_through_the_public_parser() {
    let r = SloRule::parse("warm_hit:p99=250ms").unwrap();
    assert_eq!(r.class.as_deref(), Some("warm_hit"));
    assert_eq!(r.stat, "p99");
    assert!((r.limit_s - 0.25).abs() < 1e-12);
    assert!(SloRule::parse("p42=1s").is_err());
    assert!(SloRule::parse("bogus_class:p99=1s").is_err());
}

/// End-to-end in-process replay: run a warm-burst trace twice against
/// one persisting service. The first pass populates the warm-start
/// store (cold native serves); the second pass must be served from it
/// (warm_hit class), and the benchmark document must carry the schema
/// and per-class quantiles.
#[test]
fn inproc_replay_reports_warm_hits_and_writes_the_bench_document() {
    let dir = temp_dir("inproc");
    let service = ReorderService::start(ServiceConfig {
        workers: 2,
        artifact_dir: "nonexistent-dir-ok-replay".into(),
        persist: Some(PersistConfig::new(dir.join("store"))),
        slow_threshold: Duration::from_millis(100),
        ..Default::default()
    });

    let spec = ReplaySpec { kind: TraceKind::Warm, speed: 20.0, requests: 24, seed: 7 };
    let first = replay::run_inproc(&service, &spec);
    assert_eq!(first.errors, 0, "first pass must not error");
    assert!(first.completed() + first.busy == 24);

    // every warm-pool pattern is now persisted; the rerun hits the store
    let second = replay::run_inproc(&service, &spec);
    assert_eq!(second.errors, 0);
    let warm = second
        .summary("warm_hit")
        .expect("second pass over identical patterns must contain warm-store hits");
    assert!(warm.count > 0);
    assert!(warm.p50_s <= warm.p99_s && warm.p99_s <= warm.p999_s && warm.p999_s <= warm.max_s);
    assert!(second.throughput_rps() > 0.0);

    // SLO evaluation + committed document shape
    let rules = vec![
        SloRule::parse("p99=30s").unwrap(),
        SloRule::parse("warm_hit:p50=30s").unwrap(),
    ];
    let outcomes = second.evaluate(&rules);
    assert!(outcomes.iter().all(|o| o.pass), "{outcomes:?}");
    second.check(&outcomes, false).unwrap();

    let bench = dir.join("BENCH_serving.json");
    replay::write_bench(bench.to_str().unwrap(), &second.to_json(&outcomes)).unwrap();
    let doc = std::fs::read_to_string(&bench).unwrap();
    assert!(doc.contains(&format!("\"schema\":\"{BENCH_SCHEMA}\"")), "{doc}");
    assert!(doc.contains("\"warm_hit\""), "{doc}");
    assert!(doc.contains("\"p999_s\""), "{doc}");
    assert!(doc.contains("\"slo\""), "{doc}");
    assert!(doc.ends_with('\n'));

    // the service's own observability saw the run: bounded histograms
    // recorded every completion and the trace ring holds recent traces
    let (_, h) = service
        .metrics
        .latency_histograms()
        .into_iter()
        .find(|(_, h)| h.count() > 0)
        .expect("replay must have recorded latencies");
    assert!(h.count() > 0);
    assert!(!service.metrics.recent_traces().is_empty());

    service.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
