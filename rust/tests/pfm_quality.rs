//! The determinism-and-quality test layer locking in the parallel V-cycle
//! PFM optimizer:
//!
//! * V-cycle per-level refinement never loses to the PR 4 coarsest-only
//!   multilevel path (exact nnz(L), per matrix, across the symmetric
//!   suite) — the fine-refinement budget is zeroed in both runs so the
//!   comparison isolates the V-cycle itself, and the V-cycle evaluates
//!   the coarsest-only candidate first, so ≤ holds by construction.
//! * Adaptive-ρ ADMM keeps the non-increasing trace and never ends above
//!   the fixed-ρ schedule on a badly scaled window.
//! * A single oversized probe batch cannot overshoot `OptBudget::time_ms`
//!   by more than ~2× one probe's cost (the probe-level deadline check).
//!
//! The `#[ignore]` variants widen the sweeps for the nightly
//! (`workflow_dispatch`) CI job: `cargo test -q -- --include-ignored`.

use std::time::Instant;

use pfm_reorder::factor::analyze;
use pfm_reorder::gen::grid::{laplacian_2d, scaled_node_laplacian_2d};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::pfm::{OptBudget, OrderObjective, PfmOptimizer};
use pfm_reorder::sparse::Csr;
use pfm_reorder::util::check::check_permutation;

/// Zero-fine-refinement budgets isolating the multilevel stage: the two
/// runs share every RNG draw up to (and including) the coarse ADMM, so
/// the V-cycle run's result is the coarsest-only run's result with extra
/// strictly-accepted candidates.
fn coarsest_only_budget() -> OptBudget {
    OptBudget { outer: 2, refine: 0, level_refine: 0, adaptive_rho: false, time_ms: None }
}

fn vcycle_budget() -> OptBudget {
    OptBudget { level_refine: 10, ..coarsest_only_budget() }
}

fn assert_vcycle_never_worse(a: &Csr, seed: u64, label: &str) -> (f64, f64) {
    let coarse = PfmOptimizer::new(coarsest_only_budget(), seed).optimize(a);
    let vcycle = PfmOptimizer::new(vcycle_budget(), seed).optimize(a);
    check_permutation(&coarse.order).unwrap();
    check_permutation(&vcycle.order).unwrap();
    // exact nnz(L): the reported objective is re-verified symbolically
    let coarse_lnnz = analyze(&a.permute_sym(&coarse.order)).lnnz as f64;
    let vcycle_lnnz = analyze(&a.permute_sym(&vcycle.order)).lnnz as f64;
    assert_eq!(coarse.objective, coarse_lnnz, "{label}: coarsest-only objective drifted");
    assert_eq!(vcycle.objective, vcycle_lnnz, "{label}: V-cycle objective drifted");
    assert!(
        vcycle.objective <= coarse.objective,
        "{label}: V-cycle nnz(L) {} above coarsest-only {}",
        vcycle.objective,
        coarse.objective
    );
    assert_eq!(coarse.levels_refined, 0);
    assert!(vcycle.levels_refined >= 1, "{label}: V-cycle refined no levels");
    (vcycle.objective, coarse.objective)
}

#[test]
fn vcycle_never_worse_than_coarsest_only_on_symmetric_suite() {
    let mut v_sum = 0.0;
    let mut c_sum = 0.0;
    for (i, class) in ProblemClass::ALL.iter().enumerate() {
        // n = 400: the first heavy-edge contraction can at best halve the
        // graph, so the coarsest level needs ≥ 2 contractions — the
        // V-cycle is guaranteed an intermediate level to refine
        let a = class.generate(400, 0x7AB2E2 + i as u64);
        assert!(a.nrows() > 2 * 160, "{class:?} must exercise the V-cycle path");
        let (v, c) = assert_vcycle_never_worse(&a, 0x7AB2E2, &format!("{class:?}"));
        v_sum += v;
        c_sum += c;
    }
    // per-matrix ≤ implies the suite mean can only improve (the PR's
    // acceptance criterion against the PR 4 coarsest-only path)
    assert!(v_sum <= c_sum, "suite mean regressed: {v_sum} vs {c_sum}");
}

#[test]
#[ignore = "nightly quality sweep: larger sizes and more seeds"]
fn vcycle_never_worse_full_sweep() {
    for &n in &[400usize, 576] {
        for (i, class) in ProblemClass::ALL.iter().enumerate() {
            for seed in [1u64, 9, 0x7AB2E2] {
                let a = class.generate(n, seed ^ ((i as u64) << 4));
                assert_vcycle_never_worse(&a, seed, &format!("{class:?} n={n} seed={seed}"));
            }
        }
    }
}

#[test]
fn adaptive_rho_not_worse_than_fixed_on_badly_scaled_window() {
    // one huge node (D·A·D, d = 1e6): the max-normalized ADMM window
    // becomes ~rank-1, which crushes the fixed-ρ gradient signal — the
    // badly scaled regime the residual balancing targets (same generator
    // as the admm-level firing test)
    let a = scaled_node_laplacian_2d(10, 10, 37, 1e6);
    for seed in [1u64, 2, 5] {
        let fixed =
            OptBudget { outer: 10, refine: 0, level_refine: 0, adaptive_rho: false, time_ms: None };
        let adaptive = OptBudget { adaptive_rho: true, ..fixed };
        let rf = PfmOptimizer::new(fixed, seed).optimize(&a);
        let ra = PfmOptimizer::new(adaptive, seed).optimize(&a);
        for w in ra.trace.windows(2) {
            assert!(w[1] <= w[0], "seed {seed}: adaptive trace increased: {:?}", ra.trace);
        }
        // strict acceptance caps both at the init; on this window the
        // adaptive schedule never loses (mirror-validated across seeds)
        assert!(ra.objective <= ra.init_objective);
        assert!(
            ra.objective <= rf.objective,
            "seed {seed}: adaptive {} worse than fixed {}",
            ra.objective,
            rf.objective
        );
    }
}

#[test]
#[ignore = "wall-clock sensitive: CI runs it explicitly in the release --test-threads=1 step"]
fn probe_deadline_bounds_overshoot_to_two_probe_costs() {
    // the satellite fix: `time_ms` used to be checked only between outer
    // iterations / steps, so one oversized parallel probe batch could
    // overshoot by a whole batch. The pool's per-probe deadline check
    // bounds the overshoot by ~one in-flight probe per worker; this pins
    // it at < 2× one probe's cost (plus scheduler slack for CI).
    let a = laplacian_2d(48, 48); // n = 2304: one probe is genuinely costly
    let mut obj = OrderObjective::new(&a);
    let probe_order = pfm_reorder::order::fiedler_order_with(&a, 60, 1);
    let t = Instant::now();
    obj.eval(&probe_order);
    let probe_cost = t.elapsed().as_secs_f64();

    // baseline: the budget-independent prologue (spectral init + the two
    // free candidate evaluations), measured with zero iteration budget
    let none =
        OptBudget { outer: 0, refine: 0, level_refine: 0, adaptive_rho: false, time_ms: None };
    let t = Instant::now();
    PfmOptimizer::new(none, 1).optimize(&a);
    let prologue = t.elapsed().as_secs_f64();

    let budget_ms = 40u64;
    let capped = OptBudget { refine: 100_000, time_ms: Some(budget_ms), ..none };
    let t = Instant::now();
    let rep = PfmOptimizer::new(capped, 1).with_threads(2).optimize(&a);
    let elapsed = t.elapsed().as_secs_f64();
    check_permutation(&rep.order).unwrap();

    let overshoot = elapsed - prologue - budget_ms as f64 / 1e3;
    assert!(
        overshoot < 2.0 * probe_cost + 0.25,
        "deadline overshoot {overshoot:.3}s exceeds 2 probes ({:.3}s) + slack",
        2.0 * probe_cost
    );
}

#[test]
fn parallel_determinism_grid_all_thread_counts() {
    // CI runs this with --test-threads=1 so the timing (and any future
    // timing-sensitive assertion) is honest; the pure determinism check
    // itself is timing-free because no wall-clock budget is set
    let a = laplacian_2d(24, 24); // n = 576: V-cycle + fine refinement
    let budget =
        OptBudget { outer: 1, refine: 12, level_refine: 4, adaptive_rho: true, time_ms: None };
    let base = PfmOptimizer::new(budget, 42).with_threads(1).optimize(&a);
    check_permutation(&base.order).unwrap();
    for threads in [2usize, 4, 8] {
        let rep = PfmOptimizer::new(budget, 42).with_threads(threads).optimize(&a);
        assert_eq!(rep.order, base.order, "threads={threads}");
        assert_eq!(rep.objective, base.objective, "threads={threads}");
        assert_eq!(rep.trace, base.trace, "threads={threads}");
        assert_eq!(rep.evals, base.evals, "threads={threads}");
    }
}

#[test]
#[ignore = "nightly determinism sweep: every symmetric class, both paths"]
fn parallel_determinism_full_sweep() {
    for (i, class) in ProblemClass::ALL.iter().enumerate() {
        for &n in &[140usize, 400] {
            let a = class.generate(n, 7 + i as u64);
            let budget = OptBudget {
                outer: 2,
                refine: 18,
                level_refine: 6,
                adaptive_rho: i % 2 == 0,
                time_ms: None,
            };
            let base = PfmOptimizer::new(budget, 13).with_threads(1).optimize(&a);
            for threads in [2usize, 4, 8] {
                let rep = PfmOptimizer::new(budget, 13).with_threads(threads).optimize(&a);
                assert_eq!(rep.order, base.order, "{class:?} n={n} threads={threads}");
                assert_eq!(rep.trace, base.trace, "{class:?} n={n} threads={threads}");
            }
        }
    }
}
