//! Crash-recovery integration suite for the warm-start persistence
//! layer, driven entirely through the public `pfm_reorder::persist` API:
//! populate → die mid-append → reopen → bit-identical warm hit, torn-tail
//! truncation, and proptests asserting that random corruption of WAL
//! segments and snapshots never panics and never yields an invalid
//! recovered record.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use pfm_reorder::factor::FactorKind;
use pfm_reorder::gen::grid::laplacian_2d;
use pfm_reorder::persist::{
    crc32, pattern_key, snapshot, wal, FsyncPolicy, OrderingStore, PersistConfig, PersistFault,
    StoredOrdering,
};
use pfm_reorder::sparse::Csr;
use pfm_reorder::util::check::{check_permutation, forall};
use pfm_reorder::util::rng::Pcg64;

/// Unique scratch directory per test (and per proptest iteration).
fn scratch(tag: &str) -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "pfm_recovery_{}_{}_{}",
        tag,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Test config: no fsync (tmpfs speed), manual snapshots only.
fn cfg(dir: &Path) -> PersistConfig {
    PersistConfig {
        fsync: FsyncPolicy::Never,
        snapshot_every: 0,
        ..PersistConfig::new(dir)
    }
}

/// A stored ordering whose permutation is a deterministic function of
/// `seed` — lets the tests assert bit-identity after recovery.
fn ordering(a: &Csr, seed: u64) -> StoredOrdering {
    let order = Pcg64::new(seed).permutation(a.nrows());
    StoredOrdering::new("pfm", a, order, Some(FactorKind::Cholesky), Some(1.5 + seed as f64))
}

/// encode ∘ decode is the identity on full records (integration-level
/// counterpart of the unit round-trip in `persist::record`).
#[test]
fn record_roundtrip_and_key_are_stable() {
    let a = laplacian_2d(9, 7);
    let rec = ordering(&a, 42);
    let back = StoredOrdering::decode(&rec.encode()).expect("round-trip");
    assert_eq!(back, rec);
    assert_eq!(back.key, pattern_key("pfm", a.nrows(), a.indptr(), a.indices()));
    // CRC-32 reference vector pins the checksum algorithm across refactors.
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
}

/// The headline contract: populate, die mid-append (a torn half-frame at
/// the segment tail — what a kill -9 during `write` leaves behind),
/// reopen, and get every completed record back bit-identically. The torn
/// tail is truncated once; a third open sees a clean log.
#[test]
fn populate_die_mid_append_reopen_bit_identical() {
    let dir = scratch("midappend");
    let mats: Vec<Csr> = (0..4).map(|k| laplacian_2d(6 + k, 5)).collect();
    {
        let (mut store, stats) = OrderingStore::open(cfg(&dir));
        assert_eq!(stats.replayed, 0);
        for (k, a) in mats.iter().enumerate() {
            let out = store.insert(ordering(a, k as u64));
            assert!(out.appended, "append {k} failed: {:?}", out.errors);
        }
    }
    // simulate the kill: a partial frame (header + some payload bytes,
    // shorter than the length the header promises) at the newest segment
    let (_, seg) = wal::list_segments(&dir).unwrap().pop().expect("a segment exists");
    let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
    f.write_all(&200u32.to_le_bytes()).unwrap();
    f.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
    f.write_all(&[0xAB; 37]).unwrap();
    drop(f);
    let torn_len = std::fs::metadata(&seg).unwrap().len();

    let (store, stats) = OrderingStore::open(cfg(&dir));
    assert_eq!(stats.torn_tails, 1);
    assert_eq!(stats.quarantined, 0);
    assert_eq!(stats.replayed, mats.len());
    for (k, a) in mats.iter().enumerate() {
        let hit = store.lookup("pfm", a).expect("warm hit after recovery");
        assert_eq!(hit.order, Pcg64::new(k as u64).permutation(a.nrows()), "bit-identical");
        assert_eq!(hit.fill_ratio, Some(1.5 + k as f64));
        assert_eq!(hit.factor_kind, Some(FactorKind::Cholesky));
    }
    assert!(
        std::fs::metadata(&seg).unwrap().len() < torn_len,
        "truncation must be persisted to disk"
    );
    drop(store);

    let (store, stats) = OrderingStore::open(cfg(&dir));
    assert_eq!(stats.torn_tails, 0, "second recovery must see a clean log");
    assert_eq!(stats.replayed, mats.len());
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected short write (the fault hook's `torn` mode) leaves exactly
/// the on-disk state a mid-write crash would: the record that failed is
/// absent, every earlier record recovers.
#[test]
fn injected_torn_write_recovers_the_completed_prefix() {
    let dir = scratch("torninject");
    let a0 = laplacian_2d(8, 8);
    let a1 = laplacian_2d(9, 9);
    {
        let mut config = cfg(&dir);
        config.fault = Some(PersistFault { period: 2, torn: true });
        let (mut store, _) = OrderingStore::open(config);
        assert!(store.insert(ordering(&a0, 1)).appended);
        let out = store.insert(ordering(&a1, 2)); // fault fires: torn write
        assert!(!out.appended);
        assert!(!out.errors.is_empty());
        // degraded but alive: both records still served from memory
        assert!(store.lookup("pfm", &a0).is_some());
        assert!(store.lookup("pfm", &a1).is_some());
        assert!(!store.is_persistent(), "WAL must be dropped after an append fault");
    }
    let (store, stats) = OrderingStore::open(cfg(&dir));
    assert_eq!(stats.torn_tails, 1, "the short write is a torn tail");
    assert_eq!(stats.replayed, 1);
    assert!(store.lookup("pfm", &a0).is_some());
    assert!(store.lookup("pfm", &a1).is_none(), "the torn record must not resurrect");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Proptest: flip random bytes anywhere in the WAL segments — recovery
/// must never panic, and every record it does accept must still be a
/// valid permutation of a valid pattern.
#[test]
fn prop_corrupt_wal_never_panics_or_accepts_garbage() {
    forall(40, |rng| {
        let dir = scratch("propwal");
        let mats: Vec<Csr> = (0..3).map(|k| laplacian_2d(5 + k, 4 + k)).collect();
        {
            let (mut store, _) = OrderingStore::open(cfg(&dir));
            for (k, a) in mats.iter().enumerate() {
                store.insert(ordering(a, 10 + k as u64));
            }
        }
        let segments = wal::list_segments(&dir).map_err(|e| e.to_string())?;
        if segments.is_empty() {
            return Err("expected at least one segment".into());
        }
        for _ in 0..1 + rng.next_below(6) {
            let (_, seg) = &segments[rng.next_below(segments.len())];
            let mut bytes = std::fs::read(seg).map_err(|e| e.to_string())?;
            if bytes.is_empty() {
                continue;
            }
            let at = rng.next_below(bytes.len());
            bytes[at] ^= 1 << rng.next_below(8);
            std::fs::write(seg, &bytes).map_err(|e| e.to_string())?;
        }
        let (store, stats) = OrderingStore::open(cfg(&dir));
        if stats.replayed > mats.len() {
            return Err(format!("replayed {} > {} inserted", stats.replayed, mats.len()));
        }
        for a in &mats {
            if let Some(hit) = store.lookup("pfm", a) {
                check_permutation(&hit.order)?;
                if !hit.matches("pfm", a) {
                    return Err("recovered record does not match its pattern".into());
                }
            }
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Proptest: truncate or bit-flip the snapshot — startup must never
/// panic; a damaged snapshot is quarantined (renamed, not deleted) and
/// the store still opens.
#[test]
fn prop_corrupt_snapshot_never_panics_and_is_quarantined() {
    forall(40, |rng| {
        let dir = scratch("propsnap");
        let a = laplacian_2d(7, 6);
        {
            let (mut store, _) = OrderingStore::open(cfg(&dir));
            store.insert(ordering(&a, 3));
            store.snapshot().map_err(|e| format!("snapshot: {e}"))?;
        }
        let snap = snapshot::snapshot_path(&dir);
        let mut bytes = std::fs::read(&snap).map_err(|e| e.to_string())?;
        if rng.next_below(2) == 0 {
            // truncate to a strict prefix
            bytes.truncate(rng.next_below(bytes.len().max(1)));
        } else {
            let at = rng.next_below(bytes.len());
            bytes[at] ^= 1 << rng.next_below(8);
        }
        std::fs::write(&snap, &bytes).map_err(|e| e.to_string())?;
        let (store, stats) = OrderingStore::open(cfg(&dir));
        if stats.quarantined > 0 {
            // quarantine renames — the evidence must still be on disk
            let kept = std::fs::read_dir(&dir)
                .map_err(|e| e.to_string())?
                .filter_map(|e| e.ok())
                .any(|e| e.file_name().to_string_lossy().ends_with(".quarantined"));
            if !kept {
                return Err("quarantined snapshot was not kept on disk".into());
            }
        }
        // whatever survived must be valid
        if let Some(hit) = store.lookup("pfm", &a) {
            check_permutation(&hit.order)?;
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Snapshot compaction is atomic and supersedes the log: after
/// `snapshot()`, a reopen replays everything from the snapshot alone.
#[test]
fn snapshot_then_reopen_replays_everything() {
    let dir = scratch("compact");
    let mats: Vec<Csr> = (0..5).map(|k| laplacian_2d(4 + k, 6)).collect();
    {
        let (mut store, _) = OrderingStore::open(cfg(&dir));
        for (k, a) in mats.iter().enumerate() {
            store.insert(ordering(a, 20 + k as u64));
        }
        assert_eq!(store.snapshot().unwrap(), mats.len());
    }
    let (store, stats) = OrderingStore::open(cfg(&dir));
    assert_eq!(stats.replayed, mats.len());
    assert_eq!(stats.torn_tails + stats.quarantined + stats.rejected, 0);
    for (k, a) in mats.iter().enumerate() {
        let hit = store.lookup("pfm", a).expect("hit from snapshot");
        assert_eq!(hit.order, Pcg64::new(20 + k as u64).permutation(a.nrows()));
    }
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
