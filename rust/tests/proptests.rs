//! Property-based invariants over the whole substrate, on the in-repo
//! mini-proptest harness (`util::check::forall`). Each property runs over
//! dozens of deterministic random instances; failures report the seed.

use pfm_reorder::factor::lu::{self, LuOptions};
use pfm_reorder::factor::{
    analyze, cholesky_with, factor_flops, factorize_into_parallel, fill_ratio_of_order,
    fundamental_supernodes, supernodal, FactorWorkspace, Schedule,
};
use pfm_reorder::gen::{ProblemClass, Symmetry};
use pfm_reorder::graph::Graph;
use pfm_reorder::order::{amd, nested_dissection_with, order_from_scores, rcm, Classical};
use pfm_reorder::sparse::{Coo, Csr, Dense};
use pfm_reorder::util::check::{check_permutation, forall};
use pfm_reorder::util::rng::Pcg64;

/// Random sparse SPD matrix (diagonally dominant).
fn random_spd(rng: &mut Pcg64) -> Csr {
    let n = 10 + rng.next_below(60);
    let mut coo = Coo::square(n);
    let mut diag = vec![1.0; n];
    let edges = n + rng.next_below(3 * n);
    for _ in 0..edges {
        let i = rng.next_below(n);
        let j = rng.next_below(n);
        if i == j {
            continue;
        }
        let w = 0.1 + rng.next_f64();
        coo.push_sym(i, j, -w);
        diag[i] += w;
        diag[j] += w;
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, *d + 0.25);
    }
    coo.to_csr()
}

#[test]
fn prop_permute_sym_preserves_symmetry_and_values() {
    forall(40, |rng| {
        let a = random_spd(rng);
        let order = rng.permutation(a.nrows());
        let b = a.permute_sym(&order);
        if !b.is_symmetric(1e-12) {
            return Err("PAPᵀ not symmetric".into());
        }
        if b.nnz() != a.nnz() {
            return Err(format!("nnz changed: {} -> {}", a.nnz(), b.nnz()));
        }
        // spot-check entries: B[i][j] == A[order[i]][order[j]]
        for _ in 0..10 {
            let i = rng.next_below(a.nrows());
            let j = rng.next_below(a.nrows());
            if (b.get(i, j) - a.get(order[i], order[j])).abs() > 1e-14 {
                return Err(format!("entry mismatch at ({i},{j})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_permutation_roundtrip_identity() {
    forall(25, |rng| {
        let a = random_spd(rng);
        let order = rng.permutation(a.nrows());
        let mut inv = vec![0usize; order.len()];
        for (k, &o) in order.iter().enumerate() {
            inv[o] = k;
        }
        let b = a.permute_sym(&order).permute_sym(&inv);
        if b != a {
            return Err("permute(order) then permute(inv) != id".into());
        }
        Ok(())
    });
}

#[test]
fn prop_symbolic_matches_dense_oracle() {
    forall(30, |rng| {
        let a = random_spd(rng);
        let sym = analyze(&a);
        let dense = Dense::from_rows(&a.to_dense())
            .cholesky()
            .map_err(|e| format!("dense chol: {e}"))?;
        let oracle = dense.tril_nnz(1e-11);
        if sym.lnnz != oracle {
            return Err(format!("symbolic lnnz {} vs dense {}", sym.lnnz, oracle));
        }
        Ok(())
    });
}

#[test]
fn prop_numeric_factor_structural_nnz_equals_symbolic() {
    forall(30, |rng| {
        let a = random_spd(rng);
        let sym = analyze(&a);
        let f = cholesky_with(&a, &sym).map_err(|e| e.to_string())?;
        if f.lnnz() != sym.lnnz {
            return Err(format!("numeric {} vs symbolic {}", f.lnnz(), sym.lnnz));
        }
        Ok(())
    });
}

#[test]
fn prop_solve_residual_small() {
    forall(25, |rng| {
        let a = random_spd(rng);
        let n = a.nrows();
        let f = pfm_reorder::factor::cholesky(&a).map_err(|e| e.to_string())?;
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = f.solve(&b);
        let err: f64 = x
            .iter()
            .zip(&xt)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        if err > 1e-6 {
            return Err(format!("solve error {err}"));
        }
        Ok(())
    });
}

/// The supernodal and up-looking kernels must agree entrywise to 1e-12 —
/// identical structure, near-identical values (same elimination order, the
/// blocked kernel only re-associates the sums).
fn assert_kernels_agree(a: &pfm_reorder::sparse::Csr) -> Result<(), String> {
    let sym = analyze(a);
    let up = cholesky_with(a, &sym).map_err(|e| e.to_string())?;
    let sn = supernodal::cholesky(a).map_err(|e| e.to_string())?.to_chol();
    if up.lnnz() != sn.lnnz() {
        return Err(format!("lnnz {} vs {}", up.lnnz(), sn.lnnz()));
    }
    for i in 0..a.nrows() {
        let (uc, uv) = up.row(i);
        let (sc, sv) = sn.row(i);
        if uc != sc {
            return Err(format!("row {i} pattern mismatch"));
        }
        for (k, (&x, &y)) in uv.iter().zip(sv).enumerate() {
            if (x - y).abs() > 1e-12 * 1.0_f64.max(x.abs()) {
                return Err(format!("row {i} col {} value {x} vs {y}", uc[k]));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_supernodal_matches_uplooking_on_random_spd() {
    forall(30, |rng| {
        let a = random_spd(rng);
        assert_kernels_agree(&a)
    });
}

#[test]
fn prop_supernodal_matches_uplooking_on_problem_classes() {
    forall(12, |rng| {
        let class = ProblemClass::ALL[rng.next_below(6)];
        let n = 60 + rng.next_below(140);
        let a = class.generate(n, rng.next_u64());
        // exercise both natural and AMD orderings of every class
        assert_kernels_agree(&a)?;
        assert_kernels_agree(&a.permute_sym(&amd(&a)))
    });
}

/// Every problem class (symmetric and unsymmetric) is diagonally dominant,
/// so threshold pivoting keeps the diagonal and the sparse LU must
/// reproduce the dense no-pivot reference entrywise to 1e-10 — under both
/// the natural and the AMD ordering. Symmetric classes must additionally
/// agree with Cholesky's fill count (nnz(L+U) = 2·lnnz − n).
fn assert_lu_matches_dense(a: &Csr, class: ProblemClass) -> Result<(), String> {
    let lsym = lu::analyze_lu(a);
    let f = lu::factorize(a, &lsym, LuOptions::default(), &mut FactorWorkspace::new())
        .map_err(|e| format!("{class:?}: {e}"))?;
    if !f.no_pivoting() {
        return Err(format!("{class:?}: pivoting fired on a dominant matrix"));
    }
    let (dl, du) = Dense::from_rows(&a.to_dense())
        .lu_nopivot()
        .map_err(|e| format!("{class:?}: dense LU: {e}"))?;
    let n = a.nrows();
    let scale = a.data().iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for j in 0..n {
        if (f.udiag()[j] - du.get(j, j)).abs() > 1e-10 * scale {
            return Err(format!(
                "{class:?}: U[{j}][{j}] {} vs dense {}",
                f.udiag()[j],
                du.get(j, j)
            ));
        }
        let (rows, vals) = f.l_col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            if (v - dl.get(i, j)).abs() > 1e-10 * scale.max(v.abs()) {
                return Err(format!("{class:?}: L[{i}][{j}] {v} vs {}", dl.get(i, j)));
            }
        }
        let (rows, vals) = f.u_col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            if (v - du.get(i, j)).abs() > 1e-10 * scale.max(v.abs()) {
                return Err(format!("{class:?}: U[{i}][{j}] {v} vs {}", du.get(i, j)));
            }
        }
    }
    if class.symmetry() == Symmetry::Symmetric {
        let sym = analyze(a);
        if f.lu_nnz() != 2 * sym.lnnz - n {
            return Err(format!(
                "{class:?}: LU nnz {} disagrees with Cholesky fill {}",
                f.lu_nnz(),
                2 * sym.lnnz - n
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_lu_matches_dense_reference_on_all_classes() {
    let classes: Vec<ProblemClass> = ProblemClass::ALL
        .iter()
        .chain(&ProblemClass::UNSYMMETRIC)
        .copied()
        .collect();
    forall(16, |rng| {
        let class = classes[rng.next_below(classes.len())];
        let n = 40 + rng.next_below(60);
        let a = class.generate(n, rng.next_u64());
        assert_lu_matches_dense(&a, class)?;
        assert_lu_matches_dense(&a.permute_sym(&amd(&a)), class)
    });
}

#[test]
fn prop_lu_solves_and_orderings_reduce_fill_on_unsymmetric_classes() {
    forall(10, |rng| {
        let class = ProblemClass::UNSYMMETRIC[rng.next_below(2)];
        let n = 80 + rng.next_below(140);
        let a = class.generate(n, rng.next_u64());
        let n = a.nrows();
        let f = lu::lu(&a).map_err(|e| e.to_string())?;
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = f.solve(&b);
        let err: f64 = x
            .iter()
            .zip(&xt)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        if err > 1e-6 {
            return Err(format!("{class:?}: LU solve error {err}"));
        }
        // AMD must not lose to Natural by more than noise on LU fill
        let nat = lu::lu_fill_ratio_of_order(&a, &(0..n).collect::<Vec<_>>())
            .map_err(|e| e.to_string())?;
        let amd_fill = lu::lu_fill_ratio_of_order(&a, &amd(&a)).map_err(|e| e.to_string())?;
        if amd_fill > nat * 1.3 + 0.5 {
            return Err(format!("{class:?}: amd LU fill {amd_fill} ≫ natural {nat}"));
        }
        Ok(())
    });
}

/// Random *structurally* unsymmetric matrix — transpose/symmetrize
/// properties are only meaningful when Aᵀ ≠ A.
fn random_unsym_pattern(rng: &mut Pcg64) -> Csr {
    let n = 10 + rng.next_below(50);
    let mut coo = Coo::square(n);
    for i in 0..n {
        coo.push(i, i, 2.0 + rng.next_f64());
    }
    for _ in 0..(3 * n) {
        let i = rng.next_below(n);
        let j = rng.next_below(n);
        if i != j {
            coo.push(i, j, rng.next_gaussian());
        }
    }
    coo.to_csr()
}

#[test]
fn prop_transpose_roundtrips_and_commutes_with_permutation() {
    forall(25, |rng| {
        let a = random_unsym_pattern(rng);
        let n = a.nrows();
        if a.transpose().transpose() != a {
            return Err("transpose not an involution".into());
        }
        let p = rng.permutation(n);
        // P·Aᵀ·Pᵀ == (P·A·Pᵀ)ᵀ
        if a.transpose().permute_sym(&p) != a.permute_sym(&p).transpose() {
            return Err("transpose does not commute with permute_sym".into());
        }
        // is_symmetric agrees with the literal definition A == Aᵀ
        let sym_lit = a.transpose() == a;
        if a.is_symmetric(1e-12) != sym_lit {
            return Err("is_symmetric disagrees with A == Aᵀ".into());
        }
        Ok(())
    });
}

#[test]
fn prop_symmetrize_and_is_symmetric_under_permutation() {
    forall(20, |rng| {
        // pattern-symmetric but value-unsymmetric matrix
        let a = ProblemClass::Circuit.generate(60 + rng.next_below(80), rng.next_u64());
        let n = a.nrows();
        if a.is_symmetric(1e-12) {
            return Err("circuit class must be value-unsymmetric".into());
        }
        let s = a.symmetrize();
        if !s.is_symmetric(1e-12) {
            return Err("symmetrize(a) not symmetric".into());
        }
        // idempotent on symmetric inputs and permutation-equivariant
        if s.symmetrize() != s {
            return Err("symmetrize not idempotent".into());
        }
        let p = rng.permutation(n);
        if a.permute_sym(&p).symmetrize() != s.permute_sym(&p) {
            return Err("symmetrize does not commute with permute_sym".into());
        }
        // permutation preserves (a)symmetry
        if a.permute_sym(&p).is_symmetric(1e-12) {
            return Err("permutation must preserve value-asymmetry".into());
        }
        if !s.permute_sym(&p).is_symmetric(1e-12) {
            return Err("permutation must preserve symmetry".into());
        }
        Ok(())
    });
}

#[test]
fn prop_factor_flops_ordering_monotone_on_arrow() {
    // the exact flop count must rank arrow orderings correctly: hub-last
    // (zero fill) < any mixed placement < hub-first (dense)
    forall(20, |rng| {
        let n = 10 + rng.next_below(30);
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, n as f64);
        }
        let a = coo.to_csr();
        let natural = factor_flops(&analyze(&a));
        let rev: Vec<usize> = (0..n).rev().collect();
        let reversed = factor_flops(&analyze(&a.permute_sym(&rev)));
        // random placement of the hub somewhere in the middle
        let mid = rng.permutation(n);
        let middle = factor_flops(&analyze(&a.permute_sym(&mid)));
        let hub_pos = mid.iter().position(|&o| o == n - 1).unwrap();
        if natural >= reversed {
            return Err(format!("natural {natural} !< reversed {reversed}"));
        }
        if middle < natural || middle > reversed {
            return Err(format!(
                "middle placement (hub at {hub_pos}) flops {middle} outside [{natural}, {reversed}]"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_all_orderings_are_permutations_on_all_classes() {
    forall(18, |rng| {
        let class = ProblemClass::ALL[rng.next_below(6)];
        let n = 60 + rng.next_below(120);
        let a = class.generate(n, rng.next_u64());
        for m in Classical::ALL {
            let order = m.order(&a);
            check_permutation(&order)
                .map_err(|e| format!("{} on {:?}: {e}", m.label(), class))?;
            if order.len() != a.nrows() {
                return Err(format!("{}: wrong length", m.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fill_ratio_invariant_under_relabeling() {
    // fill of (relabeled matrix, composed order) equals fill of
    // (original, order): fill ratio is permutation-equivariant
    forall(15, |rng| {
        let a = random_spd(rng);
        let n = a.nrows();
        let order = rng.permutation(n);
        let fill_a = fill_ratio_of_order(&a, &order);

        let relabel = rng.permutation(n);
        let b = a.permute_sym(&relabel);
        // B's node k is A's node relabel[k]; the same physical elimination
        // sequence in B coordinates:
        let mut pos_in_relabel = vec![0usize; n];
        for (k, &r) in relabel.iter().enumerate() {
            pos_in_relabel[r] = k;
        }
        let order_b: Vec<usize> = order.iter().map(|&o| pos_in_relabel[o]).collect();
        let fill_b = fill_ratio_of_order(&b, &order_b);
        if (fill_a - fill_b).abs() > 1e-12 {
            return Err(format!("fill not equivariant: {fill_a} vs {fill_b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_amd_never_much_worse_than_natural() {
    forall(15, |rng| {
        let class = ProblemClass::ALL[rng.next_below(6)];
        let a = class.generate(80 + rng.next_below(200), rng.next_u64());
        let n = a.nrows();
        let nat = fill_ratio_of_order(&a, &(0..n).collect::<Vec<_>>());
        let amd_fill = fill_ratio_of_order(&a, &amd(&a));
        if amd_fill > nat * 1.3 + 0.5 {
            return Err(format!("amd {amd_fill} much worse than natural {nat} on {class:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rcm_reduces_bandwidth() {
    use pfm_reorder::order::rcm::bandwidth;
    forall(15, |rng| {
        let a = random_spd(rng);
        let n = a.nrows();
        let shuffled = a.permute_sym(&rng.permutation(n));
        let before = bandwidth(&shuffled, &(0..n).collect::<Vec<_>>());
        let after = bandwidth(&shuffled, &rcm(&shuffled));
        if after > before {
            return Err(format!("rcm bandwidth {after} > natural {before}"));
        }
        Ok(())
    });
}

#[test]
fn prop_nd_deterministic_and_valid() {
    forall(10, |rng| {
        let a = ProblemClass::TwoDThreeD.generate(150 + rng.next_below(200), rng.next_u64());
        let seed = rng.next_u64();
        let o1 = nested_dissection_with(&a, seed);
        let o2 = nested_dissection_with(&a, seed);
        if o1 != o2 {
            return Err("nd not deterministic per seed".into());
        }
        check_permutation(&o1)?;
        Ok(())
    });
}

#[test]
fn prop_score_ordering_is_stable_sort() {
    forall(30, |rng| {
        let n = 5 + rng.next_below(100);
        let scores: Vec<f64> = (0..n).map(|_| (rng.next_below(10) as f64)).collect();
        let order = order_from_scores(&scores);
        check_permutation(&order)?;
        // stability: equal scores keep index order; overall ascending
        for w in order.windows(2) {
            let (a, b) = (w[0], w[1]);
            if scores[a] == scores[b] && a > b {
                return Err(format!("unstable tie: {a} before {b}"));
            }
            if scores[a] > scores[b] {
                return Err("not ascending".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_graph_components_partition_nodes() {
    forall(20, |rng| {
        let a = random_spd(rng);
        let g = Graph::from_matrix(&a);
        let (comp, count) = g.components();
        if comp.iter().any(|&c| c >= count) {
            return Err("component id out of range".into());
        }
        // edges never cross components
        for u in 0..g.n() {
            for &v in g.neighbors(u) {
                if comp[u] != comp[v] {
                    return Err(format!("edge {u}-{v} crosses components"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_market_roundtrip() {
    use pfm_reorder::sparse::io::{read_matrix_market, write_matrix_market};
    forall(10, |rng| {
        let a = random_spd(rng);
        let dir = std::env::temp_dir().join(format!(
            "pfm_prop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join("m.mtx");
        write_matrix_market(&path, &a).map_err(|e| e.to_string())?;
        let b = read_matrix_market(&path).map_err(|e| e.to_string())?;
        std::fs::remove_dir_all(&dir).ok();
        if a != b {
            return Err("matrix market roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_market_roundtrip_general_unsymmetric() {
    // the `pfm` subcommand's ingest path: read∘write identity must hold on
    // general (value-unsymmetric, even rectangular) patterns, not just the
    // symmetric storage branch
    use pfm_reorder::sparse::io::{read_matrix_market, write_matrix_market};
    forall(12, |rng| {
        let nrows = 5 + rng.next_below(40);
        let ncols = if rng.next_f64() < 0.3 { 5 + rng.next_below(40) } else { nrows };
        let mut coo = Coo::new(nrows, ncols);
        for _ in 0..(2 * nrows + rng.next_below(3 * nrows)) {
            let r = rng.next_below(nrows);
            let c = rng.next_below(ncols);
            // signed, wide-magnitude values exercise the float formatting
            coo.push(r, c, rng.next_gaussian() * 10f64.powi(rng.next_below(7) as i32 - 3));
        }
        let a = coo.to_csr();
        let dir = std::env::temp_dir().join(format!(
            "pfm_prop_gen_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join("g.mtx");
        write_matrix_market(&path, &a).map_err(|e| e.to_string())?;
        let b = read_matrix_market(&path).map_err(|e| e.to_string())?;
        std::fs::remove_dir_all(&dir).ok();
        if a != b {
            return Err(format!(
                "general roundtrip mismatch ({nrows}x{ncols}, nnz {})",
                a.nnz()
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Native PFM optimizer invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pfm_optimizer_valid_permutation_on_all_8_classes() {
    use pfm_reorder::pfm::{OptBudget, PfmOptimizer};
    let classes: Vec<ProblemClass> = ProblemClass::ALL
        .iter()
        .chain(&ProblemClass::UNSYMMETRIC)
        .copied()
        .collect();
    forall(10, |rng| {
        let class = classes[rng.next_below(classes.len())];
        let n = 60 + rng.next_below(80);
        let a = class.generate(n, rng.next_u64());
        let budget = OptBudget { outer: 1, refine: 6, ..OptBudget::default() };
        let rep = PfmOptimizer::new(budget, rng.next_u64()).optimize(&a);
        check_permutation(&rep.order).map_err(|e| format!("{class:?}: {e}"))?;
        if rep.order.len() != a.nrows() {
            return Err(format!("{class:?}: wrong length"));
        }
        let expect_kind = match class.symmetry() {
            Symmetry::Symmetric => "cholesky",
            Symmetry::Unsymmetric => "lu",
        };
        if rep.kind.label() != expect_kind {
            return Err(format!("{class:?}: objective kind {}", rep.kind.label()));
        }
        if rep.objective > rep.init_objective {
            return Err(format!(
                "{class:?}: objective {} above init {}",
                rep.objective, rep.init_objective
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_pfm_admm_objective_non_increasing() {
    use pfm_reorder::pfm::{OptBudget, PfmOptimizer};
    forall(6, |rng| {
        let class = ProblemClass::ALL[rng.next_below(6)];
        let a = class.generate(70 + rng.next_below(60), rng.next_u64());
        let budget = OptBudget { outer: 4, refine: 12, ..OptBudget::default() };
        let rep = PfmOptimizer::new(budget, rng.next_u64()).optimize(&a);
        if rep.trace.is_empty() {
            return Err(format!("{class:?}: empty trace"));
        }
        for w in rep.trace.windows(2) {
            if w[1] > w[0] {
                return Err(format!("{class:?}: trace increased {} -> {}", w[0], w[1]));
            }
        }
        if rep.objective != *rep.trace.last().unwrap() {
            return Err(format!(
                "{class:?}: reported objective {} != trace tail {}",
                rep.objective,
                rep.trace.last().unwrap()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_pfm_parallel_refinement_is_deterministic_across_thread_counts() {
    // the PR's headline invariant: for random SPD and grid classes, the
    // parallel refinement returns the *same permutation* as the sequential
    // path (threads = 1) for the same seed and budget — bit-identical, via
    // single-threaded generation + fixed-order reduction
    use pfm_reorder::pfm::{OptBudget, PfmOptimizer};
    forall(6, |rng| {
        // alternate random SPD (dense-window path, sequential-probe sizes)
        // with grids above the multilevel cap AND the pool's parallel
        // cutoff (V-cycle + per-level refinement, genuinely threaded)
        let (label, a) = if rng.next_f64() < 0.5 {
            ("random_spd", random_spd(rng))
        } else {
            let side = 21 + rng.next_below(6); // n in [441, 676], nnz > 2000
            ("grid", pfm_reorder::gen::grid::laplacian_2d(side, side))
        };
        let seed = rng.next_u64();
        let budget = OptBudget {
            outer: 1,
            refine: 9,
            level_refine: 4,
            adaptive_rho: rng.next_f64() < 0.5,
            time_ms: None,
        };
        let base = PfmOptimizer::new(budget, seed).with_threads(1).optimize(&a);
        check_permutation(&base.order)?;
        for threads in [2usize, 4, 8] {
            let rep = PfmOptimizer::new(budget, seed).with_threads(threads).optimize(&a);
            if rep.order != base.order {
                return Err(format!(
                    "{label} n={}: threads={threads} changed the ordering",
                    a.nrows()
                ));
            }
            if rep.objective != base.objective || rep.trace != base.trace {
                return Err(format!("{label}: threads={threads} changed the trace"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pfm_hierarchy_prolongation_valid_on_all_8_classes() {
    // quality-regression satellite (c): walking scores down and back up the
    // V-cycle hierarchy yields a valid permutation at every level
    use pfm_reorder::order::order_from_scores;
    use pfm_reorder::pfm::multilevel::{prolong, Hierarchy};
    let classes: Vec<ProblemClass> = ProblemClass::ALL
        .iter()
        .chain(&ProblemClass::UNSYMMETRIC)
        .copied()
        .collect();
    forall(12, |rng| {
        let class = classes[rng.next_below(classes.len())];
        let n = 90 + rng.next_below(120);
        let a = class.generate(n, rng.next_u64());
        let gm = if a.is_symmetric(1e-12) { a.clone() } else { a.symmetrize() };
        let cap = 24 + rng.next_below(40);
        let Some(h) = Hierarchy::build(&gm, cap) else {
            return Err(format!("{class:?} n={n} cap={cap}: hierarchy must build"));
        };
        let y: Vec<f64> = (0..gm.nrows()).map(|_| rng.next_gaussian()).collect();
        let rests = h.restrict_all(&y);
        let mut cur = rests.last().unwrap().clone();
        for lvl in (0..h.levels() - 1).rev() {
            cur = prolong(&cur, &h.maps[lvl + 1], &rests[lvl]);
            // prolonged scores live on the level's node set, stay finite,
            // and argsort to a valid permutation of that level
            if cur.len() != h.matrices[lvl].nrows() {
                return Err(format!("{class:?} level {lvl}: wrong length"));
            }
            if cur.iter().any(|v| !v.is_finite()) {
                return Err(format!("{class:?} level {lvl}: non-finite score"));
            }
            let order = order_from_scores(&cur);
            check_permutation(&order).map_err(|e| format!("{class:?} level {lvl}: {e}"))?;
        }
        let fine = prolong(&cur, &h.maps[0], &y);
        if fine.len() != gm.nrows() {
            return Err(format!("{class:?}: fine prolongation wrong length"));
        }
        check_permutation(&order_from_scores(&fine))
            .map_err(|e| format!("{class:?} fine: {e}"))?;
        // the tie-break must keep same-aggregate nodes in their fine
        // relative order (distinct fine scores ⇒ distinct prolonged order)
        for _ in 0..40 {
            let u = rng.next_below(fine.len());
            let v = rng.next_below(fine.len());
            if u != v
                && h.maps[0][u] == h.maps[0][v]
                && y[u] != y[v]
                && (fine[u] < fine[v]) != (y[u] < y[v])
            {
                return Err(format!(
                    "{class:?}: aggregate-internal order flipped for ({u},{v})"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Etree task-DAG parallel factorization invariants
// ---------------------------------------------------------------------------

/// SPD version of a class instance: symmetric classes are diagonally
/// dominant already; the two unsymmetric classes are symmetrized and
/// diagonally shifted until dominant.
fn spd_of(a: &Csr) -> Csr {
    if a.is_symmetric(1e-12) {
        return a.clone();
    }
    let s = a.symmetrize();
    let n = s.nrows();
    let mut shift = 0.0f64;
    for i in 0..n {
        let (cols, vals) = s.row(i);
        let mut off = 0.0;
        let mut diag = 0.0;
        for (&j, &v) in cols.iter().zip(vals) {
            if j == i {
                diag = v;
            } else {
                off += v.abs();
            }
        }
        shift = shift.max(off - diag);
    }
    let mut coo = Coo::square(n);
    for i in 0..n {
        let (cols, vals) = s.row(i);
        let mut has_diag = false;
        for (&j, &v) in cols.iter().zip(vals) {
            if j == i {
                coo.push(i, i, v + shift + 1.0);
                has_diag = true;
            } else {
                coo.push(i, j, v);
            }
        }
        if !has_diag {
            coo.push(i, i, shift + 1.0);
        }
    }
    coo.to_csr()
}

#[test]
fn prop_parallel_factor_bit_identical_on_all_8_classes() {
    // the tentpole invariant: for every problem class, under both the
    // natural and the AMD ordering, the task-DAG parallel factorization is
    // bit-identical to the sequential supernodal kernel at every thread
    // count (the flop cutoff is forced to 0 so small instances engage)
    use pfm_reorder::factor::supernodal::SupernodalSymbolic;
    let classes: Vec<ProblemClass> = ProblemClass::ALL
        .iter()
        .chain(&ProblemClass::UNSYMMETRIC)
        .copied()
        .collect();
    forall(10, |rng| {
        let class = classes[rng.next_below(classes.len())];
        let n = 80 + rng.next_below(120);
        let a0 = spd_of(&class.generate(n, rng.next_u64()));
        let mut engaged = 0usize;
        for (olabel, a) in [("natural", a0.clone()), ("amd", a0.permute_sym(&amd(&a0)))] {
            let sym = analyze(&a);
            let ssym = SupernodalSymbolic::build(&a, &sym, fundamental_supernodes(&sym));
            let mut ws = FactorWorkspace::new();
            let mut seq = vec![0.0f64; ssym.values_len()];
            supernodal::factorize_into(&a, &ssym, &mut seq, &mut ws)
                .map_err(|e| format!("{class:?}/{olabel}: sequential: {e}"))?;
            for threads in [1usize, 2, 4, 8] {
                // threads=1 and path etrees decline: the parallel entry
                // point must then be the sequential kernel verbatim
                let Some(sched) = Schedule::build_with(&ssym, threads, 0.0) else {
                    continue;
                };
                engaged += 1;
                let mut par = vec![0.0f64; ssym.values_len()];
                factorize_into_parallel(&a, &ssym, &mut par, &mut ws, &sched)
                    .map_err(|e| format!("{class:?}/{olabel} threads={threads}: {e}"))?;
                if !seq.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()) {
                    return Err(format!(
                        "{class:?}/{olabel}: threads={threads} not bit-identical"
                    ));
                }
            }
        }
        // AMD must have engaged at least once — otherwise this test
        // silently degenerates to sequential-vs-sequential
        if engaged == 0 {
            return Err(format!("{class:?} n={n}: no thread count engaged"));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_schedule_declines_serving_sized_and_path_etrees() {
    use pfm_reorder::factor::sched::PAR_MIN_FLOPS;
    use pfm_reorder::factor::supernodal::SupernodalSymbolic;
    forall(12, |rng| {
        // (a) serving-sized work below the flop cutoff never spawns, even
        // on a wide AMD etree with many threads requested
        let class = ProblemClass::ALL[rng.next_below(6)];
        let a = spd_of(&class.generate(40 + rng.next_below(60), rng.next_u64()));
        let a = a.permute_sym(&amd(&a));
        let sym = analyze(&a);
        if (factor_flops(&sym) as f64) < PAR_MIN_FLOPS {
            let ssym = SupernodalSymbolic::build(&a, &sym, fundamental_supernodes(&sym));
            if Schedule::build(&ssym, 8).is_some() {
                return Err(format!("small {class:?} must stay sequential"));
            }
        }
        // (b) a banded matrix under the natural order has a path etree —
        // no subtree width at any cutoff, at any thread count
        let side = 12 + rng.next_below(20);
        let b = pfm_reorder::gen::grid::laplacian_2d(side, side);
        let bsym = analyze(&b);
        let bssym = SupernodalSymbolic::build(&b, &bsym, fundamental_supernodes(&bsym));
        if Schedule::build_with(&bssym, 2 + rng.next_below(7), 0.0).is_some() {
            return Err(format!("path etree (side {side}) must stay sequential"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Incremental symbolic probe evaluation invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_incremental_eval_bit_identical_to_full_analyze_on_all_8_classes() {
    // the tentpole contract: for any symmetric pattern, any base ordering,
    // and any segment-move candidate, the incremental suffix re-walk
    // returns *exactly* analyze(permute_sym(cand)).lnnz — including the
    // degenerate windows (lo = 0, suffix touching the root, width-1
    // relocations, identical candidate)
    use pfm_reorder::pfm::incremental::IncrementalBase;
    let classes: Vec<ProblemClass> = ProblemClass::ALL
        .iter()
        .chain(&ProblemClass::UNSYMMETRIC)
        .copied()
        .collect();
    forall(12, |rng| {
        let class = classes[rng.next_below(classes.len())];
        let a0 = class.generate(50 + rng.next_below(90), rng.next_u64());
        // the incremental walk is defined on symmetric patterns (the
        // pool's Cholesky-only gate); unsymmetric classes run symmetrized
        let a = if a0.is_symmetric(1e-12) { a0 } else { a0.symmetrize() };
        let n = a.nrows();
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        for order in [(0..n).collect::<Vec<_>>(), amd(&a)] {
            base.prepare(&a, &order, &mut ws);
            let mut cands: Vec<Vec<usize>> = Vec::new();
            // random reverse + relocate windows
            for _ in 0..3 {
                let len = (2 + rng.next_below((n / 2).max(2))).min(n - 1);
                let s = rng.next_below(n - len);
                let mut c = order.clone();
                c[s..s + len].reverse();
                cands.push(c);
                let mut c = order.clone();
                let seg: Vec<usize> = c.splice(s..s + len, std::iter::empty()).collect();
                let at = rng.next_below(c.len() + 1);
                let tail = c.split_off(at);
                c.extend_from_slice(&seg);
                c.extend_from_slice(&tail);
                cands.push(c);
            }
            // lo = 0: whole ordering reversed
            let mut c = order.clone();
            c.reverse();
            cands.push(c);
            // suffix touching the root
            let mut c = order.clone();
            c.swap(n - 2, n - 1);
            cands.push(c);
            // width-1 relocation
            let mut c = order.clone();
            let v = c.remove(rng.next_below(n));
            c.insert(rng.next_below(n), v);
            cands.push(c);
            // identical candidate (lo == n)
            cands.push(order.clone());
            for cand in cands {
                check_permutation(&cand).map_err(|e| format!("{class:?}: {e}"))?;
                let lo = base.first_diff(&cand);
                let inc = base.eval(&a, &cand, lo, &mut ws);
                let fullv = analyze(&a.permute_sym(&cand)).lnnz as f64;
                if inc != fullv {
                    return Err(format!(
                        "{class:?} n={n} lo={lo}: incremental {inc} != full {fullv}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_probe_pool_incremental_batches_bit_identical_across_threads() {
    // pool-level determinism with incremental evaluation on: a segment
    // batch sharing a long rank prefix must (a) engage the incremental
    // path for every candidate, (b) return values bit-identical to a
    // full-evaluation pool, (c) at every thread count
    use pfm_reorder::factor::FactorKind;
    use pfm_reorder::pfm::ProbePool;
    forall(6, |rng| {
        // above the pool's parallel nnz cutoff so threads genuinely engage
        let side = 21 + rng.next_below(6);
        let a = pfm_reorder::gen::grid::laplacian_2d(side, side);
        let n = a.nrows();
        let order = amd(&a);
        let mut orders = Vec::new();
        for _ in 0..4 {
            let len = 2 + rng.next_below(n / 8);
            // windows start past n/3 > n/4: eligible by construction, and
            // spared prefix rows Σlo > n guarantee the batch engages
            let s = n / 3 + rng.next_below(n - n / 3 - len);
            let mut c = order.clone();
            c[s..s + len].reverse();
            orders.push(c);
        }
        let mut full_pool = ProbePool::new(1).with_incremental(false);
        let reference =
            full_pool.eval_orders_with_base(&a, FactorKind::Cholesky, &order, &orders, None);
        if full_pool.incremental_evals() != 0 {
            return Err("disabled pool served incremental evals".into());
        }
        for threads in [1usize, 2, 4, 8] {
            let mut pool = ProbePool::new(threads);
            let got =
                pool.eval_orders_with_base(&a, FactorKind::Cholesky, &order, &orders, None);
            if got.iter().map(|e| e.value).ne(reference.iter().map(|e| e.value)) {
                return Err(format!("threads={threads}: values diverged from full pool"));
            }
            if pool.incremental_evals() != orders.len() {
                return Err(format!(
                    "threads={threads}: {} of {} probes ran incrementally",
                    pool.incremental_evals(),
                    orders.len()
                ));
            }
            if pool.saved_units() != full_pool.saved_units() {
                return Err(format!("threads={threads}: savings ledger diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pfm_never_exceeds_spectral_init_fill_on_symmetric_suite() {
    use pfm_reorder::order::fiedler_order_with;
    use pfm_reorder::pfm::{OptBudget, PfmOptimizer, SPECTRAL_INIT_ITERS};
    forall(6, |rng| {
        let class = ProblemClass::ALL[rng.next_below(6)];
        let a = class.generate(70 + rng.next_below(80), rng.next_u64());
        let seed = rng.next_u64();
        let budget = OptBudget { outer: 2, refine: 10, ..OptBudget::default() };
        let rep = PfmOptimizer::new(budget, seed).optimize(&a);
        let spectral = fiedler_order_with(&a, SPECTRAL_INIT_ITERS, seed);
        let init_fill = fill_ratio_of_order(&a, &spectral);
        let opt_fill = fill_ratio_of_order(&a, &rep.order);
        if opt_fill > init_fill + 1e-12 {
            return Err(format!(
                "{class:?}: optimized fill {opt_fill} above spectral init {init_fill}"
            ));
        }
        // the optimizer's recorded init matches the actual spectral fill
        let init_lnnz = analyze(&a.permute_sym(&spectral)).lnnz as f64;
        if rep.init_objective != init_lnnz {
            return Err(format!(
                "{class:?}: init objective {} != spectral lnnz {init_lnnz}",
                rep.init_objective
            ));
        }
        Ok(())
    });
}
