//! Loopback integration tests of the TCP reorder gateway: the
//! exactly-one-reply contract under a burst that overruns the bounded
//! queue, per-client rate-limit isolation, graceful shutdown answering
//! every in-flight request, malformed-input rejection on a live socket,
//! and the admin protocol.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use pfm_reorder::coordinator::{Method, ServiceConfig};
use pfm_reorder::gateway::frame::{self, FrameType};
use pfm_reorder::gateway::{
    AdminCmd, BusyReason, Gateway, GatewayClient, GatewayConfig, Reply, WireRequest,
};
use pfm_reorder::gen::grid::laplacian_2d;
use pfm_reorder::order::Classical;
use pfm_reorder::pfm::OptBudget;
use pfm_reorder::runtime::Learned;
use pfm_reorder::sparse::Csr;
use pfm_reorder::util::check::check_permutation;
use pfm_reorder::util::rng::Pcg64;

fn gateway(service: ServiceConfig, rate: f64, burst: f64) -> Gateway {
    Gateway::start(GatewayConfig {
        addr: "127.0.0.1:0".to_string(),
        service,
        rate,
        burst,
        poll: Duration::from_millis(5),
    })
    .expect("bind loopback gateway")
}

fn request(id: u64, method: Method, matrix: Csr) -> WireRequest {
    WireRequest {
        id,
        method,
        seed: id,
        eval_fill: false,
        factor_kind: None,
        opt_budget: None,
        factor_threads: None,
        matrix,
    }
}

/// A burst larger than the bounded queue: every frame is answered with
/// exactly one `Response` or `Busy(QueueFull)` — zero silent drops — and
/// replies come back in submission order with the ids echoed.
#[test]
fn burst_over_bounded_queue_answers_every_request() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            queue_capacity: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-burst".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let total = 40u64;
    let a = laplacian_2d(30, 30); // Fiedler on n=900: a few ms per request
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    for i in 0..total {
        c.send_request(&request(i, Method::Classical(Classical::Fiedler), a.clone()))
            .unwrap();
    }
    let (mut served, mut busy) = (0u64, 0u64);
    for i in 0..total {
        match c.recv_reply().unwrap() {
            Reply::Result(res) => {
                assert_eq!(res.id, i, "replies must preserve submission order");
                assert_eq!(res.order.len(), 900);
                check_permutation(&res.order).unwrap();
                served += 1;
            }
            Reply::Busy { id, reason } => {
                assert_eq!(id, i, "busy must echo the request id");
                assert_eq!(reason, BusyReason::QueueFull);
                busy += 1;
            }
            other => panic!("request {i}: unexpected reply {other:?}"),
        }
    }
    assert_eq!(served + busy, total, "exactly one reply per request");
    assert!(served >= 1, "the service must have served part of the burst");
    assert!(busy >= 1, "a 40-deep instant burst over a 1-deep queue must saturate");
    drop(c);
    gw.shutdown();
    let m = gw.metrics();
    assert_eq!(m.gateway_busy_queue(), busy as usize);
    assert_eq!(m.total_completed(), served as usize);
}

/// Concurrent clients with mixed request classes: each connection gets
/// exactly one reply per request, in order, all valid permutations.
#[test]
fn concurrent_mixed_class_clients_each_get_every_reply() {
    let gw = gateway(
        ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-gwi-mixed".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let addr = gw.local_addr();
    let quick = OptBudget { outer: 1, refine: 4, level_refine: 0, ..OptBudget::default() };
    let handles: Vec<_> = (0..4u64)
        .map(|client| {
            std::thread::spawn(move || {
                let a = laplacian_2d(8, 8);
                let mut c = GatewayClient::connect(addr).unwrap();
                let per_client = 8u64;
                for i in 0..per_client {
                    let method = match i % 3 {
                        0 => Method::Classical(Classical::Amd),
                        1 => Method::Classical(Classical::Natural),
                        _ => Method::Learned(Learned::Pfm),
                    };
                    let mut req = request(client * 1000 + i, method, a.clone());
                    req.opt_budget = Some(quick);
                    c.send_request(&req).unwrap();
                }
                for i in 0..per_client {
                    match c.recv_reply().unwrap() {
                        Reply::Result(res) => {
                            assert_eq!(res.id, client * 1000 + i);
                            check_permutation(&res.order).unwrap();
                        }
                        other => panic!("client {client}: unexpected reply {other:?}"),
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    gw.shutdown();
    let m = gw.metrics();
    assert_eq!(m.total_completed(), 32);
    assert_eq!(m.gateway_connections(), 4);
    assert_eq!(m.errors(), 0);
}

/// One hot client is throttled; a calm client on the same gateway is not.
#[test]
fn rate_limited_client_is_throttled_while_others_proceed() {
    let gw = gateway(
        ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-gwi-rate".into(),
            ..Default::default()
        },
        1.0, // 1 req/s refill
        2.0, // burst of 2
    );
    let a = laplacian_2d(8, 8);

    // hog: 8 back-to-back requests — the burst admits 2, the rest bounce
    let mut hog = GatewayClient::connect(gw.local_addr()).unwrap();
    for i in 0..8 {
        hog.send_request(&request(i, Method::Classical(Classical::Amd), a.clone())).unwrap();
    }
    let (mut served, mut throttled) = (0, 0);
    for i in 0..8 {
        match hog.recv_reply().unwrap() {
            Reply::Result(res) => {
                assert_eq!(res.id, i);
                served += 1;
            }
            Reply::Busy { id, reason } => {
                assert_eq!(id, i);
                assert_eq!(reason, BusyReason::RateLimited);
                throttled += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert_eq!(served + throttled, 8, "every frame answered");
    assert!(served >= 2, "the burst capacity must be admitted");
    assert!(throttled >= 5, "a back-to-back burst of 8 against burst=2 must throttle");

    // calm client (distinct peer => its own bucket): full burst served
    let mut calm = GatewayClient::connect(gw.local_addr()).unwrap();
    for i in 100..102 {
        match calm.request(&request(i, Method::Classical(Classical::Amd), a.clone())).unwrap() {
            Reply::Result(res) => assert_eq!(res.id, i),
            other => panic!("calm client must not be throttled, got {other:?}"),
        }
    }

    // admin throttle stats see both buckets
    let stats = calm.admin(AdminCmd::Throttle).unwrap();
    assert!(stats.contains("\"enabled\":true"), "{stats}");
    assert!(stats.contains("\"throttled\":"), "{stats}");
    drop(hog);
    drop(calm);
    gw.shutdown();
    assert_eq!(gw.metrics().gateway_busy_throttled(), throttled);
}

/// Shutdown with requests in flight: the drain answers every accepted
/// request with a real result before the gateway exits — the service's
/// "shutdown answers everything" contract, extended across the wire.
#[test]
fn shutdown_answers_every_in_flight_request() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            queue_capacity: 8,
            artifact_dir: "nonexistent-dir-ok-gwi-drain".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let a = laplacian_2d(30, 30); // slow enough that shutdown lands mid-work
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    let inflight = 5u64;
    for i in 0..inflight {
        c.send_request(&request(i, Method::Classical(Classical::Fiedler), a.clone()))
            .unwrap();
    }
    // let the reader pull everything off the socket and into the service
    std::thread::sleep(Duration::from_millis(300));
    let drainer = std::thread::spawn(move || {
        let mut got = Vec::new();
        for _ in 0..inflight {
            got.push(c.recv_reply().unwrap());
        }
        got
    });
    gw.shutdown(); // blocks until writers flushed every pending reply
    let replies = drainer.join().unwrap();
    assert_eq!(replies.len() as u64, inflight);
    for (i, reply) in replies.iter().enumerate() {
        match reply {
            Reply::Result(res) => {
                assert_eq!(res.id, i as u64);
                check_permutation(&res.order).unwrap();
            }
            other => panic!("in-flight request {i} not served across shutdown: {other:?}"),
        }
    }
    assert_eq!(gw.metrics().total_completed(), inflight as usize);
}

/// Payload-level garbage is answered with an `Error` frame and the
/// connection keeps working; framing-level garbage is answered and the
/// connection closes. Nothing panics.
#[test]
fn malformed_input_is_rejected_without_killing_the_connection() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-malformed".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let a = laplacian_2d(8, 8);

    // garbage *payload* in a well-formed Request frame → Error, then the
    // same connection still serves a valid request
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    let mut s = TcpStream::connect(gw.local_addr()).unwrap();
    frame::write_frame(&mut s, FrameType::Request, b"not a request").unwrap();
    let f = frame::read_frame(&mut s).unwrap();
    assert_eq!(f.ftype, FrameType::Error);
    // zero-length payload is equally malformed at the wire layer
    frame::write_frame(&mut s, FrameType::Request, b"").unwrap();
    assert_eq!(frame::read_frame(&mut s).unwrap().ftype, FrameType::Error);
    drop(s);

    // oversize length prefix → Error frame, connection closed
    let mut s = TcpStream::connect(gw.local_addr()).unwrap();
    let mut h = frame::encode_header(FrameType::Request, 0);
    h[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&h).unwrap();
    let f = frame::read_frame(&mut s).unwrap();
    assert_eq!(f.ftype, FrameType::Error);
    assert!(matches!(
        frame::read_frame(&mut s),
        Err(frame::FrameError::CleanEof) | Err(frame::FrameError::Io(_))
    ));
    drop(s);

    // unknown protocol version → Error frame, connection closed
    let mut s = TcpStream::connect(gw.local_addr()).unwrap();
    let mut h = frame::encode_header(FrameType::Request, 0);
    h[2] = 99;
    s.write_all(&h).unwrap();
    assert_eq!(frame::read_frame(&mut s).unwrap().ftype, FrameType::Error);
    drop(s);

    // the gateway is still healthy for well-behaved clients
    match c.request(&request(1, Method::Classical(Classical::Amd), a)).unwrap() {
        Reply::Result(res) => check_permutation(&res.order).unwrap(),
        other => panic!("healthy client broken by malformed peers: {other:?}"),
    }
    drop(c);
    gw.shutdown();
    assert!(gw.metrics().gateway_malformed() >= 4);
}

/// Fuzz a live gateway with random byte strings on many connections: any
/// outcome is fine except the gateway dying.
#[test]
fn random_byte_connections_never_take_the_gateway_down() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-fuzz".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let mut rng = Pcg64::new(0x6A7E_2026);
    for _ in 0..25 {
        let mut s = TcpStream::connect(gw.local_addr()).unwrap();
        let len = rng.next_below(200);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = s.write_all(&bytes);
        drop(s);
    }
    // half-written valid frames (truncated mid-payload) as well
    for _ in 0..10 {
        let mut s = TcpStream::connect(gw.local_addr()).unwrap();
        let h = frame::encode_header(FrameType::Request, 64);
        let _ = s.write_all(&h);
        let _ = s.write_all(&[0u8; 13]);
        drop(s);
    }
    let a = laplacian_2d(8, 8);
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    match c.request(&request(9, Method::Classical(Classical::Amd), a)).unwrap() {
        Reply::Result(res) => check_permutation(&res.order).unwrap(),
        other => panic!("gateway unhealthy after fuzzing: {other:?}"),
    }
    drop(c);
    gw.shutdown();
}

/// Full warm-start persistence loop across the wire: a native-PFM result
/// is WAL-persisted, the `snapshot` admin command compacts it, and a
/// *second* gateway on the same directory serves the same pattern from
/// the store (`provenance == "warm"`) with a bit-identical permutation —
/// the crash-restart contract, minus the kill -9 (CI's smoke test covers
/// that with real processes).
#[test]
fn warm_store_survives_gateway_restart_and_snapshot_admin_compacts() {
    let dir = std::env::temp_dir().join(format!("pfm_gwi_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let service = || ServiceConfig {
        workers: 1,
        artifact_dir: "nonexistent-dir-ok-gwi-persist".into(),
        persist: Some(pfm_reorder::persist::PersistConfig::new(&dir)),
        ..Default::default()
    };
    let quick = OptBudget { outer: 1, refine: 4, time_ms: None, ..OptBudget::default() };
    let a = laplacian_2d(11, 11);

    let gw = gateway(service(), 0.0, 32.0);
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    let mut req = request(1, Method::Learned(Learned::Pfm), a.clone());
    req.opt_budget = Some(quick);
    req.factor_threads = Some(2);
    let first = match c.request(&req).unwrap() {
        Reply::Result(res) => {
            assert_eq!(res.provenance.as_deref(), Some("native"));
            assert_eq!(res.factor_threads, 2, "native run reports the requested width");
            res
        }
        other => panic!("unexpected reply {other:?}"),
    };
    let snap = c.admin(AdminCmd::Snapshot).unwrap();
    assert!(snap.contains("\"ok\":true"), "{snap}");
    assert!(snap.contains("\"records\":1"), "{snap}");
    drop(c);
    gw.shutdown();

    // second gateway, same store directory: the pattern is warm
    let gw = gateway(service(), 0.0, 32.0);
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    let mut req = request(2, Method::Learned(Learned::Pfm), a);
    req.seed = 99; // different seed on purpose: the key is the pattern
    req.opt_budget = Some(quick);
    match c.request(&req).unwrap() {
        Reply::Result(res) => {
            assert_eq!(res.provenance.as_deref(), Some("warm"));
            assert_eq!(res.order, first.order, "warm hit must be bit-identical");
            assert_eq!(res.factor_threads, 0, "warm hits run no factorization");
        }
        other => panic!("unexpected reply {other:?}"),
    }
    let m = c.admin(AdminCmd::Metrics).unwrap();
    for key in ["\"persist\"", "\"warm_hits\":1", "\"replayed\":1"] {
        assert!(m.contains(key), "metrics JSON missing {key}: {m}");
    }
    drop(c);
    gw.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `snapshot` admin command on a store-less gateway reports a clean
/// error instead of succeeding vacuously or crashing.
#[test]
fn snapshot_admin_without_persistence_reports_an_error() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-nosnap".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    let reply = c.admin(AdminCmd::Snapshot).unwrap();
    assert!(reply.contains("\"ok\":false"), "{reply}");
    assert!(reply.contains("persist-dir"), "{reply}");
    drop(c);
    gw.shutdown();
}

/// A client with an I/O timeout fails fast against a peer that accepts
/// the connection and then never answers (pre-fix, only the *connect* was
/// bounded — a wedged gateway hung `admin`/`remote` forever).
#[test]
fn client_io_timeout_bounds_a_silent_peer() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // keep the listener alive but never read or reply
    let hold = std::thread::spawn(move || {
        let conn = listener.accept().map(|(s, _)| s);
        std::thread::sleep(Duration::from_secs(5));
        drop(conn);
    });
    let mut c = GatewayClient::connect(addr).unwrap();
    c.set_io_timeout(Some(Duration::from_millis(150))).unwrap();
    let t0 = std::time::Instant::now();
    let err = c.admin(AdminCmd::Ping).expect_err("a silent peer must time out");
    assert!(err.contains("timed out"), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(3),
        "timeout must bound the wait, took {:?}",
        t0.elapsed()
    );
    drop(c);
    drop(hold); // detach; the sleeper exits on its own
}

/// Stage tracing across the wire: every served request carries a
/// per-stage breakdown (end-anchored optional wire section) whose summed
/// durations never exceed the client-observed wall clock, and the admin
/// `trace` / `metrics-text` commands surface the bounded ring and the
/// Prometheus exposition of the latency histograms.
#[test]
fn stage_breakdown_rides_the_wire_and_admin_surfaces_traces() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-stages".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let a = laplacian_2d(10, 10);
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    for i in 0..3u64 {
        let mut req = request(i, Method::Classical(Classical::Amd), a.clone());
        req.eval_fill = true;
        let t0 = std::time::Instant::now();
        match c.request(&req).unwrap() {
            Reply::Result(res) => {
                let wall = t0.elapsed().as_secs_f64();
                assert!(!res.stages.is_empty(), "every served request carries stages");
                let labels: Vec<&str> = res.stages.iter().map(|(l, _)| l.as_str()).collect();
                assert!(labels.contains(&"decode"), "stages: {labels:?}");
                assert!(labels.contains(&"rate_limit"), "stages: {labels:?}");
                assert!(labels.contains(&"queue_wait"), "stages: {labels:?}");
                assert!(labels.contains(&"order"), "stages: {labels:?}");
                assert!(res.stages.iter().all(|&(_, s)| s >= 0.0), "{:?}", res.stages);
                let sum: f64 = res.stages.iter().map(|&(_, s)| s).sum();
                assert!(sum <= wall + 1e-6, "stage sum {sum}s above client wall {wall}s");
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    let tr = c.admin(AdminCmd::Trace).unwrap();
    assert!(tr.contains("\"traces\""), "{tr}");
    assert!(tr.contains("\"queue_wait\""), "{tr}");
    assert!(tr.contains("\"encode\""), "ring must carry the encode annotation: {tr}");
    let text = c.admin(AdminCmd::MetricsText).unwrap();
    assert!(text.contains("pfm_request_latency_seconds_bucket"), "{text}");
    assert!(text.contains("pfm_queue_wait_seconds_count"), "{text}");
    assert!(text.contains("# TYPE"), "{text}");
    drop(c);
    gw.shutdown();
}

/// Admin protocol: ping, metrics (with live gateway counters), throttle.
#[test]
fn admin_protocol_reports_live_metrics() {
    let gw = gateway(
        ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gwi-admin".into(),
            ..Default::default()
        },
        0.0,
        32.0,
    );
    let a = laplacian_2d(8, 8);
    let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
    assert!(c.admin(AdminCmd::Ping).unwrap().contains("\"ok\":true"));
    match c.request(&request(3, Method::Classical(Classical::Amd), a)).unwrap() {
        Reply::Result(res) => assert_eq!(res.id, 3),
        other => panic!("unexpected reply {other:?}"),
    }
    let m = c.admin(AdminCmd::Metrics).unwrap();
    for key in [
        "\"gateway\"",
        "\"connections\":1",
        "\"frames_rx\"",
        "\"frames_tx\"",
        "\"queue_depth\"",
        "\"worker_panics\":0",
        "\"completed\":1",
    ] {
        assert!(m.contains(key), "metrics JSON missing {key}: {m}");
    }
    let t = c.admin(AdminCmd::Throttle).unwrap();
    assert!(t.contains("\"enabled\":false"), "{t}");
    drop(c);
    gw.shutdown();
    assert_eq!(gw.metrics().gateway_admin(), 3);
}
