//! Adjacency-structure view of a symmetric sparse matrix.
//!
//! Every reordering algorithm in the paper operates on the matrix's
//! adjacency graph G = (V, E), e_ij ∈ E ⇔ a_ij ≠ 0 (i ≠ j). This module
//! provides that view in CSR-of-neighbours form plus the traversals the
//! orderings need: BFS level structures, pseudo-peripheral node search, and
//! connected components.

use crate::sparse::Csr;

/// Undirected graph in CSR adjacency form (no self-loops, symmetric).
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    xadj: Vec<usize>,
    adjncy: Vec<usize>,
    /// Optional edge weights, aligned with `adjncy` (used by coarsening).
    eweights: Vec<f64>,
    /// Node weights (≥1; >1 after coarsening collapses nodes).
    vweights: Vec<f64>,
}

impl Graph {
    /// Build from the off-diagonal pattern of a symmetric matrix. Edge
    /// weights are |a_ij|; node weights start at 1.
    pub fn from_matrix(a: &Csr) -> Graph {
        assert_eq!(a.nrows(), a.ncols(), "adjacency needs a square matrix");
        let n = a.nrows();
        let mut xadj = vec![0usize; n + 1];
        for r in 0..n {
            let (cols, _) = a.row(r);
            xadj[r + 1] = xadj[r] + cols.iter().filter(|&&c| c != r).count();
        }
        let mut adjncy = vec![0usize; xadj[n]];
        let mut eweights = vec![0.0f64; xadj[n]];
        for r in 0..n {
            let (cols, vals) = a.row(r);
            let mut p = xadj[r];
            for (&c, &v) in cols.iter().zip(vals) {
                if c != r {
                    adjncy[p] = c;
                    eweights[p] = v.abs();
                    p += 1;
                }
            }
        }
        Graph { xadj, adjncy, eweights, vweights: vec![1.0; n] }
    }

    /// Build directly from parts (coarsening).
    pub fn from_parts(
        xadj: Vec<usize>,
        adjncy: Vec<usize>,
        eweights: Vec<f64>,
        vweights: Vec<f64>,
    ) -> Graph {
        debug_assert_eq!(*xadj.last().unwrap(), adjncy.len());
        debug_assert_eq!(adjncy.len(), eweights.len());
        debug_assert_eq!(xadj.len(), vweights.len() + 1);
        Graph { xadj, adjncy, eweights, vweights }
    }

    pub fn n(&self) -> usize {
        self.vweights.len()
    }

    pub fn num_edges(&self) -> usize {
        self.adjncy.len() / 2
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[usize] {
        &self.adjncy[self.xadj[v]..self.xadj[v + 1]]
    }

    #[inline]
    pub fn edge_weights(&self, v: usize) -> &[f64] {
        &self.eweights[self.xadj[v]..self.xadj[v + 1]]
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.xadj[v + 1] - self.xadj[v]
    }

    pub fn vweight(&self, v: usize) -> f64 {
        self.vweights[v]
    }

    pub fn total_vweight(&self) -> f64 {
        self.vweights.iter().sum()
    }

    /// BFS from `root`, returning (level per node, ordered visit list).
    /// Unreached nodes get level `usize::MAX` and are absent from the list.
    pub fn bfs(&self, root: usize) -> (Vec<usize>, Vec<usize>) {
        let mut level = vec![usize::MAX; self.n()];
        let mut order = Vec::with_capacity(self.n());
        let mut queue = std::collections::VecDeque::new();
        level[root] = 0;
        queue.push_back(root);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &w in self.neighbors(u) {
                if level[w] == usize::MAX {
                    level[w] = level[u] + 1;
                    queue.push_back(w);
                }
            }
        }
        (level, order)
    }

    /// Level structure rooted at `root`: vector of levels, each a node list.
    pub fn level_structure(&self, root: usize) -> Vec<Vec<usize>> {
        let (level, order) = self.bfs(root);
        let depth = order.iter().map(|&u| level[u]).max().unwrap_or(0);
        let mut levels = vec![Vec::new(); depth + 1];
        for &u in &order {
            levels[level[u]].push(u);
        }
        levels
    }

    /// Pseudo-peripheral node via the George–Liu heuristic: repeat BFS from
    /// the smallest-degree node of the deepest last level until eccentricity
    /// stops growing. Used as the CM/RCM start node and the ND region seed.
    pub fn pseudo_peripheral(&self, start: usize) -> usize {
        let mut root = start;
        let mut ecc = 0usize;
        loop {
            let levels = self.level_structure(root);
            let new_ecc = levels.len() - 1;
            if new_ecc <= ecc && ecc > 0 {
                return root;
            }
            ecc = new_ecc;
            let last = &levels[new_ecc];
            // smallest degree in the last level
            let next = *last
                .iter()
                .min_by_key(|&&u| self.degree(u))
                .expect("non-empty level");
            if next == root {
                return root;
            }
            root = next;
        }
    }

    /// Connected components: (component id per node, component count).
    pub fn components(&self) -> (Vec<usize>, usize) {
        let mut comp = vec![usize::MAX; self.n()];
        let mut count = 0;
        for s in 0..self.n() {
            if comp[s] != usize::MAX {
                continue;
            }
            let mut stack = vec![s];
            comp[s] = count;
            while let Some(u) = stack.pop() {
                for &w in self.neighbors(u) {
                    if comp[w] == usize::MAX {
                        comp[w] = count;
                        stack.push(w);
                    }
                }
            }
            count += 1;
        }
        (comp, count)
    }

    /// Induced subgraph over `nodes` (order defines new ids). Returns the
    /// subgraph and the mapping new-id → old-id.
    pub fn subgraph(&self, nodes: &[usize]) -> (Graph, Vec<usize>) {
        let mut old2new = vec![usize::MAX; self.n()];
        for (newi, &old) in nodes.iter().enumerate() {
            old2new[old] = newi;
        }
        let mut xadj = vec![0usize; nodes.len() + 1];
        let mut adjncy = Vec::new();
        let mut eweights = Vec::new();
        for (newi, &old) in nodes.iter().enumerate() {
            for (&w, &ew) in self.neighbors(old).iter().zip(self.edge_weights(old)) {
                if old2new[w] != usize::MAX {
                    adjncy.push(old2new[w]);
                    eweights.push(ew);
                }
            }
            xadj[newi + 1] = adjncy.len();
        }
        let vweights = nodes.iter().map(|&o| self.vweights[o]).collect();
        (Graph::from_parts(xadj, adjncy, eweights, vweights), nodes.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;

    /// Path graph 0-1-2-3-4.
    fn path5() -> Graph {
        let mut coo = crate::sparse::Coo::square(5);
        for i in 0..4 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..5 {
            coo.push(i, i, 2.0);
        }
        Graph::from_matrix(&coo.to_csr())
    }

    #[test]
    fn from_matrix_strips_diagonal() {
        let g = path5();
        assert_eq!(g.n(), 5);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(2), &[1, 3]);
    }

    #[test]
    fn bfs_levels() {
        let g = path5();
        let (level, order) = g.bfs(0);
        assert_eq!(level, vec![0, 1, 2, 3, 4]);
        assert_eq!(order[0], 0);
        assert_eq!(order.len(), 5);
    }

    #[test]
    fn pseudo_peripheral_of_path_is_endpoint() {
        let g = path5();
        let p = g.pseudo_peripheral(2);
        assert!(p == 0 || p == 4, "got {p}");
    }

    #[test]
    fn pseudo_peripheral_on_grid() {
        let g = Graph::from_matrix(&laplacian_2d(7, 7));
        let p = g.pseudo_peripheral(24); // center
        // corners are the peripheral nodes of a square grid
        let corners = [0, 6, 42, 48];
        assert!(corners.contains(&p), "got {p}");
    }

    #[test]
    fn components_split() {
        // two disjoint edges
        let mut coo = crate::sparse::Coo::square(4);
        coo.push_sym(0, 1, -1.0);
        coo.push_sym(2, 3, -1.0);
        for i in 0..4 {
            coo.push(i, i, 2.0);
        }
        let g = Graph::from_matrix(&coo.to_csr());
        let (comp, count) = g.components();
        assert_eq!(count, 2);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[2], comp[3]);
        assert_ne!(comp[0], comp[2]);
    }

    #[test]
    fn subgraph_maps_ids() {
        let g = path5();
        let (sub, map) = g.subgraph(&[1, 2, 3]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.num_edges(), 2);
        assert_eq!(sub.neighbors(1), &[0, 2]); // node 2 in original
        assert_eq!(map, vec![1, 2, 3]);
    }

    #[test]
    fn level_structure_partitions_nodes() {
        let g = Graph::from_matrix(&laplacian_2d(5, 5));
        let levels = g.level_structure(0);
        let total: usize = levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, 25);
        assert_eq!(levels[0], vec![0]);
    }
}
