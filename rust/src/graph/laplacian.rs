//! Graph Laplacian construction and a Lanczos eigensolver for the Fiedler
//! vector (second-smallest eigenvector), the basis of the spectral ordering
//! baseline and the reference for the network's spectral embedding.

use crate::graph::adjacency::Graph;
use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// Combinatorial Laplacian L = D − A of a graph (unit edge weights).
pub fn laplacian(g: &Graph) -> Csr {
    let n = g.n();
    let mut coo = Coo::square(n);
    for u in 0..n {
        let deg = g.degree(u) as f64;
        coo.push(u, u, deg);
        for &v in g.neighbors(u) {
            coo.push(u, v, -1.0);
        }
    }
    coo.to_csr()
}

/// Normalized Laplacian L̂ = I − D^{-1/2} A D^{-1/2}.
pub fn normalized_laplacian(g: &Graph) -> Csr {
    let n = g.n();
    let dinv_sqrt: Vec<f64> = (0..n)
        .map(|u| {
            let d = g.degree(u) as f64;
            if d > 0.0 {
                1.0 / d.sqrt()
            } else {
                0.0
            }
        })
        .collect();
    let mut coo = Coo::square(n);
    for u in 0..n {
        coo.push(u, u, 1.0);
        for &v in g.neighbors(u) {
            coo.push(u, v, -dinv_sqrt[u] * dinv_sqrt[v]);
        }
    }
    coo.to_csr()
}

/// Fiedler vector via Lanczos iteration on the Laplacian, deflating the
/// constant vector (the known nullspace for a connected graph).
///
/// Returns the approximate second-smallest eigenvector. Deterministic for a
/// given seed. `iters` Lanczos steps with full reorthogonalization — at the
/// few-thousand-node scale this is exact enough for ordering purposes.
pub fn fiedler_vector(g: &Graph, iters: usize, seed: u64) -> Vec<f64> {
    let n = g.n();
    assert!(n >= 2);
    let lap = laplacian(g);
    let m = iters.min(n - 1).max(2);

    // Lanczos on L with starting vector orthogonal to 1.
    let mut rng = Pcg64::new(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    project_out_constant(&mut v);
    normalize(&mut v);

    let mut vs: Vec<Vec<f64>> = vec![v.clone()];
    let mut alphas: Vec<f64> = Vec::with_capacity(m);
    let mut betas: Vec<f64> = Vec::with_capacity(m);

    let mut w_prev: Option<Vec<f64>> = None;
    for j in 0..m {
        let mut w = lap.matvec(&vs[j]);
        let alpha = dot(&w, &vs[j]);
        alphas.push(alpha);
        for (wi, vi) in w.iter_mut().zip(&vs[j]) {
            *wi -= alpha * vi;
        }
        if let Some(prev) = &w_prev {
            let beta_prev = *betas.last().unwrap();
            for (wi, pi) in w.iter_mut().zip(prev) {
                *wi -= beta_prev * pi;
            }
        }
        // full reorthogonalization (stability over speed; n is small)
        project_out_constant(&mut w);
        for vk in &vs {
            let c = dot(&w, vk);
            for (wi, vi) in w.iter_mut().zip(vk) {
                *wi -= c * vi;
            }
        }
        let beta = norm(&w);
        if beta < 1e-12 {
            break;
        }
        betas.push(beta);
        for wi in w.iter_mut() {
            *wi /= beta;
        }
        w_prev = Some(vs[j].clone());
        vs.push(w);
        if vs.len() > m {
            break;
        }
    }

    // smallest eigenpair of the tridiagonal (alphas, betas) via dense
    // symmetric QL-free approach: build dense tridiag and use Jacobi.
    let k = alphas.len();
    let mut t = vec![0.0f64; k * k];
    for i in 0..k {
        t[i * k + i] = alphas[i];
        if i + 1 < k && i < betas.len() {
            t[i * k + i + 1] = betas[i];
            t[(i + 1) * k + i] = betas[i];
        }
    }
    let (evals, evecs) = jacobi_eigen(&mut t, k);
    // smallest eigenvalue of L restricted to 1⊥ ≈ λ₂
    let mut idx: Vec<usize> = (0..k).collect();
    idx.sort_by(|&a, &b| evals[a].partial_cmp(&evals[b]).unwrap());
    let target = idx[0];

    // Ritz vector = V · y
    let mut fied = vec![0.0f64; n];
    for (j, vj) in vs.iter().take(k).enumerate() {
        let y = evecs[j * k + target];
        for (fi, vi) in fied.iter_mut().zip(vj) {
            *fi += y * vi;
        }
    }
    project_out_constant(&mut fied);
    normalize(&mut fied);
    fied
}

/// Rayleigh quotient vᵀLv / vᵀv for testing convergence.
pub fn rayleigh(lap: &Csr, v: &[f64]) -> f64 {
    let lv = lap.matvec(v);
    dot(v, &lv) / dot(v, v).max(1e-300)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn normalize(a: &mut [f64]) {
    let nm = norm(a);
    if nm > 1e-300 {
        for x in a.iter_mut() {
            *x /= nm;
        }
    }
}

fn project_out_constant(a: &mut [f64]) {
    let mean = a.iter().sum::<f64>() / a.len() as f64;
    for x in a.iter_mut() {
        *x -= mean;
    }
}

/// Cyclic Jacobi eigen-decomposition for small dense symmetric matrices
/// (row-major `t`, size k). Returns (eigenvalues, eigenvectors column-major
/// in a row-major buffer: evecs[i*k + j] = component i of eigenvector j).
pub fn jacobi_eigen(t: &mut [f64], k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut v = vec![0.0f64; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..k {
            for j in (i + 1)..k {
                off += t[i * k + j] * t[i * k + j];
            }
        }
        if off < 1e-24 {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = t[p * k + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = t[p * k + p];
                let aqq = t[q * k + q];
                let theta = (aqq - app) / (2.0 * apq);
                let sign = if theta >= 0.0 { 1.0 } else { -1.0 };
                let tt = sign / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (tt * tt + 1.0).sqrt();
                let s = tt * c;
                // rotate rows/cols p,q of t
                for i in 0..k {
                    let tip = t[i * k + p];
                    let tiq = t[i * k + q];
                    t[i * k + p] = c * tip - s * tiq;
                    t[i * k + q] = s * tip + c * tiq;
                }
                for i in 0..k {
                    let tpi = t[p * k + i];
                    let tqi = t[q * k + i];
                    t[p * k + i] = c * tpi - s * tqi;
                    t[q * k + i] = s * tpi + c * tqi;
                }
                for i in 0..k {
                    let vip = v[i * k + p];
                    let viq = v[i * k + q];
                    v[i * k + p] = c * vip - s * viq;
                    v[i * k + q] = s * vip + c * viq;
                }
            }
        }
    }
    let evals: Vec<f64> = (0..k).map(|i| t[i * k + i]).collect();
    (evals, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::graph::adjacency::Graph;

    fn path_graph(n: usize) -> Graph {
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 2.0);
        }
        Graph::from_matrix(&coo.to_csr())
    }

    #[test]
    fn laplacian_rows_sum_zero() {
        let g = path_graph(6);
        let lap = laplacian(&g);
        for r in 0..6 {
            let (_, vals) = lap.row(r);
            assert!((vals.iter().sum::<f64>()).abs() < 1e-12);
        }
    }

    #[test]
    fn jacobi_on_2x2() {
        let mut t = vec![2.0, 1.0, 1.0, 2.0];
        let (evals, _) = jacobi_eigen(&mut t, 2);
        let mut e = evals.clone();
        e.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((e[0] - 1.0).abs() < 1e-10);
        assert!((e[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn fiedler_of_path_is_monotone() {
        // The Fiedler vector of a path graph is a (co)sine ramp — strictly
        // monotone along the path, so sorting by it recovers the path order.
        let g = path_graph(20);
        let f = fiedler_vector(&g, 15, 1);
        let increasing = f.windows(2).all(|w| w[0] < w[1]);
        let decreasing = f.windows(2).all(|w| w[0] > w[1]);
        assert!(increasing || decreasing, "fiedler not monotone: {f:?}");
    }

    #[test]
    fn fiedler_rayleigh_close_to_lambda2() {
        // For a path P_n, λ₂ = 2(1 − cos(π/n)).
        let n = 16;
        let g = path_graph(n);
        let lap = laplacian(&g);
        let f = fiedler_vector(&g, 14, 2);
        let lam2 = 2.0 * (1.0 - (std::f64::consts::PI / n as f64).cos());
        let rq = rayleigh(&lap, &f);
        assert!(
            (rq - lam2).abs() < 0.05 * lam2 + 1e-9,
            "rayleigh {rq} vs λ₂ {lam2}"
        );
    }

    #[test]
    fn fiedler_separates_grid() {
        // On a 2:1 rectangle the Fiedler vector splits the long axis:
        // columns 0..nx/2 mostly one sign, the rest the other.
        let a = laplacian_2d(16, 8);
        let g = Graph::from_matrix(&a);
        let f = fiedler_vector(&g, 30, 3);
        let left: f64 = (0..8).map(|x| (0..8).map(|y| f[y * 16 + x]).sum::<f64>()).sum();
        let right: f64 =
            (8..16).map(|x| (0..8).map(|y| f[y * 16 + x]).sum::<f64>()).sum();
        assert!(left * right < 0.0, "halves not separated: {left} vs {right}");
    }

    #[test]
    fn normalized_laplacian_diag_is_one() {
        let g = path_graph(5);
        let nl = normalized_laplacian(&g);
        for i in 0..5 {
            assert!((nl.get(i, i) - 1.0).abs() < 1e-12);
        }
    }
}
