//! Graph substrate: adjacency views, traversals, Laplacians, the Lanczos
//! Fiedler solver, and multilevel coarsening. Everything the ordering
//! algorithms and the spectral baseline need.

pub mod adjacency;
pub mod coarsen;
pub mod laplacian;

pub use adjacency::Graph;
pub use laplacian::{fiedler_vector, laplacian, normalized_laplacian};
