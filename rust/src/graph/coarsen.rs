//! Multilevel graph coarsening via heavy-edge matching.
//!
//! Two consumers: the METIS-like nested-dissection ordering (coarsen →
//! bisect → refine) and the harness that mirrors the paper's multigrid
//! encoder structure on the Rust side. The matching is the Graclus-style
//! greedy heavy-edge rule: visit nodes in random order, match each
//! unmatched node with its heaviest unmatched neighbour.

use crate::graph::adjacency::Graph;
use crate::util::rng::Pcg64;

/// One coarsening step: mapping fine→coarse plus the coarse graph.
#[derive(Clone, Debug)]
pub struct CoarseLevel {
    pub graph: Graph,
    /// fine node id → coarse node id
    pub fine_to_coarse: Vec<usize>,
}

/// Greedy heavy-edge matching; returns fine→coarse map and coarse node
/// count. Unmatched nodes map alone.
pub fn heavy_edge_matching(g: &Graph, rng: &mut Pcg64) -> (Vec<usize>, usize) {
    let n = g.n();
    let mut matched = vec![usize::MAX; n];
    let order = rng.permutation(n);
    let mut coarse = 0usize;
    for &u in &order {
        if matched[u] != usize::MAX {
            continue;
        }
        // heaviest unmatched neighbour
        let mut best: Option<(usize, f64)> = None;
        for (&v, &w) in g.neighbors(u).iter().zip(g.edge_weights(u)) {
            if v != u && matched[v] == usize::MAX {
                match best {
                    Some((_, bw)) if bw >= w => {}
                    _ => best = Some((v, w)),
                }
            }
        }
        match best {
            Some((v, _)) => {
                matched[u] = coarse;
                matched[v] = coarse;
            }
            None => {
                matched[u] = coarse;
            }
        }
        coarse += 1;
    }
    (matched, coarse)
}

/// Contract a graph along a fine→coarse map.
pub fn contract(g: &Graph, fine_to_coarse: &[usize], coarse_n: usize) -> Graph {
    let mut vweights = vec![0.0f64; coarse_n];
    for u in 0..g.n() {
        vweights[fine_to_coarse[u]] += g.vweight(u);
    }
    // accumulate coarse edges in per-node maps
    let mut maps: Vec<std::collections::BTreeMap<usize, f64>> =
        vec![std::collections::BTreeMap::new(); coarse_n];
    for u in 0..g.n() {
        let cu = fine_to_coarse[u];
        for (&v, &w) in g.neighbors(u).iter().zip(g.edge_weights(u)) {
            let cv = fine_to_coarse[v];
            if cu != cv {
                *maps[cu].entry(cv).or_insert(0.0) += w;
            }
        }
    }
    let mut xadj = vec![0usize; coarse_n + 1];
    let mut adjncy = Vec::new();
    let mut eweights = Vec::new();
    for (cu, m) in maps.iter().enumerate() {
        for (&cv, &w) in m {
            adjncy.push(cv);
            eweights.push(w);
        }
        xadj[cu + 1] = adjncy.len();
    }
    Graph::from_parts(xadj, adjncy, eweights, vweights)
}

/// Coarsen until ≤ `target_n` nodes or no further contraction possible.
/// Returns the hierarchy from fine (index 0 = first coarse level) to
/// coarsest.
pub fn coarsen_to(g: &Graph, target_n: usize, rng: &mut Pcg64) -> Vec<CoarseLevel> {
    let mut levels: Vec<CoarseLevel> = Vec::new();
    let mut current = g.clone();
    while current.n() > target_n {
        let (map, coarse_n) = heavy_edge_matching(&current, rng);
        if coarse_n >= current.n() {
            break; // no contraction achieved (e.g. no edges)
        }
        let coarse = contract(&current, &map, coarse_n);
        levels.push(CoarseLevel { graph: coarse.clone(), fine_to_coarse: map });
        current = coarse;
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::graph::adjacency::Graph;

    #[test]
    fn matching_halves_node_count() {
        let g = Graph::from_matrix(&laplacian_2d(8, 8));
        let mut rng = Pcg64::new(1);
        let (map, coarse_n) = heavy_edge_matching(&g, &mut rng);
        assert!(coarse_n >= 32 && coarse_n < 64, "coarse_n={coarse_n}");
        assert!(map.iter().all(|&c| c < coarse_n));
        // each coarse node has 1 or 2 fine nodes
        let mut counts = vec![0usize; coarse_n];
        for &c in &map {
            counts[c] += 1;
        }
        assert!(counts.iter().all(|&c| (1..=2).contains(&c)));
    }

    #[test]
    fn contract_preserves_total_vweight() {
        let g = Graph::from_matrix(&laplacian_2d(6, 6));
        let mut rng = Pcg64::new(2);
        let (map, coarse_n) = heavy_edge_matching(&g, &mut rng);
        let c = contract(&g, &map, coarse_n);
        assert!((c.total_vweight() - g.total_vweight()).abs() < 1e-12);
        // coarse graph symmetric: u in N(v) iff v in N(u)
        for u in 0..c.n() {
            for &v in c.neighbors(u) {
                assert!(c.neighbors(v).contains(&u));
            }
        }
    }

    #[test]
    fn coarsen_reaches_target() {
        let g = Graph::from_matrix(&laplacian_2d(16, 16));
        let mut rng = Pcg64::new(3);
        let levels = coarsen_to(&g, 10, &mut rng);
        assert!(!levels.is_empty());
        assert!(levels.last().unwrap().graph.n() <= 16, "didn't coarsen enough");
        // strictly decreasing sizes
        let mut prev = g.n();
        for l in &levels {
            assert!(l.graph.n() < prev);
            prev = l.graph.n();
        }
    }

    #[test]
    fn coarsen_handles_edgeless_graph() {
        // isolated nodes: matching can't contract; must terminate
        let mut coo = crate::sparse::Coo::square(5);
        for i in 0..5 {
            coo.push(i, i, 1.0);
        }
        let g = Graph::from_matrix(&coo.to_csr());
        let mut rng = Pcg64::new(4);
        let levels = coarsen_to(&g, 2, &mut rng);
        assert!(levels.is_empty());
    }
}
