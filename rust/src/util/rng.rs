//! Deterministic pseudo-random number generation.
//!
//! The image's crate cache has no `rand` crate, so we implement the small
//! slice of functionality the workload generators and tests need:
//! [`Pcg64`], a PCG-XSL-RR 128/64 generator (the same algorithm behind
//! `rand_pcg::Pcg64`), seeded deterministically so every experiment in
//! EXPERIMENTS.md is reproducible bit-for-bit.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
        };
        rng.state = rng
            .inc
            .wrapping_add(seed as u128 ^ ((seed as u128) << 64));
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via Lemire multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "next_below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (two uniforms per call, no caching so
    /// the stream position stays predictable).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n), order randomized.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniformish() {
        let mut r = Pcg64::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.next_below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Pcg64::new(6);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Pcg64::new(8);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }
}
