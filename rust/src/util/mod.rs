//! Self-contained utility substrate: deterministic RNG, timing/benchmark
//! helpers, a mini property-testing harness, and a JSON writer. These stand
//! in for `rand`, `criterion`, `proptest`, and `serde_json`, which are not
//! available in the offline crate set.

pub mod check;
pub mod json;
pub mod rng;
pub mod sync;
pub mod timer;
