//! Poison-tolerant synchronization helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking thread into a cascade: the
//! panic poisons the mutex, every other holder's `unwrap` then panics too,
//! and a whole worker pool (or the dispatcher) dies from a single fault.
//! The data guarded by the coordinator's mutexes is either plain counters
//! (metrics) or a channel receiver — both remain valid after an
//! interrupted critical section — so recovering the guard is always the
//! right call here.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// What `std::thread::available_parallelism` reports, defaulting to 1 when
/// the platform can't say (the documented failure mode for restricted
/// environments — a safe, sequential default).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Clamp a requested worker count to what the machine can actually run:
/// at least 1, at most [`available_parallelism`]. Every pool constructor
/// goes through this so a config asking for 64 threads on a 4-core box
/// spawns 4 workers instead of oversubscribing — and metrics report the
/// clamped (*effective*) value, not the request.
pub fn effective_threads(requested: usize) -> usize {
    requested.max(1).min(available_parallelism())
}

/// Clamp an *outer* worker count whose workers each run `inner`-way
/// parallel work inside (probe pool × parallel factorization): the
/// product `outer × inner` must not exceed the machine, so the outer
/// count is capped at `available_parallelism / inner` (≥ 1).
pub fn composed_threads(outer: usize, inner: usize) -> usize {
    let budget = (available_parallelism() / inner.max(1)).max(1);
    outer.max(1).min(budget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn thread_clamps_are_bounded_and_monotone() {
        let avail = available_parallelism();
        assert!(avail >= 1);
        assert_eq!(effective_threads(0), 1);
        assert_eq!(effective_threads(1), 1);
        assert_eq!(effective_threads(usize::MAX), avail);
        for req in 1..=2 * avail {
            let eff = effective_threads(req);
            assert!(eff >= 1 && eff <= avail && eff <= req);
        }
        // composition: outer × inner never exceeds the machine (except the
        // guaranteed minimum of one outer worker)
        for outer in 1..=2 * avail {
            for inner in 1..=2 * avail {
                let eff = composed_threads(outer, inner);
                assert!(eff >= 1 && eff <= outer);
                assert!(eff == 1 || eff * inner <= avail);
            }
        }
        // inner = 1 degenerates to the plain clamp
        assert_eq!(composed_threads(usize::MAX, 1), avail);
    }

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned(), "panic while holding the lock must poison");
        // plain lock().unwrap() would panic here; the helper recovers
        let mut g = lock_unpoisoned(&m);
        *g += 1;
        assert_eq!(*g, 8);
    }
}
