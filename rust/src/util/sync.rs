//! Poison-tolerant synchronization helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking thread into a cascade: the
//! panic poisons the mutex, every other holder's `unwrap` then panics too,
//! and a whole worker pool (or the dispatcher) dies from a single fault.
//! The data guarded by the coordinator's mutexes is either plain counters
//! (metrics) or a channel receiver — both remain valid after an
//! interrupted critical section — so recovering the guard is always the
//! right call here.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned(), "panic while holding the lock must poison");
        // plain lock().unwrap() would panic here; the helper recovers
        let mut g = lock_unpoisoned(&m);
        *g += 1;
        assert_eq!(*g, 8);
    }
}
