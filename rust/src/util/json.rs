//! Minimal JSON writer (no `serde` in the offline crate set).
//!
//! Only what the metrics endpoints and experiment emitters need: objects,
//! arrays, strings, numbers, booleans. Output is deterministic (insertion
//! order preserved).

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert a field (object values only; panics otherwise).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .set("name", "pfm")
            .set("n", 128usize)
            .set("ok", true)
            .set("ratio", 1.5f64)
            .set("tags", vec!["a", "b"]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"pfm","n":128,"ok":true,"ratio":1.5,"tags":["a","b"]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
