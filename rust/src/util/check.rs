//! Mini property-testing harness.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! subset the test suite needs: run a property over many deterministic
//! random cases and, on failure, report the seed that reproduces it.

use crate::util::rng::Pcg64;

/// Run `prop` over `cases` deterministic random instances. `prop` gets a
/// fresh RNG per case; return `Err(msg)` to fail. Panics with the failing
/// case's seed so `forall_seeded(seed..seed+1, ..)` reproduces it.
pub fn forall(cases: u64, prop: impl Fn(&mut Pcg64) -> Result<(), String>) {
    forall_seeded(0..cases, prop)
}

/// Same as [`forall`] but over an explicit seed range (for reproducing).
pub fn forall_seeded(
    seeds: std::ops::Range<u64>,
    prop: impl Fn(&mut Pcg64) -> Result<(), String>,
) {
    for seed in seeds {
        let mut rng = Pcg64::new(0x9e37_79b9 ^ seed.wrapping_mul(0x85eb_ca6b));
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case seed={seed}: {msg}");
        }
    }
}

/// Assert two floats are close in absolute + relative terms.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9)
    };
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol): (f64, f64, f64) = ($a as f64, $b as f64, $tol as f64);
        let scale = 1.0_f64.max(a.abs()).max(b.abs());
        assert!(
            (a - b).abs() <= tol * scale,
            "assert_close failed: {} vs {} (tol {}, scale {})",
            a,
            b,
            tol,
            scale
        );
    }};
}

/// Assert that a slice of floats matches another within tolerance.
pub fn assert_vec_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "assert_vec_close failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Validate that `perm` is a permutation of 0..n. Returns an error message
/// describing the violation if not.
pub fn check_permutation(perm: &[usize]) -> Result<(), String> {
    let n = perm.len();
    let mut seen = vec![false; n];
    for (pos, &p) in perm.iter().enumerate() {
        if p >= n {
            return Err(format!("perm[{pos}]={p} out of range (n={n})"));
        }
        if seen[p] {
            return Err(format!("perm value {p} duplicated (second at pos {pos})"));
        }
        seen[p] = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(25, |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(5, |rng| {
            if rng.next_f64() < 2.0 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_macro() {
        assert_close!(1.0, 1.0 + 1e-12);
        assert_close!(1e9, 1e9 * (1.0 + 1e-12));
    }

    #[test]
    fn permutation_check() {
        assert!(check_permutation(&[2, 0, 1]).is_ok());
        assert!(check_permutation(&[0, 0, 1]).is_err());
        assert!(check_permutation(&[0, 3, 1]).is_err());
    }
}
