//! Wall-clock timing helpers and a small statistics toolkit used by the
//! benchmark harness (no `criterion` in the offline crate set, so the
//! benches under `rust/benches/` are hand-rolled on top of this module).

use std::time::{Duration, Instant};

/// Time a closure once, returning (result, elapsed seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Summary statistics over repeated timings.
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            max: xs[n - 1],
            median: xs[n / 2],
            p95: xs[((n as f64 * 0.95) as usize).min(n - 1)],
        }
    }
}

/// Benchmark runner: warms up, then measures `iters` runs of `f`.
pub struct Bench {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup: 2, iters: 10 }
    }

    pub fn warmup(mut self, w: usize) -> Self {
        self.warmup = w;
        self
    }

    pub fn iters(mut self, i: usize) -> Self {
        self.iters = i;
        self
    }

    /// Run the benchmark, printing a criterion-style one-line summary and
    /// returning the stats.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Stats::from_samples(samples);
        println!(
            "{:<44} time: [{} {} {}]  (n={})",
            self.name,
            fmt_duration(s.min),
            fmt_duration(s.median),
            fmt_duration(s.max),
            s.n
        );
        s
    }
}

/// Human format for a duration in seconds.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// A simple deadline helper for bounded loops in services/tests.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    end: Instant,
}

impl Deadline {
    pub fn in_duration(d: Duration) -> Deadline {
        Deadline { end: Instant::now() + d }
    }

    pub fn expired(&self) -> bool {
        Instant::now() >= self.end
    }

    pub fn remaining(&self) -> Duration {
        self.end.saturating_duration_since(Instant::now())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(2.5e-3).ends_with(" ms"));
        assert!(fmt_duration(2.5e-6).ends_with(" µs"));
        assert!(fmt_duration(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn deadline_expires() {
        let d = Deadline::in_duration(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(d.expired());
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
