//! The ADMM outer loop (paper Algorithm 1, instance-wise) and the
//! sampled-subgradient refinement pass.
//!
//! Per outer iteration, mirroring the build-time Python trainer
//! (`python/compile/train.py`) with the node scores themselves as the
//! optimization variable (no network — this is the *native, per-instance*
//! optimizer the serving path runs):
//!
//! 1. **L-update** — `l_steps` norm-clipped gradient steps on the smooth
//!    part of Eq. 12, then the proximal operator of the ‖L‖₁ term
//!    (soft-threshold) composed with the tril projection;
//! 2. **score-update** — gradient steps on the smooth part through the
//!    Sinkhorn-normalized soft permutation (backprop in `perm`),
//!    re-standardized after each step (projection onto the scale-invariant
//!    manifold);
//! 3. **Γ-update** — dual ascent on the factorization constraint.
//!
//! Every outer iteration ends with an **acceptance test on the discrete
//! golden criterion** (`objective::OrderObjective`): the hard argsort of
//! the current scores is evaluated and kept only if it improves on the
//! best-so-far. The reported trace is therefore non-increasing by
//! construction, and the optimizer can never return an ordering worse
//! than its init — the property the serving path and the ablation tests
//! rely on.
//!
//! When `AdmmParams::adaptive_rho` is set (the `OptBudget::adaptive_rho`
//! flag), the penalty follows the standard residual-balancing update
//! (Boyd et al. §3.4.1, μ=10, τ=2): after each dual ascent, ρ doubles when
//! the primal residual ‖R‖ dominates the dual residual ρ‖Δ(LLᵀ)‖ by more
//! than μ× and halves in the mirrored case, clamped to [1e-4, 1e4]. The
//! unscaled dual Γ is kept as-is across ρ changes. Acceptance is untouched,
//! so the trace stays non-increasing either way — adaptation can only
//! change *which* score iterates get proposed, never let a worse ordering
//! through. The paper's fixed ρ=1 stalls dual convergence on badly scaled
//! windows (a max-normalized window with one dominant node crushes the
//! gradient signal to ~‖A‖/amax); growing ρ restores it.
//!
//! [`refine`] is the large-n workhorse: per step, a *batch* of candidates
//! is generated from the current state — [`PROBES_PER_STEP`] two-sided
//! SPSA probe pairs of the discrete objective, or as many rank-space
//! segment moves (reverse / relocate a window of the current ordering) —
//! and evaluated in parallel by [`ProbePool`], then reduced in
//! probe-index order under the same strict-acceptance rule (see
//! `pfm::probes` for the determinism argument). The averaged multi-probe
//! SPSA estimate has lower variance than PR 4's single-direction probe,
//! so the parallel width buys quality as well as wall clock. Each probe
//! needs only sparse symbolic work, so cost scales with nnz(L) rather
//! than n² and the pass keeps working far above the dense-window cap.

use std::time::Instant;

use crate::factor::FactorKind;
use crate::order::order_from_scores;
use crate::pfm::objective::{
    best_exact, conjugate, residual, residual_from, smooth_grad_l, smooth_grad_p,
    smooth_grad_upstream, smooth_value, DenseWindow, OrderObjective,
};
use crate::pfm::perm::{rank_scores, standardize, SoftPerm};
use crate::pfm::probes::{ProbePool, PROBES_PER_STEP};
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Clamp range of the adaptive penalty parameter.
const RHO_MIN: f64 = 1e-4;
const RHO_MAX: f64 = 1e4;

/// ADMM + proximal-gradient hyperparameters (defaults mirror the Python
/// trainer where the two share a knob).
#[derive(Clone, Debug)]
pub struct AdmmParams {
    /// penalty parameter ρ (paper: 1)
    pub rho: f64,
    /// kernel width of the soft permutation
    pub sigma: f64,
    /// Sinkhorn normalization rounds
    pub sinkhorn_iters: usize,
    /// gradient steps per L-update
    pub l_steps: usize,
    /// L-update step size
    pub l_lr: f64,
    /// gradient-norm clip (both subproblems)
    pub clip: f64,
    /// soft-threshold level of the ‖L‖₁ prox
    pub prox_eta: f64,
    /// score-update step size
    pub y_lr: f64,
    /// gradient steps per score-update
    pub y_steps: usize,
    /// scale of the random tril initialization of L
    pub l_init_scale: f64,
    /// residual-balancing ρ adaptation (off = the paper's fixed ρ)
    pub adaptive_rho: bool,
    /// residual-imbalance trigger μ of the adaptive update
    pub adapt_mu: f64,
    /// multiplicative ρ step τ of the adaptive update
    pub adapt_tau: f64,
}

impl Default for AdmmParams {
    fn default() -> Self {
        AdmmParams {
            rho: 1.0,
            sigma: 0.15,
            sinkhorn_iters: 8,
            l_steps: 8,
            l_lr: 0.05,
            clip: 10.0,
            prox_eta: 5e-4,
            y_lr: 0.15,
            y_steps: 2,
            l_init_scale: 0.1,
            adaptive_rho: false,
            adapt_mu: 10.0,
            adapt_tau: 2.0,
        }
    }
}

/// Outcome of an ADMM run (or a refinement pass extends the same fields).
pub struct AdmmOutcome {
    /// best scores found (standardized; argsort = returned ordering)
    pub y: Vec<f64>,
    /// discrete objective of `argsort(y)`
    pub objective: f64,
    /// outer iterations actually run (≤ budget; deadline may cut in)
    pub outer_iters: usize,
    /// augmented-Lagrangian value per outer iteration (diagnostic)
    pub aug_lagrangian: Vec<f64>,
    /// penalty parameter after the last iteration (= `params.rho` unless
    /// the adaptive update fired)
    pub rho_final: f64,
}

fn clip_norm(g: &mut [f64], clip: f64) {
    let norm = g.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > clip {
        let s = clip / norm;
        for v in g.iter_mut() {
            *v *= s;
        }
    }
}

fn soft_threshold_tril(l: &mut [f64], n: usize, eta: f64) {
    for i in 0..n {
        for j in 0..n {
            let v = &mut l[i * n + j];
            *v = if j > i {
                0.0
            } else {
                v.signum() * (v.abs() - eta).max(0.0)
            };
        }
    }
}

/// Run the ADMM outer loop on the dense window of `win_src`, accepting on
/// the discrete objective `obj` (which may evaluate a different matrix —
/// the multilevel path optimizes a coarse window against the coarse
/// objective; the unsymmetric path optimizes the symmetrized window
/// against the true LU objective).
///
/// `y` must be standardized; `best_f` is the objective of `argsort(y0)`
/// (the caller has evaluated the init). `trace` gets the best-so-far
/// objective appended once per outer iteration.
#[allow(clippy::too_many_arguments)]
pub fn admm_optimize(
    win: &DenseWindow,
    obj: &mut OrderObjective,
    y0: &[f64],
    best_f: f64,
    params: &AdmmParams,
    outer: usize,
    deadline: Option<Instant>,
    rng: &mut Pcg64,
    trace: &mut Vec<f64>,
) -> AdmmOutcome {
    let n = win.n;
    assert_eq!(y0.len(), n);
    let mut y = y0.to_vec();
    let mut best_y = y.clone();
    let mut best_f = best_f;
    let mut rho = params.rho;

    // L = tril(randn)·scale, Γ = 0 (trainer lines 6-7)
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            l[i * n + j] = params.l_init_scale * rng.next_gaussian();
        }
    }
    let mut gamma = vec![0.0f64; n * n];
    let mut aug = Vec::with_capacity(outer);
    let mut iters = 0usize;
    let mut prev_llt: Option<Vec<f64>> = None;

    // carried across the iteration boundary: the dual-ascent refresh below
    // is also the next L-update's permutation (y unchanged in between)
    let mut sp = SoftPerm::forward(&y, params.sigma, params.sinkhorn_iters);
    for _ in 0..outer {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            break;
        }
        iters += 1;

        // --- L-update: projected clipped gradient steps on the smooth
        // part, then the ‖·‖₁ prox. P is fixed here, so the O(n³) P A Pᵀ
        // is hoisted out of the step loop; the gradient is projected onto
        // the tril constraint set every step so the norm clip and the
        // descent direction see exactly the matrix the residual scores ---
        let a_theta = conjugate(&sp.p, &win.a, n);
        for _ in 0..params.l_steps {
            let r = residual_from(&a_theta, &l, n);
            let g = smooth_grad_upstream(&r, &gamma, rho);
            let mut gl = smooth_grad_l(&g, &l, n);
            for i in 0..n {
                for gv in &mut gl[i * n + i + 1..(i + 1) * n] {
                    *gv = 0.0;
                }
            }
            clip_norm(&mut gl, params.clip);
            for (lv, gv) in l.iter_mut().zip(&gl) {
                *lv -= params.l_lr * gv;
            }
        }
        soft_threshold_tril(&mut l, n, params.prox_eta);

        // --- score-update: smooth gradient through the Sinkhorn chain
        // (the first step reuses the carried forward pass — y unchanged) ---
        for step in 0..params.y_steps {
            if step > 0 {
                sp = SoftPerm::forward(&y, params.sigma, params.sinkhorn_iters);
            }
            let r = residual(&sp.p, &win.a, &l, n);
            let g = smooth_grad_upstream(&r, &gamma, rho);
            let gp = smooth_grad_p(&g, &sp.p, &win.a, n);
            let mut dy = sp.backprop(&gp);
            clip_norm(&mut dy, params.clip);
            for (yv, gv) in y.iter_mut().zip(&dy) {
                *yv -= params.y_lr * gv;
            }
            standardize(&mut y);
        }

        // --- dual ascent with the refreshed permutation ---
        sp = SoftPerm::forward(&y, params.sigma, params.sinkhorn_iters);
        let r = residual(&sp.p, &win.a, &l, n);
        for (gm, rv) in gamma.iter_mut().zip(&r) {
            *gm += rho * rv;
        }
        let l1: f64 = l.iter().map(|v| v.abs()).sum();
        aug.push(l1 + smooth_value(&r, &gamma, rho));

        // --- residual-balancing ρ update (Γ is the unscaled dual, so it
        // carries over a ρ change unchanged) ---
        if params.adaptive_rho {
            let cur = llt(&l, n);
            let r_norm = frob(&r);
            if let Some(prev) = &prev_llt {
                let s_norm = rho * dist(&cur, prev);
                if r_norm > params.adapt_mu * s_norm {
                    rho = (rho * params.adapt_tau).min(RHO_MAX);
                } else if s_norm > params.adapt_mu * r_norm {
                    rho = (rho / params.adapt_tau).max(RHO_MIN);
                }
            }
            prev_llt = Some(cur);
        }

        // --- acceptance on the discrete golden criterion (exact sources
        // only: a failed LU's structural bound must not displace the
        // incumbent — the incumbent's value may itself be numeric) ---
        let order = order_from_scores(&y);
        let f = obj.eval_sourced(&order);
        if f.is_exact() && f.value < best_f {
            best_f = f.value;
            best_y = y.clone();
        }
        trace.push(best_f);
    }

    AdmmOutcome {
        y: best_y,
        objective: best_f,
        outer_iters: iters,
        aug_lagrangian: aug,
        rho_final: rho,
    }
}

/// `L Lᵀ` over L's lower-triangular support (row-major n×n).
fn llt(l: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..=i.min(j) {
                s += l[i * n + k] * l[j * n + k];
            }
            out[i * n + j] = s;
        }
    }
    out
}

fn frob(m: &[f64]) -> f64 {
    m.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// One bonus refinement step is granted per this many rows of spared
/// symbolic work (`ProbePool::saved_units`), pricing the bonus step as a
/// full-cost segment batch ([`PROBES_PER_STEP`] probes × n rows each).
/// Conservative — an incremental segment batch actually costs a fraction
/// of that — so the bonus steps are strictly paid for by already-banked
/// savings and total analyze-equivalent work can never exceed the
/// nominal budget's.
const ROWS_PER_BONUS_STEP: u64 = PROBES_PER_STEP as u64;

/// Sampled-subgradient refinement: multi-direction SPSA probe batches
/// interleaved with batches of rank-space segment moves, all evaluated by
/// the probe pool and reduced under strict acceptance on the discrete
/// objective of `a` (any permutation-symmetric level matrix — the fine
/// matrix or a V-cycle level). Returns the number of steps run; `y` /
/// `best_f` are updated in place and `trace` gets one best-so-far entry
/// per step.
///
/// Segment-move batches are evaluated against the incumbent ordering via
/// [`ProbePool::eval_orders_with_base`], so candidates sharing a long
/// rank prefix take the incremental suffix re-walk. The rows that splice
/// spares accumulate in the pool's savings ledger, and `refine` converts
/// them into **bonus steps** — up to `steps` extra (≤ 2× the nominal
/// budget), all segment-move shaped (the cheap, incremental-eligible
/// kind). The ledger is a pure function of the candidate orderings, not
/// of timing or of whether incremental evaluation is actually enabled,
/// so the step schedule — and therefore the accepted ordering — is
/// identical at any thread count and in full-vs-incremental A/B runs.
///
/// Every RNG draw happens in the single-threaded generation phase and the
/// batch shape is fixed ([`PROBES_PER_STEP`]), so the result is
/// bit-identical at any pool thread count as long as no wall-clock
/// deadline expires mid-run (see `pfm::probes`). One step
/// costs `2·PROBES_PER_STEP + 1` evaluations (SPSA) or `PROBES_PER_STEP`
/// (segment moves) — wider than PR 4's single-probe step, but the batch
/// runs in parallel and the averaged subgradient is lower-variance.
/// Acceptance scans consider exact evaluation sources only: a failed LU
/// probe's structural bound can never displace the incumbent.
#[allow(clippy::too_many_arguments)]
pub fn refine(
    a: &Csr,
    kind: FactorKind,
    pool: &mut ProbePool,
    y: &mut Vec<f64>,
    best_f: &mut f64,
    steps: usize,
    deadline: Option<Instant>,
    rng: &mut Pcg64,
    trace: &mut Vec<f64>,
) -> usize {
    let n = y.len();
    if n < 4 {
        return 0;
    }
    // the pool may hold a base prepared on a different matrix (a previous
    // V-cycle level); an ordering match alone must never resurrect it
    pool.invalidate_base();
    let saved0 = pool.saved_units();
    let mut eps = 0.35f64;
    let mut run = 0usize;
    let mut bonus = 0usize;
    let mut orders: Vec<Vec<usize>> = Vec::with_capacity(2 * PROBES_PER_STEP);
    let mut step = 0usize;
    while step < steps + bonus {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            break;
        }
        run += 1;
        // bonus steps (step ≥ nominal budget) are always segment-move
        // shaped: the savings that funded them price a full-cost segment
        // batch, and segment moves are what the incremental path serves
        if step < steps && step % 3 < 2 {
            // --- SPSA batch: two-sided probes around the current scores ---
            let mut deltas: Vec<Vec<f64>> = Vec::with_capacity(PROBES_PER_STEP);
            let mut cands: Vec<Vec<f64>> = Vec::with_capacity(2 * PROBES_PER_STEP);
            for _ in 0..PROBES_PER_STEP {
                let delta: Vec<f64> =
                    (0..n).map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 }).collect();
                cands.push(y.iter().zip(&delta).map(|(v, d)| v + eps * d).collect());
                cands.push(y.iter().zip(&delta).map(|(v, d)| v - eps * d).collect());
                deltas.push(delta);
            }
            orders.clear();
            orders.extend(cands.iter().map(|c| order_from_scores(c)));
            let fs = pool.eval_orders(a, kind, &orders, deadline);
            let mut improved = false;
            // best acceptable probe: exact sources only, strict < keeps
            // the lowest index on ties
            if let Some(bi) = best_exact(&fs) {
                if fs[bi].value < *best_f {
                    *best_f = fs[bi].value;
                    *y = cands[bi].clone();
                    standardize(y);
                    improved = true;
                }
            }
            // averaged subgradient over the finite probe pairs (skipped
            // probes are ∞; a fallback bound still carries slope signal
            // for the gradient estimate even though it can't be accepted)
            let mut ghat = vec![0.0f64; n];
            let inv = 1.0 / (2.0 * eps * PROBES_PER_STEP as f64);
            for (k, delta) in deltas.iter().enumerate() {
                let (fp, fm) = (fs[2 * k].value, fs[2 * k + 1].value);
                if !fp.is_finite() || !fm.is_finite() {
                    continue;
                }
                let scale = (fp - fm) * inv;
                for (g, d) in ghat.iter_mut().zip(delta) {
                    *g += scale * d;
                }
            }
            let gn = ghat.iter().map(|v| v * v).sum::<f64>().sqrt();
            if gn > 1e-9 {
                let s = 0.5 / gn;
                let mut cand: Vec<f64> = y.iter().zip(&ghat).map(|(v, g)| v - s * g).collect();
                standardize(&mut cand);
                let gorder = vec![order_from_scores(&cand)];
                let f = pool.eval_orders(a, kind, &gorder, deadline)[0];
                if f.is_exact() && f.value < *best_f {
                    *best_f = f.value;
                    *y = cand;
                    improved = true;
                }
            }
            eps = (eps * if improved { 1.3 } else { 0.85 }).clamp(0.02, 1.0);
        } else {
            // --- segment-move batch: reverse/relocate windows of the
            // current ordering, best-of-batch acceptance ---
            let order = order_from_scores(y);
            orders.clear();
            for _ in 0..PROBES_PER_STEP {
                let len = (2 + rng.next_below((n / 8).max(2))).min(n - 1);
                let s = rng.next_below(n - len);
                let mut cand_order = order.clone();
                if rng.next_f64() < 0.5 {
                    cand_order[s..s + len].reverse();
                } else {
                    let seg: Vec<usize> =
                        cand_order.splice(s..s + len, std::iter::empty()).collect();
                    let at = rng.next_below(cand_order.len() + 1);
                    let tail = cand_order.split_off(at);
                    cand_order.extend(seg);
                    cand_order.extend(tail);
                }
                orders.push(cand_order);
            }
            let fs = pool.eval_orders_with_base(a, kind, &order, &orders, deadline);
            if let Some(bi) = best_exact(&fs) {
                if fs[bi].value < *best_f {
                    *best_f = fs[bi].value;
                    // scores = ranks of the accepted ordering (argsort inverts)
                    *y = rank_scores(&orders[bi]);
                }
            }
        }
        trace.push(*best_f);
        step += 1;
        // convert banked savings into bonus steps, capped at the nominal
        // budget (≤ 2× total). Monotone in the ledger, so the loop bound
        // only ever grows and terminates at the cap.
        bonus = (((pool.saved_units() - saved0) / (ROWS_PER_BONUS_STEP * n as u64)) as usize)
            .min(steps);
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::order::fiedler_order_with;
    use crate::util::check::check_permutation;

    #[test]
    fn grad_p_matches_finite_differences() {
        // close the loop on the one formula perm.rs can't see: d(smooth)/dP
        let n = 6;
        let mut rng = Pcg64::new(9);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_gaussian();
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let p: Vec<f64> = (0..n * n).map(|_| rng.next_f64()).collect();
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                l[i * n + j] = rng.next_gaussian();
            }
        }
        let gamma: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian()).collect();
        let r = residual(&p, &a, &l, n);
        let g = smooth_grad_upstream(&r, &gamma, 1.0);
        let gp = smooth_grad_p(&g, &p, &a, n);
        let eps = 1e-6;
        for e in [(0usize, 0usize), (1, 3), (4, 2), (5, 5), (2, 4)] {
            let (i, j) = e;
            let mut pp = p.clone();
            pp[i * n + j] += eps;
            let mut pm = p.clone();
            pm[i * n + j] -= eps;
            let fp = smooth_value(&residual(&pp, &a, &l, n), &gamma, 1.0);
            let fm = smooth_value(&residual(&pm, &a, &l, n), &gamma, 1.0);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - gp[i * n + j]).abs() < 1e-5 * fd.abs().max(1.0),
                "P[{i}][{j}]: fd {fd} vs analytic {}",
                gp[i * n + j]
            );
        }
    }

    #[test]
    fn admm_trace_is_non_increasing_and_never_worse_than_init() {
        let a = laplacian_2d(9, 7);
        let win = DenseWindow::from_csr(&a);
        let mut obj = OrderObjective::new(&a);
        let y0 = rank_scores(&fiedler_order_with(&a, 60, 1));
        let init_f = obj.eval(&order_from_scores(&y0));
        let mut rng = Pcg64::new(1);
        let mut trace = vec![init_f];
        let out = admm_optimize(
            &win,
            &mut obj,
            &y0,
            init_f,
            &AdmmParams::default(),
            4,
            None,
            &mut rng,
            &mut trace,
        );
        assert_eq!(out.outer_iters, 4);
        assert_eq!(trace.len(), 5);
        for w in trace.windows(2) {
            assert!(w[1] <= w[0], "trace increased: {trace:?}");
        }
        assert!(out.objective <= init_f);
        check_permutation(&order_from_scores(&out.y)).unwrap();
        assert_eq!(out.aug_lagrangian.len(), 4);
        assert_eq!(out.rho_final, 1.0, "fixed-ρ run must not move the penalty");
    }

    #[test]
    fn refine_improves_or_holds_and_respects_deadline() {
        let a = laplacian_2d(10, 10);
        let mut obj = OrderObjective::new(&a);
        let mut pool = ProbePool::new(1);
        let y0 = rank_scores(&fiedler_order_with(&a, 60, 2));
        let init_f = obj.eval(&order_from_scores(&y0));
        let mut y = y0.clone();
        let mut best = init_f;
        let mut rng = Pcg64::new(3);
        let mut trace = vec![init_f];
        let run = refine(
            &a,
            FactorKind::Cholesky,
            &mut pool,
            &mut y,
            &mut best,
            45,
            None,
            &mut rng,
            &mut trace,
        );
        // savings from incremental segment batches may fund bonus steps,
        // but never more than the nominal budget again
        assert!((45..=90).contains(&run), "run={run}");
        assert!(best <= init_f);
        assert!(pool.evals() > 45, "each step evaluates a whole probe batch");
        for w in trace.windows(2) {
            assert!(w[1] <= w[0]);
        }
        // the returned scores argsort to a valid permutation achieving best
        let order = order_from_scores(&y);
        check_permutation(&order).unwrap();
        assert_eq!(obj.eval(&order), best);

        // an already-expired deadline runs zero steps
        let mut y2 = y0;
        let mut b2 = init_f;
        let run2 = refine(
            &a,
            FactorKind::Cholesky,
            &mut pool,
            &mut y2,
            &mut b2,
            50,
            Some(Instant::now()),
            &mut rng,
            &mut trace,
        );
        assert_eq!(run2, 0);
        assert_eq!(b2, init_f);
    }

    #[test]
    fn refine_is_bit_identical_across_thread_counts() {
        // nnz ≈ 3k keeps the batches above the pool's parallel cutoff, so
        // the threaded path is what's being compared
        let a = laplacian_2d(26, 24);
        let y0 = rank_scores(&fiedler_order_with(&a, 60, 4));
        let mut obj = OrderObjective::new(&a);
        let init_f = obj.eval(&order_from_scores(&y0));
        let mut reference: Option<(Vec<usize>, f64, Vec<f64>)> = None;
        for threads in [1usize, 2, 4, 8] {
            let mut pool = ProbePool::new(threads);
            let mut y = y0.clone();
            let mut best = init_f;
            let mut rng = Pcg64::new(17);
            let mut trace = vec![init_f];
            refine(
                &a,
                FactorKind::Cholesky,
                &mut pool,
                &mut y,
                &mut best,
                30,
                None,
                &mut rng,
                &mut trace,
            );
            let got = (order_from_scores(&y), best, trace);
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(&got, want, "threads={threads} diverged"),
            }
        }
    }

    #[test]
    fn refine_trajectory_is_identical_with_incremental_off() {
        // the incremental path must change cost only, never the search:
        // same seed, same budget, incremental on vs off → bit-identical
        // scores, objective, trace, and step count — while the on-run
        // provably performs fewer full symbolic analyses
        let a = laplacian_2d(20, 20);
        let y0 = rank_scores(&fiedler_order_with(&a, 60, 6));
        let mut obj = OrderObjective::new(&a);
        let init_f = obj.eval(&order_from_scores(&y0));
        let mut outs = Vec::new();
        for incremental in [true, false] {
            let mut pool = ProbePool::new(1).with_incremental(incremental);
            let mut y = y0.clone();
            let mut best = init_f;
            let mut rng = Pcg64::new(21);
            let mut trace = vec![init_f];
            let run = refine(
                &a,
                FactorKind::Cholesky,
                &mut pool,
                &mut y,
                &mut best,
                24,
                None,
                &mut rng,
                &mut trace,
            );
            outs.push((order_from_scores(&y), best, trace, run, pool));
        }
        let (on, off) = (&outs[0], &outs[1]);
        assert_eq!(on.0, off.0, "accepted orderings diverged");
        assert_eq!(on.1, off.1);
        assert_eq!(on.2, off.2);
        assert_eq!(on.3, off.3, "step schedules diverged");
        assert_eq!(on.4.saved_units(), off.4.saved_units(), "ledger must be mode-independent");
        assert_eq!(on.4.evals(), off.4.evals());
        assert!(on.4.incremental_evals() > 0, "incremental run never engaged");
        assert_eq!(off.4.incremental_evals(), 0);
        // strictly fewer full analyze-equivalent passes with incremental on
        assert!(
            on.4.full_evals() + on.4.base_prepares() < off.4.full_evals(),
            "full={} prepares={} vs all-full={}",
            on.4.full_evals(),
            on.4.base_prepares(),
            off.4.full_evals()
        );
    }

    #[test]
    fn fallback_lu_bounds_are_never_accepted() {
        // a zero column makes every pivot sequence singular: all probe
        // evaluations come back as structural A+Aᵀ bounds. The old
        // reduction compared those bounds as if they were numeric counts
        // and "improved" on the incumbent; the sourced reduction must
        // hold the line exactly.
        use crate::sparse::Coo;
        let n = 24;
        let mut coo = Coo::square(n);
        for i in 0..n {
            if i != 2 {
                coo.push(i, i, 2.0 + i as f64);
                coo.push(2, i, 0.5);
            }
        }
        for i in 0..n - 1 {
            coo.push(i, i + 1, -0.25);
        }
        let a = coo.to_csr();
        let mut obj = OrderObjective::new(&a);
        assert_eq!(obj.kind(), FactorKind::Lu);
        let id: Vec<usize> = (0..n).collect();
        let init = obj.eval_sourced(&id);
        assert!(!init.is_exact(), "test premise: the init itself is a fallback bound");
        let mut pool = ProbePool::new(2);
        let mut y = rank_scores(&id);
        let mut best = init.value;
        let mut rng = Pcg64::new(5);
        let mut trace = vec![init.value];
        let run = refine(
            &a,
            FactorKind::Lu,
            &mut pool,
            &mut y,
            &mut best,
            15,
            None,
            &mut rng,
            &mut trace,
        );
        assert!(run >= 15, "refine must actually run");
        assert_eq!(best, init.value, "a fallback bound displaced the incumbent");
        assert_eq!(order_from_scores(&y), id, "scores moved on fallback-only evidence");
        assert!(trace.iter().all(|&f| f == init.value));
        // the probes really did run and really did produce finite bounds —
        // the old `is_finite()` reduction would have accepted one
        assert!(pool.evals() > 0 && pool.skipped() == 0);
    }

    #[test]
    fn adaptive_rho_fires_on_badly_scaled_window_and_never_hurts() {
        // a max-normalized window with one dominant node: the window is
        // ~rank-1, L fits it in a few steps (dual residual → 0) while the
        // primal residual plateaus — exactly the imbalance the
        // residual-balancing update corrects by growing ρ
        let a = crate::gen::grid::scaled_node_laplacian_2d(10, 10, 37, 1e6);
        let win = DenseWindow::from_csr(&a);
        let y0 = rank_scores(&fiedler_order_with(&a, 60, 1));

        let fixed = AdmmParams::default();
        let adaptive = AdmmParams { adaptive_rho: true, ..AdmmParams::default() };
        // whether the trigger crosses μ=10 within a short run depends on
        // the L-init draws (mirror-validated: most seeds fire here, some
        // stay balanced), so the firing assertion quantifies over a seed
        // set while the quality assertions hold per seed
        let mut fired = false;
        for seed in [1u64, 2, 3, 5, 7] {
            let mut obj_f = OrderObjective::new(&a);
            let mut obj_a = OrderObjective::new(&a);
            let init_f = obj_f.eval(&order_from_scores(&y0));
            assert_eq!(init_f, obj_a.eval(&order_from_scores(&y0)));
            let mut tr_f = vec![init_f];
            let out_f = admm_optimize(
                &win,
                &mut obj_f,
                &y0,
                init_f,
                &fixed,
                12,
                None,
                &mut Pcg64::new(seed),
                &mut tr_f,
            );
            let mut tr_a = vec![init_f];
            let out_a = admm_optimize(
                &win,
                &mut obj_a,
                &y0,
                init_f,
                &adaptive,
                12,
                None,
                &mut Pcg64::new(seed),
                &mut tr_a,
            );
            assert_eq!(out_f.rho_final, 1.0, "fixed-ρ run moved the penalty");
            fired |= out_a.rho_final != 1.0;
            for w in tr_a.windows(2) {
                assert!(w[1] <= w[0], "seed {seed}: adaptive trace increased: {tr_a:?}");
            }
            // strict acceptance: neither run can end above the init, and
            // on this window the adaptive run never loses to the fixed one
            // (mirror-validated across seeds before the port)
            assert!(out_f.objective <= init_f && out_a.objective <= init_f);
            assert!(
                out_a.objective <= out_f.objective,
                "seed {seed}: adaptive {} worse than fixed {}",
                out_a.objective,
                out_f.objective
            );
        }
        assert!(fired, "ρ adaptation never fired on the badly scaled window");
    }
}
