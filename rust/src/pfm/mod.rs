//! Native PFM optimizer: in-Rust ADMM + proximal fill-in minimization.
//!
//! The paper's headline contribution — minimizing ‖L‖₁(+‖U‖₁) of the
//! reordered matrix's triangular factors via score reparameterization,
//! ADMM, and proximal gradient descent — executed *natively, per
//! instance*, with no network artifact required. This is what lets
//! `Learned::Pfm` serve real optimized orderings instead of falling back
//! to the spectral baseline when the PJRT runtime has no artifact.
//!
//! Pipeline (see DESIGN.md §PFM-Optimizer):
//!
//! ```text
//!        scores y (spectral ranks | random)         [init]
//!                 │
//!   n ≤ cap ──────┤────── n > cap
//!      │          │          │
//!      ▼          │          ▼
//!  dense ADMM     │   coarsen (heavy-edge) → dense ADMM on the
//!  (perm+admm)    │   coarse window → prolong scores  (multilevel)
//!      │          │          │
//!      └──────────┼──────────┘
//!                 ▼
//!   sampled-subgradient refinement (SPSA + segment moves)   [admm::refine]
//!                 │
//!                 ▼
//!   argsort(y) — every step accepted only if it lowers the exact
//!   structural factor nnz (objective::OrderObjective), so the result is
//!   never worse than the init on the golden criterion.
//! ```

pub mod admm;
pub mod multilevel;
pub mod objective;
pub mod perm;

use std::time::{Duration, Instant};

pub use admm::AdmmParams;
pub use multilevel::DEFAULT_DENSE_CAP;
pub use objective::OrderObjective;

use crate::factor::FactorKind;
use crate::order::{fiedler_order_with, order_from_scores};
use crate::pfm::admm::{admm_optimize, refine};
use crate::pfm::multilevel::{coarsen, prolong, restrict};
use crate::pfm::objective::DenseWindow;
use crate::pfm::perm::{rank_scores, standardize};
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Lanczos budget of the spectral init — matches the `S_e` baseline and
/// the runtime's spectral fallback exactly, so the optimizer's init
/// ordering *is* the baseline ordering and acceptance can only improve it.
pub const SPECTRAL_INIT_ITERS: usize = 60;

/// Optimization budget: how much work one `optimize` call may spend.
/// Iteration budgets bound work deterministically; the optional wall-clock
/// cap bounds serving latency (checked between iterations — an iteration
/// in flight completes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptBudget {
    /// ADMM outer iterations (dense or coarse window)
    pub outer: usize,
    /// sampled-subgradient refinement steps at the native scale
    pub refine: usize,
    /// wall-clock cap in milliseconds
    pub time_ms: Option<u64>,
}

impl Default for OptBudget {
    fn default() -> Self {
        OptBudget { outer: 6, refine: 60, time_ms: None }
    }
}

impl OptBudget {
    /// The coordinator's default: bounded in both iterations and wall
    /// clock, so a serving request can never stall the network thread.
    pub fn serving() -> OptBudget {
        OptBudget { outer: 4, refine: 24, time_ms: Some(250) }
    }
}

/// Score initialization — the paper's ablation axis (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreInit {
    /// Ranks of the spectral (Fiedler) ordering: the `S_e` embedding.
    Spectral,
    /// Seeded Gaussian scores (the `randinit` ablation).
    Random,
}

/// The native proximal fill-in minimizer.
#[derive(Clone, Debug)]
pub struct PfmOptimizer {
    pub budget: OptBudget,
    pub seed: u64,
    pub init: ScoreInit,
    /// ADMM hyperparameters (defaults mirror the build-time trainer)
    pub params: AdmmParams,
    /// dense-window / multilevel cap
    pub dense_cap: usize,
}

impl PfmOptimizer {
    pub fn new(budget: OptBudget, seed: u64) -> PfmOptimizer {
        PfmOptimizer {
            budget,
            seed,
            init: ScoreInit::Spectral,
            params: AdmmParams::default(),
            dense_cap: DEFAULT_DENSE_CAP,
        }
    }

    pub fn with_init(mut self, init: ScoreInit) -> PfmOptimizer {
        self.init = init;
        self
    }

    /// Optimize an elimination ordering for `a`. Symmetric matrices are
    /// driven by the exact Cholesky criterion; unsymmetric ones order on
    /// their symmetrized proxy (like every score-based method here) while
    /// accepting on the true LU criterion.
    pub fn optimize(&self, a: &Csr) -> PfmReport {
        let n = a.nrows();
        let deadline = self.budget.time_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        if n <= 2 {
            let order: Vec<usize> = (0..n).collect();
            let objective = if n == 0 { 0.0 } else { OrderObjective::new(a).eval(&order) };
            return PfmReport {
                order,
                objective,
                init_objective: objective,
                natural_objective: objective,
                outer_iters: 0,
                refine_steps: 0,
                evals: usize::from(n > 0),
                trace: vec![objective],
                coarse_n: None,
                kind: FactorKind::for_matrix(a),
            };
        }

        let mut obj = OrderObjective::new(a);
        // score-based machinery (spectral init, coarsening, ADMM window)
        // needs symmetric edge weights
        let proxy = match obj.kind() {
            FactorKind::Cholesky => None,
            FactorKind::Lu => Some(a.symmetrize()),
        };
        let gm = proxy.as_ref().unwrap_or(a);

        let mut rng = Pcg64::new(self.seed);
        let mut y = match self.init {
            ScoreInit::Spectral => {
                // init ordering == the S_e fallback ordering, exactly
                rank_scores(&fiedler_order_with(gm, SPECTRAL_INIT_ITERS, self.seed))
            }
            ScoreInit::Random => {
                let mut y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
                standardize(&mut y);
                y
            }
        };

        let init_objective = obj.eval(&order_from_scores(&y));
        let mut best_f = init_objective;
        let mut trace = vec![init_objective];

        // free candidate: never return something worse than no reordering
        let identity: Vec<usize> = (0..n).collect();
        let id_f = obj.eval(&identity);
        if id_f < best_f {
            best_f = id_f;
            y = rank_scores(&identity);
        }
        trace.push(best_f);

        // --- ADMM window: dense directly, or coarsened above the cap ---
        let mut outer_iters = 0usize;
        let mut coarse_n = None;
        let mut coarse_evals = 0usize;
        if self.budget.outer > 0 && !deadline.is_some_and(|d| Instant::now() >= d) {
            if n <= self.dense_cap {
                let win = DenseWindow::from_csr(gm);
                let out = admm_optimize(
                    &win,
                    &mut obj,
                    &y,
                    best_f,
                    &self.params,
                    self.budget.outer,
                    deadline,
                    &mut rng,
                    &mut trace,
                );
                outer_iters = out.outer_iters;
                best_f = out.objective;
                y = out.y;
            } else if let Some(c) = coarsen(gm, self.dense_cap, &mut rng) {
                let cn = c.matrix.nrows();
                // partial contraction can stall above the cap (no edges to
                // merge) — only pay for the dense window when it is small
                if cn >= 4 && cn <= 2 * self.dense_cap {
                    coarse_n = Some(cn);
                    let mut cobj = OrderObjective::new(&c.matrix);
                    let mut yc = restrict(&y, &c.fine_to_coarse, cn);
                    standardize(&mut yc);
                    let cf = cobj.eval(&order_from_scores(&yc));
                    let mut ctrace = vec![cf];
                    let win = DenseWindow::from_csr(&c.matrix);
                    let out = admm_optimize(
                        &win,
                        &mut cobj,
                        &yc,
                        cf,
                        &self.params,
                        self.budget.outer,
                        deadline,
                        &mut rng,
                        &mut ctrace,
                    );
                    outer_iters = out.outer_iters;
                    coarse_evals = cobj.evals;
                    // prolonged scores are a candidate, accepted only if
                    // they improve the *fine* golden criterion
                    let mut cand = prolong(&out.y, &c.fine_to_coarse, &y);
                    standardize(&mut cand);
                    let f = obj.eval(&order_from_scores(&cand));
                    if f < best_f {
                        best_f = f;
                        y = cand;
                    }
                    trace.push(best_f);
                }
            }
        }

        // --- sampled-subgradient refinement at the native scale ---
        let refine_steps = refine(
            &mut obj,
            &mut y,
            &mut best_f,
            self.budget.refine,
            deadline,
            &mut rng,
            &mut trace,
        );

        let order = order_from_scores(&y);
        PfmReport {
            order,
            objective: best_f,
            init_objective,
            natural_objective: id_f,
            outer_iters,
            refine_steps,
            evals: obj.evals + coarse_evals,
            trace,
            coarse_n,
            kind: obj.kind(),
        }
    }
}

/// What one `optimize` call did and found.
#[derive(Clone, Debug)]
pub struct PfmReport {
    /// optimized elimination ordering (`order[k]` = node eliminated k-th)
    pub order: Vec<usize>,
    /// structural factor nnz of `order` — nnz(L) (Cholesky) or nnz(L+U)
    /// (LU); never exceeds `init_objective`
    pub objective: f64,
    /// structural factor nnz of the init ordering
    pub init_objective: f64,
    /// structural factor nnz of the natural (identity) ordering — the
    /// always-evaluated free candidate, so `objective` never exceeds it
    pub natural_objective: f64,
    /// ADMM outer iterations run
    pub outer_iters: usize,
    /// refinement steps run
    pub refine_steps: usize,
    /// discrete objective evaluations (fine + coarse)
    pub evals: usize,
    /// best-so-far objective trace (non-increasing)
    pub trace: Vec<f64>,
    /// coarse problem size when the multilevel path engaged
    pub coarse_n: Option<usize>,
    /// factorization kind the objective ran
    pub kind: FactorKind,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::analyze;
    use crate::gen::grid::laplacian_2d;
    use crate::gen::ProblemClass;
    use crate::util::check::check_permutation;

    #[test]
    fn optimize_returns_valid_permutation_never_worse_than_init() {
        let a = laplacian_2d(12, 10);
        let opt = PfmOptimizer::new(OptBudget { outer: 3, refine: 30, time_ms: None }, 7);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert!(rep.objective <= rep.init_objective);
        // the reported objective is the real symbolic count of the order
        let pap = a.permute_sym(&rep.order);
        assert_eq!(rep.objective, analyze(&pap).lnnz as f64);
        for w in rep.trace.windows(2) {
            assert!(w[1] <= w[0], "trace increased: {:?}", rep.trace);
        }
        assert!(rep.coarse_n.is_none(), "n=120 is under the dense cap");
        assert_eq!(rep.kind, FactorKind::Cholesky);
        assert!(rep.evals >= 2);
    }

    #[test]
    fn multilevel_engages_above_the_cap() {
        let a = laplacian_2d(24, 24); // n = 576 > 160
        let opt = PfmOptimizer::new(OptBudget { outer: 2, refine: 12, time_ms: None }, 3);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert!(rep.objective <= rep.init_objective);
        let cn = rep.coarse_n.expect("multilevel must engage at n=576");
        assert!(cn <= 2 * DEFAULT_DENSE_CAP);
        assert!(rep.outer_iters > 0, "coarse ADMM must run");
    }

    #[test]
    fn random_init_differs_from_spectral_on_seeded_grid() {
        // the Table 3 ablation: randinit must be a genuinely different
        // method, not a silent alias of the spectral path
        let a = ProblemClass::Other.generate(120, 5);
        let budget = OptBudget { outer: 2, refine: 10, time_ms: None };
        let spec = PfmOptimizer::new(budget, 11).optimize(&a);
        let rand = PfmOptimizer::new(budget, 11).with_init(ScoreInit::Random).optimize(&a);
        check_permutation(&spec.order).unwrap();
        check_permutation(&rand.order).unwrap();
        assert_ne!(spec.order, rand.order, "random init collapsed to the spectral path");
        assert_ne!(spec.init_objective, rand.init_objective);
    }

    #[test]
    fn zero_budget_returns_init_and_tiny_inputs_are_identity() {
        let a = laplacian_2d(8, 8);
        let opt = PfmOptimizer::new(OptBudget { outer: 0, refine: 0, time_ms: None }, 1);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert_eq!(rep.outer_iters, 0);
        assert_eq!(rep.refine_steps, 0);
        assert!(rep.objective <= rep.init_objective);

        for n in [0usize, 1, 2] {
            let mut coo = crate::sparse::Coo::square(n);
            for i in 0..n {
                coo.push(i, i, 2.0);
            }
            let tiny = coo.to_csr();
            let rep = opt.optimize(&tiny);
            assert_eq!(rep.order, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn unsymmetric_input_optimizes_on_lu_criterion() {
        let a = ProblemClass::ConvDiff.generate(100, 9);
        let opt = PfmOptimizer::new(OptBudget { outer: 2, refine: 16, time_ms: None }, 2);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert_eq!(rep.kind, FactorKind::Lu);
        assert!(rep.objective <= rep.init_objective);
        assert!(rep.objective >= a.nnz() as f64, "nnz(L+U) ≥ nnz(A)");
    }

    #[test]
    fn time_budget_bounds_the_run() {
        let a = laplacian_2d(20, 20);
        let opt = PfmOptimizer::new(
            OptBudget { outer: 1000, refine: 100_000, time_ms: Some(0) },
            1,
        );
        let t0 = Instant::now();
        let rep = opt.optimize(&a);
        // expired deadline: init + identity evals only, no iterations
        assert_eq!(rep.outer_iters, 0);
        assert_eq!(rep.refine_steps, 0);
        check_permutation(&rep.order).unwrap();
        assert!(t0.elapsed().as_secs() < 30, "deadline did not bound the run");
    }
}
