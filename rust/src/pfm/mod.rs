//! Native PFM optimizer: in-Rust ADMM + proximal fill-in minimization.
//!
//! The paper's headline contribution — minimizing ‖L‖₁(+‖U‖₁) of the
//! reordered matrix's triangular factors via score reparameterization,
//! ADMM, and proximal gradient descent — executed *natively, per
//! instance*, with no network artifact required. This is what lets
//! `Learned::Pfm` serve real optimized orderings instead of falling back
//! to the spectral baseline when the PJRT runtime has no artifact.
//!
//! Pipeline (see DESIGN.md §PFM-Optimizer):
//!
//! ```text
//!        scores y (spectral ranks | random)         [init]
//!                 │
//!   n ≤ cap ──────┤────── n > cap
//!      │          │          │
//!      ▼          │          ▼
//!  dense ADMM     │   coarsen keeping every level (Hierarchy) →
//!  (perm+admm,    │   dense ADMM on the coarsest window →
//!   adaptive ρ    │   V-cycle back up: prolong + budgeted probe-pool
//!   optional)     │   refinement per level, each accepted on that
//!      │          │   level's discrete criterion        (multilevel)
//!      └──────────┼──────────┘
//!                 ▼
//!   sampled-subgradient refinement (multi-probe SPSA + segment-move
//!   batches through probes::ProbePool — parallel, bit-identical at any
//!   thread count)                                       [admm::refine]
//!                 │
//!                 ▼
//!   argsort(y) — every step accepted only if it lowers the exact
//!   structural factor nnz (objective::OrderObjective), so the result is
//!   never worse than the init on the golden criterion.
//! ```

pub mod admm;
pub mod incremental;
pub mod multilevel;
pub mod objective;
pub mod perm;
pub mod probes;

use std::time::{Duration, Instant};

pub use admm::AdmmParams;
pub use multilevel::{Hierarchy, DEFAULT_DENSE_CAP};
pub use objective::{Eval, EvalSource, OrderObjective};
pub use probes::{ProbePool, PROBES_PER_STEP};

use crate::factor::{FactorKind, SymbolicCache};
use crate::order::{fiedler_order_with, order_from_scores};
use crate::pfm::admm::{admm_optimize, refine};
use crate::pfm::multilevel::prolong;
use crate::pfm::objective::DenseWindow;
use crate::pfm::perm::{rank_scores, standardize};
use crate::sparse::Csr;
use crate::util::rng::Pcg64;
use crate::util::sync::composed_threads;

/// Lanczos budget of the spectral init — matches the `S_e` baseline and
/// the runtime's spectral fallback exactly, so the optimizer's init
/// ordering *is* the baseline ordering and acceptance can only improve it.
pub const SPECTRAL_INIT_ITERS: usize = 60;

/// Optimization budget: how much work one `optimize` call may spend.
/// Iteration budgets bound work deterministically; the optional wall-clock
/// cap bounds serving latency (checked between iterations *and* before
/// every probe inside a parallel batch, so overshoot is bounded by one
/// in-flight probe per worker, not one batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptBudget {
    /// ADMM outer iterations (dense or coarse window)
    pub outer: usize,
    /// sampled-subgradient refinement steps at the native scale (one step
    /// evaluates a whole probe batch — see `admm::refine`)
    pub refine: usize,
    /// refinement steps per intermediate level on the V-cycle way up
    /// (0 = the PR 4 coarsest-only multilevel behavior)
    pub level_refine: usize,
    /// residual-balancing adaptive ρ in the ADMM loop (μ=10, τ=2);
    /// off = the paper's fixed ρ=1
    pub adaptive_rho: bool,
    /// wall-clock cap in milliseconds
    pub time_ms: Option<u64>,
}

impl Default for OptBudget {
    fn default() -> Self {
        OptBudget { outer: 6, refine: 60, level_refine: 8, adaptive_rho: false, time_ms: None }
    }
}

impl OptBudget {
    /// The coordinator's default: bounded in both iterations and wall
    /// clock, so a serving request can never stall the network thread.
    /// Adaptive ρ is on — serving sees arbitrarily scaled inputs, and the
    /// strict-acceptance rule means adaptation can never serve a worse
    /// ordering than the fixed-ρ schedule's init.
    pub fn serving() -> OptBudget {
        OptBudget {
            outer: 4,
            refine: 24,
            level_refine: 6,
            adaptive_rho: true,
            time_ms: Some(250),
        }
    }
}

/// Wall-clock split of one `optimize` call across its major phases,
/// reported for observability (the coordinator turns these into
/// per-request trace spans). Phases that did not run stay 0. Time not
/// covered here (init, prolongation, identity evals) is the caller's to
/// attribute; the sum never exceeds the call's wall time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimes {
    /// coarsening-hierarchy construction — 0 when the hierarchy came
    /// from a [`SharedPrep`] or the dense path ran
    pub coarsen_s: f64,
    /// ADMM on the dense or coarsest window
    pub admm_s: f64,
    /// refinement passes: V-cycle per-level + native-scale subgradient
    pub refine_s: f64,
    /// portion of `refine_s` spent inside incremental-engaged probe
    /// batches (base preparation + suffix re-walks) — always ≤ `refine_s`
    pub refine_incr_s: f64,
}

/// Score initialization — the paper's ablation axis (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreInit {
    /// Ranks of the spectral (Fiedler) ordering: the `S_e` embedding.
    Spectral,
    /// Seeded Gaussian scores (the `randinit` ablation).
    Random,
}

/// The native proximal fill-in minimizer.
#[derive(Clone, Debug)]
pub struct PfmOptimizer {
    pub budget: OptBudget,
    pub seed: u64,
    pub init: ScoreInit,
    /// ADMM hyperparameters (defaults mirror the build-time trainer)
    pub params: AdmmParams,
    /// dense-window / multilevel cap
    pub dense_cap: usize,
    /// probe-pool workers for the refinement passes — threads buy wall
    /// clock, not quality: results are bit-identical at any value unless
    /// a wall-clock budget expires mid-run (where results are timing-
    /// dependent at *any* thread count; see `pfm::probes`)
    pub probe_threads: usize,
    /// parallel-factorization width each probe may use (`factor::sched`).
    /// The probe objective is symbolic for Cholesky and sequential for LU
    /// today, so this knob's effect *here* is the oversubscription cap:
    /// the effective pool width is `composed_threads(probe_threads,
    /// factor_threads)` so probes × factors never exceed the machine. The
    /// numeric win itself lands on the solver/serving path
    /// (`DirectSolver::prepare_kind_threaded`).
    pub factor_threads: usize,
    /// evaluate eligible refinement probes via the incremental suffix
    /// re-walk (`pfm::incremental`). Quality-neutral: the search
    /// trajectory, accepted orderings, and trace are bit-identical on or
    /// off — the toggle changes only where the exact count comes from
    /// (and how much it costs). On by default; `--no-incremental` in the
    /// CLI maps here for A/B runs.
    pub incremental: bool,
}

impl PfmOptimizer {
    pub fn new(budget: OptBudget, seed: u64) -> PfmOptimizer {
        PfmOptimizer {
            budget,
            seed,
            init: ScoreInit::Spectral,
            params: AdmmParams::default(),
            dense_cap: DEFAULT_DENSE_CAP,
            probe_threads: 1,
            factor_threads: 1,
            incremental: true,
        }
    }

    /// Toggle incremental probe evaluation (on by default; see the
    /// [`incremental`](Self::incremental) field docs).
    pub fn with_incremental(mut self, on: bool) -> PfmOptimizer {
        self.incremental = on;
        self
    }

    pub fn with_init(mut self, init: ScoreInit) -> PfmOptimizer {
        self.init = init;
        self
    }

    /// Set the probe-pool width. Determinism: for a given seed and budget
    /// the permutation is identical at any thread count, as long as no
    /// wall-clock deadline expires mid-run — an expiring `time_ms` makes
    /// the skip-set timing-dependent at any width (never-worse-than-init
    /// still holds; see `pfm::probes`).
    pub fn with_threads(mut self, threads: usize) -> PfmOptimizer {
        self.probe_threads = threads.max(1);
        self
    }

    /// Set the per-probe parallel-factorization width (see the
    /// [`factor_threads`](Self::factor_threads) field docs: today this
    /// caps the probe pool so the product never oversubscribes).
    pub fn with_factor_threads(mut self, threads: usize) -> PfmOptimizer {
        self.factor_threads = threads.max(1);
        self
    }

    /// Optimize an elimination ordering for `a`. Symmetric matrices are
    /// driven by the exact Cholesky criterion; unsymmetric ones order on
    /// their symmetrized proxy (like every score-based method here) while
    /// accepting on the true LU criterion.
    pub fn optimize(&self, a: &Csr) -> PfmReport {
        self.optimize_shared(a, None)
    }

    /// Like [`optimize`](Self::optimize), reusing a [`SharedPrep`] computed
    /// once for a batch of identical-matrix requests (the coordinator's
    /// network-thread batching). Since hierarchies are seed-independent,
    /// a shared run is bit-identical to a solo run on the same matrix.
    pub fn optimize_shared(&self, a: &Csr, prep: Option<&SharedPrep>) -> PfmReport {
        let n = a.nrows();
        let deadline = self.budget.time_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        if n <= 2 {
            let order: Vec<usize> = (0..n).collect();
            let objective = if n == 0 { 0.0 } else { OrderObjective::new(a).eval(&order) };
            return PfmReport {
                order,
                objective,
                init_objective: objective,
                natural_objective: objective,
                outer_iters: 0,
                refine_steps: 0,
                levels_refined: 0,
                evals: usize::from(n > 0),
                incremental_probes: 0,
                full_probes: usize::from(n > 0),
                probe_prepares: 0,
                trace: vec![objective],
                coarse_n: None,
                probe_threads: composed_threads(self.probe_threads, self.factor_threads),
                kind: FactorKind::for_matrix(a),
                phases: PhaseTimes::default(),
            };
        }

        let mut obj = OrderObjective::new(a);
        // score-based machinery (spectral init, coarsening, ADMM window)
        // needs symmetric edge weights
        let proxy = match obj.kind() {
            FactorKind::Cholesky => None,
            FactorKind::Lu => Some(a.symmetrize()),
        };
        let gm = proxy.as_ref().unwrap_or(a);

        let mut pool = ProbePool::new(composed_threads(self.probe_threads, self.factor_threads))
            .with_incremental(self.incremental);
        let mut rng = Pcg64::new(self.seed);
        let mut y = match self.init {
            ScoreInit::Spectral => {
                // init ordering == the S_e fallback ordering, exactly
                rank_scores(&fiedler_order_with(gm, SPECTRAL_INIT_ITERS, self.seed))
            }
            ScoreInit::Random => {
                let mut y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
                standardize(&mut y);
                y
            }
        };

        let init_eval = obj.eval_sourced(&order_from_scores(&y));
        let init_objective = init_eval.value;
        let mut best_f = init_objective;
        let mut trace = vec![init_objective];

        // free candidate: never return something worse than no reordering.
        // The symbolic Cholesky count of the identity is pattern-keyed
        // shareable (SharedPrep); the LU count is numeric, so unsymmetric
        // matrices always evaluate it themselves. A fallback LU bound may
        // displace the incumbent only while the incumbent is itself a
        // bound — an exact measurement is never traded for an estimate.
        let identity: Vec<usize> = (0..n).collect();
        let id_eval = match prep
            .and_then(|p| p.natural_objective)
            .filter(|_| obj.kind() == FactorKind::Cholesky)
        {
            Some(v) => Eval { value: v, source: EvalSource::Symbolic },
            None => obj.eval_sourced(&identity),
        };
        let id_f = id_eval.value;
        if id_f < best_f && (id_eval.is_exact() || !init_eval.is_exact()) {
            best_f = id_f;
            y = rank_scores(&identity);
        }
        trace.push(best_f);

        // --- ADMM window: dense directly, or coarsened above the cap ---
        let mut outer_iters = 0usize;
        let mut coarse_n = None;
        let mut coarse_evals = 0usize;
        let mut levels_refined = 0usize;
        let mut phases = PhaseTimes::default();
        let mut params = self.params.clone();
        params.adaptive_rho |= self.budget.adaptive_rho;
        let multilevel_wanted = self.budget.outer > 0 || self.budget.level_refine > 0;
        if multilevel_wanted && !deadline.is_some_and(|d| Instant::now() >= d) {
            if n <= self.dense_cap {
                if self.budget.outer > 0 {
                    let win = DenseWindow::from_csr(gm);
                    let t_admm = Instant::now();
                    let out = admm_optimize(
                        &win,
                        &mut obj,
                        &y,
                        best_f,
                        &params,
                        self.budget.outer,
                        deadline,
                        &mut rng,
                        &mut trace,
                    );
                    phases.admm_s += t_admm.elapsed().as_secs_f64();
                    outer_iters = out.outer_iters;
                    best_f = out.objective;
                    y = out.y;
                }
            } else {
                // the hierarchy is seed-independent, so a prep computed
                // once for a batch of requests carrying this same matrix
                // slots in for the local build exactly
                let built;
                let hier: Option<&Hierarchy> = match prep.and_then(|p| p.hierarchy.as_ref()) {
                    Some(h) => Some(h),
                    None => {
                        let t_coarsen = Instant::now();
                        built = Hierarchy::build(gm, self.dense_cap);
                        phases.coarsen_s += t_coarsen.elapsed().as_secs_f64();
                        built.as_ref()
                    }
                };
                // partial contraction can stall above the cap (no edges to
                // merge) — only pay for the dense window when it is small
                if let Some(h) = hier.filter(|h| {
                    let cn = h.coarsest().nrows();
                    cn >= 4 && cn <= 2 * self.dense_cap
                }) {
                    let cn = h.coarsest().nrows();
                    coarse_n = Some(cn);
                    let rests = h.restrict_all(&y);
                    let mut yc = rests.last().expect("nonempty hierarchy").clone();
                    standardize(&mut yc);
                    let mut cobj = OrderObjective::new(h.coarsest());
                    let cf = cobj.eval(&order_from_scores(&yc));
                    let mut ctrace = vec![cf];
                    let win = DenseWindow::from_csr(h.coarsest());
                    let t_admm = Instant::now();
                    let out = admm_optimize(
                        &win,
                        &mut cobj,
                        &yc,
                        cf,
                        &params,
                        self.budget.outer,
                        deadline,
                        &mut rng,
                        &mut ctrace,
                    );
                    phases.admm_s += t_admm.elapsed().as_secs_f64();
                    outer_iters = out.outer_iters;
                    coarse_evals = cobj.evals;
                    // candidate A — direct prolongation through the
                    // composed map (the coarsest-only path), evaluated
                    // first so the V-cycle below can refine but never
                    // regress it; accepted only if it improves the *fine*
                    // golden criterion
                    let mut cand = prolong(&out.y, &h.composed(), &y);
                    standardize(&mut cand);
                    let f = obj.eval_sourced(&order_from_scores(&cand));
                    if f.is_exact() && f.value < best_f {
                        best_f = f.value;
                        y = cand;
                    }
                    trace.push(best_f);
                    // candidate B — V-cycle walk: prolong level by level,
                    // refining each intermediate level under its own
                    // discrete criterion with the probe pool
                    if self.budget.level_refine > 0 && h.levels() >= 2 {
                        let mut yl = out.y;
                        let mut ltrace: Vec<f64> = Vec::new();
                        for lvl in (0..h.levels() - 1).rev() {
                            yl = prolong(&yl, &h.maps[lvl + 1], &rests[lvl]);
                            standardize(&mut yl);
                            let lm = &h.matrices[lvl];
                            let lorder = vec![order_from_scores(&yl)];
                            let le =
                                pool.eval_orders(lm, FactorKind::Cholesky, &lorder, deadline)[0];
                            // skipped = the deadline already passed: keep
                            // prolonging (cheap, keeps the walk well-formed)
                            // but skip the level's refinement work
                            if le.evaluated() {
                                let mut lf = le.value;
                                ltrace.clear();
                                ltrace.push(lf);
                                let t_refine = Instant::now();
                                let steps = refine(
                                    lm,
                                    FactorKind::Cholesky,
                                    &mut pool,
                                    &mut yl,
                                    &mut lf,
                                    self.budget.level_refine,
                                    deadline,
                                    &mut rng,
                                    &mut ltrace,
                                );
                                phases.refine_s += t_refine.elapsed().as_secs_f64();
                                if steps > 0 {
                                    levels_refined += 1;
                                }
                            }
                        }
                        let mut cand = prolong(&yl, &h.maps[0], &y);
                        standardize(&mut cand);
                        let f = obj.eval_sourced(&order_from_scores(&cand));
                        if f.is_exact() && f.value < best_f {
                            best_f = f.value;
                            y = cand;
                        }
                        trace.push(best_f);
                    }
                }
            }
        }

        // --- sampled-subgradient refinement at the native scale ---
        let t_refine = Instant::now();
        let refine_steps = refine(
            a,
            obj.kind(),
            &mut pool,
            &mut y,
            &mut best_f,
            self.budget.refine,
            deadline,
            &mut rng,
            &mut trace,
        );
        phases.refine_s += t_refine.elapsed().as_secs_f64();
        phases.refine_incr_s = pool.incremental_secs().min(phases.refine_s);

        let order = order_from_scores(&y);
        PfmReport {
            order,
            objective: best_f,
            init_objective,
            natural_objective: id_f,
            outer_iters,
            refine_steps,
            levels_refined,
            evals: obj.evals + coarse_evals + pool.evals(),
            incremental_probes: pool.incremental_evals(),
            full_probes: obj.evals + coarse_evals + pool.full_evals(),
            probe_prepares: pool.base_prepares(),
            trace,
            coarse_n,
            probe_threads: pool.threads(),
            kind: obj.kind(),
            phases,
        }
    }
}

/// Work shareable across a batch of native-PFM requests for the same
/// matrix: the identity ordering's symbolic Cholesky objective and the
/// coarsening hierarchy of the (symmetrized) matrix. Hierarchies are
/// driven by a constant seed (`multilevel::COARSEN_SEED`), so sharing a
/// prep computed from an *identical* matrix is bit-transparent — each
/// request still runs its own seed, init, and budget (the coordinator
/// keys groups on exact pattern + values for precisely this reason). A
/// prep from a same-pattern, different-value matrix is still *safe* —
/// every shared candidate is re-accepted on the request's own golden
/// criterion — but no longer bit-identical to a solo run.
pub struct SharedPrep {
    /// discrete objective of the identity ordering — `Some` only for the
    /// symbolic (Cholesky) kind; the LU natural objective is numeric and
    /// therefore evaluated per request
    pub natural_objective: Option<f64>,
    /// coarsening hierarchy, when the matrix is above the dense cap
    pub hierarchy: Option<Hierarchy>,
}

/// Compute the shareable prep for `a`. When `cache` is given, the identity
/// analysis goes through the pattern-keyed [`SymbolicCache`] — repeated
/// preps for one topology become cache hits, which is how the
/// coordinator's `shared_analyses` accounting stays observable.
pub fn prepare_shared(a: &Csr, dense_cap: usize, cache: Option<&mut SymbolicCache>) -> SharedPrep {
    let kind = FactorKind::for_matrix(a);
    let natural_objective = match kind {
        FactorKind::Cholesky => Some(match cache {
            Some(c) => c.analyze(a).sym.lnnz as f64,
            None => crate::factor::analyze(a).lnnz as f64,
        }),
        FactorKind::Lu => None,
    };
    let hierarchy = if a.nrows() > dense_cap {
        match kind {
            FactorKind::Cholesky => Hierarchy::build(a, dense_cap),
            FactorKind::Lu => Hierarchy::build(&a.symmetrize(), dense_cap),
        }
    } else {
        None
    };
    SharedPrep { natural_objective, hierarchy }
}

/// What one `optimize` call did and found.
#[derive(Clone, Debug)]
pub struct PfmReport {
    /// optimized elimination ordering (`order[k]` = node eliminated k-th)
    pub order: Vec<usize>,
    /// structural factor nnz of `order` — nnz(L) (Cholesky) or nnz(L+U)
    /// (LU); never exceeds `init_objective`
    pub objective: f64,
    /// structural factor nnz of the init ordering
    pub init_objective: f64,
    /// structural factor nnz of the natural (identity) ordering — the
    /// always-evaluated free candidate, so `objective` never exceeds it
    pub natural_objective: f64,
    /// ADMM outer iterations run
    pub outer_iters: usize,
    /// refinement steps run at the native scale
    pub refine_steps: usize,
    /// intermediate V-cycle levels that received a refinement pass
    pub levels_refined: usize,
    /// discrete objective evaluations (fine + coarse + probe pool)
    pub evals: usize,
    /// evaluations served by the incremental suffix re-walk
    /// (`pfm::incremental`) — bit-identical to full passes, sublinear
    /// cost. Always 0 with [`PfmOptimizer::incremental`] off.
    /// `incremental_probes + full_probes == evals`.
    pub incremental_probes: usize,
    /// evaluations that ran a full symbolic/numeric pass over the
    /// permuted matrix (fine + coarse + probe pool)
    pub full_probes: usize,
    /// full symbolic passes spent preparing incremental base state
    /// (amortized across every incremental probe of a batch; not counted
    /// in `evals`)
    pub probe_prepares: usize,
    /// best-so-far objective trace (non-increasing)
    pub trace: Vec<f64>,
    /// coarse problem size when the multilevel path engaged
    pub coarse_n: Option<usize>,
    /// probe-pool width the refinement ran with (quality-neutral absent
    /// an expiring wall-clock deadline)
    pub probe_threads: usize,
    /// factorization kind the objective ran
    pub kind: FactorKind,
    /// wall-clock split across coarsen / ADMM / refine (all zero when the
    /// instance was too small for any phase to run)
    pub phases: PhaseTimes,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::analyze;
    use crate::gen::grid::laplacian_2d;
    use crate::gen::ProblemClass;
    use crate::util::check::check_permutation;

    #[test]
    fn optimize_returns_valid_permutation_never_worse_than_init() {
        let a = laplacian_2d(12, 10);
        let budget = OptBudget { outer: 3, refine: 30, ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 7);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert!(rep.objective <= rep.init_objective);
        // the reported objective is the real symbolic count of the order
        let pap = a.permute_sym(&rep.order);
        assert_eq!(rep.objective, analyze(&pap).lnnz as f64);
        for w in rep.trace.windows(2) {
            assert!(w[1] <= w[0], "trace increased: {:?}", rep.trace);
        }
        assert!(rep.coarse_n.is_none(), "n=120 is under the dense cap");
        assert_eq!(rep.kind, FactorKind::Cholesky);
        assert_eq!(rep.levels_refined, 0, "dense path has no levels");
        assert_eq!(rep.probe_threads, 1);
        assert!(rep.evals >= 2);
    }

    #[test]
    fn multilevel_engages_above_the_cap_and_vcycle_refines_levels() {
        let a = laplacian_2d(24, 24); // n = 576 > 160
        let budget = OptBudget { outer: 2, refine: 12, level_refine: 6, ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 3);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert!(rep.objective <= rep.init_objective);
        let cn = rep.coarse_n.expect("multilevel must engage at n=576");
        assert!(cn <= 2 * DEFAULT_DENSE_CAP);
        assert!(rep.outer_iters > 0, "coarse ADMM must run");
        assert!(rep.levels_refined >= 1, "V-cycle must refine intermediate levels");
    }

    #[test]
    fn optimize_is_deterministic_across_thread_counts() {
        // quick in-module determinism check (the cross-class proptest and
        // the CI job live in tests/); covers the V-cycle + fine refinement,
        // and at n=576 the fine batches take the pool's threaded path
        let a = laplacian_2d(24, 24);
        let budget = OptBudget { outer: 1, refine: 9, level_refine: 4, ..OptBudget::default() };
        let base = PfmOptimizer::new(budget, 11).with_threads(1).optimize(&a);
        for threads in [2usize, 4, 8] {
            let rep = PfmOptimizer::new(budget, 11).with_threads(threads).optimize(&a);
            assert_eq!(rep.order, base.order, "threads={threads} changed the ordering");
            assert_eq!(rep.objective, base.objective);
            assert_eq!(rep.trace, base.trace, "threads={threads} changed the trace");
            assert_eq!(rep.evals, base.evals);
            assert_eq!(rep.probe_threads, crate::util::sync::effective_threads(threads));
        }
    }

    #[test]
    fn incremental_split_is_consistent_and_ab_bit_identical() {
        // the tentpole's optimizer-level contract: incremental on vs off
        // is a pure cost toggle (same ordering, objective, trace, eval
        // count), and the report's probe split accounts for every eval
        let a = laplacian_2d(24, 24); // n = 576 → threaded pool + V-cycle
        let budget = OptBudget { outer: 1, refine: 24, level_refine: 4, ..OptBudget::default() };
        let on = PfmOptimizer::new(budget, 9).optimize(&a);
        assert_eq!(on.incremental_probes + on.full_probes, on.evals);
        assert!(on.incremental_probes > 0, "incremental path never engaged at n=576");
        assert!(on.probe_prepares > 0);
        assert!(on.phases.refine_incr_s <= on.phases.refine_s);
        let off = PfmOptimizer::new(budget, 9).with_incremental(false).optimize(&a);
        assert_eq!(off.incremental_probes, 0);
        assert_eq!(off.probe_prepares, 0);
        assert_eq!(off.order, on.order, "incremental toggle changed the search");
        assert_eq!(off.objective, on.objective);
        assert_eq!(off.trace, on.trace);
        assert_eq!(off.evals, on.evals);
        // strictly fewer full passes, even charging base preparations
        assert!(on.full_probes + on.probe_prepares < off.full_probes);
    }

    #[test]
    fn shared_prep_is_bit_transparent() {
        let a = laplacian_2d(19, 18); // n = 342 → hierarchy in the prep
        let budget = OptBudget { outer: 1, refine: 6, level_refine: 3, ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 5);
        let solo = opt.optimize(&a);
        let prep = prepare_shared(&a, DEFAULT_DENSE_CAP, None);
        assert_eq!(prep.natural_objective, Some(solo.natural_objective));
        assert!(prep.hierarchy.is_some());
        let shared = opt.optimize_shared(&a, Some(&prep));
        assert_eq!(shared.order, solo.order);
        assert_eq!(shared.objective, solo.objective);
        assert_eq!(shared.trace, solo.trace);
        // the shared run skips its own identity evaluation
        assert_eq!(shared.evals + 1, solo.evals);
    }

    #[test]
    fn random_init_differs_from_spectral_on_seeded_grid() {
        // the Table 3 ablation: randinit must be a genuinely different
        // method, not a silent alias of the spectral path
        let a = ProblemClass::Other.generate(120, 5);
        let budget = OptBudget { outer: 2, refine: 10, ..OptBudget::default() };
        let spec = PfmOptimizer::new(budget, 11).optimize(&a);
        let rand = PfmOptimizer::new(budget, 11).with_init(ScoreInit::Random).optimize(&a);
        check_permutation(&spec.order).unwrap();
        check_permutation(&rand.order).unwrap();
        assert_ne!(spec.order, rand.order, "random init collapsed to the spectral path");
        assert_ne!(spec.init_objective, rand.init_objective);
    }

    #[test]
    fn zero_budget_returns_init_and_tiny_inputs_are_identity() {
        let a = laplacian_2d(8, 8);
        let budget = OptBudget { outer: 0, refine: 0, level_refine: 0, ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 1);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert_eq!(rep.outer_iters, 0);
        assert_eq!(rep.refine_steps, 0);
        assert_eq!(rep.levels_refined, 0);
        assert!(rep.objective <= rep.init_objective);

        for n in [0usize, 1, 2] {
            let mut coo = crate::sparse::Coo::square(n);
            for i in 0..n {
                coo.push(i, i, 2.0);
            }
            let tiny = coo.to_csr();
            let rep = opt.optimize(&tiny);
            assert_eq!(rep.order, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn unsymmetric_input_optimizes_on_lu_criterion() {
        let a = ProblemClass::ConvDiff.generate(100, 9);
        let budget = OptBudget { outer: 2, refine: 16, ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 2);
        let rep = opt.optimize(&a);
        check_permutation(&rep.order).unwrap();
        assert_eq!(rep.kind, FactorKind::Lu);
        assert!(rep.objective <= rep.init_objective);
        assert!(rep.objective >= a.nnz() as f64, "nnz(L+U) ≥ nnz(A)");
    }

    #[test]
    fn time_budget_bounds_the_run() {
        let a = laplacian_2d(20, 20);
        let budget =
            OptBudget { outer: 1000, refine: 100_000, time_ms: Some(0), ..OptBudget::default() };
        let opt = PfmOptimizer::new(budget, 1);
        let t0 = Instant::now();
        let rep = opt.optimize(&a);
        // expired deadline: init + identity evals only, no iterations
        assert_eq!(rep.outer_iters, 0);
        assert_eq!(rep.refine_steps, 0);
        check_permutation(&rep.order).unwrap();
        assert!(t0.elapsed().as_secs() < 30, "deadline did not bound the run");
    }
}
