//! Incremental symbolic probe evaluation — the o(nnz(L)) refinement
//! unlock (ROADMAP item 2).
//!
//! A refinement probe asks for `analyze(&a.permute_sym(cand)).lnnz`, but
//! the segment-move candidates `admm::refine` generates agree with the
//! incumbent ordering on a (usually long) rank prefix `[0, lo)`. Row i of
//! the reordered matrix B = PAPᵀ depends only on the leading
//! (i+1)×(i+1) submatrix of B, and that submatrix is *identical* between
//! base and candidate for every i < lo — so row i's elimination-tree
//! edges and row-subtree count are identical too. The incremental
//! evaluator therefore:
//!
//! 1. splices the base's prefix row-count sum (`prefix[lo]`, precomputed
//!    once per base ordering by [`IncrementalBase::prepare`]);
//! 2. re-seeds the partial etree exactly as a from-scratch run would
//!    have it after processing rows `0..lo`: a prefix node keeps its
//!    base parent iff that parent is itself in the prefix (an edge of
//!    the leading submatrix's forest); every other node is a root;
//! 3. replays the interleaved etree-extension + row-subtree count walk
//!    of `factor::analyze` for rows `lo..n` only, in *rank space* (no
//!    `permute_sym`: row `cand[i]` of A is scanned and each neighbor v
//!    is mapped through `inv` to its candidate rank).
//!
//! The result is **bit-identical** to full `analyze` on the permuted
//! matrix — both sides sum the same exact integer row counts — at cost
//! O(n + Σ_{i≥lo} row_nnz(i)) instead of O(nnz(L)). See DESIGN.md
//! §PFM-Optimizer "Incremental probes" for the correctness argument.
//!
//! LU-kind probes (numeric, pivoting-dependent) and candidates whose
//! changed suffix is most of the matrix take the full path instead; the
//! gate lives in [`suffix_eligible`] / [`ProbePool`](crate::pfm::probes)
//! so the decision is a pure function of the candidate (never timing),
//! preserving bit-identical results at any thread count.

use crate::factor::etree::NONE;
use crate::factor::FactorWorkspace;
use crate::sparse::Csr;

/// Per-base-ordering state the incremental evaluator resumes from:
/// the ordering, its inverse, its rank-space etree, and the prefix sums
/// of its exact row counts. Buffers are reused across `prepare` calls
/// (the probe pool holds one and re-prepares it per refinement batch).
#[derive(Debug, Default)]
pub struct IncrementalBase {
    /// base ordering (rank → original index)
    order: Vec<usize>,
    /// inverse ordering (original index → rank)
    inv: Vec<usize>,
    /// etree of the base-reordered matrix, in rank space
    parent: Vec<usize>,
    /// prefix[i] = Σ_{k<i} row_nnz[k] of the base factor; len n+1, so
    /// prefix[n] == lnnz(base)
    prefix: Vec<usize>,
}

impl IncrementalBase {
    pub fn new() -> IncrementalBase {
        IncrementalBase::default()
    }

    pub fn n(&self) -> usize {
        self.order.len()
    }

    /// Exact nnz(L) of the base ordering (equals
    /// `analyze(&a.permute_sym(order)).lnnz`).
    pub fn lnnz(&self) -> usize {
        *self.prefix.last().unwrap_or(&0)
    }

    /// Full symbolic pass over `a` under `order` — one
    /// `analyze`-equivalent walk that also records everything eval needs
    /// to resume mid-stream. Uses `ws`'s incremental scratch for the
    /// ancestor/mark arrays (grown once, reused across batches).
    pub fn prepare(&mut self, a: &Csr, order: &[usize], ws: &mut FactorWorkspace) {
        let n = order.len();
        debug_assert_eq!(a.nrows(), n);
        self.order.clear();
        self.order.extend_from_slice(order);
        self.inv.clear();
        self.inv.resize(n, 0);
        for (i, &v) in order.iter().enumerate() {
            self.inv[v] = i;
        }
        self.parent.clear();
        self.parent.resize(n, NONE);
        self.prefix.clear();
        self.prefix.reserve(n + 1);
        self.prefix.push(0);
        ws.acquire_incremental(n);
        let ancestor = &mut ws.inc_ancestor[..n];
        let mark = &mut ws.inc_mark[..n];
        for v in ancestor.iter_mut() {
            *v = NONE;
        }
        for v in mark.iter_mut() {
            *v = NONE;
        }
        let mut total = 0usize;
        for i in 0..n {
            total += walk_row(a, &self.order, &self.inv, &mut self.parent, ancestor, mark, i);
            self.prefix.push(total);
        }
    }

    /// First rank where `cand` differs from the base ordering (`n` if the
    /// orderings are identical). The caller passes this as `lo` to
    /// [`eval`](Self::eval); scanning here (instead of trusting the
    /// generator's window bounds) makes relocations that happen to be
    /// no-ops, palindromic reversals, etc. exactly as cheap as they are.
    pub fn first_diff(&self, cand: &[usize]) -> usize {
        debug_assert_eq!(cand.len(), self.order.len());
        for (i, (&b, &c)) in self.order.iter().zip(cand).enumerate() {
            if b != c {
                return i;
            }
        }
        self.order.len()
    }

    /// Exact `analyze(&a.permute_sym(cand)).lnnz` for a candidate that
    /// agrees with the base on ranks `[0, lo)` (`lo` from
    /// [`first_diff`](Self::first_diff)): splice the base's prefix row
    /// counts, re-walk rows `lo..n` only. Bit-identical to the full path
    /// (both sum the same integers; lnnz < 2⁵³ so the f64 is exact).
    pub fn eval(&self, a: &Csr, cand: &[usize], lo: usize, ws: &mut FactorWorkspace) -> f64 {
        let n = self.order.len();
        debug_assert_eq!(cand.len(), n);
        debug_assert_eq!(self.first_diff(cand), lo.min(n));
        if lo >= n {
            return self.lnnz() as f64;
        }
        ws.acquire_incremental(n);
        let inv = &mut ws.inc_inv[..n];
        let parent = &mut ws.inc_parent[..n];
        let ancestor = &mut ws.inc_ancestor[..n];
        let mark = &mut ws.inc_mark[..n];
        // candidate inverse = base inverse patched on the moved suffix
        inv.copy_from_slice(&self.inv);
        for (i, &v) in cand.iter().enumerate().skip(lo) {
            inv[v] = i;
        }
        // partial-forest resume: a prefix node keeps its base parent iff
        // that edge lies inside the prefix (rows < lo of the candidate
        // matrix are identical to the base's, and parent[j] < lo is
        // decided by exactly those rows); everything else is a root.
        // Seeding ancestor = parent is valid for Liu's compression — the
        // immediate parent is an ancestor in the partial forest.
        for j in 0..lo {
            let p = self.parent[j];
            let seed = if p != NONE && p < lo { p } else { NONE };
            parent[j] = seed;
            ancestor[j] = seed;
        }
        for j in lo..n {
            parent[j] = NONE;
            ancestor[j] = NONE;
        }
        for m in mark.iter_mut() {
            *m = NONE;
        }
        let mut total = self.prefix[lo];
        for i in lo..n {
            total += walk_row(a, cand, inv, parent, ancestor, mark, i);
        }
        total as f64
    }
}

/// Process row `i` of the reordered matrix in rank space: extend the
/// partial etree (Liu's path-halving construction) and count row i's
/// subtree walk, returning row_nnz[i] (diagonal included). One body
/// shared by `prepare` (from row 0) and `eval` (from row lo) so the two
/// can never drift.
///
/// Mirrors `factor::analyze` exactly, with two deliberate differences:
/// neighbors arrive in original-index order, so their mapped ranks are
/// unsorted and `j >= i` must `continue` (not `break` — that relies on
/// sorted CSR columns); and the etree is extended in the same pass, which
/// is equivalent because the count walk only distinguishes
/// `parent[node] < i` (final, identical to the full etree's edge) from
/// `NONE`/`>= i` (both break).
fn walk_row(
    a: &Csr,
    order: &[usize],
    inv: &[usize],
    parent: &mut [usize],
    ancestor: &mut [usize],
    mark: &mut [usize],
    i: usize,
) -> usize {
    mark[i] = i;
    let mut row = 1usize; // diagonal
    let (cols, _) = a.row(order[i]);
    for &v in cols {
        let j = inv[v];
        if j >= i {
            continue;
        }
        // etree extension: link the root of j's tree to i, compressing
        // ancestor pointers along the way
        let mut node = j;
        while node != NONE && node < i {
            let next = ancestor[node];
            ancestor[node] = i;
            if next == NONE {
                parent[node] = i;
                break;
            }
            node = next;
        }
        // row-subtree count walk (Gilbert–Ng–Peyton marker trick)
        let mut node = j;
        while mark[node] != i {
            mark[node] = i;
            row += 1;
            if parent[node] == NONE || parent[node] >= i {
                break;
            }
            node = parent[node];
        }
    }
    row
}

/// Should a candidate whose first differing rank is `lo` (of `n`) take
/// the incremental path? The re-walked suffix costs O(n + suffix
/// row counts); below a quarter-length prefix the splice saves too
/// little over the flat O(n) overhead to beat the full walk. A pure
/// function of (n, lo) — never timing — so the engage decision is
/// identical at every thread count and in full-vs-incremental A/B runs.
pub fn suffix_eligible(n: usize, lo: usize) -> bool {
    4 * lo >= n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::analyze;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::gen::ProblemClass;
    use crate::order::amd;
    use crate::util::rng::Pcg64;

    fn full(a: &Csr, order: &[usize]) -> f64 {
        analyze(&a.permute_sym(order)).lnnz as f64
    }

    #[test]
    fn prepare_matches_full_analyze() {
        let a = laplacian_2d(9, 7);
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        for order in [(0..63).collect::<Vec<_>>(), amd(&a), (0..63).rev().collect::<Vec<_>>()] {
            base.prepare(&a, &order, &mut ws);
            assert_eq!(base.lnnz() as f64, full(&a, &order));
        }
    }

    #[test]
    fn eval_matches_full_on_segment_moves() {
        let a = laplacian_3d(4, 4, 4);
        let n = a.nrows();
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        let order = amd(&a);
        base.prepare(&a, &order, &mut ws);
        let mut rng = Pcg64::new(7);
        for _ in 0..40 {
            let len = 2 + rng.next_below(n / 2);
            let s = rng.next_below(n - len);
            let mut cand = order.clone();
            if rng.next_below(2) == 0 {
                cand[s..s + len].reverse();
            } else {
                let seg: Vec<usize> = cand.splice(s..s + len, std::iter::empty()).collect();
                let at = rng.next_below(cand.len() + 1);
                let tail = cand.split_off(at);
                cand.extend_from_slice(&seg);
                cand.extend_from_slice(&tail);
            }
            let lo = base.first_diff(&cand);
            assert_eq!(base.eval(&a, &cand, lo, &mut ws), full(&a, &cand));
        }
    }

    #[test]
    fn eval_handles_degenerate_windows() {
        let a = laplacian_2d(8, 8);
        let n = a.nrows();
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        let order: Vec<usize> = (0..n).collect();
        base.prepare(&a, &order, &mut ws);
        // identical candidate: lo == n, zero re-walk
        assert_eq!(base.first_diff(&order), n);
        assert_eq!(base.eval(&a, &order, n, &mut ws), base.lnnz() as f64);
        // lo == 0 (whole ordering reversed): incremental path degenerates
        // to a full walk but must still be exact
        let rev: Vec<usize> = (0..n).rev().collect();
        assert_eq!(base.first_diff(&rev), 0);
        assert_eq!(base.eval(&a, &rev, 0, &mut ws), full(&a, &rev));
        // suffix touching the root: reverse the last two ranks
        let mut tail = order.clone();
        tail.swap(n - 2, n - 1);
        let lo = base.first_diff(&tail);
        assert_eq!(lo, n - 2);
        assert_eq!(base.eval(&a, &tail, lo, &mut ws), full(&a, &tail));
    }

    #[test]
    fn eval_exact_on_unsymmetric_pattern_classes_symmetrized() {
        // incremental eval is Cholesky-only at the pool level, but the
        // walk itself must be exact on any symmetric pattern, including
        // the symmetrized circuit class
        let a = ProblemClass::Circuit.generate(80, 3).symmetrize();
        let n = a.nrows();
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        let order = amd(&a);
        base.prepare(&a, &order, &mut ws);
        let mut rng = Pcg64::new(11);
        for _ in 0..20 {
            let len = 2 + rng.next_below(n / 3);
            let s = rng.next_below(n - len);
            let mut cand = order.clone();
            cand[s..s + len].reverse();
            let lo = base.first_diff(&cand);
            assert_eq!(base.eval(&a, &cand, lo, &mut ws), full(&a, &cand));
        }
    }

    #[test]
    fn eligibility_gate_is_a_pure_threshold() {
        assert!(!suffix_eligible(100, 0));
        assert!(!suffix_eligible(100, 24));
        assert!(suffix_eligible(100, 25));
        assert!(suffix_eligible(100, 100));
        assert!(suffix_eligible(1, 1));
        assert!(!suffix_eligible(1, 0));
    }

    #[test]
    fn workspace_scratch_steady_state_is_allocation_free() {
        let a = laplacian_2d(10, 10);
        let mut ws = FactorWorkspace::new();
        let mut base = IncrementalBase::new();
        let order: Vec<usize> = (0..100).collect();
        base.prepare(&a, &order, &mut ws);
        let grown = ws.grow_events();
        let mut cand = order.clone();
        cand[60..80].reverse();
        let lo = base.first_diff(&cand);
        for _ in 0..16 {
            base.eval(&a, &cand, lo, &mut ws);
            base.prepare(&a, &order, &mut ws);
        }
        assert_eq!(ws.grow_events(), grown, "steady state must not reallocate");
    }
}
