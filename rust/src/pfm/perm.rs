//! Reparameterization 1 + 2 of the paper (§Reordering Network): node
//! scores → (soft) permutation matrix.
//!
//! The soft path is a **Sinkhorn-normalized score-difference kernel**:
//! anchors `t` are the sorted scores (treated stop-gradient, the
//! straight-through convention), the kernel is the Gaussian
//! `K[i][u] = exp(−(ỹ[u] − t_i)² / 2σ²)` over standardized scores ỹ, and
//! `T` rounds of row/column normalization push `K` toward the Birkhoff
//! polytope. At σ→0 the kernel collapses to the hard permutation matrix of
//! `argsort(y)`, so the soft matrix always stays in a neighbourhood of the
//! ordering the serving path would actually use.
//!
//! The hard path — inference and every acceptance test in the optimizer —
//! is the straight-through sort: [`crate::order::order_from_scores`], the
//! same argsort every learned method serves through.
//!
//! The backward pass ([`SoftPerm::backprop`]) replays the unrolled Sinkhorn
//! iterations in reverse (quotient rule per normalization) and chains
//! through the Gaussian kernel; it was validated against finite differences
//! (relative error ~1e−9) on random instances before the port.

/// Additive floor keeping Sinkhorn's normalizations away from 0/0 when a
/// kernel row is numerically empty.
const KERNEL_EPS: f64 = 1e-12;

/// Standardized rank scores of an ordering: `y[u] = k` where
/// `order[k] = u`. Ranks are distinct, so
/// `order_from_scores(&rank_scores(order)) == order` exactly — the shared
/// inverse every acceptance path uses to turn an accepted ordering back
/// into scores.
pub fn rank_scores(order: &[usize]) -> Vec<f64> {
    let mut y = vec![0.0f64; order.len()];
    for (pos, &u) in order.iter().enumerate() {
        y[u] = pos as f64;
    }
    standardize(&mut y);
    y
}

/// Standardize scores in place: zero mean, unit variance (σ only has
/// meaning relative to the score scale). Degenerate all-equal scores keep
/// their (zero) centered values.
pub fn standardize(y: &mut [f64]) {
    let n = y.len() as f64;
    if y.is_empty() {
        return;
    }
    let mean = y.iter().sum::<f64>() / n;
    let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let sd = var.sqrt().max(1e-8);
    for v in y.iter_mut() {
        *v = (*v - mean) / sd;
    }
}

/// A soft permutation `P[i][u]` (row = position, column = node) with the
/// forward tape needed to backpropagate through the Sinkhorn iterations.
pub struct SoftPerm {
    pub n: usize,
    /// row-major n×n doubly-stochastic (approximately) matrix
    pub p: Vec<f64>,
    /// Gaussian kernel before normalization
    kernel: Vec<f64>,
    /// `ỹ[u] − t_i` per entry (kernel exponent input)
    diff: Vec<f64>,
    /// per-iteration tape: (pre-normalization matrix, row sums, col sums)
    tape: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)>,
    sigma: f64,
}

impl SoftPerm {
    /// Forward pass: standardized scores → soft permutation. `y` must
    /// already be standardized (see [`standardize`]).
    pub fn forward(y: &[f64], sigma: f64, sinkhorn_iters: usize) -> SoftPerm {
        let n = y.len();
        let mut t: Vec<f64> = y.to_vec();
        t.sort_by(f64::total_cmp);
        let mut diff = vec![0.0f64; n * n];
        let mut kernel = vec![0.0f64; n * n];
        let inv2s2 = 1.0 / (2.0 * sigma * sigma);
        for i in 0..n {
            for u in 0..n {
                let d = y[u] - t[i];
                diff[i * n + u] = d;
                kernel[i * n + u] = (-d * d * inv2s2).exp();
            }
        }
        let mut m: Vec<f64> = kernel.iter().map(|k| k + KERNEL_EPS).collect();
        let mut tape = Vec::with_capacity(sinkhorn_iters);
        for _ in 0..sinkhorn_iters {
            let pre = m.clone();
            let mut rows = vec![0.0f64; n];
            for i in 0..n {
                rows[i] = m[i * n..(i + 1) * n].iter().sum();
                let inv = 1.0 / rows[i];
                for v in &mut m[i * n..(i + 1) * n] {
                    *v *= inv;
                }
            }
            let mut cols = vec![0.0f64; n];
            for i in 0..n {
                for u in 0..n {
                    cols[u] += m[i * n + u];
                }
            }
            for i in 0..n {
                for u in 0..n {
                    m[i * n + u] /= cols[u];
                }
            }
            tape.push((pre, rows, cols));
        }
        SoftPerm { n, p: m, kernel, diff, tape, sigma }
    }

    /// Backward pass: gradient w.r.t. `P` → gradient w.r.t. the scores.
    /// Anchors are stop-gradient (straight-through), standardization is
    /// treated as a projection (callers re-standardize after each update),
    /// so this is a subgradient of the smooth objective — exact for the
    /// unrolled Sinkhorn + kernel chain.
    pub fn backprop(&self, dp: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(dp.len(), n * n);
        let mut g = dp.to_vec();
        // replay normalizations in reverse; each is m' = m / s with the
        // quotient rule dL/dm = (dL/dm' − Σ dL/dm'·m'/s·s …) — concretely:
        // column step N = M/c:  dM[i][u] = (g[i][u] − Σ_k g[k][u]·N[k][u])/c[u]
        // row step    N = M/r:  dM[i][u] = (g[i][u] − Σ_k g[i][k]·N[i][k])/r[i]
        for (pre, rows, cols) in self.tape.iter().rev() {
            // reconstruct the row-normalized intermediate (input of the
            // column step)
            let mut rn = pre.clone();
            for i in 0..n {
                let inv = 1.0 / rows[i];
                for v in &mut rn[i * n..(i + 1) * n] {
                    *v *= inv;
                }
            }
            // column-normalization backward
            let mut coldot = vec![0.0f64; n];
            for i in 0..n {
                for u in 0..n {
                    coldot[u] += g[i * n + u] * rn[i * n + u] / cols[u];
                }
            }
            for i in 0..n {
                for u in 0..n {
                    g[i * n + u] = (g[i * n + u] - coldot[u]) / cols[u];
                }
            }
            // row-normalization backward
            for i in 0..n {
                let mut rowdot = 0.0;
                for u in 0..n {
                    rowdot += g[i * n + u] * pre[i * n + u] / (rows[i] * rows[i]);
                }
                for u in 0..n {
                    g[i * n + u] = g[i * n + u] / rows[i] - rowdot;
                }
            }
        }
        // kernel backward: K = exp(−d²/2σ²), d = y[u] − t_i  ⇒
        // dK/dy[u] = K · (−d)/σ²
        let inv_s2 = 1.0 / (self.sigma * self.sigma);
        let mut dy = vec![0.0f64; n];
        for i in 0..n {
            for u in 0..n {
                dy[u] += g[i * n + u] * self.kernel[i * n + u] * (-self.diff[i * n + u]) * inv_s2;
            }
        }
        dy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::order_from_scores;
    use crate::util::rng::Pcg64;

    fn rand_scores(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        let mut y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        standardize(&mut y);
        y
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut y = vec![3.0, 5.0, 7.0, 9.0];
        standardize(&mut y);
        let mean: f64 = y.iter().sum::<f64>() / 4.0;
        let var: f64 = y.iter().map(|v| v * v).sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-9);
        // constant scores don't blow up
        let mut c = vec![2.0; 5];
        standardize(&mut c);
        assert!(c.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn soft_perm_is_doubly_stochastic() {
        let mut rng = Pcg64::new(1);
        let y = rand_scores(14, &mut rng);
        let sp = SoftPerm::forward(&y, 0.15, 8);
        let n = sp.n;
        for u in 0..n {
            let col: f64 = (0..n).map(|i| sp.p[i * n + u]).sum();
            assert!((col - 1.0).abs() < 1e-9, "col {u} sums to {col}");
        }
        for i in 0..n {
            let row: f64 = sp.p[i * n..(i + 1) * n].iter().sum();
            // last normalization is by columns; rows are approximately 1
            assert!((row - 1.0).abs() < 0.2, "row {i} sums to {row}");
        }
        assert!(sp.p.iter().all(|&v| (0.0..=1.0 + 1e-9).contains(&v)));
    }

    #[test]
    fn small_sigma_recovers_hard_permutation() {
        // well-separated scores (spacing ≫ σ): a shuffled ramp. Gaussian
        // draws can land two scores within σ of each other, where the
        // kernel legitimately splits mass across the tie.
        let mut rng = Pcg64::new(2);
        let order0 = rng.permutation(10);
        let mut y = vec![0.0f64; 10];
        for (pos, &u) in order0.iter().enumerate() {
            y[u] = pos as f64;
        }
        standardize(&mut y);
        let sp = SoftPerm::forward(&y, 0.02, 10);
        let order = order_from_scores(&y);
        assert_eq!(order, order0);
        // P[i][order[i]] ≈ 1: position i holds the i-th smallest score
        for (i, &u) in order.iter().enumerate() {
            assert!(
                sp.p[i * sp.n + u] > 0.95,
                "P[{i}][{u}] = {}",
                sp.p[i * sp.n + u]
            );
        }
    }

    #[test]
    fn backprop_matches_finite_differences() {
        // frozen-anchor finite-difference check of the full y → P chain,
        // contracted with a fixed random cotangent
        let n = 9;
        let sigma = 0.2;
        let iters = 6;
        let mut rng = Pcg64::new(3);
        let y = rand_scores(n, &mut rng);
        let dp: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian()).collect();

        let sp = SoftPerm::forward(&y, sigma, iters);
        let dy = sp.backprop(&dp);

        // forward with anchors frozen to sort(y0)
        let mut anchors = y.clone();
        anchors.sort_by(f64::total_cmp);
        let eval = |yv: &[f64]| -> f64 {
            let inv2s2 = 1.0 / (2.0 * sigma * sigma);
            let mut m = vec![0.0f64; n * n];
            for i in 0..n {
                for u in 0..n {
                    let d = yv[u] - anchors[i];
                    m[i * n + u] = (-d * d * inv2s2).exp() + KERNEL_EPS;
                }
            }
            for _ in 0..iters {
                for i in 0..n {
                    let s: f64 = m[i * n..(i + 1) * n].iter().sum();
                    for v in &mut m[i * n..(i + 1) * n] {
                        *v /= s;
                    }
                }
                for u in 0..n {
                    let s: f64 = (0..n).map(|i| m[i * n + u]).sum();
                    for i in 0..n {
                        m[i * n + u] /= s;
                    }
                }
            }
            m.iter().zip(&dp).map(|(p, d)| p * d).sum()
        };
        let eps = 1e-6;
        for u in 0..n {
            let mut yp = y.clone();
            yp[u] += eps;
            let mut ym = y.clone();
            ym[u] -= eps;
            let fd = (eval(&yp) - eval(&ym)) / (2.0 * eps);
            assert!(
                (fd - dy[u]).abs() < 1e-5 * fd.abs().max(1.0),
                "node {u}: fd {fd} vs analytic {}",
                dy[u]
            );
        }
    }
}
