//! Zero-dependency scoped worker pool for parallel probe evaluation.
//!
//! The refinement loop's work unit is "evaluate the discrete objective of
//! one candidate ordering": a `permute_sym` + symbolic analysis (Cholesky)
//! or numeric Gilbert–Peierls factorization (LU). Candidates inside one
//! refinement step are independent by construction — they are all
//! generated *before* any of them is evaluated — so a step's batch fans
//! out over `std::thread::scope` workers and the results are reduced in
//! probe-index order afterwards.
//!
//! # Incremental evaluation
//!
//! [`eval_orders_with_base`](ProbePool::eval_orders_with_base) is the
//! segment-move entry point: candidates that share a long rank prefix
//! with the incumbent ordering are evaluated by the sublinear suffix
//! re-walk of [`crate::pfm::incremental`] instead of a full
//! `permute_sym` + `analyze`. Eligibility (`suffix_eligible`, Cholesky
//! only) and batch engagement (prefix savings must cover the one-time
//! base preparation) are pure functions of the candidate orderings —
//! never of timing or thread count — and the incremental value is
//! bit-identical to the full path, so every determinism guarantee below
//! is preserved. The pool counts `incremental` / `full` evaluations and
//! accumulates `saved_units` (rows *not* re-walked, in units of one
//! row), which `admm::refine` converts into bonus refinement steps.
//! `saved_units` accrues from the candidate structure even when
//! incremental evaluation is disabled, so an A/B run (incremental on vs
//! off) follows the identical search trajectory and must produce the
//! identical ordering — the equivalence the bench pair asserts.
//!
//! # Determinism
//!
//! Orderings are **bit-identical to the sequential path at any thread
//! count** because the three phases are strictly separated:
//!
//! 1. *generation* (single-threaded): every RNG draw happens here, in a
//!    fixed order that does not depend on the thread count;
//! 2. *evaluation* (parallel): each probe is a pure function of
//!    `(matrix, order)` — no RNG, no shared mutable state — and writes its
//!    result to its own index of the result vector;
//! 3. *reduction* (single-threaded): acceptance decisions scan the result
//!    vector in probe-index order with strict `<` comparisons, so ties
//!    resolve to the lowest index regardless of which worker finished
//!    first.
//!
//! The one caveat is an **expiring wall-clock deadline**: which probes get
//! skipped depends on when each one starts, which is timing — two runs
//! differ under an expiring deadline even at the same thread count, so no
//! thread count can promise bit-equality there. What always holds, budget
//! or not, is the strict-acceptance invariant (skipped probes come back
//! [`EvalSource::Skipped`] with value `∞` and are never accepted, so the
//! result is never worse than the init). The determinism tests and the
//! speedup bench therefore pin `time_ms: None`.
//!
//! # Thread safety
//!
//! The scoped pool needs no `unsafe` and no locks: the matrix is a shared
//! `&Csr` (all `Vec`-backed, `Sync`), each worker takes an exclusive
//! `&mut FactorWorkspace` from the pool's per-worker set (created once,
//! reused across batches — the steady state allocates nothing), and the
//! result vector is split into disjoint `&mut` chunks. `thread::scope`
//! joins every worker before returning, so no borrow outlives the call.
//!
//! # Deadlines
//!
//! A worker checks the optional deadline *before each probe* and returns
//! [`Eval::skipped`] for probes it skips. This bounds budget overshoot by
//! one in-flight probe per worker instead of one full batch (the
//! `OptBudget::serving()` wall-clock contract). Skipped probes are
//! counted separately from evaluated ones — `evals()` reports only work
//! actually performed, and the source tag (not value finiteness) is what
//! distinguishes "never ran" from "ran and failed".

use std::time::Instant;

use crate::factor::{FactorKind, FactorWorkspace};
use crate::pfm::incremental::{suffix_eligible, IncrementalBase};
use crate::pfm::objective::{eval_order_sourced, Eval, EvalSource};
use crate::sparse::Csr;
use crate::util::sync::effective_threads;

/// Two-sided SPSA directions (and segment-move candidates) generated per
/// refinement step. Fixed — the batch shape must not depend on the thread
/// count or determinism across thread counts would be lost.
pub const PROBES_PER_STEP: usize = 4;

/// Minimum nnz(A) for which a probe batch fans out to scoped threads.
/// Below this a probe (permute + symbolic analysis) costs little more
/// than a thread spawn, so the pool runs the batch sequentially — same
/// results by construction (the phases are identical), just without
/// paying spawn/join per batch on small serving matrices and the deepest
/// V-cycle levels.
const PAR_MIN_NNZ: usize = 2_000;

/// Per-candidate routing decided in the single-threaded generation
/// phase: the first rank where it differs from the batch base, and
/// whether the suffix re-walk applies.
#[derive(Clone, Copy)]
struct Route {
    lo: usize,
    incremental: bool,
}

/// A reusable worker pool: per-worker factorization workspaces plus the
/// configured parallelism. Threads are scoped per batch (no long-lived
/// channels to keep alive); the workspaces and the incremental base
/// persist across batches.
pub struct ProbePool {
    threads: usize,
    workspaces: Vec<FactorWorkspace>,
    /// evaluate eligible candidates via the incremental suffix re-walk?
    /// (off = full path for everything; the search trajectory is
    /// identical either way, only the cost per probe changes)
    incremental_enabled: bool,
    /// reusable per-base state for the incremental evaluator
    base: IncrementalBase,
    /// base ordering the savings ledger (and, when enabled, `base`)
    /// currently reflects — engaged batches off an unchanged incumbent
    /// reuse the preparation instead of paying it again. Tracked in both
    /// modes so the ledger (and therefore `admm::refine`'s bonus-step
    /// schedule) is identical whether or not incremental eval is on.
    accounted_base: Vec<usize>,
    accounted_valid: bool,
    evaluated: usize,
    skipped: usize,
    incremental: usize,
    base_prepares: usize,
    /// rows spared from re-walking by prefix splicing, net of base
    /// preparations (units of one matrix row; accrues from candidate
    /// structure alone, independent of `incremental_enabled`)
    saved_units: u64,
    /// wall clock spent inside incremental-engaged batches (prepare +
    /// probes) — the stage trace's `refine_incremental` span
    incr_secs: f64,
}

impl ProbePool {
    /// Pool with `threads` workers, clamped to `[1, available_parallelism]`
    /// — a request beyond the machine would only oversubscribe (results
    /// are bit-identical at any width, so clamping is free).
    /// [`threads`](Self::threads) reports the *effective* width.
    pub fn new(threads: usize) -> ProbePool {
        let threads = effective_threads(threads);
        ProbePool {
            threads,
            workspaces: FactorWorkspace::pool(threads),
            incremental_enabled: true,
            base: IncrementalBase::new(),
            accounted_base: Vec::new(),
            accounted_valid: false,
            evaluated: 0,
            skipped: 0,
            incremental: 0,
            base_prepares: 0,
            saved_units: 0,
            incr_secs: 0.0,
        }
    }

    /// Toggle incremental evaluation (on by default). Off forces every
    /// probe down the full `permute_sym` + analyze path — values and
    /// accepted orderings are bit-identical either way.
    pub fn with_incremental(mut self, on: bool) -> ProbePool {
        self.incremental_enabled = on;
        self
    }

    pub fn incremental_enabled(&self) -> bool {
        self.incremental_enabled
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Discrete-objective evaluations actually performed (deadline-skipped
    /// probes are not counted — see [`skipped`](Self::skipped)).
    pub fn evals(&self) -> usize {
        self.evaluated
    }

    /// Probes skipped because the deadline expired before they started.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Evaluations served by the incremental suffix re-walk.
    pub fn incremental_evals(&self) -> usize {
        self.incremental
    }

    /// Evaluations that ran the full `permute_sym` + analysis path.
    pub fn full_evals(&self) -> usize {
        self.evaluated - self.incremental
    }

    /// Full symbolic passes spent preparing incremental bases.
    pub fn base_prepares(&self) -> usize {
        self.base_prepares
    }

    /// Net rows spared from re-walking (prefix splices minus base
    /// preparations), in units of one matrix row. A pure function of the
    /// candidate batches seen — identical at any thread count and
    /// whether or not incremental evaluation is enabled.
    pub fn saved_units(&self) -> u64 {
        self.saved_units
    }

    /// Wall clock spent inside incremental-engaged probe batches.
    pub fn incremental_secs(&self) -> f64 {
        self.incr_secs
    }

    /// Evaluate the discrete objective of every candidate ordering.
    /// `results[i]` corresponds to `orders[i]`; probes skipped because
    /// `deadline` passed come back as [`Eval::skipped`].
    pub fn eval_orders(
        &mut self,
        a: &Csr,
        kind: FactorKind,
        orders: &[Vec<usize>],
        deadline: Option<Instant>,
    ) -> Vec<Eval> {
        self.run_batch(a, kind, orders, None, deadline)
    }

    /// Evaluate a batch of candidates that were derived from `base_order`
    /// (the segment-move entry point). Candidates sharing a long enough
    /// rank prefix with the base are evaluated incrementally when the
    /// batch's total spared prefix work exceeds the one-time base
    /// preparation; everything else (and every LU probe) takes the full
    /// path. Values are bit-identical to [`eval_orders`](Self::eval_orders)
    /// in all cases.
    pub fn eval_orders_with_base(
        &mut self,
        a: &Csr,
        kind: FactorKind,
        base_order: &[usize],
        orders: &[Vec<usize>],
        deadline: Option<Instant>,
    ) -> Vec<Eval> {
        if orders.is_empty() {
            return Vec::new();
        }
        let n = base_order.len();
        // generation-phase routing: pure candidate structure, no timing
        let routes: Vec<Route> = orders
            .iter()
            .map(|o| {
                let lo = first_diff(base_order, o);
                Route { lo, incremental: kind == FactorKind::Cholesky && suffix_eligible(n, lo) }
            })
            .collect();
        let spared: u64 = routes.iter().filter(|r| r.incremental).map(|r| r.lo as u64).sum();
        // engage only when the spliced prefixes outweigh the base
        // preparation — free when the incumbent is unchanged since the
        // last engaged batch, one full symbolic pass otherwise
        let reuse = self.accounted_valid && self.accounted_base == base_order;
        let prep_cost = if reuse { 0 } else { n as u64 };
        let engage = spared > prep_cost;
        if engage {
            self.saved_units += spared - prep_cost;
            if !reuse {
                self.accounted_base.clear();
                self.accounted_base.extend_from_slice(base_order);
                self.accounted_valid = true;
            }
        }
        if !(engage && self.incremental_enabled) {
            return self.run_batch(a, kind, orders, None, deadline);
        }
        let t0 = Instant::now();
        if !reuse {
            self.base.prepare(a, base_order, &mut self.workspaces[0]);
            self.base_prepares += 1;
        }
        let results = self.run_batch(a, kind, orders, Some(&routes), deadline);
        self.incr_secs += t0.elapsed().as_secs_f64();
        results
    }

    /// Drop the prepared-base association. Call when the matrix the pool
    /// will evaluate may have changed (e.g. entering a new refinement
    /// pass or V-cycle level) — an ordering match alone must never reuse
    /// a base prepared on a different matrix.
    pub fn invalidate_base(&mut self) {
        self.accounted_valid = false;
    }

    /// Shared batch driver: fan out (or run sequentially under the nnz
    /// cutoff), tally counters from the tagged results. `routes` carries
    /// per-candidate incremental routing; `None` means all-full.
    fn run_batch(
        &mut self,
        a: &Csr,
        kind: FactorKind,
        orders: &[Vec<usize>],
        routes: Option<&[Route]>,
        deadline: Option<Instant>,
    ) -> Vec<Eval> {
        if orders.is_empty() {
            return Vec::new();
        }
        let nw = if a.nnz() < PAR_MIN_NNZ { 1 } else { self.threads.min(orders.len()) };
        let mut results = vec![Eval::skipped(); orders.len()];
        let base = &self.base;
        let workspaces = &mut self.workspaces;
        if nw <= 1 {
            let ws = &mut workspaces[0];
            for (k, (o, r)) in orders.iter().zip(results.iter_mut()).enumerate() {
                *r = eval_probe(a, kind, base, ws, o, routes.map(|rt| rt[k]), deadline);
            }
        } else {
            let chunk = orders.len().div_ceil(nw);
            std::thread::scope(|s| {
                for (wi, (ws, (ord_chunk, res_chunk))) in workspaces
                    .iter_mut()
                    .zip(orders.chunks(chunk).zip(results.chunks_mut(chunk)))
                    .enumerate()
                {
                    s.spawn(move || {
                        for (k, (o, r)) in ord_chunk.iter().zip(res_chunk.iter_mut()).enumerate()
                        {
                            let route = routes.map(|rt| rt[wi * chunk + k]);
                            *r = eval_probe(a, kind, base, ws, o, route, deadline);
                        }
                    });
                }
            });
        }
        for e in &results {
            if e.evaluated() {
                self.evaluated += 1;
            } else {
                self.skipped += 1;
            }
            if e.source == EvalSource::Incremental {
                self.incremental += 1;
            }
        }
        results
    }
}

/// First rank where `cand` differs from `base` (`base.len()` if equal).
fn first_diff(base: &[usize], cand: &[usize]) -> usize {
    base.iter().zip(cand).position(|(b, c)| b != c).unwrap_or(base.len())
}

/// One probe: deadline check, then the golden criterion of `order` on `a`
/// — via the incremental suffix re-walk when routed there, the full
/// permute + analysis otherwise. Bit-identical values either way.
fn eval_probe(
    a: &Csr,
    kind: FactorKind,
    base: &IncrementalBase,
    ws: &mut FactorWorkspace,
    order: &[usize],
    route: Option<Route>,
    deadline: Option<Instant>,
) -> Eval {
    if deadline.is_some_and(|d| Instant::now() >= d) {
        return Eval::skipped();
    }
    match route {
        Some(r) if r.incremental => {
            Eval { value: base.eval(a, order, r.lo, ws), source: EvalSource::Incremental }
        }
        _ => eval_order_sourced(a, kind, ws, order),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::analyze;
    use crate::gen::grid::laplacian_2d;
    use crate::util::rng::Pcg64;

    #[test]
    fn pool_matches_sequential_at_every_thread_count() {
        let a = laplacian_2d(32, 32); // nnz ≈ 5k: above the parallel cutoff
        assert!(a.nnz() >= PAR_MIN_NNZ, "test must exercise the threaded path");
        let n = a.nrows();
        let mut rng = Pcg64::new(3);
        let orders: Vec<Vec<usize>> = (0..11).map(|_| rng.permutation(n)).collect();
        let mut seq = ProbePool::new(1);
        let base = seq.eval_orders(&a, FactorKind::Cholesky, &orders, None);
        assert_eq!(seq.evals(), 11);
        assert_eq!(seq.skipped(), 0);
        // ground truth through the direct symbolic path
        for (o, f) in orders.iter().zip(&base) {
            assert_eq!(f.value, analyze(&a.permute_sym(o)).lnnz as f64);
            assert_eq!(f.source, EvalSource::Symbolic);
        }
        for threads in [2, 3, 4, 8, 16] {
            let mut pool = ProbePool::new(threads);
            let fs = pool.eval_orders(&a, FactorKind::Cholesky, &orders, None);
            assert_eq!(fs, base, "threads={threads}");
            assert_eq!(pool.evals(), 11);
        }
    }

    #[test]
    fn incremental_batch_is_bit_identical_and_counted() {
        let a = laplacian_2d(32, 32);
        let n = a.nrows();
        let base_order: Vec<usize> = (0..n).collect();
        // segment moves high in the ordering: eligible and engaging
        let mut orders = Vec::new();
        for s in [600usize, 700, 800, 900] {
            let mut o = base_order.clone();
            o[s..s + 80].reverse();
            orders.push(o);
        }
        let mut full = ProbePool::new(1).with_incremental(false);
        let want = full.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
        assert_eq!(full.incremental_evals(), 0);
        assert_eq!(full.full_evals(), 4);
        assert!(full.saved_units() > 0, "savings accrue even with incremental off");
        for threads in [1, 2, 4, 8] {
            let mut pool = ProbePool::new(threads);
            let got =
                pool.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.value, w.value, "threads={threads}");
                assert_eq!(g.source, EvalSource::Incremental);
            }
            assert_eq!(pool.incremental_evals(), 4, "threads={threads}");
            assert_eq!(pool.full_evals(), 0);
            assert_eq!(pool.base_prepares(), 1);
            assert_eq!(pool.saved_units(), full.saved_units(), "mode-independent savings");
        }
    }

    #[test]
    fn unchanged_incumbent_reuses_the_prepared_base() {
        let a = laplacian_2d(32, 32);
        let n = a.nrows();
        let base_order: Vec<usize> = (0..n).collect();
        let mut orders = Vec::new();
        for s in [600usize, 700, 800, 900] {
            let mut o = base_order.clone();
            o[s..s + 80].reverse();
            orders.push(o);
        }
        let mut pool = ProbePool::new(2);
        pool.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
        let saved1 = pool.saved_units();
        pool.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
        assert_eq!(pool.base_prepares(), 1, "second batch must reuse the base");
        // without the prepare to amortize, the second batch saves more
        assert!(pool.saved_units() > 2 * saved1);
        // invalidation forces a fresh preparation
        pool.invalidate_base();
        pool.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
        assert_eq!(pool.base_prepares(), 2);
        assert_eq!(pool.incremental_evals(), 12);
    }

    #[test]
    fn short_prefix_batches_do_not_engage() {
        let a = laplacian_2d(16, 16);
        let n = a.nrows();
        let base_order: Vec<usize> = (0..n).collect();
        // every candidate differs from rank 0 on: nothing to splice
        let orders: Vec<Vec<usize>> = (0..4).map(|_| (0..n).rev().collect()).collect();
        let mut pool = ProbePool::new(2);
        let res = pool.eval_orders_with_base(&a, FactorKind::Cholesky, &base_order, &orders, None);
        assert!(res.iter().all(|e| e.source == EvalSource::Symbolic));
        assert_eq!(pool.incremental_evals(), 0);
        assert_eq!(pool.base_prepares(), 0);
        assert_eq!(pool.saved_units(), 0);
    }

    #[test]
    fn expired_deadline_skips_probes() {
        let a = laplacian_2d(8, 8);
        let orders: Vec<Vec<usize>> = vec![(0..64).collect(); 6];
        let mut pool = ProbePool::new(4);
        let fs = pool.eval_orders(&a, FactorKind::Cholesky, &orders, Some(Instant::now()));
        // the explicit status — not value finiteness — is what says
        // "never ran": the counter stays honest even for objectives that
        // could legitimately come back infinite
        assert!(fs.iter().all(|e| e.source == EvalSource::Skipped), "{fs:?}");
        assert!(fs.iter().all(|e| e.value.is_infinite() && !e.evaluated()));
        assert_eq!(pool.evals(), 0, "skipped probes must not count as evals");
        assert_eq!(pool.skipped(), 6, "…but must be visible as skips");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let a = laplacian_2d(4, 4);
        let mut pool = ProbePool::new(4);
        assert!(pool.eval_orders(&a, FactorKind::Cholesky, &[], None).is_empty());
        assert!(pool
            .eval_orders_with_base(&a, FactorKind::Cholesky, &[0, 1], &[], None)
            .is_empty());
        assert_eq!(pool.evals(), 0);
        assert_eq!(pool.skipped(), 0);
    }
}
