//! Zero-dependency scoped worker pool for parallel probe evaluation.
//!
//! The refinement loop's work unit is "evaluate the discrete objective of
//! one candidate ordering": a `permute_sym` + symbolic analysis (Cholesky)
//! or numeric Gilbert–Peierls factorization (LU). Candidates inside one
//! refinement step are independent by construction — they are all
//! generated *before* any of them is evaluated — so a step's batch fans
//! out over `std::thread::scope` workers and the results are reduced in
//! probe-index order afterwards.
//!
//! # Determinism
//!
//! Orderings are **bit-identical to the sequential path at any thread
//! count** because the three phases are strictly separated:
//!
//! 1. *generation* (single-threaded): every RNG draw happens here, in a
//!    fixed order that does not depend on the thread count;
//! 2. *evaluation* (parallel): each probe is a pure function of
//!    `(matrix, order)` — no RNG, no shared mutable state — and writes its
//!    result to its own index of the result vector;
//! 3. *reduction* (single-threaded): acceptance decisions scan the result
//!    vector in probe-index order with strict `<` comparisons, so ties
//!    resolve to the lowest index regardless of which worker finished
//!    first.
//!
//! The one caveat is an **expiring wall-clock deadline**: which probes get
//! skipped depends on when each one starts, which is timing — two runs
//! differ under an expiring deadline even at the same thread count, so no
//! thread count can promise bit-equality there. What always holds, budget
//! or not, is the strict-acceptance invariant (skipped probes are `∞` and
//! never accepted, so the result is never worse than the init). The
//! determinism tests and the speedup bench therefore pin `time_ms: None`.
//!
//! # Thread safety
//!
//! The scoped pool needs no `unsafe` and no locks: the matrix is a shared
//! `&Csr` (all `Vec`-backed, `Sync`), each worker takes an exclusive
//! `&mut FactorWorkspace` from the pool's per-worker set (created once,
//! reused across batches — the steady state allocates nothing), and the
//! result vector is split into disjoint `&mut` chunks. `thread::scope`
//! joins every worker before returning, so no borrow outlives the call.
//!
//! # Deadlines
//!
//! A worker checks the optional deadline *before each probe* and returns
//! `f64::INFINITY` for probes it skips (never accepted — every real
//! objective value is finite). This bounds budget overshoot by one
//! in-flight probe per worker instead of one full batch (the
//! `OptBudget::serving()` wall-clock contract).

use std::time::Instant;

use crate::factor::{FactorKind, FactorWorkspace};
use crate::pfm::objective::eval_order;
use crate::sparse::Csr;
use crate::util::sync::effective_threads;

/// Two-sided SPSA directions (and segment-move candidates) generated per
/// refinement step. Fixed — the batch shape must not depend on the thread
/// count or determinism across thread counts would be lost.
pub const PROBES_PER_STEP: usize = 4;

/// Minimum nnz(A) for which a probe batch fans out to scoped threads.
/// Below this a probe (permute + symbolic analysis) costs little more
/// than a thread spawn, so the pool runs the batch sequentially — same
/// results by construction (the phases are identical), just without
/// paying spawn/join per batch on small serving matrices and the deepest
/// V-cycle levels.
const PAR_MIN_NNZ: usize = 2_000;

/// A reusable worker pool: per-worker factorization workspaces plus the
/// configured parallelism. Threads are scoped per batch (no long-lived
/// channels to keep alive); the workspaces persist across batches.
pub struct ProbePool {
    threads: usize,
    workspaces: Vec<FactorWorkspace>,
    evals: usize,
}

impl ProbePool {
    /// Pool with `threads` workers, clamped to `[1, available_parallelism]`
    /// — a request beyond the machine would only oversubscribe (results
    /// are bit-identical at any width, so clamping is free).
    /// [`threads`](Self::threads) reports the *effective* width.
    pub fn new(threads: usize) -> ProbePool {
        let threads = effective_threads(threads);
        ProbePool { threads, workspaces: FactorWorkspace::pool(threads), evals: 0 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Discrete-objective evaluations actually performed (deadline-skipped
    /// probes are not counted).
    pub fn evals(&self) -> usize {
        self.evals
    }

    /// Evaluate the discrete objective of every candidate ordering.
    /// `results[i]` corresponds to `orders[i]`; probes skipped because
    /// `deadline` passed come back as `f64::INFINITY`.
    pub fn eval_orders(
        &mut self,
        a: &Csr,
        kind: FactorKind,
        orders: &[Vec<usize>],
        deadline: Option<Instant>,
    ) -> Vec<f64> {
        if orders.is_empty() {
            return Vec::new();
        }
        let nw = if a.nnz() < PAR_MIN_NNZ { 1 } else { self.threads.min(orders.len()) };
        let mut results = vec![f64::INFINITY; orders.len()];
        if nw <= 1 {
            let ws = &mut self.workspaces[0];
            for (o, r) in orders.iter().zip(results.iter_mut()) {
                *r = eval_probe(a, kind, ws, o, deadline);
            }
        } else {
            let chunk = orders.len().div_ceil(nw);
            std::thread::scope(|s| {
                for (ws, (ord_chunk, res_chunk)) in self
                    .workspaces
                    .iter_mut()
                    .zip(orders.chunks(chunk).zip(results.chunks_mut(chunk)))
                {
                    s.spawn(move || {
                        for (o, r) in ord_chunk.iter().zip(res_chunk.iter_mut()) {
                            *r = eval_probe(a, kind, ws, o, deadline);
                        }
                    });
                }
            });
        }
        self.evals += results.iter().filter(|f| f.is_finite()).count();
        results
    }
}

/// One probe: deadline check, then the golden criterion of `order` on `a`.
fn eval_probe(
    a: &Csr,
    kind: FactorKind,
    ws: &mut FactorWorkspace,
    order: &[usize],
    deadline: Option<Instant>,
) -> f64 {
    if deadline.is_some_and(|d| Instant::now() >= d) {
        return f64::INFINITY;
    }
    eval_order(a, kind, ws, order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::analyze;
    use crate::gen::grid::laplacian_2d;
    use crate::util::rng::Pcg64;

    #[test]
    fn pool_matches_sequential_at_every_thread_count() {
        let a = laplacian_2d(32, 32); // nnz ≈ 5k: above the parallel cutoff
        assert!(a.nnz() >= PAR_MIN_NNZ, "test must exercise the threaded path");
        let n = a.nrows();
        let mut rng = Pcg64::new(3);
        let orders: Vec<Vec<usize>> = (0..11).map(|_| rng.permutation(n)).collect();
        let mut seq = ProbePool::new(1);
        let base = seq.eval_orders(&a, FactorKind::Cholesky, &orders, None);
        assert_eq!(seq.evals(), 11);
        // ground truth through the direct symbolic path
        for (o, f) in orders.iter().zip(&base) {
            assert_eq!(*f, analyze(&a.permute_sym(o)).lnnz as f64);
        }
        for threads in [2, 3, 4, 8, 16] {
            let mut pool = ProbePool::new(threads);
            let fs = pool.eval_orders(&a, FactorKind::Cholesky, &orders, None);
            assert_eq!(fs, base, "threads={threads}");
            assert_eq!(pool.evals(), 11);
        }
    }

    #[test]
    fn expired_deadline_skips_probes() {
        let a = laplacian_2d(8, 8);
        let orders: Vec<Vec<usize>> = vec![(0..64).collect(); 6];
        let mut pool = ProbePool::new(4);
        let fs = pool.eval_orders(&a, FactorKind::Cholesky, &orders, Some(Instant::now()));
        assert!(fs.iter().all(|f| f.is_infinite()), "{fs:?}");
        assert_eq!(pool.evals(), 0, "skipped probes must not count as evals");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let a = laplacian_2d(4, 4);
        let mut pool = ProbePool::new(4);
        assert!(pool.eval_orders(&a, FactorKind::Cholesky, &[], None).is_empty());
        assert_eq!(pool.evals(), 0);
    }
}
