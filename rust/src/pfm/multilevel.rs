//! Coarsen–optimize–prolong: what makes the native optimizer servable at
//! production sizes.
//!
//! The dense ADMM window is O(n²) memory and O(n³) per iteration, so it is
//! capped. Above the cap the matrix's graph is coarsened with the existing
//! heavy-edge machinery ([`crate::graph::coarsen::coarsen_to`]) down to the
//! cap — and, new in the V-cycle path, **every intermediate level is kept**
//! ([`Hierarchy`]): per-level fine→coarse maps plus each level's
//! SPD-shifted weighted-Laplacian matrix. The ADMM loop runs on the
//! coarsest window (accepting on the *coarsest* discrete objective), and
//! the optimized scores walk back up level by level: prolong to the next
//! finer level (aggregate score + infinitesimal fine tie-break, preserving
//! within-aggregate order), then a budgeted probe-pool refinement pass
//! accepted on *that level's* discrete criterion. Both the direct
//! prolongation (the PR 4 coarsest-only candidate) and the V-cycle result
//! are candidates at the finest level, each accepted only if it improves
//! the fine golden criterion — so the V-cycle can refine but never
//! regress the coarsest-only path.
//!
//! Coarsening is driven by a **dedicated constant-seeded RNG**
//! ([`COARSEN_SEED`]), not the request seed: the hierarchy is a structural
//! property of the matrix, identical for every seed — which is what lets
//! the coordinator compute it once per pattern and share it across a
//! same-pattern batch with bit-identical results to solo runs.

use crate::graph::coarsen::coarsen_to;
use crate::graph::Graph;
use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// Default dense-window / multilevel cap: above this the optimizer
/// coarsens. 160² doubles ≈ 200 KiB per dense buffer and keeps one ADMM
/// iteration in the low tens of millions of flops.
pub const DEFAULT_DENSE_CAP: usize = 160;

/// Scale of the fine-score tie-break added to prolonged coarse scores —
/// small enough that aggregates never interleave (coarse scores are
/// standardized ranks, gap ≥ 1/n ≫ 1e-3·σ-range/n for the caps in use).
const TIEBREAK: f64 = 1e-3;

/// Seed of the dedicated coarsening RNG (heavy-edge matching visit order).
/// Constant so a hierarchy depends only on the matrix — shareable across
/// same-pattern requests, identical between shared and solo runs.
pub const COARSEN_SEED: u64 = 0xC0A2_5EED;

/// Weighted graph Laplacian of a coarse level, shifted to be SPD — the
/// matrix whose fill the coarse ADMM optimizes against.
pub fn coarse_matrix(g: &Graph) -> Csr {
    let n = g.n();
    let mut coo = Coo::square(n);
    let mut diag = vec![1.0f64; n];
    for u in 0..n {
        for (&v, &w) in g.neighbors(u).iter().zip(g.edge_weights(u)) {
            if v != u {
                coo.push(u, v, -w);
                diag[u] += w;
            }
        }
    }
    for (u, d) in diag.iter().enumerate() {
        coo.push(u, u, *d);
    }
    coo.to_csr()
}

/// The full coarsening hierarchy of a matrix's graph, finest to coarsest.
/// Level `i` has matrix `matrices[i]`; `maps[0]` sends original nodes to
/// level 0 and `maps[i]` sends level `i-1` nodes to level `i`.
pub struct Hierarchy {
    /// per-level fine→coarse aggregation maps (see type docs)
    pub maps: Vec<Vec<usize>>,
    /// per-level SPD-shifted weighted Laplacians
    pub matrices: Vec<Csr>,
}

impl Hierarchy {
    /// Coarsen `a`'s graph until ≤ `cap` nodes, keeping every level.
    /// Deterministic per matrix (driven by [`COARSEN_SEED`]). Returns
    /// `None` when `a` is already small or no contraction is possible
    /// (edgeless graph).
    pub fn build(a: &Csr, cap: usize) -> Option<Hierarchy> {
        let n = a.nrows();
        if n <= cap {
            return None;
        }
        let mut rng = Pcg64::new(COARSEN_SEED);
        let g = Graph::from_matrix(a);
        let levels = coarsen_to(&g, cap, &mut rng);
        if levels.is_empty() {
            return None;
        }
        Some(Hierarchy {
            maps: levels.iter().map(|l| l.fine_to_coarse.clone()).collect(),
            matrices: levels.iter().map(|l| coarse_matrix(&l.graph)).collect(),
        })
    }

    /// Number of coarse levels.
    pub fn levels(&self) -> usize {
        self.maps.len()
    }

    /// The coarsest level's matrix (the ADMM window source).
    pub fn coarsest(&self) -> &Csr {
        self.matrices.last().expect("hierarchy has at least one level")
    }

    /// Composed original → coarsest map (the PR 4 single-shot
    /// prolongation path).
    pub fn composed(&self) -> Vec<usize> {
        let mut map = self.maps[0].clone();
        for lvl in &self.maps[1..] {
            for m in map.iter_mut() {
                *m = lvl[*m];
            }
        }
        map
    }

    /// Restrict fine scores through every level. `out[i]` holds the scores
    /// at level `i` (mean per aggregate of the next finer level) — the
    /// V-cycle's per-level prolongation tie-breaks.
    pub fn restrict_all(&self, y_fine: &[f64]) -> Vec<Vec<f64>> {
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(self.levels());
        for (i, (map, m)) in self.maps.iter().zip(&self.matrices).enumerate() {
            let src: &[f64] = if i == 0 { y_fine } else { &out[i - 1] };
            out.push(restrict(src, map, m.nrows()));
        }
        out
    }
}

/// Restrict fine scores to the coarse level: mean per aggregate.
pub fn restrict(y_fine: &[f64], fine_to_coarse: &[usize], coarse_n: usize) -> Vec<f64> {
    let mut sum = vec![0.0f64; coarse_n];
    let mut cnt = vec![0usize; coarse_n];
    for (u, &c) in fine_to_coarse.iter().enumerate() {
        sum[c] += y_fine[u];
        cnt[c] += 1;
    }
    for (s, &c) in sum.iter_mut().zip(&cnt) {
        *s /= c.max(1) as f64;
    }
    sum
}

/// Prolong coarse scores to the fine level, tie-breaking inside each
/// aggregate with the (standardized) fine scores so the within-aggregate
/// order of the init survives.
pub fn prolong(y_coarse: &[f64], fine_to_coarse: &[usize], y_fine_tiebreak: &[f64]) -> Vec<f64> {
    fine_to_coarse
        .iter()
        .zip(y_fine_tiebreak)
        .map(|(&c, &t)| y_coarse[c] + TIEBREAK * t)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::order::order_from_scores;
    use crate::util::check::check_permutation;

    #[test]
    fn hierarchy_respects_cap_and_maps_every_node() {
        let a = laplacian_2d(24, 24); // n = 576
        let h = Hierarchy::build(&a, 160).expect("must coarsen");
        let cn = h.coarsest().nrows();
        assert!(cn <= 160 + 160 / 2, "coarse n {cn} way over cap");
        assert!(cn < 576);
        assert!(h.levels() >= 2, "576 → ≤160 needs ≥ 2 halvings");
        // every level's map covers the finer level and lands in range
        let mut fine_n = 576;
        for (map, m) in h.maps.iter().zip(&h.matrices) {
            assert_eq!(map.len(), fine_n);
            let coarse_n = m.nrows();
            assert!(coarse_n < fine_n);
            assert!(map.iter().all(|&c| c < coarse_n));
            // level matrices are symmetric and SPD-shifted
            assert!(m.is_symmetric(1e-12));
            assert!(m.diag_dominance_margin() > 0.0);
            fine_n = coarse_n;
        }
        // composed map equals walking the per-level maps
        let composed = h.composed();
        assert_eq!(composed.len(), 576);
        for u in 0..576 {
            let mut c = h.maps[0][u];
            for lvl in &h.maps[1..] {
                c = lvl[c];
            }
            assert_eq!(composed[u], c);
        }
    }

    #[test]
    fn hierarchy_is_deterministic_per_matrix() {
        let a = laplacian_2d(20, 20);
        let h1 = Hierarchy::build(&a, 100).unwrap();
        let h2 = Hierarchy::build(&a, 100).unwrap();
        assert_eq!(h1.maps, h2.maps);
        assert_eq!(h1.levels(), h2.levels());
        assert_eq!(h1.coarsest().nrows(), h2.coarsest().nrows());
    }

    #[test]
    fn small_or_edgeless_inputs_do_not_coarsen() {
        let a = laplacian_2d(5, 5);
        assert!(Hierarchy::build(&a, 160).is_none(), "already under cap");
        let mut coo = Coo::square(40);
        for i in 0..40 {
            coo.push(i, i, 1.0);
        }
        assert!(Hierarchy::build(&coo.to_csr(), 10).is_none(), "edgeless");
    }

    #[test]
    fn restrict_prolong_roundtrip_preserves_order_at_every_level() {
        let a = laplacian_2d(20, 20); // n = 400
        let h = Hierarchy::build(&a, 100).unwrap();
        let y_fine: Vec<f64> = (0..400).map(|u| u as f64 / 400.0).collect();
        let rests = h.restrict_all(&y_fine);
        assert_eq!(rests.len(), h.levels());
        for (r, m) in rests.iter().zip(&h.matrices) {
            assert_eq!(r.len(), m.nrows());
        }
        // walk back up level by level: every prolongation argsorts to a
        // valid permutation of its level
        let mut y = rests.last().unwrap().clone();
        for lvl in (0..h.levels() - 1).rev() {
            y = prolong(&y, &h.maps[lvl + 1], &rests[lvl]);
            check_permutation(&order_from_scores(&y))
                .unwrap_or_else(|e| panic!("level {lvl}: {e}"));
        }
        let y_back = prolong(&y, &h.maps[0], &y_fine);
        check_permutation(&order_from_scores(&y_back)).unwrap();
        // nodes of the same level-0 aggregate stay in their fine relative
        // order (tie-break makes all scores distinct within an aggregate)
        for u in 0..399 {
            for v in (u + 1)..400 {
                if h.maps[0][u] == h.maps[0][v] {
                    assert!(
                        (y_back[u] < y_back[v]) == (y_fine[u] < y_fine[v]),
                        "aggregate-internal order flipped for ({u},{v})"
                    );
                }
            }
        }
    }
}
