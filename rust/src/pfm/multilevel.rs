//! Coarsen–optimize–prolong: what makes the native optimizer servable at
//! production sizes.
//!
//! The dense ADMM window is O(n²) memory and O(n³) per iteration, so it is
//! capped. Above the cap the matrix's graph is coarsened with the existing
//! heavy-edge machinery ([`crate::graph::coarsen::coarsen_to`]) down to the
//! cap, the ADMM loop runs on the coarsest level's weighted-Laplacian
//! window (accepting on the *coarse* discrete objective), and the
//! optimized coarse scores are prolonged back: every fine node inherits
//! its aggregate's score, with the fine init scores as an infinitesimal
//! tie-break so the within-aggregate order is preserved. The prolonged
//! scores are a *candidate* — the caller accepts them only if they improve
//! the fine-level golden criterion, then polishes with the sampled-
//! subgradient refinement that works at any n.

use crate::graph::coarsen::coarsen_to;
use crate::graph::Graph;
use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// Default dense-window / multilevel cap: above this the optimizer
/// coarsens. 160² doubles ≈ 200 KiB per dense buffer and keeps one ADMM
/// iteration in the low tens of millions of flops.
pub const DEFAULT_DENSE_CAP: usize = 160;

/// Scale of the fine-score tie-break added to prolonged coarse scores —
/// small enough that aggregates never interleave (coarse scores are
/// standardized ranks, gap ≥ 1/n ≫ 1e-3·σ-range/n for the caps in use).
const TIEBREAK: f64 = 1e-3;

/// Weighted graph Laplacian of a coarse level, shifted to be SPD — the
/// matrix whose fill the coarse ADMM optimizes against.
pub fn coarse_matrix(g: &Graph) -> Csr {
    let n = g.n();
    let mut coo = Coo::square(n);
    let mut diag = vec![1.0f64; n];
    for u in 0..n {
        for (&v, &w) in g.neighbors(u).iter().zip(g.edge_weights(u)) {
            if v != u {
                coo.push(u, v, -w);
                diag[u] += w;
            }
        }
    }
    for (u, d) in diag.iter().enumerate() {
        coo.push(u, u, *d);
    }
    coo.to_csr()
}

/// A coarsening of a fine graph down to (at most around) `cap` nodes.
pub struct Coarsening {
    /// composed fine node → coarsest node map
    pub fine_to_coarse: Vec<usize>,
    /// coarsest-level matrix (weighted Laplacian, SPD-shifted)
    pub matrix: Csr,
    /// number of levels contracted
    pub levels: usize,
}

/// Coarsen the graph of `a` until ≤ `cap` nodes. Returns `None` when no
/// contraction is possible (edgeless graph) or `a` is already small.
pub fn coarsen(a: &Csr, cap: usize, rng: &mut Pcg64) -> Option<Coarsening> {
    let n = a.nrows();
    if n <= cap {
        return None;
    }
    let g = Graph::from_matrix(a);
    let levels = coarsen_to(&g, cap, rng);
    if levels.is_empty() {
        return None;
    }
    // compose the per-level maps into fine → coarsest
    let mut map: Vec<usize> = levels[0].fine_to_coarse.clone();
    for level in &levels[1..] {
        for m in map.iter_mut() {
            *m = level.fine_to_coarse[*m];
        }
    }
    let coarsest = &levels[levels.len() - 1].graph;
    Some(Coarsening {
        fine_to_coarse: map,
        matrix: coarse_matrix(coarsest),
        levels: levels.len(),
    })
}

/// Restrict fine scores to the coarse level: mean per aggregate.
pub fn restrict(y_fine: &[f64], fine_to_coarse: &[usize], coarse_n: usize) -> Vec<f64> {
    let mut sum = vec![0.0f64; coarse_n];
    let mut cnt = vec![0usize; coarse_n];
    for (u, &c) in fine_to_coarse.iter().enumerate() {
        sum[c] += y_fine[u];
        cnt[c] += 1;
    }
    for (s, &c) in sum.iter_mut().zip(&cnt) {
        *s /= c.max(1) as f64;
    }
    sum
}

/// Prolong coarse scores to the fine level, tie-breaking inside each
/// aggregate with the (standardized) fine scores so the within-aggregate
/// order of the init survives.
pub fn prolong(y_coarse: &[f64], fine_to_coarse: &[usize], y_fine_tiebreak: &[f64]) -> Vec<f64> {
    fine_to_coarse
        .iter()
        .zip(y_fine_tiebreak)
        .map(|(&c, &t)| y_coarse[c] + TIEBREAK * t)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::order::order_from_scores;
    use crate::util::check::check_permutation;

    #[test]
    fn coarsen_respects_cap_and_maps_every_node() {
        let a = laplacian_2d(24, 24); // n = 576
        let mut rng = Pcg64::new(1);
        let c = coarsen(&a, 160, &mut rng).expect("must coarsen");
        let cn = c.matrix.nrows();
        assert!(cn <= 160 + 160 / 2, "coarse n {cn} way over cap");
        assert!(cn < 576);
        assert_eq!(c.fine_to_coarse.len(), 576);
        assert!(c.fine_to_coarse.iter().all(|&m| m < cn));
        assert!(c.levels >= 1);
        // coarse matrix is symmetric and SPD-shifted (diag dominant)
        assert!(c.matrix.is_symmetric(1e-12));
        assert!(c.matrix.diag_dominance_margin() > 0.0);
    }

    #[test]
    fn small_or_edgeless_inputs_do_not_coarsen() {
        let a = laplacian_2d(5, 5);
        let mut rng = Pcg64::new(2);
        assert!(coarsen(&a, 160, &mut rng).is_none(), "already under cap");
        let mut coo = Coo::square(40);
        for i in 0..40 {
            coo.push(i, i, 1.0);
        }
        assert!(coarsen(&coo.to_csr(), 10, &mut rng).is_none(), "edgeless");
    }

    #[test]
    fn restrict_prolong_roundtrip_preserves_order() {
        let a = laplacian_2d(20, 20); // n = 400
        let mut rng = Pcg64::new(3);
        let c = coarsen(&a, 100, &mut rng).unwrap();
        let y_fine: Vec<f64> = (0..400).map(|u| u as f64 / 400.0).collect();
        let y_c = restrict(&y_fine, &c.fine_to_coarse, c.matrix.nrows());
        assert_eq!(y_c.len(), c.matrix.nrows());
        let y_back = prolong(&y_c, &c.fine_to_coarse, &y_fine);
        // prolonged scores argsort to a valid permutation (tie-break makes
        // all scores distinct within an aggregate)
        check_permutation(&order_from_scores(&y_back)).unwrap();
        // nodes of the same aggregate stay in their fine relative order
        for u in 0..399 {
            for v in (u + 1)..400 {
                if c.fine_to_coarse[u] == c.fine_to_coarse[v] {
                    assert!(
                        (y_back[u] < y_back[v]) == (y_fine[u] < y_fine[v]),
                        "aggregate-internal order flipped for ({u},{v})"
                    );
                }
            }
        }
    }
}
