//! The factorization-in-loop objective.
//!
//! Two faces of the same criterion:
//!
//! * **Discrete (golden)** — [`OrderObjective`] evaluates a hard
//!   permutation through the existing factor machinery: exact nnz(L) via
//!   [`crate::factor::analyze`] for symmetric matrices, numeric nnz(L+U)
//!   via the Gilbert–Peierls kernel (structural A+Aᵀ bound on a singular
//!   pivot sequence) for unsymmetric ones. Every acceptance decision in
//!   the optimizer is made on this, so the optimizer can never report an
//!   ordering worse than its init on the criterion that matters.
//! * **Smooth (ADMM window)** — the augmented-Lagrangian pieces of the
//!   paper's Eq. 12 on a dense max-normalized window: residual
//!   `R = P A Pᵀ − L Lᵀ`, smooth part `⟨Γ, R⟩ + ρ/2‖R‖²`, with closed-form
//!   gradients w.r.t. the dense factor `L` and the soft permutation `P`
//!   (the ‖L‖₁ term is handled by the proximal operator in `admm`). The
//!   dense window is what the score gradient flows through for small n;
//!   beyond the multilevel cap the optimizer switches to sampled
//!   subgradients — two-sided SPSA probes of the discrete objective
//!   (generated in `admm::refine`, evaluated by `probes::ProbePool`),
//!   which need only sparse symbolic work and therefore scale with
//!   nnz(L), not n².
//!
//! [`eval_order`] is the shared work unit: a pure function of
//! `(matrix, kind, order)` over caller-owned scratch, which is exactly
//! what lets the probe pool evaluate candidates in parallel with
//! per-worker workspaces while [`OrderObjective`] keeps the convenient
//! owning wrapper for the sequential paths.

use crate::factor::lu::{self, LuOptions};
use crate::factor::{analyze, analyze_lu, FactorKind, FactorWorkspace};
use crate::sparse::Csr;

/// How an [`Eval`] value was produced. The acceptance scans gate on
/// [`is_exact`](EvalSource::is_exact): a `LuBound` is a structural
/// *upper bound* substituted when the numeric LU fails on a candidate's
/// pivot sequence — comparing it against a numeric nnz(L+U) (or letting
/// it displace the incumbent) manufactures wins that are artifacts of
/// the fallback, so the optimizer must never accept one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalSource {
    /// exact nnz(L) via symbolic analysis of the permuted matrix
    Symbolic,
    /// exact nnz(L) via the incremental suffix re-walk
    /// (`pfm::incremental` — bit-identical to `Symbolic`)
    Incremental,
    /// exact numeric nnz(L+U) from the Gilbert–Peierls kernel
    NumericLu,
    /// structural A+Aᵀ bound: the LU factorization failed (singular
    /// pivot sequence) — comparable to other bounds only, never exact
    LuBound,
    /// never evaluated (probe-pool deadline expired first)
    Skipped,
}

impl EvalSource {
    /// Is this an exact measurement of the golden criterion?
    pub fn is_exact(self) -> bool {
        matches!(self, EvalSource::Symbolic | EvalSource::Incremental | EvalSource::NumericLu)
    }
}

/// A discrete-objective evaluation tagged with its provenance. Lower
/// `value` is better, but only [`is_exact`](Eval::is_exact) evaluations
/// may win an acceptance scan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Eval {
    pub value: f64,
    pub source: EvalSource,
}

impl Eval {
    /// A probe the pool never ran: infinite value, never acceptable.
    pub fn skipped() -> Eval {
        Eval { value: f64::INFINITY, source: EvalSource::Skipped }
    }

    /// Did the probe actually run (regardless of outcome)?
    pub fn evaluated(&self) -> bool {
        self.source != EvalSource::Skipped
    }

    pub fn is_exact(&self) -> bool {
        self.source.is_exact()
    }
}

/// Index of the best *acceptable* candidate in a probe batch: the
/// minimum value among exact-source evaluations, ties to the lowest
/// index (strict `<` in probe-index order — the determinism contract).
/// Fallback bounds and skipped probes never win; `None` if nothing in
/// the batch is exact.
pub fn best_exact(evals: &[Eval]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, e) in evals.iter().enumerate() {
        if !e.is_exact() {
            continue;
        }
        if best.map_or(true, |b| e.value < evals[b].value) {
            best = Some(i);
        }
    }
    best
}

/// Discrete objective evaluator: hard ordering → structural factor nnz.
/// Owns the scratch workspace so repeated evaluations (the SPSA inner
/// loop) reuse allocations.
pub struct OrderObjective<'a> {
    a: &'a Csr,
    kind: FactorKind,
    ws: FactorWorkspace,
    /// number of objective evaluations performed (optimizer bookkeeping)
    pub evals: usize,
}

impl<'a> OrderObjective<'a> {
    /// Evaluator for `a`, on the factorization its symmetry calls for.
    pub fn new(a: &'a Csr) -> OrderObjective<'a> {
        OrderObjective { a, kind: FactorKind::for_matrix(a), ws: FactorWorkspace::new(), evals: 0 }
    }

    pub fn kind(&self) -> FactorKind {
        self.kind
    }

    /// Structural factor size of `a` under `order`: nnz(L) for Cholesky,
    /// nnz(L+U) for LU (numeric when the factorization succeeds, the
    /// structural A+Aᵀ bound otherwise). Lower is better; this is the
    /// golden criterion the paper's ‖L‖₁ approximates.
    pub fn eval(&mut self, order: &[usize]) -> f64 {
        self.eval_sourced(order).value
    }

    /// [`eval`](Self::eval) with the evaluation source attached, for
    /// acceptance scans that must distinguish a numeric nnz(L+U) from
    /// the structural bound a failed LU substitutes.
    pub fn eval_sourced(&mut self, order: &[usize]) -> Eval {
        self.evals += 1;
        eval_order_sourced(self.a, self.kind, &mut self.ws, order)
    }

    /// Entrywise ℓ₁ norm of the factors under `order` (‖L‖₁ + ‖Lᵀ‖₁ for
    /// Cholesky, ‖L‖₁+‖U‖₁ for LU) — the paper's surrogate, reported for
    /// diagnostics; `None` if the numeric factorization fails.
    pub fn numeric_l1(&mut self, order: &[usize]) -> Option<f64> {
        let pap = self.a.permute_sym(order);
        match self.kind {
            FactorKind::Cholesky => {
                let sym = analyze(&pap);
                crate::factor::cholesky_with_ws(&pap, &sym, &mut self.ws)
                    .ok()
                    .map(|f| 2.0 * f.l1_norm())
            }
            FactorKind::Lu => {
                let lsym = analyze_lu(&pap);
                lu::factorize(&pap, &lsym, LuOptions::default(), &mut self.ws)
                    .ok()
                    .map(|f| f.l1_norm())
            }
        }
    }
}

/// The golden criterion as a pure function over caller-owned scratch —
/// the probe pool's work unit. Equals [`OrderObjective::eval`] exactly
/// (that method delegates here), so parallel probe results are
/// interchangeable with sequential ones.
pub fn eval_order(a: &Csr, kind: FactorKind, ws: &mut FactorWorkspace, order: &[usize]) -> f64 {
    eval_order_sourced(a, kind, ws, order).value
}

/// [`eval_order`] with provenance: a failed LU probe comes back tagged
/// [`EvalSource::LuBound`] instead of silently impersonating a numeric
/// count, so reductions can refuse to accept it over an exact one.
pub fn eval_order_sourced(
    a: &Csr,
    kind: FactorKind,
    ws: &mut FactorWorkspace,
    order: &[usize],
) -> Eval {
    let pap = a.permute_sym(order);
    match kind {
        FactorKind::Cholesky => {
            Eval { value: analyze(&pap).lnnz as f64, source: EvalSource::Symbolic }
        }
        FactorKind::Lu => {
            let lsym = analyze_lu(&pap);
            match lu::factorize(&pap, &lsym, LuOptions::default(), ws) {
                Ok(f) => Eval { value: f.lu_nnz() as f64, source: EvalSource::NumericLu },
                Err(_) => Eval { value: lsym.lu_nnz_bound as f64, source: EvalSource::LuBound },
            }
        }
    }
}

/// Dense max-normalized window of a (symmetric or symmetrized) matrix —
/// the arena the ADMM inner loop optimizes over. Row-major n×n.
pub struct DenseWindow {
    pub n: usize,
    pub a: Vec<f64>,
}

impl DenseWindow {
    /// Densify and max-normalize (orderings are scale-invariant, the ADMM
    /// penalty is not — mirrors the Python trainer's normalization).
    pub fn from_csr(a: &Csr) -> DenseWindow {
        let n = a.nrows();
        let mut d = vec![0.0f64; n * n];
        let mut amax = 0.0f64;
        for r in 0..n {
            let (cols, vals) = a.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                d[r * n + c] = v;
                amax = amax.max(v.abs());
            }
        }
        let inv = 1.0 / amax.max(1e-12);
        for v in &mut d {
            *v *= inv;
        }
        DenseWindow { n, a: d }
    }
}

/// `C = A·B` for row-major n×n (ikj loop order: contiguous inner scans).
pub fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let (crow, brow) = (&mut c[i * n..(i + 1) * n], &b[k * n..(k + 1) * n]);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// `A_θ = P A Pᵀ` (all row-major n×n): `(PA)·Pᵀ`, contracting over the
/// shared column index. Hoist this out of any loop where `P` is fixed —
/// it is two O(n³) products, the dominant ADMM cost.
pub fn conjugate(p: &[f64], a: &[f64], n: usize) -> Vec<f64> {
    let pa = matmul(p, a, n);
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += pa[i * n + k] * p[j * n + k];
            }
            out[i * n + j] = s;
        }
    }
    out
}

/// Residual `R = A_θ − L Lᵀ` from a precomputed reordered window (the
/// L-update iterates this with `A_θ` fixed).
pub fn residual_from(a_theta: &[f64], l: &[f64], n: usize) -> Vec<f64> {
    let mut r = a_theta.to_vec();
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            // L Lᵀ over L's lower-triangular support
            for k in 0..=i.min(j) {
                s += l[i * n + k] * l[j * n + k];
            }
            r[i * n + j] -= s;
        }
    }
    r
}

/// Residual `R = P A Pᵀ − L Lᵀ`.
pub fn residual(p: &[f64], a: &[f64], l: &[f64], n: usize) -> Vec<f64> {
    residual_from(&conjugate(p, a, n), l, n)
}

/// Smooth part of the augmented Lagrangian: `⟨Γ, R⟩ + ρ/2‖R‖²`.
pub fn smooth_value(r: &[f64], gamma: &[f64], rho: f64) -> f64 {
    let dual: f64 = gamma.iter().zip(r).map(|(g, rv)| g * rv).sum();
    let pen: f64 = r.iter().map(|rv| rv * rv).sum();
    dual + 0.5 * rho * pen
}

/// `G = Γ + ρR`, the gradient of the smooth part w.r.t. the reordered
/// matrix — shared upstream factor of both parameter gradients.
pub fn smooth_grad_upstream(r: &[f64], gamma: &[f64], rho: f64) -> Vec<f64> {
    gamma.iter().zip(r).map(|(g, rv)| g + rho * rv).collect()
}

/// Gradient of the smooth part w.r.t. the soft permutation:
/// `(G + Gᵀ) P A` (A symmetric).
pub fn smooth_grad_p(g: &[f64], p: &[f64], a: &[f64], n: usize) -> Vec<f64> {
    let mut gs = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            gs[i * n + j] = g[i * n + j] + g[j * n + i];
        }
    }
    matmul(&matmul(&gs, p, n), a, n)
}

/// Gradient of the smooth part w.r.t. the dense factor: `−(G + Gᵀ) L`.
pub fn smooth_grad_l(g: &[f64], l: &[f64], n: usize) -> Vec<f64> {
    let mut gs = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            gs[i * n + j] = -(g[i * n + j] + g[j * n + i]);
        }
    }
    matmul(&gs, l, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::gen::ProblemClass;
    use crate::util::rng::Pcg64;

    #[test]
    fn discrete_objective_matches_symbolic_lnnz() {
        let a = laplacian_2d(8, 8);
        let mut obj = OrderObjective::new(&a);
        assert_eq!(obj.kind(), FactorKind::Cholesky);
        let id: Vec<usize> = (0..64).collect();
        let f = obj.eval(&id);
        assert_eq!(f, analyze(&a).lnnz as f64);
        assert_eq!(obj.evals, 1);
        // ℓ₁ surrogate exists and is positive
        assert!(obj.numeric_l1(&id).unwrap() > 0.0);
    }

    #[test]
    fn discrete_objective_routes_unsymmetric_to_lu() {
        let a = ProblemClass::Circuit.generate(60, 3);
        let mut obj = OrderObjective::new(&a);
        assert_eq!(obj.kind(), FactorKind::Lu);
        let id: Vec<usize> = (0..a.nrows()).collect();
        let f = obj.eval(&id);
        assert!(f >= a.nnz() as f64, "nnz(L+U) ≥ nnz(A)");
    }

    #[test]
    fn dense_window_is_max_normalized() {
        let a = laplacian_2d(4, 4);
        let w = DenseWindow::from_csr(&a);
        let amax = w.a.iter().cloned().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!((amax - 1.0).abs() < 1e-12);
        // symmetric window
        for i in 0..w.n {
            for j in 0..w.n {
                assert_eq!(w.a[i * w.n + j], w.a[j * w.n + i]);
            }
        }
    }

    #[test]
    fn residual_zero_for_exact_factor() {
        // A = L₀L₀ᵀ with P = I must give R = 0
        let n = 5;
        let mut l0 = vec![0.0f64; n * n];
        let mut rng = Pcg64::new(4);
        for i in 0..n {
            for j in 0..=i {
                l0[i * n + j] =
                    if i == j { 1.0 + rng.next_f64() } else { 0.3 * rng.next_gaussian() };
            }
        }
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l0[i * n + k] * l0[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let mut p = vec![0.0f64; n * n];
        for i in 0..n {
            p[i * n + i] = 1.0;
        }
        let r = residual(&p, &a, &l0, n);
        assert!(r.iter().all(|v| v.abs() < 1e-12));
        assert!(smooth_value(&r, &vec![0.0; n * n], 1.0).abs() < 1e-20);
    }

    #[test]
    fn grad_l_matches_finite_differences() {
        let n = 6;
        let mut rng = Pcg64::new(5);
        let a: Vec<f64> = {
            let mut m = vec![0.0f64; n * n];
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.next_gaussian();
                    m[i * n + j] = v;
                    m[j * n + i] = v;
                }
            }
            m
        };
        let mut p = vec![0.0f64; n * n];
        for i in 0..n {
            p[i * n + i] = 1.0;
        }
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                l[i * n + j] = rng.next_gaussian();
            }
        }
        let gamma: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian()).collect();
        let rho = 1.0;
        let r = residual(&p, &a, &l, n);
        let g = smooth_grad_upstream(&r, &gamma, rho);
        let gl = smooth_grad_l(&g, &l, n);
        let eps = 1e-6;
        for i in 0..n {
            for j in 0..=i {
                let mut lp = l.clone();
                lp[i * n + j] += eps;
                let mut lm = l.clone();
                lm[i * n + j] -= eps;
                let fp = smooth_value(&residual(&p, &a, &lp, n), &gamma, rho);
                let fm = smooth_value(&residual(&p, &a, &lm, n), &gamma, rho);
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - gl[i * n + j]).abs() < 1e-5 * fd.abs().max(1.0),
                    "L[{i}][{j}]: fd {fd} vs analytic {}",
                    gl[i * n + j]
                );
            }
        }
    }

    /// Unsymmetric matrix with an identically-zero column: every pivot
    /// candidate in that column is 0, so the Gilbert–Peierls kernel
    /// reports `Singular` under *any* ordering — the candidate shape that
    /// used to let the structural bound impersonate a numeric count.
    fn singular_unsymmetric(n: usize) -> Csr {
        use crate::sparse::Coo;
        let mut coo = Coo::square(n);
        for i in 0..n {
            if i != 2 {
                coo.push(i, i, 2.0 + i as f64);
                // row 2 stays nonempty so the pattern is unsymmetric and
                // the zero column (no entries anywhere in column 2) is a
                // column-only defect
                coo.push(2, i, 0.5);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn failed_lu_probe_is_tagged_as_bound_not_numeric() {
        let a = singular_unsymmetric(6);
        let mut ws = FactorWorkspace::new();
        let id: Vec<usize> = (0..6).collect();
        let e = eval_order_sourced(&a, FactorKind::Lu, &mut ws, &id);
        assert_eq!(e.source, EvalSource::LuBound, "singular LU must be tagged as fallback");
        assert!(!e.is_exact() && e.evaluated());
        assert_eq!(e.value, analyze_lu(&a.permute_sym(&id)).lu_nnz_bound as f64);
        // a healthy LU stays numeric-exact
        let u = ProblemClass::Circuit.generate(50, 8);
        let idu: Vec<usize> = (0..u.nrows()).collect();
        let eu = eval_order_sourced(&u, FactorKind::Lu, &mut ws, &idu);
        assert_eq!(eu.source, EvalSource::NumericLu);
        assert!(eu.is_exact());
        // and Cholesky is symbolic-exact
        let s = laplacian_2d(5, 5);
        let ids: Vec<usize> = (0..25).collect();
        assert_eq!(
            eval_order_sourced(&s, FactorKind::Cholesky, &mut ws, &ids).source,
            EvalSource::Symbolic
        );
    }

    #[test]
    fn best_exact_never_prefers_a_fallback_bound() {
        let num = |v| Eval { value: v, source: EvalSource::NumericLu };
        let bound = |v| Eval { value: v, source: EvalSource::LuBound };
        // the bound is "better" numerically but must not win
        assert_eq!(best_exact(&[bound(10.0), num(20.0)]), Some(1));
        // ties resolve to the lowest probe index (determinism contract)
        assert_eq!(best_exact(&[num(5.0), num(5.0), num(4.0), num(4.0)]), Some(2));
        // nothing exact → nothing acceptable
        assert_eq!(best_exact(&[bound(1.0), Eval::skipped()]), None);
        assert_eq!(best_exact(&[]), None);
        // skipped probes are transparent
        assert_eq!(
            best_exact(&[Eval::skipped(), Eval { value: 7.0, source: EvalSource::Incremental }]),
            Some(1)
        );
    }

    #[test]
    fn eval_order_free_function_matches_owning_evaluator() {
        // the probe pool's work unit must equal the sequential evaluator
        // on both factorization kinds
        let mut ws = FactorWorkspace::new();
        let a = laplacian_2d(7, 9);
        let mut obj = OrderObjective::new(&a);
        let rev: Vec<usize> = (0..a.nrows()).rev().collect();
        assert_eq!(eval_order(&a, FactorKind::Cholesky, &mut ws, &rev), obj.eval(&rev));
        let u = ProblemClass::Circuit.generate(50, 8);
        let mut uobj = OrderObjective::new(&u);
        let id: Vec<usize> = (0..u.nrows()).collect();
        assert_eq!(eval_order(&u, FactorKind::Lu, &mut ws, &id), uobj.eval(&id));
    }
}
