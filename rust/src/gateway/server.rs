//! The TCP reorder gateway: an acceptor thread plus a reader/writer
//! thread pair per connection, fronting a [`ReorderService`].
//!
//! ```text
//!                     accept            frames              try_submit
//!   clients ──TCP──► [acceptor] ──► [reader thread] ───────► service
//!                                        │    ▲                  │
//!                                 Outgoing│    │rate limiter      │responses
//!                                        ▼    │                  ▼
//!                                   [writer thread] ◄── mpsc::Receiver
//! ```
//!
//! Contracts (tested in `tests/gateway_integration.rs`):
//!
//! * **Exactly one reply per frame.** Every decoded request frame is
//!   answered with a `Response`, `Error`, or `Busy` — saturation and
//!   throttling are explicit `Busy` frames, never silent drops.
//! * **Replies preserve submission order per connection** (the writer
//!   drains its queue FIFO); the echoed request id is still the
//!   correlation key.
//! * **Malformed input never panics the gateway.** Payload-level garbage
//!   gets an `Error` frame and the connection stays open; framing-level
//!   garbage (bad magic/version/type, oversize prefix) gets a final
//!   `Error` and the connection closes, because byte sync is gone.
//! * **Shutdown answers every in-flight request** — the coordinator's
//!   drain contract extended across the network boundary: readers stop
//!   accepting work, writers flush every pending reply while the service
//!   is still live, and only then does the service itself shut down.

use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{
    BusyKind, Metrics, ReorderResponse, ReorderService, ServiceConfig, TrySubmitError,
};
use crate::gateway::frame::{self, Frame, FrameError, FrameType, HEADER_LEN};
use crate::gateway::rate_limit::RateLimiter;
use crate::gateway::wire::{self, AdminCmd, BusyReason};
use crate::obs::trace::{Stage, StageLog};
use crate::util::sync::lock_unpoisoned;

/// Default listen address of `pfm serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7744";

/// How many poll ticks a reader waits for the rest of a half-received
/// frame once shutdown has begun, before giving the connection up as
/// truncated (bounds shutdown latency against a stalled client).
const SHUTDOWN_PATIENCE_TICKS: u32 = 100;

/// Gateway configuration.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// listen address, e.g. `"127.0.0.1:7744"` (port 0 for tests)
    pub addr: String,
    /// configuration of the fronted reorder service
    pub service: ServiceConfig,
    /// per-client token-bucket refill rate, requests/second; `<= 0`
    /// disables rate limiting
    pub rate: f64,
    /// token-bucket capacity (burst head-room of a fresh client)
    pub burst: f64,
    /// reader poll tick: how often a blocked read re-checks the shutdown
    /// flag (also the shutdown-latency granularity)
    pub poll: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: DEFAULT_ADDR.to_string(),
            service: ServiceConfig::default(),
            rate: 0.0,
            burst: 32.0,
            poll: Duration::from_millis(50),
        }
    }
}

/// Shared per-connection context.
struct ConnCtx {
    service: Arc<ReorderService>,
    limiter: Arc<RateLimiter>,
    shutdown: Arc<AtomicBool>,
    poll: Duration,
}

/// A running gateway. Call [`shutdown`](Gateway::shutdown) (or send the
/// admin `shutdown` command and let [`serve_until_shutdown`] notice) to
/// stop it; both run the full graceful drain.
///
/// [`serve_until_shutdown`]: Gateway::serve_until_shutdown
pub struct Gateway {
    addr: SocketAddr,
    service: Arc<ReorderService>,
    limiter: Arc<RateLimiter>,
    shutdown: Arc<AtomicBool>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Gateway {
    /// Bind the listener, start the fronted service, spawn the acceptor.
    pub fn start(config: GatewayConfig) -> io::Result<Gateway> {
        let listener = TcpListener::bind(&config.addr)?;
        let addr = listener.local_addr()?;
        let service = ReorderService::start(config.service);
        let limiter = Arc::new(RateLimiter::new(config.rate, config.burst));
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let ctx = Arc::new(ConnCtx {
            service: service.clone(),
            limiter: limiter.clone(),
            shutdown: shutdown.clone(),
            poll: config.poll.max(Duration::from_millis(1)),
        });
        let acceptor = {
            let ctx = ctx.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("pfm-gw-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if ctx.shutdown.load(Ordering::Relaxed) {
                            break; // the wake-up connection from shutdown()
                        }
                        let Ok(stream) = stream else { continue };
                        ctx.service.metrics.record_gateway_connection();
                        let ctx = ctx.clone();
                        let spawned = std::thread::Builder::new()
                            .name("pfm-gw-conn".into())
                            .spawn(move || connection_loop(stream, &ctx));
                        if let Ok(handle) = spawned {
                            let mut c = lock_unpoisoned(&conns);
                            c.retain(|t| !t.is_finished());
                            c.push(handle);
                        }
                    }
                })
                .expect("spawn gateway acceptor")
        };

        Ok(Gateway {
            addr,
            service,
            limiter,
            shutdown,
            acceptor: Mutex::new(Some(acceptor)),
            conns,
        })
    }

    /// The bound listen address (resolves port 0 for tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live metrics of the fronted service (includes gateway counters).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.service.metrics.clone()
    }

    /// Per-client throttle stats as JSON.
    pub fn throttle_stats(&self) -> String {
        self.limiter.stats_json()
    }

    /// Whether shutdown has been requested (locally or via admin frame).
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Block until something requests shutdown (the admin `shutdown`
    /// command, or [`shutdown`](Gateway::shutdown) from another thread),
    /// then run the graceful drain.
    pub fn serve_until_shutdown(&self) {
        while !self.shutdown.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.shutdown();
    }

    /// Graceful shutdown (idempotent): stop accepting, let every reader
    /// exit at its next poll tick, let every writer flush every in-flight
    /// reply *while the service is still live*, then shut the service
    /// down. No accepted request is ever dropped unanswered.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // wake the acceptor out of its blocking accept
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(h) = lock_unpoisoned(&self.acceptor).take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = lock_unpoisoned(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.service.shutdown();
    }
}

/// What the per-connection writer sends, in FIFO order.
enum Outgoing {
    /// An already-encoded frame (errors, busy, admin replies).
    Immediate(FrameType, Vec<u8>),
    /// A submitted request: the writer blocks on the service's reply and
    /// encodes it. FIFO consumption is what makes per-connection reply
    /// order match submission order.
    Pending { id: u64, rx: mpsc::Receiver<ReorderResponse> },
}

/// Reader side of one connection: frames in, handling, `Outgoing` out.
fn connection_loop(mut stream: TcpStream, ctx: &ConnCtx) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "unknown".to_string());
    if stream.set_read_timeout(Some(ctx.poll)).is_err() {
        return;
    }
    let Ok(wstream) = stream.try_clone() else { return };
    let metrics = ctx.service.metrics.clone();
    let (wtx, wrx) = mpsc::channel::<Outgoing>();
    let writer = {
        let metrics = metrics.clone();
        std::thread::Builder::new()
            .name("pfm-gw-write".into())
            .spawn(move || writer_loop(wstream, wrx, &metrics))
    };
    let Ok(writer) = writer else { return };

    loop {
        match read_frame_interruptible(&mut stream, &ctx.shutdown) {
            Ok(ReadOutcome::Closed) | Ok(ReadOutcome::ShutdownIdle) => break,
            Ok(ReadOutcome::Frame(f)) => {
                metrics.record_gateway_frame_rx();
                if !handle_frame(f, &peer, ctx, &wtx) {
                    break;
                }
            }
            Err(FrameError::Io(_)) | Err(FrameError::CleanEof) => break,
            Err(e) => {
                // framing-level failure: byte sync is gone — answer once,
                // best-effort, and close the connection
                metrics.record_gateway_malformed();
                let _ = wtx.send(Outgoing::Immediate(
                    FrameType::Error,
                    wire::encode_error(0, &e.to_string()),
                ));
                break;
            }
        }
    }
    // dropping our sender ends the writer once it has flushed everything
    drop(wtx);
    let _ = writer.join();
}

/// Handle one well-framed frame; returns whether to keep the connection.
fn handle_frame(f: Frame, peer: &str, ctx: &ConnCtx, wtx: &mpsc::Sender<Outgoing>) -> bool {
    let metrics = &ctx.service.metrics;
    match f.ftype {
        FrameType::Request => {
            // the stage log starts at frame receipt, so decode and
            // rate-limit admission are part of the request's breakdown
            let mut stages = StageLog::new();
            let req = match stages.time(Stage::Decode, || wire::decode_request(&f.payload)) {
                Ok(r) => r,
                Err(e) => {
                    // payload-level garbage: framing is intact, so answer
                    // and keep serving this client
                    metrics.record_gateway_malformed();
                    let _ = wtx.send(Outgoing::Immediate(
                        FrameType::Error,
                        wire::encode_error(e.id, &e.message),
                    ));
                    return true;
                }
            };
            if ctx.shutdown.load(Ordering::Relaxed) {
                let _ = wtx.send(Outgoing::Immediate(
                    FrameType::Error,
                    wire::encode_error(req.id, "gateway shutting down"),
                ));
                return true;
            }
            if !stages.time(Stage::RateLimit, || ctx.limiter.admit(peer)) {
                metrics.record_gateway_busy(BusyKind::RateLimited);
                let _ = wtx.send(Outgoing::Immediate(
                    FrameType::Busy,
                    wire::encode_busy(req.id, BusyReason::RateLimited),
                ));
                return true;
            }
            let submitted = ctx.service.try_submit_traced(
                req.matrix,
                req.method,
                req.seed,
                req.eval_fill,
                req.factor_kind,
                req.opt_budget,
                req.factor_threads,
                stages,
            );
            match submitted {
                Ok(rx) => {
                    let _ = wtx.send(Outgoing::Pending { id: req.id, rx });
                }
                Err(TrySubmitError::Saturated) => {
                    metrics.record_gateway_busy(BusyKind::QueueFull);
                    let _ = wtx.send(Outgoing::Immediate(
                        FrameType::Busy,
                        wire::encode_busy(req.id, BusyReason::QueueFull),
                    ));
                }
                Err(TrySubmitError::ShutDown) => {
                    let _ = wtx.send(Outgoing::Immediate(
                        FrameType::Error,
                        wire::encode_error(req.id, "service shut down"),
                    ));
                }
            }
            true
        }
        FrameType::Admin => match wire::decode_admin(&f.payload) {
            Err(e) => {
                metrics.record_gateway_malformed();
                let _ = wtx.send(Outgoing::Immediate(FrameType::Error, wire::encode_error(0, &e)));
                true
            }
            Ok(cmd) => {
                metrics.record_gateway_admin();
                let json = match cmd {
                    AdminCmd::Ping => "{\"ok\":true}".to_string(),
                    AdminCmd::Metrics => metrics.to_json().to_string(),
                    AdminCmd::Trace => metrics.traces_json().to_string(),
                    AdminCmd::MetricsText => metrics.prometheus_text(),
                    AdminCmd::Throttle => ctx.limiter.stats_json(),
                    AdminCmd::Shutdown => "{\"ok\":true,\"shutting_down\":true}".to_string(),
                    AdminCmd::Snapshot => match ctx.service.persist_snapshot() {
                        Ok(n) => {
                            crate::util::json::Json::obj()
                                .set("ok", true)
                                .set("records", n)
                                .to_string()
                        }
                        Err(e) => crate::util::json::Json::obj()
                            .set("ok", false)
                            .set("error", e)
                            .to_string(),
                    },
                };
                let _ = wtx.send(Outgoing::Immediate(
                    FrameType::AdminResponse,
                    wire::encode_admin_response(&json),
                ));
                if cmd == AdminCmd::Shutdown {
                    // ack is already queued ahead of the flag taking
                    // effect; serve_until_shutdown runs the full drain
                    ctx.shutdown.store(true, Ordering::Relaxed);
                }
                true
            }
        },
        FrameType::Response | FrameType::Error | FrameType::Busy | FrameType::AdminResponse => {
            // server→client types arriving at the server: protocol
            // violation, close after answering
            metrics.record_gateway_malformed();
            let _ = wtx.send(Outgoing::Immediate(
                FrameType::Error,
                wire::encode_error(0, "client sent a server-only frame type"),
            ));
            false
        }
    }
}

/// Writer side of one connection: flush `Outgoing` in FIFO order. A
/// failed write marks the client dead but the loop keeps *draining*
/// pending receivers, so a vanished client never wedges a service worker
/// behind an unconsumed reply channel.
fn writer_loop(mut stream: TcpStream, wrx: mpsc::Receiver<Outgoing>, metrics: &Metrics) {
    let mut dead = false;
    while let Ok(out) = wrx.recv() {
        let (ftype, payload) = match out {
            Outgoing::Immediate(t, p) => (t, p),
            Outgoing::Pending { id, rx } => match rx.recv() {
                Ok(resp) => match resp.result {
                    Ok(res) => {
                        // annotate the ring entry (keyed by coordinator
                        // id) with the encode span after the fact — the
                        // trace was already recorded at compute time
                        let t0 = Instant::now();
                        let payload = wire::encode_result(id, &res);
                        metrics.annotate_trace_encode(resp.id, t0.elapsed().as_secs_f64());
                        (FrameType::Response, payload)
                    }
                    Err(msg) => (FrameType::Error, wire::encode_error(id, &msg)),
                },
                Err(_) => (
                    FrameType::Error,
                    wire::encode_error(id, "service shut down before responding"),
                ),
            },
        };
        if !dead {
            if frame::write_frame(&mut stream, ftype, &payload).is_ok() {
                metrics.record_gateway_frame_tx();
            } else {
                dead = true;
            }
        }
    }
}

/// Outcome of an interruptible frame read.
enum ReadOutcome {
    Frame(Frame),
    /// Shutdown was requested while idle at a frame boundary.
    ShutdownIdle,
    /// The peer closed cleanly at a frame boundary.
    Closed,
}

fn is_poll_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Like [`frame::read_frame`], but over a socket with a read timeout: a
/// timeout at a frame boundary re-checks the shutdown flag (so idle
/// connections notice shutdown within one poll tick), while a timeout
/// *mid-frame* keeps waiting — a slow client must not desync framing —
/// with bounded patience once shutdown has begun.
fn read_frame_interruptible(
    stream: &mut TcpStream,
    shutdown: &AtomicBool,
) -> Result<ReadOutcome, FrameError> {
    let mut late_ticks = 0u32;
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match stream.read(&mut header[got..]) {
            Ok(0) => {
                return if got == 0 { Ok(ReadOutcome::Closed) } else { Err(FrameError::Truncated) }
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_poll_timeout(&e) => {
                if shutdown.load(Ordering::Relaxed) {
                    if got == 0 {
                        return Ok(ReadOutcome::ShutdownIdle);
                    }
                    late_ticks += 1;
                    if late_ticks > SHUTDOWN_PATIENCE_TICKS {
                        return Err(FrameError::Truncated);
                    }
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let (ftype, len) = frame::parse_header(&header)?;
    let mut payload = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match stream.read(&mut payload[got..]) {
            Ok(0) => return Err(FrameError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_poll_timeout(&e) => {
                if shutdown.load(Ordering::Relaxed) {
                    late_ticks += 1;
                    if late_ticks > SHUTDOWN_PATIENCE_TICKS {
                        return Err(FrameError::Truncated);
                    }
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(ReadOutcome::Frame(Frame { ftype, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::Method;
    use crate::gateway::client::{GatewayClient, Reply};
    use crate::gateway::wire::WireRequest;
    use crate::gen::grid::laplacian_2d;
    use crate::order::Classical;
    use crate::util::check::check_permutation;
    use std::io::Write;

    fn test_gateway(service: ServiceConfig) -> Gateway {
        Gateway::start(GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            service,
            poll: Duration::from_millis(5),
            ..GatewayConfig::default()
        })
        .expect("bind loopback gateway")
    }

    #[test]
    fn admin_ping_metrics_and_one_request_roundtrip() {
        let gw = test_gateway(ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-gw-unit".into(),
            ..ServiceConfig::default()
        });
        let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
        assert!(c.admin(AdminCmd::Ping).unwrap().contains("\"ok\":true"));

        let req = WireRequest {
            id: 7,
            method: Method::Classical(Classical::Amd),
            seed: 1,
            eval_fill: true,
            factor_kind: None,
            opt_budget: None,
            factor_threads: None,
            matrix: laplacian_2d(8, 8),
        };
        match c.request(&req).unwrap() {
            Reply::Result(res) => {
                assert_eq!(res.id, 7);
                assert_eq!(res.method, "AMD");
                assert_eq!(res.order.len(), 64);
                check_permutation(&res.order).unwrap();
                assert!(res.fill_ratio.is_some(), "eval_fill was requested");
            }
            other => panic!("expected a result, got {other:?}"),
        }

        let m = c.admin(AdminCmd::Metrics).unwrap();
        assert!(m.contains("\"gateway\""), "{m}");
        assert!(m.contains("\"connections\":1"), "{m}");
        drop(c);
        gw.shutdown();
        assert_eq!(gw.metrics().gateway_admin(), 2);
    }

    #[test]
    fn garbage_bytes_are_answered_and_do_not_kill_the_gateway() {
        let gw = test_gateway(ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gw-garbage".into(),
            ..ServiceConfig::default()
        });
        // raw socket spewing non-protocol bytes
        let mut s = TcpStream::connect(gw.local_addr()).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let f = frame::read_frame(&mut s).expect("an error frame before close");
        assert_eq!(f.ftype, FrameType::Error);
        // the gateway keeps accepting fresh connections afterwards
        let mut c = GatewayClient::connect(gw.local_addr()).unwrap();
        assert!(c.admin(AdminCmd::Ping).unwrap().contains("ok"));
        drop(c);
        gw.shutdown();
        assert!(gw.metrics().gateway_malformed() >= 1);
    }

    #[test]
    fn admin_shutdown_frame_drives_serve_until_shutdown() {
        let gw = test_gateway(ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-gw-shutdown".into(),
            ..ServiceConfig::default()
        });
        let addr = gw.local_addr();
        let remote = std::thread::spawn(move || {
            let mut c = GatewayClient::connect(addr).unwrap();
            c.admin(AdminCmd::Shutdown).unwrap()
        });
        // returns only after the graceful drain completes
        gw.serve_until_shutdown();
        assert!(gw.is_shutting_down());
        let ack = remote.join().unwrap();
        assert!(ack.contains("shutting_down"), "{ack}");
    }
}
