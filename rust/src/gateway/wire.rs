//! Payload codecs for the gateway protocol: a bounds-checked cursor
//! reader, and encode/decode for every frame body. Everything is
//! little-endian; strings are u16-length-prefixed UTF-8 (decoded lossily,
//! so a hostile byte string can never make decoding fail with a panic).
//!
//! Decoding is defensive end to end: every read is bounds-checked, array
//! lengths are validated against the remaining payload *before* any
//! allocation, and a decoded matrix is structurally verified (square,
//! monotone `indptr`, sorted in-range column indices) before it is handed
//! to `Csr::from_parts` — whose own checks are debug-only and must never
//! be the last line of defense on the wire path.

use crate::coordinator::Method;
use crate::factor::FactorKind;
use crate::pfm::OptBudget;
use crate::sparse::Csr;

/// Largest matrix dimension the gateway will decode. Combined with the
/// frame-level payload cap this bounds every allocation a hostile client
/// can trigger.
pub const MAX_WIRE_N: usize = 1 << 22;

/// Why the gateway sent a `Busy` frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BusyReason {
    /// The service's bounded queue was full — backpressure, retry later.
    QueueFull = 0,
    /// This client exceeded its token bucket — throttled, slow down.
    RateLimited = 1,
}

impl BusyReason {
    pub fn from_u8(b: u8) -> Option<BusyReason> {
        match b {
            0 => Some(BusyReason::QueueFull),
            1 => Some(BusyReason::RateLimited),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BusyReason::QueueFull => "queue_full",
            BusyReason::RateLimited => "rate_limited",
        }
    }
}

/// Admin-protocol commands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdminCmd {
    /// Liveness probe; answers `{"ok":true}`.
    Ping = 0,
    /// Full coordinator + gateway metrics snapshot (JSON).
    Metrics = 1,
    /// Per-client token-bucket stats (JSON).
    Throttle = 2,
    /// Ask the gateway to shut down gracefully (acked before it begins).
    Shutdown = 3,
    /// Compact the warm-start persistence store into one snapshot
    /// (errors when the service runs without `--persist-dir`).
    Snapshot = 4,
    /// Recent request traces from the coordinator's bounded ring (JSON).
    Trace = 5,
    /// Prometheus text exposition of counters + latency histograms.
    MetricsText = 6,
}

impl AdminCmd {
    pub fn from_u8(b: u8) -> Option<AdminCmd> {
        match b {
            0 => Some(AdminCmd::Ping),
            1 => Some(AdminCmd::Metrics),
            2 => Some(AdminCmd::Throttle),
            3 => Some(AdminCmd::Shutdown),
            4 => Some(AdminCmd::Snapshot),
            5 => Some(AdminCmd::Trace),
            6 => Some(AdminCmd::MetricsText),
            _ => None,
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Option<AdminCmd> {
        match s.to_ascii_lowercase().as_str() {
            "ping" => Some(AdminCmd::Ping),
            "metrics" => Some(AdminCmd::Metrics),
            "throttle" => Some(AdminCmd::Throttle),
            "shutdown" => Some(AdminCmd::Shutdown),
            "snapshot" => Some(AdminCmd::Snapshot),
            "trace" => Some(AdminCmd::Trace),
            "metrics-text" => Some(AdminCmd::MetricsText),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdminCmd::Ping => "ping",
            AdminCmd::Metrics => "metrics",
            AdminCmd::Throttle => "throttle",
            AdminCmd::Shutdown => "shutdown",
            AdminCmd::Snapshot => "snapshot",
            AdminCmd::Trace => "trace",
            AdminCmd::MetricsText => "metrics-text",
        }
    }
}

/// A decoded reorder request, ready for `ReorderService::try_submit_*`.
#[derive(Clone, Debug)]
pub struct WireRequest {
    /// Client-chosen correlation id, echoed verbatim on every reply.
    pub id: u64,
    pub method: Method,
    pub seed: u64,
    pub eval_fill: bool,
    pub factor_kind: Option<FactorKind>,
    pub opt_budget: Option<OptBudget>,
    /// parallel-factorization width for the native-optimizer path (`None`
    /// uses the service's configured default)
    pub factor_threads: Option<usize>,
    pub matrix: Csr,
}

/// A decoded reorder result (client side of `ReorderResult` — labels come
/// back as owned strings).
#[derive(Clone, Debug)]
pub struct WireResult {
    pub id: u64,
    pub method: String,
    pub provenance: Option<String>,
    pub latency: f64,
    pub batch_size: usize,
    pub fill_ratio: Option<f64>,
    pub factor_kind: Option<String>,
    pub opt_iters: usize,
    pub probe_threads: usize,
    pub factor_threads: usize,
    pub levels_refined: usize,
    pub order: Vec<usize>,
    /// per-stage breakdown as (stage label, seconds); empty when the
    /// server predates the stage section (it is end-anchored + optional)
    pub stages: Vec<(String, f64)>,
}

/// Payload-level decode failure: the frame was well-formed, the body was
/// not. Carries the request id when it was readable (0 otherwise) so the
/// error reply can still be correlated.
#[derive(Debug)]
pub struct DecodeFailure {
    pub id: u64,
    pub message: String,
}

impl std::fmt::Display for DecodeFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

// ---------------------------------------------------------------- reader

/// Bounds-checked little-endian cursor over a payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!("payload truncated: wanted {n} bytes, {} left", self.remaining()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// u16-length-prefixed string, decoded lossily (never fails on bytes).
    fn str16(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        Ok(String::from_utf8_lossy(self.take(len)?).into_owned())
    }

    fn done(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after payload", self.remaining()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- writer

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// u16-length-prefixed string; truncated at 4 KiB (error messages only —
/// protocol labels are all short).
fn put_str16(buf: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let n = bytes.len().min(4096);
    put_u16(buf, n as u16);
    buf.extend_from_slice(&bytes[..n]);
}

// -------------------------------------------------------------- requests

const FLAG_EVAL_FILL: u8 = 1 << 0;
const FLAG_HAS_KIND: u8 = 1 << 1;
const FLAG_HAS_BUDGET: u8 = 1 << 2;
const FLAG_HAS_FACTOR_THREADS: u8 = 1 << 3;

/// Encode a reorder request payload. Fails (rather than truncating) when
/// the matrix cannot fit the frame-level payload cap.
pub fn encode_request(req: &WireRequest) -> Result<Vec<u8>, String> {
    let a = &req.matrix;
    if a.nrows() != a.ncols() {
        return Err(format!("matrix must be square, got {}x{}", a.nrows(), a.ncols()));
    }
    if a.nrows() > MAX_WIRE_N {
        return Err(format!("matrix dimension {} above wire cap {MAX_WIRE_N}", a.nrows()));
    }
    let est = 64 + 4 * (a.nrows() + 1) + 12 * a.nnz();
    if est > super::frame::MAX_PAYLOAD {
        return Err(format!(
            "matrix too large for one frame ({est} bytes > {} cap)",
            super::frame::MAX_PAYLOAD
        ));
    }
    let mut buf = Vec::with_capacity(est);
    put_u64(&mut buf, req.id);
    put_str16(&mut buf, req.method.label());
    put_u64(&mut buf, req.seed);
    let mut flags = 0u8;
    if req.eval_fill {
        flags |= FLAG_EVAL_FILL;
    }
    if req.factor_kind.is_some() {
        flags |= FLAG_HAS_KIND;
    }
    if req.opt_budget.is_some() {
        flags |= FLAG_HAS_BUDGET;
    }
    if req.factor_threads.is_some() {
        flags |= FLAG_HAS_FACTOR_THREADS;
    }
    buf.push(flags);
    if let Some(kind) = req.factor_kind {
        buf.push(match kind {
            FactorKind::Cholesky => 0,
            FactorKind::Lu => 1,
        });
    }
    if let Some(b) = req.opt_budget {
        put_u32(&mut buf, b.outer as u32);
        put_u32(&mut buf, b.refine as u32);
        put_u32(&mut buf, b.level_refine as u32);
        buf.push(b.adaptive_rho as u8);
        buf.push(b.time_ms.is_some() as u8);
        put_u64(&mut buf, b.time_ms.unwrap_or(0));
    }
    if let Some(t) = req.factor_threads {
        put_u32(&mut buf, t.min(u32::MAX as usize) as u32);
    }
    put_u32(&mut buf, a.nrows() as u32);
    put_u32(&mut buf, a.ncols() as u32);
    put_u32(&mut buf, a.nnz() as u32);
    for &p in a.indptr() {
        put_u32(&mut buf, p as u32);
    }
    for &c in a.indices() {
        put_u32(&mut buf, c as u32);
    }
    for &x in a.data() {
        put_f64(&mut buf, x);
    }
    Ok(buf)
}

/// Decode and validate a reorder request payload. Never panics; a failure
/// carries the client id when it was readable so the error reply can be
/// correlated.
pub fn decode_request(payload: &[u8]) -> Result<WireRequest, DecodeFailure> {
    let mut r = Reader::new(payload);
    // read the id first so later failures stay correlatable
    let id = r.u64().map_err(|m| DecodeFailure { id: 0, message: m })?;
    let fail = |message: String| DecodeFailure { id, message };
    let method_label = r.str16().map_err(&fail)?;
    let method = Method::from_label(&method_label)
        .ok_or_else(|| fail(format!("unknown method `{method_label}`")))?;
    let seed = r.u64().map_err(&fail)?;
    let flags = r.u8().map_err(&fail)?;
    let factor_kind = if flags & FLAG_HAS_KIND != 0 {
        Some(match r.u8().map_err(&fail)? {
            0 => FactorKind::Cholesky,
            1 => FactorKind::Lu,
            k => return Err(fail(format!("unknown factor kind {k}"))),
        })
    } else {
        None
    };
    let opt_budget = if flags & FLAG_HAS_BUDGET != 0 {
        let outer = r.u32().map_err(&fail)? as usize;
        let refine = r.u32().map_err(&fail)? as usize;
        let level_refine = r.u32().map_err(&fail)? as usize;
        let adaptive_rho = r.u8().map_err(&fail)? != 0;
        let has_time = r.u8().map_err(&fail)? != 0;
        let time_ms = r.u64().map_err(&fail)?;
        Some(OptBudget {
            outer,
            refine,
            level_refine,
            adaptive_rho,
            time_ms: has_time.then_some(time_ms),
        })
    } else {
        None
    };
    let factor_threads = if flags & FLAG_HAS_FACTOR_THREADS != 0 {
        Some(r.u32().map_err(&fail)? as usize)
    } else {
        None
    };
    let nrows = r.u32().map_err(&fail)? as usize;
    let ncols = r.u32().map_err(&fail)? as usize;
    let nnz = r.u32().map_err(&fail)? as usize;
    if nrows != ncols {
        return Err(fail(format!("matrix must be square, got {nrows}x{ncols}")));
    }
    if nrows == 0 {
        return Err(fail("empty matrix".to_string()));
    }
    if nrows > MAX_WIRE_N {
        return Err(fail(format!("matrix dimension {nrows} above wire cap {MAX_WIRE_N}")));
    }
    // size everything against the actual payload before allocating
    let need = 4 * (nrows + 1) + 12 * nnz;
    if r.remaining() < need {
        return Err(fail(format!(
            "payload truncated: matrix needs {need} bytes, {} left",
            r.remaining()
        )));
    }
    let mut indptr = Vec::with_capacity(nrows + 1);
    for _ in 0..=nrows {
        indptr.push(r.u32().map_err(&fail)? as usize);
    }
    if indptr[nrows] != nnz {
        return Err(fail("indptr must run from 0 to nnz".to_string()));
    }
    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        indices.push(r.u32().map_err(&fail)? as usize);
    }
    // structural validation is shared with WAL/snapshot replay
    // (`persist::record`): one untrusted-CSR validator, two consumers
    Csr::validate_parts(nrows, ncols, &indptr, &indices).map_err(&fail)?;
    let mut data = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        data.push(r.f64().map_err(&fail)?);
    }
    r.done().map_err(&fail)?;
    let matrix = Csr::from_parts(nrows, ncols, indptr, indices, data);
    Ok(WireRequest {
        id,
        method,
        seed,
        eval_fill: flags & FLAG_EVAL_FILL != 0,
        factor_kind,
        opt_budget,
        factor_threads,
        matrix,
    })
}

// --------------------------------------------------------------- results

/// Encode a successful reorder result payload.
pub fn encode_result(id: u64, res: &crate::coordinator::ReorderResult) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + 4 * res.order.len());
    put_u64(&mut buf, id);
    put_str16(&mut buf, res.method);
    put_str16(&mut buf, res.provenance.map(|p| p.label()).unwrap_or(""));
    put_f64(&mut buf, res.latency);
    put_u32(&mut buf, res.batch_size as u32);
    buf.push(res.fill_ratio.is_some() as u8);
    put_f64(&mut buf, res.fill_ratio.unwrap_or(0.0));
    put_str16(&mut buf, res.factor_kind.unwrap_or(""));
    put_u32(&mut buf, res.opt_iters as u32);
    put_u32(&mut buf, res.probe_threads as u32);
    put_u32(&mut buf, res.factor_threads as u32);
    put_u32(&mut buf, res.levels_refined as u32);
    put_u32(&mut buf, res.order.len() as u32);
    for &v in &res.order {
        put_u32(&mut buf, v as u32);
    }
    // end-anchored optional section: per-stage spans. Old clients stop
    // reading after the order array; new clients read it only when bytes
    // remain, so both directions stay compatible.
    if !res.stages.is_empty() {
        let n = res.stages.len().min(u8::MAX as usize);
        buf.push(n as u8);
        for span in &res.stages[..n] {
            put_str16(&mut buf, span.stage.label());
            put_f64(&mut buf, span.secs);
        }
    }
    buf
}

/// Decode a reorder result payload (client side).
pub fn decode_result(payload: &[u8]) -> Result<WireResult, String> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let method = r.str16()?;
    let provenance = r.str16()?;
    let latency = r.f64()?;
    let batch_size = r.u32()? as usize;
    let has_fill = r.u8()? != 0;
    let fill = r.f64()?;
    let factor_kind = r.str16()?;
    let opt_iters = r.u32()? as usize;
    let probe_threads = r.u32()? as usize;
    let factor_threads = r.u32()? as usize;
    let levels_refined = r.u32()? as usize;
    let n = r.u32()? as usize;
    if n > MAX_WIRE_N {
        return Err(format!("order length {n} above wire cap {MAX_WIRE_N}"));
    }
    if r.remaining() < 4 * n {
        return Err(format!("payload truncated: order needs {} bytes", 4 * n));
    }
    let mut order = Vec::with_capacity(n);
    for _ in 0..n {
        order.push(r.u32()? as usize);
    }
    // optional end-anchored stage section (absent from old servers)
    let mut stages = Vec::new();
    if r.remaining() > 0 {
        let count = r.u8()? as usize;
        for _ in 0..count {
            let label = r.str16()?;
            let secs = r.f64()?;
            stages.push((label, secs));
        }
    }
    r.done()?;
    Ok(WireResult {
        id,
        method,
        provenance: (!provenance.is_empty()).then_some(provenance),
        latency,
        batch_size,
        fill_ratio: has_fill.then_some(fill),
        factor_kind: (!factor_kind.is_empty()).then_some(factor_kind),
        opt_iters,
        probe_threads,
        factor_threads,
        levels_refined,
        order,
        stages,
    })
}

// ---------------------------------------------------- busy/error/admin

/// Encode a `Busy` payload.
pub fn encode_busy(id: u64, reason: BusyReason) -> Vec<u8> {
    let mut buf = Vec::with_capacity(9);
    put_u64(&mut buf, id);
    buf.push(reason as u8);
    buf
}

/// Decode a `Busy` payload.
pub fn decode_busy(payload: &[u8]) -> Result<(u64, BusyReason), String> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let reason = BusyReason::from_u8(r.u8()?).ok_or("unknown busy reason")?;
    r.done()?;
    Ok((id, reason))
}

/// Encode an `Error` payload (id + UTF-8 message as the remainder).
pub fn encode_error(id: u64, message: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + message.len().min(4096));
    put_u64(&mut buf, id);
    let bytes = message.as_bytes();
    buf.extend_from_slice(&bytes[..bytes.len().min(4096)]);
    buf
}

/// Decode an `Error` payload.
pub fn decode_error(payload: &[u8]) -> Result<(u64, String), String> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let rest = r.take(r.remaining())?;
    Ok((id, String::from_utf8_lossy(rest).into_owned()))
}

/// Encode an `Admin` payload.
pub fn encode_admin(cmd: AdminCmd) -> Vec<u8> {
    vec![cmd as u8]
}

/// Decode an `Admin` payload.
pub fn decode_admin(payload: &[u8]) -> Result<AdminCmd, String> {
    let mut r = Reader::new(payload);
    let cmd = AdminCmd::from_u8(r.u8()?).ok_or("unknown admin command")?;
    r.done()?;
    Ok(cmd)
}

/// Encode an `AdminResponse` payload (UTF-8 JSON as the whole body).
pub fn encode_admin_response(json: &str) -> Vec<u8> {
    json.as_bytes().to_vec()
}

/// Decode an `AdminResponse` payload.
pub fn decode_admin_response(payload: &[u8]) -> String {
    String::from_utf8_lossy(payload).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::ReorderResult;
    use crate::gen::grid::laplacian_2d;
    use crate::obs::trace::{Span, Stage};
    use crate::order::Classical;
    use crate::runtime::{Learned, Provenance};
    use crate::util::rng::Pcg64;

    fn sample_request() -> WireRequest {
        WireRequest {
            id: 42,
            method: Method::Classical(Classical::Amd),
            seed: 7,
            eval_fill: true,
            factor_kind: Some(FactorKind::Lu),
            opt_budget: Some(OptBudget {
                outer: 2,
                refine: 8,
                level_refine: 3,
                adaptive_rho: true,
                time_ms: Some(250),
            }),
            factor_threads: Some(3),
            matrix: laplacian_2d(6, 6),
        }
    }

    #[test]
    fn request_roundtrip_full() {
        let req = sample_request();
        let payload = encode_request(&req).unwrap();
        let got = decode_request(&payload).unwrap();
        assert_eq!(got.id, 42);
        assert_eq!(got.method, req.method);
        assert_eq!(got.seed, 7);
        assert!(got.eval_fill);
        assert_eq!(got.factor_kind, Some(FactorKind::Lu));
        let b = got.opt_budget.unwrap();
        assert_eq!((b.outer, b.refine, b.level_refine), (2, 8, 3));
        assert!(b.adaptive_rho);
        assert_eq!(b.time_ms, Some(250));
        assert_eq!(got.factor_threads, Some(3));
        assert_eq!(got.matrix, req.matrix);
    }

    #[test]
    fn request_roundtrip_minimal() {
        let req = WireRequest {
            id: 1,
            method: Method::Learned(Learned::Pfm),
            seed: 0,
            eval_fill: false,
            factor_kind: None,
            opt_budget: None,
            factor_threads: None,
            matrix: Csr::identity(3),
        };
        let payload = encode_request(&req).unwrap();
        let got = decode_request(&payload).unwrap();
        assert_eq!(got.method, req.method);
        assert_eq!(got.factor_kind, None);
        assert!(got.opt_budget.is_none());
        assert_eq!(got.factor_threads, None);
        assert!(!got.eval_fill);
        assert_eq!(got.matrix, req.matrix);
    }

    #[test]
    fn malformed_requests_are_rejected_with_the_id() {
        let payload = encode_request(&sample_request()).unwrap();
        // zero-length payload
        let e = decode_request(&[]).unwrap_err();
        assert_eq!(e.id, 0);
        // truncations at every prefix length must error, never panic
        for cut in 0..payload.len() {
            assert!(decode_request(&payload[..cut]).is_err(), "prefix {cut} decoded");
        }
        // trailing garbage
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_request(&long).unwrap_err().message.contains("trailing"));
        // unknown method label
        let bad = WireRequest { id: 9, ..sample_request() };
        let mut p = encode_request(&bad).unwrap();
        // method label starts right after the u64 id + u16 len; corrupt it
        p[10] = b'?';
        let e = decode_request(&p).unwrap_err();
        assert_eq!(e.id, 9, "id must survive a bad method label");
        assert!(e.message.contains("unknown method"));
    }

    #[test]
    fn structurally_invalid_matrices_are_rejected() {
        // hand-build a payload with an out-of-range column index by
        // corrupting a valid one (last index word of the indices array)
        let req = WireRequest {
            id: 5,
            method: Method::Classical(Classical::Natural),
            seed: 0,
            eval_fill: false,
            factor_kind: None,
            opt_budget: None,
            factor_threads: None,
            matrix: Csr::identity(4),
        };
        let good = encode_request(&req).unwrap();
        // layout after header fields: nrows ncols nnz, 5×u32 indptr,
        // 4×u32 indices, 4×f64 data → indices end 32 bytes before data
        let data_start = good.len() - 4 * 8;
        let mut bad = good.clone();
        bad[data_start - 4..data_start].copy_from_slice(&100u32.to_le_bytes());
        let e = decode_request(&bad).unwrap_err();
        assert!(e.message.contains("out of range"), "{}", e.message);
        // non-monotone indptr
        let mut bad = good.clone();
        let indptr_start = bad.len() - 4 * 8 - 4 * 4 - 5 * 4;
        bad[indptr_start + 4..indptr_start + 8].copy_from_slice(&3u32.to_le_bytes());
        bad[indptr_start + 8..indptr_start + 12].copy_from_slice(&1u32.to_le_bytes());
        assert!(decode_request(&bad).is_err());
        // nrows != ncols
        let mut bad = good;
        let nrows_start = indptr_start - 12;
        bad[nrows_start..nrows_start + 4].copy_from_slice(&5u32.to_le_bytes());
        let e = decode_request(&bad).unwrap_err();
        assert!(e.message.contains("square") || e.message.contains("truncated"), "{}", e.message);
    }

    #[test]
    fn result_roundtrip() {
        let res = ReorderResult {
            order: vec![2, 0, 1, 3],
            method: "AMD",
            provenance: Some(Provenance::NativeOptimizer),
            latency: 0.25,
            batch_size: 4,
            fill_ratio: Some(1.75),
            factor_kind: Some("lu"),
            opt_iters: 6,
            probe_threads: 2,
            factor_threads: 4,
            levels_refined: 3,
            stages: vec![
                Span { stage: Stage::QueueWait, secs: 0.001 },
                Span { stage: Stage::Order, secs: 0.2 },
                Span { stage: Stage::SymbolicMiss, secs: 0.04 },
            ],
        };
        let payload = encode_result(99, &res);
        let got = decode_result(&payload).unwrap();
        assert_eq!(got.id, 99);
        assert_eq!(got.method, "AMD");
        assert_eq!(got.provenance.as_deref(), Some("native"));
        assert_eq!(got.latency, 0.25);
        assert_eq!(got.batch_size, 4);
        assert_eq!(got.fill_ratio, Some(1.75));
        assert_eq!(got.factor_kind.as_deref(), Some("lu"));
        assert_eq!((got.opt_iters, got.probe_threads, got.levels_refined), (6, 2, 3));
        assert_eq!(got.factor_threads, 4);
        assert_eq!(got.order, vec![2, 0, 1, 3]);
        assert_eq!(
            got.stages,
            vec![
                ("queue_wait".to_string(), 0.001),
                ("order".to_string(), 0.2),
                ("symbolic_miss".to_string(), 0.04),
            ]
        );
    }

    #[test]
    fn result_without_optionals_roundtrips() {
        let res = ReorderResult {
            order: vec![0],
            method: "Natural",
            provenance: None,
            latency: 0.0,
            batch_size: 0,
            fill_ratio: None,
            factor_kind: None,
            opt_iters: 0,
            probe_threads: 0,
            factor_threads: 0,
            levels_refined: 0,
            stages: Vec::new(),
        };
        let payload = encode_result(1, &res);
        let got = decode_result(&payload).unwrap();
        assert_eq!(got.provenance, None);
        assert_eq!(got.fill_ratio, None);
        assert_eq!(got.factor_kind, None);
        // an empty stage list encodes to no stage section at all — the
        // payload a pre-stage server would have produced — and decodes
        // back to an empty list (backward compatibility both ways)
        assert!(got.stages.is_empty());
        let mut with_header = res.clone();
        with_header.stages = vec![Span { stage: Stage::Decode, secs: 0.5 }];
        let longer = encode_result(1, &with_header);
        assert!(longer.len() > payload.len(), "stage section must add bytes");
        assert_eq!(decode_result(&longer).unwrap().stages, vec![("decode".to_string(), 0.5)]);
    }

    #[test]
    fn busy_error_admin_roundtrip() {
        for reason in [BusyReason::QueueFull, BusyReason::RateLimited] {
            let (id, r) = decode_busy(&encode_busy(17, reason)).unwrap();
            assert_eq!((id, r), (17, reason));
        }
        assert!(decode_busy(&encode_busy(1, BusyReason::QueueFull)[..7]).is_err());
        assert!(decode_busy(&[0; 9]).is_ok());
        assert!(decode_busy(&[0, 0, 0, 0, 0, 0, 0, 0, 9]).is_err(), "unknown reason");

        let (id, msg) = decode_error(&encode_error(3, "boom")).unwrap();
        assert_eq!((id, msg.as_str()), (3, "boom"));
        let (_, empty) = decode_error(&encode_error(3, "")).unwrap();
        assert!(empty.is_empty());

        for cmd in [
            AdminCmd::Ping,
            AdminCmd::Metrics,
            AdminCmd::Throttle,
            AdminCmd::Shutdown,
            AdminCmd::Snapshot,
            AdminCmd::Trace,
            AdminCmd::MetricsText,
        ] {
            assert_eq!(decode_admin(&encode_admin(cmd)).unwrap(), cmd);
            assert_eq!(AdminCmd::parse(cmd.label()), Some(cmd));
        }
        assert!(decode_admin(&[]).is_err(), "zero-length admin payload");
        assert!(decode_admin(&[77]).is_err(), "unknown admin command");
        assert!(decode_admin(&[0, 0]).is_err(), "trailing bytes");

        assert_eq!(decode_admin_response(&encode_admin_response("{\"a\":1}")), "{\"a\":1}");
    }

    #[test]
    fn fuzz_decoders_never_panic_on_random_payloads() {
        let mut rng = Pcg64::new(0x31A3_2026);
        for _ in 0..2000 {
            let len = rng.next_below(160);
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let _ = decode_request(&bytes);
            let _ = decode_result(&bytes);
            let _ = decode_busy(&bytes);
            let _ = decode_error(&bytes);
            let _ = decode_admin(&bytes);
            let _ = decode_admin_response(&bytes);
        }
    }

    #[test]
    fn fuzz_corrupted_request_payloads_never_panic() {
        // single- and multi-byte corruptions of a valid request: decode
        // must return Ok or Err, never panic — this is what protects
        // `Csr::from_parts` (debug-only checks) on the wire path
        let mut rng = Pcg64::new(0x31A4_2026);
        let base = encode_request(&sample_request()).unwrap();
        for _ in 0..3000 {
            let mut bytes = base.clone();
            for _ in 0..1 + rng.next_below(6) {
                let i = rng.next_below(bytes.len());
                bytes[i] = (rng.next_u64() & 0xFF) as u8;
            }
            if let Ok(req) = decode_request(&bytes) {
                // anything that decodes must be structurally safe to use
                assert_eq!(req.matrix.nrows(), req.matrix.ncols());
            }
        }
    }
}
