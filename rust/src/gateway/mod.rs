//! L4 gateway: the coordinator on the wire. A zero-dependency TCP front
//! end (`std::net` only) that speaks a length-prefixed binary framing
//! protocol, feeds the [`ReorderService`](crate::coordinator::ReorderService)
//! through its non-blocking submission path, and extends the service's
//! "every accepted request gets answered" contract across the network
//! boundary. See DESIGN.md §Gateway.
//!
//! * [`frame`] — versioned frame header + panic-free frame codec
//! * [`wire`] — payload codecs (requests, results, busy/error/admin)
//! * [`rate_limit`] — per-client token buckets
//! * [`server`] — acceptor + per-connection reader/writer threads
//! * [`client`] — blocking client (CLI, tests, CI smoke)

pub mod client;
pub mod frame;
pub mod rate_limit;
pub mod server;
pub mod wire;

pub use client::{GatewayClient, Reply};
pub use frame::{Frame, FrameError, FrameType, MAX_PAYLOAD};
pub use rate_limit::RateLimiter;
pub use server::{Gateway, GatewayConfig, DEFAULT_ADDR};
pub use wire::{AdminCmd, BusyReason, WireRequest, WireResult};
