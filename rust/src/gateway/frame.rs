//! Length-prefixed binary framing for the TCP reorder gateway.
//!
//! Every message on the wire is one frame:
//!
//! ```text
//!   byte 0..2   magic  0x50 0x46  ("PF")
//!   byte 2      protocol version  (currently 1)
//!   byte 3      frame type        (see `FrameType`)
//!   byte 4..8   payload length    (u32, little-endian)
//!   byte 8..    payload           (length bytes)
//! ```
//!
//! Decoding is **panic-free by contract**: malformed input — wrong magic,
//! unknown version or type, an oversize length prefix, a truncated stream
//! — surfaces as a typed [`FrameError`], never a panic or an unbounded
//! allocation (payload buffers are only reserved after the length passes
//! the [`MAX_PAYLOAD`] cap). Fuzz-style tests below feed random byte
//! strings through the decoder.

use std::io::{self, Read, Write};

/// Frame magic: `"PF"`.
pub const MAGIC: [u8; 2] = [0x50, 0x46];
/// Current protocol version. Frames from other versions are rejected.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 8;
/// Hard cap on payload length (64 MiB) — an oversize length prefix is a
/// protocol error, answered and rejected before any allocation happens.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Kinds of frames the protocol speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameType {
    /// Client → gateway: a reorder request (wire-encoded, see `wire`).
    Request = 1,
    /// Gateway → client: a successful reorder result.
    Response = 2,
    /// Gateway → client: a request-scoped error (id + message).
    Error = 3,
    /// Gateway → client: explicit backpressure — the request was *not*
    /// served (bounded queue full, or the client is rate-limited) and the
    /// client should retry later. Never silent: every submitted frame is
    /// answered with exactly one Response, Error, or Busy.
    Busy = 4,
    /// Client → gateway: an admin command (metrics, throttle stats, ping,
    /// shutdown).
    Admin = 5,
    /// Gateway → client: admin reply (UTF-8 JSON payload).
    AdminResponse = 6,
}

impl FrameType {
    pub fn from_u8(b: u8) -> Option<FrameType> {
        match b {
            1 => Some(FrameType::Request),
            2 => Some(FrameType::Response),
            3 => Some(FrameType::Error),
            4 => Some(FrameType::Busy),
            5 => Some(FrameType::Admin),
            6 => Some(FrameType::AdminResponse),
            _ => None,
        }
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub ftype: FrameType,
    pub payload: Vec<u8>,
}

/// Everything that can go wrong reading a frame.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying transport error.
    Io(io::Error),
    /// The peer closed the connection cleanly at a frame boundary.
    CleanEof,
    /// The stream ended mid-frame (truncated header or payload).
    Truncated,
    /// First two bytes were not the protocol magic.
    BadMagic([u8; 2]),
    /// Unknown protocol version.
    BadVersion(u8),
    /// Unknown frame type.
    BadType(u8),
    /// Length prefix above [`MAX_PAYLOAD`].
    Oversize(u32),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io error: {e}"),
            FrameError::CleanEof => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "truncated frame"),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {:02x}{:02x} (expected 5046)", m[0], m[1])
            }
            FrameError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (expected {VERSION})")
            }
            FrameError::BadType(t) => write!(f, "unknown frame type {t}"),
            FrameError::Oversize(n) => {
                write!(f, "payload length {n} exceeds cap {MAX_PAYLOAD}")
            }
        }
    }
}

/// Encode a frame header.
pub fn encode_header(ftype: FrameType, payload_len: usize) -> [u8; HEADER_LEN] {
    let len = payload_len as u32;
    let mut h = [0u8; HEADER_LEN];
    h[0] = MAGIC[0];
    h[1] = MAGIC[1];
    h[2] = VERSION;
    h[3] = ftype as u8;
    h[4..8].copy_from_slice(&len.to_le_bytes());
    h
}

/// Validate a raw header, returning the frame type and payload length.
pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(FrameType, usize), FrameError> {
    if h[0] != MAGIC[0] || h[1] != MAGIC[1] {
        return Err(FrameError::BadMagic([h[0], h[1]]));
    }
    if h[2] != VERSION {
        return Err(FrameError::BadVersion(h[2]));
    }
    let ftype = FrameType::from_u8(h[3]).ok_or(FrameError::BadType(h[3]))?;
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if len as usize > MAX_PAYLOAD {
        return Err(FrameError::Oversize(len));
    }
    Ok((ftype, len as usize))
}

/// Write one frame (header + payload).
pub fn write_frame(w: &mut impl Write, ftype: FrameType, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_PAYLOAD);
    w.write_all(&encode_header(ftype, payload.len()))?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking read of one frame. Distinguishes a clean close at a frame
/// boundary (`CleanEof`) from a stream that died mid-frame (`Truncated`).
pub fn read_frame(r: &mut impl Read) -> Result<Frame, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    // first byte by hand so a clean EOF is distinguishable
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                return Err(if got == 0 { FrameError::CleanEof } else { FrameError::Truncated })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let (ftype, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match r.read(&mut payload[got..]) {
            Ok(0) => return Err(FrameError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(Frame { ftype, payload })
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::util::rng::Pcg64;
    use std::io::Cursor;

    fn roundtrip(ftype: FrameType, payload: &[u8]) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, ftype, payload).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn frames_roundtrip() {
        for (t, p) in [
            (FrameType::Request, b"hello".as_slice()),
            (FrameType::Response, &[0u8; 1000]),
            (FrameType::Error, b""),
            (FrameType::Busy, &[7]),
            (FrameType::Admin, &[1]),
            (FrameType::AdminResponse, b"{\"ok\":true}"),
        ] {
            let f = roundtrip(t, p);
            assert_eq!(f.ftype, t);
            assert_eq!(f.payload, p);
        }
    }

    #[test]
    fn zero_length_payload_is_a_valid_frame() {
        let f = roundtrip(FrameType::Error, b"");
        assert!(f.payload.is_empty());
    }

    #[test]
    fn clean_eof_vs_truncation() {
        // empty stream → clean close
        match read_frame(&mut Cursor::new(Vec::new())) {
            Err(FrameError::CleanEof) => {}
            other => panic!("expected CleanEof, got {other:?}"),
        }
        // partial header → truncated
        match read_frame(&mut Cursor::new(vec![MAGIC[0], MAGIC[1], VERSION])) {
            Err(FrameError::Truncated) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // full header, missing payload → truncated
        let mut buf = encode_header(FrameType::Request, 100).to_vec();
        buf.extend_from_slice(&[1, 2, 3]);
        match read_frame(&mut Cursor::new(buf)) {
            Err(FrameError::Truncated) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_version_type_and_oversize_are_rejected() {
        let mut h = encode_header(FrameType::Request, 0);
        h[0] = b'X';
        assert!(matches!(parse_header(&h), Err(FrameError::BadMagic(_))));

        let mut h = encode_header(FrameType::Request, 0);
        h[2] = 99;
        assert!(matches!(parse_header(&h), Err(FrameError::BadVersion(99))));

        let mut h = encode_header(FrameType::Request, 0);
        h[3] = 0;
        assert!(matches!(parse_header(&h), Err(FrameError::BadType(0))));
        let mut h = encode_header(FrameType::Request, 0);
        h[3] = 200;
        assert!(matches!(parse_header(&h), Err(FrameError::BadType(200))));

        let mut h = encode_header(FrameType::Request, 0);
        h[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        // the oversize prefix is rejected from the header alone — no
        // 4 GiB allocation ever happens
        assert!(matches!(parse_header(&h), Err(FrameError::Oversize(_))));
        let mut buf = h.to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        assert!(matches!(read_frame(&mut Cursor::new(buf)), Err(FrameError::Oversize(_))));
    }

    #[test]
    fn fuzz_random_byte_strings_never_panic() {
        // the decoder must survive arbitrary garbage: any outcome is fine
        // except a panic or a huge allocation (bounded by MAX_PAYLOAD)
        let mut rng = Pcg64::new(0xF0A_2026);
        for _ in 0..2000 {
            let len = rng.next_below(96);
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let _ = read_frame(&mut Cursor::new(bytes));
        }
    }

    #[test]
    fn fuzz_corrupted_valid_frames_never_panic() {
        // start from a well-formed frame, flip random bytes: decode must
        // return *something* (Ok for benign flips, Err otherwise), never
        // panic
        let mut rng = Pcg64::new(0xF0B_2026);
        let mut base = Vec::new();
        let payload: Vec<u8> = (0..48).map(|i| i as u8).collect();
        write_frame(&mut base, FrameType::Request, &payload).unwrap();
        for _ in 0..2000 {
            let mut bytes = base.clone();
            for _ in 0..1 + rng.next_below(4) {
                let i = rng.next_below(bytes.len());
                bytes[i] = (rng.next_u64() & 0xFF) as u8;
            }
            let _ = read_frame(&mut Cursor::new(bytes));
        }
    }
}
