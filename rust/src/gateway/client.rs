//! Blocking client for the gateway protocol — used by the `admin` and
//! `remote` CLI subcommands, the integration tests, and the CI smoke
//! check. One connection, synchronous request/reply.

use std::io;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::gateway::frame::{read_frame, write_frame, FrameError, FrameType};
use crate::gateway::wire::{self, AdminCmd, BusyReason, WireRequest, WireResult};

/// Any reply the gateway can send for one submitted frame.
#[derive(Debug)]
pub enum Reply {
    /// A served reorder request.
    Result(WireResult),
    /// Explicit backpressure: the request was not served — retry later.
    Busy { id: u64, reason: BusyReason },
    /// A request-scoped error (decode failure, worker panic, shutdown).
    Error { id: u64, message: String },
    /// An admin reply (UTF-8 JSON).
    Admin(String),
}

/// A blocking gateway connection.
pub struct GatewayClient {
    stream: TcpStream,
}

impl GatewayClient {
    /// Connect to a running gateway.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<GatewayClient> {
        Ok(GatewayClient { stream: TcpStream::connect(addr)? })
    }

    /// Like [`connect`](Self::connect) with a connect timeout (admin CLI:
    /// fail fast when no gateway is listening).
    pub fn connect_timeout(addr: &SocketAddr, timeout: Duration) -> io::Result<GatewayClient> {
        Ok(GatewayClient { stream: TcpStream::connect_timeout(addr, timeout)? })
    }

    /// Bound every subsequent read *and* write on this connection.
    /// Without this, a hung or wedged gateway blocks the client forever —
    /// `connect_timeout` only covers the handshake. `None` removes the
    /// bound. A timeout mid-read surfaces as a receive error naming the
    /// timeout (the connection is not usable afterwards: the stream
    /// position is mid-frame).
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> io::Result<()> {
        // a zero Duration would be interpreted as "no timeout" by the OS
        // setsockopt — treat it as the smallest real bound instead
        let timeout = timeout.map(|t| t.max(Duration::from_millis(1)));
        self.stream.set_read_timeout(timeout)?;
        self.stream.set_write_timeout(timeout)
    }

    /// Clone the underlying connection so one thread can keep sending
    /// while another receives (the gateway's per-connection replies are
    /// FIFO, so a dedicated receiver can correlate them in order). Both
    /// halves share the socket and its timeouts.
    pub fn try_clone(&self) -> io::Result<GatewayClient> {
        Ok(GatewayClient { stream: self.stream.try_clone()? })
    }

    /// Send one reorder request frame (does not wait for the reply).
    pub fn send_request(&mut self, req: &WireRequest) -> Result<(), String> {
        let payload = wire::encode_request(req)?;
        write_frame(&mut self.stream, FrameType::Request, &payload)
            .map_err(|e| format!("send failed: {e}"))
    }

    /// Read the next reply frame, whatever it is.
    pub fn recv_reply(&mut self) -> Result<Reply, String> {
        let frame = read_frame(&mut self.stream).map_err(|e| match e {
            FrameError::CleanEof => "gateway closed the connection".to_string(),
            FrameError::Io(ref io)
                if io.kind() == io::ErrorKind::WouldBlock
                    || io.kind() == io::ErrorKind::TimedOut =>
            {
                "timed out waiting for the gateway's reply (see --timeout-ms)".to_string()
            }
            other => format!("receive failed: {other}"),
        })?;
        match frame.ftype {
            FrameType::Response => Ok(Reply::Result(wire::decode_result(&frame.payload)?)),
            FrameType::Busy => {
                let (id, reason) = wire::decode_busy(&frame.payload)?;
                Ok(Reply::Busy { id, reason })
            }
            FrameType::Error => {
                let (id, message) = wire::decode_error(&frame.payload)?;
                Ok(Reply::Error { id, message })
            }
            FrameType::AdminResponse => {
                Ok(Reply::Admin(wire::decode_admin_response(&frame.payload)))
            }
            FrameType::Request | FrameType::Admin => {
                Err(format!("gateway sent a client-only frame type {:?}", frame.ftype))
            }
        }
    }

    /// Submit one request and wait for its reply.
    pub fn request(&mut self, req: &WireRequest) -> Result<Reply, String> {
        self.send_request(req)?;
        self.recv_reply()
    }

    /// Run one admin command and return the JSON reply.
    pub fn admin(&mut self, cmd: AdminCmd) -> Result<String, String> {
        write_frame(&mut self.stream, FrameType::Admin, &wire::encode_admin(cmd))
            .map_err(|e| format!("send failed: {e}"))?;
        match self.recv_reply()? {
            Reply::Admin(json) => Ok(json),
            Reply::Error { message, .. } => Err(message),
            other => Err(format!("unexpected reply to admin command: {other:?}")),
        }
    }
}
