//! Per-client token-bucket rate limiting for the gateway.
//!
//! Each client (keyed by peer address string) gets its own bucket holding
//! up to `burst` tokens, refilled continuously at `rate` tokens/second. A
//! request spends one token; an empty bucket means the request is answered
//! with an explicit `Busy(RateLimited)` frame — one hot client is
//! throttled without slowing anyone else down.
//!
//! The refill clock is passed in (`admit_at`) so tests are deterministic;
//! `admit` is the wall-clock convenience wrapper.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// One client's bucket plus its lifetime admit/throttle counters.
struct Bucket {
    tokens: f64,
    last: Instant,
    allowed: u64,
    throttled: u64,
}

/// Token-bucket limiter shared by all connection threads.
pub struct RateLimiter {
    /// tokens per second; `<= 0` disables limiting entirely
    rate: f64,
    /// bucket capacity (a fresh client can burst this many requests)
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl RateLimiter {
    /// `rate` in requests/second, `burst` the bucket capacity. A
    /// non-positive `rate` turns the limiter off (every request admitted,
    /// still counted).
    pub fn new(rate: f64, burst: f64) -> RateLimiter {
        RateLimiter { rate, burst: burst.max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    /// Whether limiting is active.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Admit or throttle one request from `client` at wall-clock now.
    pub fn admit(&self, client: &str) -> bool {
        self.admit_at(client, Instant::now())
    }

    /// Admit or throttle one request from `client` at time `now`. `now`
    /// values may arrive out of order across threads; elapsed time is
    /// clamped at zero so the bucket never refills backwards.
    pub fn admit_at(&self, client: &str, now: Instant) -> bool {
        let mut buckets = lock_unpoisoned(&self.buckets);
        let b = buckets.entry(client.to_string()).or_insert(Bucket {
            tokens: self.burst,
            last: now,
            allowed: 0,
            throttled: 0,
        });
        if self.rate > 0.0 {
            let dt = now.saturating_duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * self.rate).min(self.burst);
            b.last = now;
            if b.tokens < 1.0 {
                b.throttled += 1;
                return false;
            }
            b.tokens -= 1.0;
        }
        b.allowed += 1;
        true
    }

    /// Total requests throttled across all clients.
    pub fn total_throttled(&self) -> u64 {
        lock_unpoisoned(&self.buckets).values().map(|b| b.throttled).sum()
    }

    /// Per-client stats as JSON — the admin `throttle` reply.
    pub fn stats_json(&self) -> String {
        let buckets = lock_unpoisoned(&self.buckets);
        let mut clients: Vec<_> = buckets.iter().collect();
        clients.sort_by(|a, b| a.0.cmp(b.0));
        let obj = Json::obj()
            .set("rate", self.rate)
            .set("burst", self.burst)
            .set("enabled", self.rate > 0.0);
        let mut list = Json::obj();
        for (name, b) in clients {
            list = list.set(
                name,
                Json::obj()
                    .set("allowed", b.allowed as i64)
                    .set("throttled", b.throttled as i64),
            );
        }
        obj.set("clients", list).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::time::Duration;

    #[test]
    fn burst_then_throttle_then_refill() {
        let rl = RateLimiter::new(10.0, 3.0);
        let t0 = Instant::now();
        // a fresh client can spend its whole burst instantly
        for i in 0..3 {
            assert!(rl.admit_at("a", t0), "burst admit {i}");
        }
        // the fourth request at the same instant is throttled
        assert!(!rl.admit_at("a", t0));
        assert_eq!(rl.total_throttled(), 1);
        // 100 ms at 10 req/s refills exactly one token
        assert!(rl.admit_at("a", t0 + Duration::from_millis(100)));
        assert!(!rl.admit_at("a", t0 + Duration::from_millis(100)));
    }

    #[test]
    fn clients_are_isolated() {
        let rl = RateLimiter::new(5.0, 2.0);
        let t0 = Instant::now();
        assert!(rl.admit_at("hog", t0));
        assert!(rl.admit_at("hog", t0));
        assert!(!rl.admit_at("hog", t0), "hog exhausted its bucket");
        // a different client is untouched by the hog's throttling
        assert!(rl.admit_at("calm", t0));
        assert!(rl.admit_at("calm", t0));
    }

    #[test]
    fn non_positive_rate_disables_limiting() {
        let rl = RateLimiter::new(0.0, 1.0);
        let t0 = Instant::now();
        for _ in 0..100 {
            assert!(rl.admit_at("any", t0));
        }
        assert_eq!(rl.total_throttled(), 0);
        assert!(!rl.enabled());
    }

    #[test]
    fn out_of_order_timestamps_never_refill_backwards() {
        let rl = RateLimiter::new(1.0, 1.0);
        let t0 = Instant::now();
        assert!(rl.admit_at("a", t0 + Duration::from_secs(5)));
        // an earlier timestamp arriving late must not panic or mint tokens
        assert!(!rl.admit_at("a", t0));
        assert!(!rl.admit_at("a", t0 + Duration::from_secs(5)));
    }

    #[test]
    fn stats_json_reports_per_client_counts() {
        let rl = RateLimiter::new(10.0, 1.0);
        let t0 = Instant::now();
        assert!(rl.admit_at("b", t0));
        assert!(!rl.admit_at("b", t0));
        assert!(rl.admit_at("a", t0));
        let s = rl.stats_json();
        assert!(s.contains("\"rate\":10"), "{s}");
        assert!(s.contains("\"clients\""), "{s}");
        assert!(s.contains("\"throttled\":1"), "{s}");
        // deterministic client order (sorted by name)
        assert!(s.find("\"a\"").unwrap() < s.find("\"b\"").unwrap(), "{s}");
    }
}
