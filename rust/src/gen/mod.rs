//! Workload generation: structured grids, unstructured Delaunay/FEM meshes,
//! and the six SuiteSparse-class synthetic families (see DESIGN.md for the
//! substitution rationale — the real SuiteSparse collection is not available
//! in this environment).

pub mod classes;
pub mod grid;
pub mod mesh;

pub use classes::{
    test_suite, training_suite, unsymmetric_suite, ProblemClass, Symmetry, TestMatrix,
};
