//! SuiteSparse-class workload generators.
//!
//! The paper's test set is 148 SuiteSparse matrices grouped into six
//! application classes. SuiteSparse itself is not available in this
//! environment (repro substitution — see DESIGN.md), so each class is
//! replaced by a synthetic generator reproducing its characteristic
//! sparsity *pattern*, which is what drives fill-in behaviour under
//! reordering:
//!
//! * **SP** (structural)      → 3D stencils with next-nearest couplings
//! * **CFD**                  → anisotropic 9-point convection–diffusion
//! * **MRP** (model reduction)→ banded system + dense coupling border (block-arrow)
//! * **2D3D** (discretized)   → plain 5/7-point Laplacians
//! * **TP** (thermal)         → heterogeneous-conductivity grids
//! * **Other**                → Watts–Strogatz & random geometric graphs

use crate::gen::grid;
use crate::gen::mesh::{self, Geometry};
use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// Whether a problem class produces symmetric (SPD, Cholesky-factorable)
/// or general unsymmetric-value matrices (LU territory).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Symmetry {
    Symmetric,
    Unsymmetric,
}

/// The six problem classes of the paper's Table 2, plus the two
/// unsymmetric families the kind-generic LU engine unlocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProblemClass {
    /// Structural problem (44 matrices in the paper's test set).
    Sp,
    /// Computational fluid dynamics (25).
    Cfd,
    /// Model reduction problem (16).
    Mrp,
    /// 2D/3D discretized problem (12).
    TwoDThreeD,
    /// Thermal problem (5).
    Tp,
    /// Everything else (46).
    Other,
    /// Upwind convection–diffusion: value-unsymmetric 5-point stencil.
    ConvDiff,
    /// Circuit-style network: random unsymmetric-value conductance graph.
    Circuit,
}

impl ProblemClass {
    /// The symmetric (SPD) classes of the paper's Table 2.
    pub const ALL: [ProblemClass; 6] = [
        ProblemClass::Cfd,
        ProblemClass::Mrp,
        ProblemClass::Sp,
        ProblemClass::TwoDThreeD,
        ProblemClass::Tp,
        ProblemClass::Other,
    ];

    /// The unsymmetric classes evaluated through the LU engine.
    pub const UNSYMMETRIC: [ProblemClass; 2] =
        [ProblemClass::ConvDiff, ProblemClass::Circuit];

    /// Short label used in tables (matches the paper's column headers).
    pub fn label(&self) -> &'static str {
        match self {
            ProblemClass::Cfd => "CFD",
            ProblemClass::Mrp => "MRP",
            ProblemClass::Sp => "SP",
            ProblemClass::TwoDThreeD => "2D3D",
            ProblemClass::Tp => "TP",
            ProblemClass::Other => "Other",
            ProblemClass::ConvDiff => "ConvDiff",
            ProblemClass::Circuit => "Circuit",
        }
    }

    pub fn from_label(s: &str) -> Option<ProblemClass> {
        Some(match s.to_ascii_uppercase().as_str() {
            "CFD" => ProblemClass::Cfd,
            "MRP" => ProblemClass::Mrp,
            "SP" => ProblemClass::Sp,
            "2D3D" => ProblemClass::TwoDThreeD,
            "TP" => ProblemClass::Tp,
            "OTHER" => ProblemClass::Other,
            "CONVDIFF" => ProblemClass::ConvDiff,
            "CIRCUIT" => ProblemClass::Circuit,
            _ => return None,
        })
    }

    /// Which factorization kind this class's matrices call for.
    pub fn symmetry(&self) -> Symmetry {
        match self {
            ProblemClass::ConvDiff | ProblemClass::Circuit => Symmetry::Unsymmetric,
            _ => Symmetry::Symmetric,
        }
    }

    /// Generate one matrix of this class with roughly `n` rows.
    /// Deterministic in (class, n, seed).
    pub fn generate(&self, n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed ^ class_salt(*self));
        match self {
            ProblemClass::TwoDThreeD => {
                if rng.next_f64() < 0.5 {
                    let side = (n as f64).sqrt().round().max(2.0) as usize;
                    grid::laplacian_2d(side, side)
                } else {
                    let side = (n as f64).cbrt().round().max(2.0) as usize;
                    grid::laplacian_3d(side, side, side)
                }
            }
            ProblemClass::Cfd => {
                // elongated channel-like grids with anisotropy
                let aspect = 1.0 + 3.0 * rng.next_f64();
                let ny = ((n as f64 / aspect).sqrt().round().max(2.0)) as usize;
                let nx = (n / ny).max(2);
                let eps = 10f64.powf(rng.uniform(-2.0, 0.0));
                grid::cfd_stencil_2d(nx, ny, eps, &mut rng)
            }
            ProblemClass::Tp => {
                // rectangular grids so the TP pattern is not identical to
                // the square 2D3D Laplacian pattern
                let aspect = 1.5 + rng.next_f64();
                let ny = ((n as f64 / aspect).sqrt().round().max(2.0)) as usize;
                let nx = (n / ny).max(2);
                let contrast = rng.uniform(1.0, 2.5);
                grid::thermal_grid_2d(nx, ny, contrast, &mut rng)
            }
            ProblemClass::Sp => {
                let side = (n as f64).cbrt().round().max(2.0) as usize;
                grid::structural_grid_3d(side, side, side, &mut rng)
            }
            ProblemClass::Mrp => block_arrow(n, &mut rng),
            ProblemClass::Other => {
                if rng.next_f64() < 0.5 {
                    watts_strogatz_spd(n, 6, 0.1, &mut rng)
                } else {
                    random_geometric_spd(n, &mut rng)
                }
            }
            ProblemClass::ConvDiff => {
                // elongated channels like the CFD class, but upwind
                // convection makes the values genuinely unsymmetric
                let aspect = 1.0 + 2.0 * rng.next_f64();
                let ny = ((n as f64 / aspect).sqrt().round().max(2.0)) as usize;
                let nx = (n / ny).max(2);
                let peclet = rng.uniform(0.5, 4.0);
                grid::convection_diffusion_2d(nx, ny, peclet, &mut rng)
            }
            ProblemClass::Circuit => circuit_network(n, &mut rng),
        }
    }
}

fn class_salt(c: ProblemClass) -> u64 {
    match c {
        ProblemClass::Cfd => 0xC0FD,
        ProblemClass::Mrp => 0x14B9,
        ProblemClass::Sp => 0x59A7,
        ProblemClass::TwoDThreeD => 0x2D3D,
        ProblemClass::Tp => 0x7E44,
        ProblemClass::Other => 0x07E2,
        ProblemClass::ConvDiff => 0xC04D,
        ProblemClass::Circuit => 0xC12C,
    }
}

/// Circuit-style network with unsymmetric values: a ring backbone plus
/// random chords (the netlist), where each connection carries a
/// conductance `g` made asymmetric on a random subset of edges (controlled
/// sources: `a_uv = −(g+s)`, `a_vu = −(g−s)` with `|s| < g`). Grounded
/// through the diagonal (row-sum + 1), so the matrix is strictly
/// row-diagonally dominant — circuit matrices are the canonical
/// "unsymmetric values, symmetric pattern" LU workload.
pub fn circuit_network(n: usize, rng: &mut Pcg64) -> Csr {
    assert!(n >= 3);
    let mut coo = Coo::square(n);
    let mut rowsum = vec![0.0f64; n];
    let connect = |coo: &mut Coo, rowsum: &mut [f64], u: usize, v: usize, r: &mut Pcg64| {
        let g = 0.5 + r.next_f64();
        // half the edges get a controlled-source asymmetry
        let s = if r.next_f64() < 0.5 { g * r.uniform(0.1, 0.8) } else { 0.0 };
        coo.push(u, v, -(g + s));
        coo.push(v, u, -(g - s));
        rowsum[u] += g + s;
        rowsum[v] += g - s;
    };
    // ring backbone keeps the network connected
    for u in 0..n {
        connect(&mut coo, &mut rowsum, u, (u + 1) % n, rng);
    }
    // random chords (~2 per node), deduplicated against nothing: COO sums
    // duplicates, which just merges parallel branches — physical for
    // circuits
    for _ in 0..(2 * n) {
        let u = rng.next_below(n);
        let v = rng.next_below(n);
        if u != v {
            connect(&mut coo, &mut rowsum, u, v, rng);
        }
    }
    for (i, s) in rowsum.iter().enumerate() {
        coo.push(i, i, s + 1.0);
    }
    coo.to_csr()
}

/// Model-reduction-like pattern: a banded interior system (the reduced
/// dynamics) plus a small set of "port" rows, each coupled to a contiguous
/// window of the interior and to a few random long-range taps. SuiteSparse
/// MRP matrices are predominantly banded with moderate port coupling —
/// ports that touch O(n) of the interior (a pure block-arrow) would make
/// the class degenerate under every local ordering.
pub fn block_arrow(n: usize, rng: &mut Pcg64) -> Csr {
    let ports = (n / 40).clamp(2, 20);
    let interior = n - ports;
    let band = 4 + rng.next_below(5);
    let mut coo = Coo::square(n);
    let mut diag = vec![1.0f64; n];
    // banded interior
    for i in 0..interior {
        for off in 1..=band {
            if i + off < interior {
                let w = 0.5 + rng.next_f64();
                coo.push_sym(i, i + off, -w / off as f64);
                diag[i] += w / off as f64;
                diag[i + off] += w / off as f64;
            }
        }
    }
    // port coupling: a contiguous interior window + a few random taps
    let window = (interior / (2 * ports)).max(4);
    for p in 0..ports {
        let row = interior + p;
        let start = (p * interior / ports).min(interior.saturating_sub(window));
        for col in start..(start + window).min(interior) {
            let w = 0.1 + 0.4 * rng.next_f64();
            coo.push_sym(row, col, -w);
            diag[row] += w;
            diag[col] += w;
        }
        for &col in rng.sample_distinct(interior, 4.min(interior)).iter() {
            let w = 0.05 + 0.15 * rng.next_f64();
            coo.push_sym(row, col, -w);
            diag[row] += w;
            diag[col] += w;
        }
        // port-port chain
        if p > 0 {
            let w = 0.2;
            coo.push_sym(row, interior + p - 1, -w);
            diag[row] += w;
            diag[interior + p - 1] += w;
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, *d);
    }
    coo.to_csr()
}

/// Watts–Strogatz small-world graph turned into an SPD graph Laplacian
/// (+identity). Irregular long-range edges → "Other" class behaviour.
pub fn watts_strogatz_spd(n: usize, k: usize, beta: f64, rng: &mut Pcg64) -> Csr {
    assert!(k % 2 == 0 && k < n);
    // ring lattice with k/2 neighbours either side, then rewire
    let mut edges: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for i in 0..n {
        for j in 1..=(k / 2) {
            let mut u = i;
            let mut v = (i + j) % n;
            if rng.next_f64() < beta {
                // rewire target
                let mut t = rng.next_below(n);
                let mut guard = 0;
                while (t == u || edges.contains(&(u.min(t), u.max(t)))) && guard < 20 {
                    t = rng.next_below(n);
                    guard += 1;
                }
                v = t;
            }
            if u == v {
                continue;
            }
            if u > v {
                std::mem::swap(&mut u, &mut v);
            }
            edges.insert((u, v));
        }
    }
    laplacian_from_edges(n, edges.into_iter(), rng)
}

/// Random geometric graph (unit square, radius tuned for ~8 mean degree)
/// as an SPD Laplacian.
pub fn random_geometric_spd(n: usize, rng: &mut Pcg64) -> Csr {
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let radius = (8.0 / (std::f64::consts::PI * n as f64)).sqrt();
    let r2 = radius * radius;
    // cell grid for neighbour search
    let cells = ((1.0 / radius).floor() as usize).max(1);
    let mut grid: Vec<Vec<usize>> = vec![Vec::new(); cells * cells];
    let cell_of = |x: f64, y: f64| {
        let cx = ((x * cells as f64) as usize).min(cells - 1);
        let cy = ((y * cells as f64) as usize).min(cells - 1);
        cy * cells + cx
    };
    for (i, &(x, y)) in pts.iter().enumerate() {
        grid[cell_of(x, y)].push(i);
    }
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for (i, &(x, y)) in pts.iter().enumerate() {
        let cx = ((x * cells as f64) as usize).min(cells - 1) as isize;
        let cy = ((y * cells as f64) as usize).min(cells - 1) as isize;
        for dy in -1..=1isize {
            for dx in -1..=1isize {
                let (nx, ny) = (cx + dx, cy + dy);
                if nx < 0 || ny < 0 || nx >= cells as isize || ny >= cells as isize {
                    continue;
                }
                for &j in &grid[ny as usize * cells + nx as usize] {
                    if j <= i {
                        continue;
                    }
                    let (dx, dy) = (pts[j].0 - x, pts[j].1 - y);
                    if dx * dx + dy * dy <= r2 {
                        edges.push((i, j));
                    }
                }
            }
        }
    }
    laplacian_from_edges(n, edges.into_iter(), rng)
}

fn laplacian_from_edges(
    n: usize,
    edges: impl Iterator<Item = (usize, usize)>,
    rng: &mut Pcg64,
) -> Csr {
    let mut coo = Coo::square(n);
    let mut deg = vec![0.0f64; n];
    for (u, v) in edges {
        let w = 0.5 + rng.next_f64();
        coo.push_sym(u, v, -w);
        deg[u] += w;
        deg[v] += w;
    }
    for (i, d) in deg.iter().enumerate() {
        coo.push(i, i, d + 1.0);
    }
    coo.to_csr()
}

/// A named test matrix (the synthetic stand-in for one SuiteSparse entry).
#[derive(Clone, Debug)]
pub struct TestMatrix {
    pub name: String,
    pub class: ProblemClass,
    pub matrix: Csr,
}

/// Shared suite builder: `per_class` matrices per class per size, with
/// one seed-mixing formula and naming scheme for every suite flavour.
fn suite_for(
    classes: &[ProblemClass],
    sizes: &[usize],
    per_class: usize,
    seed: u64,
) -> Vec<TestMatrix> {
    let mut out = Vec::new();
    for &n in sizes {
        for &class in classes {
            for rep in 0..per_class {
                let s = seed
                    .wrapping_mul(0x9e3779b97f4a7c15)
                    .wrapping_add((n as u64) << 8)
                    .wrapping_add(rep as u64);
                let m = class.generate(n, s);
                out.push(TestMatrix {
                    name: format!("{}_n{}_r{}", class.label().to_lowercase(), n, rep),
                    class,
                    matrix: m,
                });
            }
        }
    }
    out
}

/// Build a test suite mirroring the paper's class mix at a scaled-down
/// size. `sizes` are target dimensions; `per_class` matrices per class per
/// size.
pub fn test_suite(sizes: &[usize], per_class: usize, seed: u64) -> Vec<TestMatrix> {
    suite_for(&ProblemClass::ALL, sizes, per_class, seed)
}

/// Build the unsymmetric evaluation suite (ConvDiff ∪ Circuit) mirroring
/// [`test_suite`]'s shape: `per_class` matrices per class per size,
/// deterministic in `seed`. These matrices go through the LU engine.
pub fn unsymmetric_suite(sizes: &[usize], per_class: usize, seed: u64) -> Vec<TestMatrix> {
    suite_for(&ProblemClass::UNSYMMETRIC, sizes, per_class, seed)
}

/// The training mix of the paper (2D3D ∪ Delaunay ∪ FEM over GradeL /
/// Hole3 / Hole6): `count` matrices with sizes in [lo, hi].
pub fn training_suite(count: usize, lo: usize, hi: usize, seed: u64) -> Vec<TestMatrix> {
    let mut rng = Pcg64::new(seed);
    let geoms = [Geometry::GradeL, Geometry::Hole3, Geometry::Hole6];
    let mut out = Vec::new();
    for i in 0..count {
        let n = lo + rng.next_below(hi - lo + 1);
        let kind = i % 3;
        let (name, matrix) = match kind {
            0 => {
                let m = ProblemClass::TwoDThreeD.generate(n, rng.next_u64());
                (format!("train_2d3d_{i}"), m)
            }
            1 => {
                let g = geoms[rng.next_below(3)];
                let mesh = mesh::delaunay_mesh(g, n, &mut rng);
                (format!("train_delaunay_{i}"), mesh::mesh_graph_laplacian(&mesh))
            }
            _ => {
                let g = geoms[rng.next_below(3)];
                let mesh = mesh::delaunay_mesh(g, n, &mut rng);
                (format!("train_fem_{i}"), mesh::fem_stiffness(&mesh, 1.0))
            }
        };
        out.push(TestMatrix { name, class: ProblemClass::TwoDThreeD, matrix });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_classes_generate_symmetric_spd_patterns() {
        for &class in &ProblemClass::ALL {
            let a = class.generate(200, 77);
            assert!(a.nrows() >= 100, "{:?} too small: {}", class, a.nrows());
            assert!(a.is_symmetric(1e-10), "{class:?} not symmetric");
            // weak dominance suffices: Dirichlet Laplacians have margin 0 on
            // interior rows but are PD via irreducibility + boundary rows
            assert!(
                a.diag_dominance_margin() >= 0.0,
                "{class:?} not (weakly) diagonally dominant"
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for class in ProblemClass::ALL.iter().chain(&ProblemClass::UNSYMMETRIC) {
            let a = class.generate(150, 5);
            let b = class.generate(150, 5);
            assert_eq!(a, b, "{class:?} not deterministic");
        }
    }

    #[test]
    fn unsymmetric_classes_are_value_unsymmetric_dominant() {
        for &class in &ProblemClass::UNSYMMETRIC {
            assert_eq!(class.symmetry(), Symmetry::Unsymmetric);
            let a = class.generate(200, 77);
            assert!(a.nrows() >= 100, "{class:?} too small");
            assert!(!a.is_symmetric(1e-12), "{class:?} must be value-unsymmetric");
            // pattern stays symmetric — the A+Aᵀ LU bound is tight here
            let t = a.transpose();
            assert_eq!(a.indptr(), t.indptr(), "{class:?} pattern not symmetric");
            assert_eq!(a.indices(), t.indices(), "{class:?} pattern not symmetric");
            assert!(
                a.diag_dominance_margin() >= 0.0,
                "{class:?} not (weakly) diagonally dominant"
            );
        }
        for &class in &ProblemClass::ALL {
            assert_eq!(class.symmetry(), Symmetry::Symmetric);
        }
    }

    #[test]
    fn unsymmetric_suite_covers_both_classes() {
        let suite = unsymmetric_suite(&[100, 200], 2, 1);
        assert_eq!(suite.len(), 2 * 2 * 2);
        for &class in &ProblemClass::UNSYMMETRIC {
            assert!(suite.iter().any(|t| t.class == class));
        }
        for t in &suite {
            assert!(!t.matrix.is_symmetric(1e-12), "{} symmetric", t.name);
        }
    }

    #[test]
    fn block_arrow_has_port_border() {
        let mut rng = Pcg64::new(3);
        let a = block_arrow(200, &mut rng);
        let ports = (200 / 40).clamp(2, 20);
        let interior = 200 - ports;
        // port rows are denser than interior rows (window + taps vs band)
        let port_deg = a.off_diag_degree(interior + 1);
        let int_deg = a.off_diag_degree(10);
        assert!(
            port_deg > int_deg,
            "port {port_deg} vs interior {int_deg}"
        );
    }

    #[test]
    fn watts_strogatz_connected_degree() {
        let mut rng = Pcg64::new(4);
        let a = watts_strogatz_spd(100, 6, 0.1, &mut rng);
        let mean_deg =
            (0..100).map(|i| a.off_diag_degree(i)).sum::<usize>() as f64 / 100.0;
        assert!((4.0..8.0).contains(&mean_deg), "mean degree {mean_deg}");
    }

    #[test]
    fn suite_covers_all_classes() {
        let suite = test_suite(&[100, 200], 2, 1);
        assert_eq!(suite.len(), 2 * 6 * 2);
        for &class in &ProblemClass::ALL {
            assert!(suite.iter().any(|t| t.class == class));
        }
    }

    #[test]
    fn training_suite_mixes_kinds() {
        let ts = training_suite(9, 60, 120, 2);
        assert_eq!(ts.len(), 9);
        assert!(ts.iter().any(|t| t.name.contains("2d3d")));
        assert!(ts.iter().any(|t| t.name.contains("delaunay")));
        assert!(ts.iter().any(|t| t.name.contains("fem")));
        for t in &ts {
            assert!(t.matrix.is_symmetric(1e-10), "{} not symmetric", t.name);
        }
    }

    #[test]
    fn labels_roundtrip() {
        for c in ProblemClass::ALL.iter().chain(&ProblemClass::UNSYMMETRIC) {
            assert_eq!(ProblemClass::from_label(c.label()), Some(*c));
        }
        assert_eq!(ProblemClass::from_label("nope"), None);
    }
}
