//! Unstructured 2D meshes: Bowyer–Watson Delaunay triangulation over point
//! clouds sampled inside the three geometries the paper's training set uses
//! (Gatti et al. 2021): **GradeL** (graded L-shaped domain), **Hole3** and
//! **Hole6** (plates with 3/6 circular holes). FEM stiffness assembly with
//! linear (P1) triangle elements turns a mesh into an SPD system matrix.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// A 2D point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub x: f64,
    pub y: f64,
}

/// Triangle as indices into a point array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tri(pub usize, pub usize, pub usize);

/// A triangulated domain.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub points: Vec<Point>,
    pub tris: Vec<Tri>,
}

/// The three training geometries of the paper (plus a plain square for
/// sanity baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Geometry {
    /// Unit square, uniform density.
    Square,
    /// L-shaped domain with density graded toward the re-entrant corner.
    GradeL,
    /// Unit square with 3 circular holes.
    Hole3,
    /// Unit square with 6 circular holes.
    Hole6,
}

impl Geometry {
    /// Is `p` inside the domain?
    pub fn contains(&self, p: Point) -> bool {
        let in_square = (0.0..=1.0).contains(&p.x) && (0.0..=1.0).contains(&p.y);
        if !in_square {
            return false;
        }
        match self {
            Geometry::Square => true,
            Geometry::GradeL => !(p.x > 0.5 && p.y > 0.5), // remove upper-right quadrant
            Geometry::Hole3 => !Self::in_holes(p, &HOLES3),
            Geometry::Hole6 => !Self::in_holes(p, &HOLES6),
        }
    }

    fn in_holes(p: Point, holes: &[(f64, f64, f64)]) -> bool {
        holes.iter().any(|&(cx, cy, r)| {
            let (dx, dy) = (p.x - cx, p.y - cy);
            dx * dx + dy * dy < r * r
        })
    }

    /// Rejection-sample `n` points in the domain. GradeL grades the density
    /// toward the re-entrant corner at (0.5, 0.5) the way graded FEM meshes
    /// do.
    pub fn sample(&self, n: usize, rng: &mut Pcg64) -> Vec<Point> {
        let mut pts = Vec::with_capacity(n);
        while pts.len() < n {
            let mut p = Point { x: rng.next_f64(), y: rng.next_f64() };
            if *self == Geometry::GradeL {
                // pull samples toward the corner: square the distance field
                let t = rng.next_f64();
                if t < 0.5 {
                    p.x = 0.5 + (p.x - 0.5) * rng.next_f64();
                    p.y = 0.5 + (p.y - 0.5) * rng.next_f64();
                }
            }
            if self.contains(p) {
                pts.push(p);
            }
        }
        pts
    }
}

const HOLES3: [(f64, f64, f64); 3] =
    [(0.25, 0.25, 0.12), (0.75, 0.35, 0.12), (0.45, 0.75, 0.12)];
const HOLES6: [(f64, f64, f64); 6] = [
    (0.2, 0.2, 0.09),
    (0.5, 0.2, 0.09),
    (0.8, 0.2, 0.09),
    (0.2, 0.7, 0.09),
    (0.5, 0.8, 0.09),
    (0.8, 0.7, 0.09),
];

/// Bowyer–Watson incremental Delaunay triangulation. O(n²) worst case,
/// fine at the n ≤ few-thousand scale the training set uses.
pub fn delaunay(points: &[Point]) -> Vec<Tri> {
    assert!(points.len() >= 3, "need at least 3 points");
    // Super-triangle enclosing all points.
    let (mut minx, mut miny, mut maxx, mut maxy) =
        (f64::INFINITY, f64::INFINITY, f64::NEG_INFINITY, f64::NEG_INFINITY);
    for p in points {
        minx = minx.min(p.x);
        miny = miny.min(p.y);
        maxx = maxx.max(p.x);
        maxy = maxy.max(p.y);
    }
    let d = (maxx - minx).max(maxy - miny).max(1e-9) * 20.0;
    let cx = (minx + maxx) / 2.0;
    let cy = (miny + maxy) / 2.0;
    let mut pts: Vec<Point> = points.to_vec();
    let s0 = pts.len();
    pts.push(Point { x: cx - d, y: cy - d });
    pts.push(Point { x: cx + d, y: cy - d });
    pts.push(Point { x: cx, y: cy + d });

    let mut tris: Vec<Tri> = vec![Tri(s0, s0 + 1, s0 + 2)];
    for (pi, p) in points.iter().enumerate() {
        // find all triangles whose circumcircle contains p
        let mut bad: Vec<usize> = Vec::new();
        for (ti, t) in tris.iter().enumerate() {
            if in_circumcircle(&pts, *t, *p) {
                bad.push(ti);
            }
        }
        // boundary of the cavity = edges appearing exactly once among bad tris
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for &ti in &bad {
            let Tri(a, b, c) = tris[ti];
            for &(u, v) in &[(a, b), (b, c), (c, a)] {
                let key = (u.min(v), u.max(v));
                if let Some(pos) = edges.iter().position(|&e| e == key) {
                    edges.swap_remove(pos); // shared edge → interior, drop
                } else {
                    edges.push(key);
                }
            }
        }
        // remove bad triangles (descending order keeps indices valid)
        bad.sort_unstable_by(|a, b| b.cmp(a));
        for ti in bad {
            tris.swap_remove(ti);
        }
        // re-triangulate the cavity
        for (u, v) in edges {
            tris.push(make_ccw(&pts, Tri(u, v, pi)));
        }
    }
    // drop triangles touching the super-triangle
    tris.retain(|&Tri(a, b, c)| a < s0 && b < s0 && c < s0);
    tris
}

fn make_ccw(pts: &[Point], t: Tri) -> Tri {
    if orient2d(pts[t.0], pts[t.1], pts[t.2]) < 0.0 {
        Tri(t.0, t.2, t.1)
    } else {
        t
    }
}

/// Twice the signed area of triangle abc (> 0 when counter-clockwise).
fn orient2d(a: Point, b: Point, c: Point) -> f64 {
    (b.x - a.x) * (c.y - a.y) - (b.y - a.y) * (c.x - a.x)
}

/// Is p strictly inside the circumcircle of (CCW) triangle t?
fn in_circumcircle(pts: &[Point], t: Tri, p: Point) -> bool {
    let t = make_ccw(pts, t);
    let (a, b, c) = (pts[t.0], pts[t.1], pts[t.2]);
    let (ax, ay) = (a.x - p.x, a.y - p.y);
    let (bx, by) = (b.x - p.x, b.y - p.y);
    let (cx, cy) = (c.x - p.x, c.y - p.y);
    let det = (ax * ax + ay * ay) * (bx * cy - cx * by)
        - (bx * bx + by * by) * (ax * cy - cx * ay)
        + (cx * cx + cy * cy) * (ax * by - bx * ay);
    det > 1e-12
}

/// Generate a Delaunay mesh of `n` interior points in `geom`.
pub fn delaunay_mesh(geom: Geometry, n: usize, rng: &mut Pcg64) -> Mesh {
    let points = geom.sample(n, rng);
    let tris = delaunay(&points);
    Mesh { points, tris }
}

/// Assemble the P1 FEM stiffness matrix (Laplace operator) over a mesh.
/// Each triangle contributes the standard linear-element local stiffness;
/// a small mass-matrix shift (`shift · area/3` lumped) makes the global
/// matrix SPD without boundary conditions.
pub fn fem_stiffness(mesh: &Mesh, shift: f64) -> Csr {
    let n = mesh.points.len();
    let mut coo = Coo::square(n);
    let mut lumped = vec![0.0f64; n];
    for &Tri(i, j, k) in &mesh.tris {
        let (p1, p2, p3) = (mesh.points[i], mesh.points[j], mesh.points[k]);
        let area2 = orient2d(p1, p2, p3).abs(); // 2·area
        if area2 < 1e-14 {
            continue; // degenerate sliver
        }
        let area = area2 / 2.0;
        // gradients of the barycentric basis functions
        let b = [p2.y - p3.y, p3.y - p1.y, p1.y - p2.y];
        let c = [p3.x - p2.x, p1.x - p3.x, p2.x - p1.x];
        let ids = [i, j, k];
        for r in 0..3 {
            for s in 0..=r {
                let kij = (b[r] * b[s] + c[r] * c[s]) / (4.0 * area);
                if r == s {
                    coo.push(ids[r], ids[r], kij);
                } else {
                    coo.push_sym(ids[r], ids[s], kij);
                }
            }
            lumped[ids[r]] += area / 3.0;
        }
    }
    for (i, m) in lumped.iter().enumerate() {
        // isolated points (not in any retained triangle) still need a pivot
        coo.push(i, i, shift * m + 1e-9);
    }
    coo.to_csr()
}

/// Graph Laplacian of the mesh edges (unit weights): an alternative
/// "Delaunay matrix" family used in the paper's training mix.
pub fn mesh_graph_laplacian(mesh: &Mesh) -> Csr {
    let n = mesh.points.len();
    let mut coo = Coo::square(n);
    let mut deg = vec![0.0f64; n];
    let mut seen: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for &Tri(a, b, c) in &mesh.tris {
        for &(u, v) in &[(a, b), (b, c), (c, a)] {
            let key = (u.min(v), u.max(v));
            if seen.insert(key) {
                coo.push_sym(u, v, -1.0);
                deg[u] += 1.0;
                deg[v] += 1.0;
            }
        }
    }
    for (i, d) in deg.iter().enumerate() {
        coo.push(i, i, d + 1e-3);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delaunay_square_of_4() {
        let pts = vec![
            Point { x: 0.0, y: 0.0 },
            Point { x: 1.0, y: 0.0 },
            Point { x: 0.0, y: 1.0 },
            Point { x: 1.0, y: 1.0 },
        ];
        let tris = delaunay(&pts);
        assert_eq!(tris.len(), 2);
    }

    #[test]
    fn delaunay_empty_circumcircle_property() {
        let mut rng = Pcg64::new(21);
        let pts: Vec<Point> = (0..60)
            .map(|_| Point { x: rng.next_f64(), y: rng.next_f64() })
            .collect();
        let tris = delaunay(&pts);
        assert!(!tris.is_empty());
        // No point lies strictly inside any triangle's circumcircle.
        for &t in &tris {
            for (pi, &p) in pts.iter().enumerate() {
                if pi == t.0 || pi == t.1 || pi == t.2 {
                    continue;
                }
                assert!(
                    !in_circumcircle(&pts, t, p),
                    "point {pi} inside circumcircle of {t:?}"
                );
            }
        }
    }

    #[test]
    fn geometries_respect_holes() {
        assert!(Geometry::Hole3.contains(Point { x: 0.05, y: 0.05 }));
        assert!(!Geometry::Hole3.contains(Point { x: 0.25, y: 0.25 }));
        assert!(!Geometry::GradeL.contains(Point { x: 0.9, y: 0.9 }));
        assert!(Geometry::GradeL.contains(Point { x: 0.1, y: 0.9 }));
        assert!(!Geometry::Square.contains(Point { x: 1.5, y: 0.5 }));
    }

    #[test]
    fn fem_matrix_is_spd_symmetric() {
        let mut rng = Pcg64::new(22);
        let mesh = delaunay_mesh(Geometry::Square, 80, &mut rng);
        let a = fem_stiffness(&mesh, 1.0);
        assert_eq!(a.nrows(), 80);
        assert!(a.is_symmetric(1e-10));
        // Laplace stiffness + lumped mass must be positive definite:
        // dense-Cholesky a small one to verify.
        let d = crate::sparse::Dense::from_rows(&a.to_dense());
        assert!(d.cholesky().is_ok(), "FEM matrix not SPD");
    }

    #[test]
    fn mesh_laplacian_rows_sum_to_shift() {
        let mut rng = Pcg64::new(23);
        let mesh = delaunay_mesh(Geometry::Hole6, 120, &mut rng);
        let a = mesh_graph_laplacian(&mesh);
        assert!(a.is_symmetric(1e-12));
        for r in 0..a.nrows() {
            let (_, vals) = a.row(r);
            let sum: f64 = vals.iter().sum();
            assert!((sum - 1e-3).abs() < 1e-9, "row {r} sums to {sum}");
        }
    }

    #[test]
    fn mesh_is_deterministic() {
        let m1 = delaunay_mesh(Geometry::GradeL, 50, &mut Pcg64::new(9));
        let m2 = delaunay_mesh(Geometry::GradeL, 50, &mut Pcg64::new(9));
        assert_eq!(m1.points, m2.points);
        assert_eq!(m1.tris, m2.tris);
    }
}
