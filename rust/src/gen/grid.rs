//! Structured-grid SPD matrix generators.
//!
//! These produce the discretized-PDE sparsity patterns that dominate the
//! SuiteSparse classes the paper evaluates on: 5-point / 7-point Laplacians
//! (2D3D class), anisotropic convection–diffusion stencils (CFD class), and
//! heterogeneous-conductivity grids (thermal class).

use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// 2D 5-point Laplacian on an nx×ny grid (Dirichlet boundary folded into
/// the diagonal). SPD, n = nx·ny.
pub fn laplacian_2d(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let idx = |x: usize, y: usize| y * nx + x;
    let mut coo = Coo::square(n);
    for y in 0..ny {
        for x in 0..nx {
            let i = idx(x, y);
            coo.push(i, i, 4.0);
            if x + 1 < nx {
                coo.push_sym(i, idx(x + 1, y), -1.0);
            }
            if y + 1 < ny {
                coo.push_sym(i, idx(x, y + 1), -1.0);
            }
        }
    }
    coo.to_csr()
}

/// [`laplacian_2d`] symmetrically rescaled by `D A D` with `d = scale` on
/// one `node` and 1 elsewhere — still SPD with the identical pattern, but
/// (for large `scale`) with a badly scaled value range: the max-normalized
/// dense window used by the PFM ADMM becomes ~rank-1 and the smooth
/// gradient signal collapses. This is the adaptive-ρ stress workload; the
/// elimination orderings themselves are scale-invariant, so quality
/// comparisons against the unscaled grid stay meaningful.
pub fn scaled_node_laplacian_2d(nx: usize, ny: usize, node: usize, scale: f64) -> Csr {
    let base = laplacian_2d(nx, ny);
    let d = |i: usize| if i == node { scale } else { 1.0 };
    let mut coo = Coo::square(base.nrows());
    for r in 0..base.nrows() {
        let (cols, vals) = base.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(r, c, v * d(r) * d(c));
        }
    }
    coo.to_csr()
}

/// 3D 7-point Laplacian on an nx×ny×nz grid. SPD, n = nx·ny·nz.
pub fn laplacian_3d(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut coo = Coo::square(n);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                coo.push(i, i, 6.0);
                if x + 1 < nx {
                    coo.push_sym(i, idx(x + 1, y, z), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(i, idx(x, y + 1, z), -1.0);
                }
                if z + 1 < nz {
                    coo.push_sym(i, idx(x, y, z + 1), -1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// 2D 9-point anisotropic convection–diffusion stencil (CFD-like pattern):
/// diffusion anisotropy `eps` in y, plus diagonal couplings. Symmetrized
/// (the paper's pipeline only factors symmetric matrices) and made SPD by
/// diagonal dominance.
pub fn cfd_stencil_2d(nx: usize, ny: usize, eps: f64, rng: &mut Pcg64) -> Csr {
    let n = nx * ny;
    let idx = |x: usize, y: usize| y * nx + x;
    let mut coo = Coo::square(n);
    for y in 0..ny {
        for x in 0..nx {
            let i = idx(x, y);
            // jittered anisotropic couplings — CFD meshes are irregular in
            // magnitude even on structured topology
            let jx = 1.0 + 0.2 * rng.next_f64();
            let jy = eps * (1.0 + 0.2 * rng.next_f64());
            let jd = 0.25 * (1.0 + 0.2 * rng.next_f64());
            let mut diag = 0.0;
            if x + 1 < nx {
                coo.push_sym(i, idx(x + 1, y), -jx);
                diag += jx;
            }
            if y + 1 < ny {
                coo.push_sym(i, idx(x, y + 1), -jy);
                diag += jy;
            }
            if x + 1 < nx && y + 1 < ny {
                coo.push_sym(i, idx(x + 1, y + 1), -jd);
                diag += jd;
            }
            if x > 0 && y + 1 < ny {
                coo.push_sym(i, idx(x - 1, y + 1), -jd);
                diag += jd;
            }
            // dominance slack keeps the matrix SPD regardless of the
            // mirrored contributions
            coo.push(i, i, 2.0 * (1.0 + eps + 1.0) + diag);
        }
    }
    coo.to_csr()
}

/// 2D upwind convection–diffusion stencil (ConvDiff class): 5-point
/// diffusion plus first-order upwind convection with flow in +x/+y, so the
/// upstream coupling is strengthened by the local Péclet number while the
/// downstream one keeps its diffusive weight — a genuinely
/// **value-unsymmetric** (pattern-symmetric) matrix, the canonical
/// workload the LU engine exists for. Weakly row-diagonally dominant by
/// construction (Dirichlet boundary folded into the diagonal), so
/// threshold pivoting keeps the diagonal and the A+Aᵀ symbolic bound is
/// tight.
pub fn convection_diffusion_2d(nx: usize, ny: usize, peclet: f64, rng: &mut Pcg64) -> Csr {
    assert!(peclet >= 0.0);
    let n = nx * ny;
    let idx = |x: usize, y: usize| y * nx + x;
    let mut coo = Coo::square(n);
    for y in 0..ny {
        for x in 0..nx {
            let i = idx(x, y);
            // jittered local Péclet numbers (velocity varies over the field)
            let cx = peclet * (1.0 + 0.2 * rng.next_f64());
            let cy = 0.5 * peclet * (1.0 + 0.2 * rng.next_f64());
            if x > 0 {
                coo.push(i, idx(x - 1, y), -(1.0 + cx)); // upstream in x
            }
            if x + 1 < nx {
                coo.push(i, idx(x + 1, y), -1.0); // downstream: diffusion only
            }
            if y > 0 {
                coo.push(i, idx(x, y - 1), -(1.0 + cy)); // upstream in y
            }
            if y + 1 < ny {
                coo.push(i, idx(x, y + 1), -1.0);
            }
            coo.push(i, i, 4.0 + cx + cy);
        }
    }
    coo.to_csr()
}

/// 2D heterogeneous-conductivity thermal grid (TP class): 5-point stencil
/// with lognormal edge conductivities — strong coefficient contrast, the
/// structure thermal problems show in SuiteSparse.
pub fn thermal_grid_2d(nx: usize, ny: usize, contrast: f64, rng: &mut Pcg64) -> Csr {
    let n = nx * ny;
    let idx = |x: usize, y: usize| y * nx + x;
    let mut cond = |r: &mut Pcg64| (contrast * r.next_gaussian()).exp();
    let mut coo = Coo::square(n);
    let mut diag = vec![1e-8; n]; // tiny regularization
    for y in 0..ny {
        for x in 0..nx {
            let i = idx(x, y);
            if x + 1 < nx {
                let k = cond(rng);
                coo.push_sym(i, idx(x + 1, y), -k);
                diag[i] += k;
                diag[idx(x + 1, y)] += k;
            }
            if y + 1 < ny {
                let k = cond(rng);
                coo.push_sym(i, idx(x, y + 1), -k);
                diag[i] += k;
                diag[idx(x, y + 1)] += k;
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 1.0);
    }
    coo.to_csr()
}

/// 3D structural-like stencil (SP class): 7-point grid with added
/// next-nearest (edge-diagonal) couplings, mimicking the denser rows of
/// FEM stiffness matrices from solid mechanics.
pub fn structural_grid_3d(nx: usize, ny: usize, nz: usize, rng: &mut Pcg64) -> Csr {
    let n = nx * ny * nz;
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut coo = Coo::square(n);
    let mut diag = vec![1.0; n];
    let mut couple = |coo: &mut Coo, diag: &mut [f64], i: usize, j: usize, w: f64| {
        coo.push_sym(i, j, -w);
        diag[i] += w;
        diag[j] += w;
    };
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                let w = 1.0 + 0.1 * rng.next_f64();
                if x + 1 < nx {
                    couple(&mut coo, &mut diag, i, idx(x + 1, y, z), w);
                }
                if y + 1 < ny {
                    couple(&mut coo, &mut diag, i, idx(x, y + 1, z), w);
                }
                if z + 1 < nz {
                    couple(&mut coo, &mut diag, i, idx(x, y, z + 1), w);
                }
                // next-nearest in-plane couplings (shear terms)
                let ws = 0.3 * (1.0 + 0.1 * rng.next_f64());
                if x + 1 < nx && y + 1 < ny {
                    couple(&mut coo, &mut diag, i, idx(x + 1, y + 1, z), ws);
                }
                if x + 1 < nx && z + 1 < nz {
                    couple(&mut coo, &mut diag, i, idx(x + 1, y, z + 1), ws);
                }
                if y + 1 < ny && z + 1 < nz {
                    couple(&mut coo, &mut diag, i, idx(x, y + 1, z + 1), ws);
                }
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, *d);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplacian_2d_shape() {
        let a = laplacian_2d(4, 3);
        assert_eq!(a.nrows(), 12);
        assert!(a.is_symmetric(1e-12));
        // interior node has 4 off-diagonal neighbours
        assert_eq!(a.off_diag_degree(5), 4);
        // corner has 2
        assert_eq!(a.off_diag_degree(0), 2);
        assert!(a.diag_dominance_margin() >= 0.0);
    }

    #[test]
    fn laplacian_3d_shape() {
        let a = laplacian_3d(3, 3, 3);
        assert_eq!(a.nrows(), 27);
        assert!(a.is_symmetric(1e-12));
        // center node (1,1,1) has 6 neighbours
        assert_eq!(a.off_diag_degree(13), 6);
    }

    #[test]
    fn scaled_node_laplacian_keeps_pattern_and_symmetry() {
        let base = laplacian_2d(5, 4);
        let a = scaled_node_laplacian_2d(5, 4, 7, 1e6);
        assert_eq!(a.nrows(), 20);
        assert_eq!(a.indptr(), base.indptr());
        assert_eq!(a.indices(), base.indices());
        assert!(a.is_symmetric(1e-12));
        // D A D: the scaled node's diagonal picks up scale², its incident
        // edges scale¹, everything else is untouched
        assert_eq!(a.get(7, 7), base.get(7, 7) * 1e12);
        assert_eq!(a.get(7, 8), base.get(7, 8) * 1e6);
        assert_eq!(a.get(0, 1), base.get(0, 1));
    }

    #[test]
    fn convection_diffusion_is_unsymmetric_dominant() {
        let mut rng = Pcg64::new(21);
        let a = convection_diffusion_2d(8, 7, 2.0, &mut rng);
        assert_eq!(a.nrows(), 56);
        assert!(!a.is_symmetric(1e-12), "upwind scheme must break value symmetry");
        // pattern stays symmetric (union of the 5-point stencil)
        let t = a.transpose();
        assert_eq!(a.indptr(), t.indptr());
        assert_eq!(a.indices(), t.indices());
        assert!(a.diag_dominance_margin() >= 0.0);
        // zero Péclet degenerates to the plain (symmetric) Laplacian values
        let b = convection_diffusion_2d(8, 7, 0.0, &mut Pcg64::new(21));
        assert!(b.is_symmetric(1e-12));
    }

    #[test]
    fn cfd_is_spd_ish() {
        let mut rng = Pcg64::new(11);
        let a = cfd_stencil_2d(8, 8, 0.1, &mut rng);
        assert!(a.is_symmetric(1e-12));
        assert!(a.diag_dominance_margin() > 0.0, "must be diagonally dominant");
    }

    #[test]
    fn thermal_is_spd() {
        let mut rng = Pcg64::new(12);
        let a = thermal_grid_2d(10, 10, 1.5, &mut rng);
        assert!(a.is_symmetric(1e-12));
        assert!(a.diag_dominance_margin() > 0.0);
    }

    #[test]
    fn structural_denser_than_laplacian() {
        let mut rng = Pcg64::new(13);
        let a = structural_grid_3d(4, 4, 4, &mut rng);
        let l = laplacian_3d(4, 4, 4);
        assert!(a.is_symmetric(1e-12));
        assert!(a.nnz() > l.nnz(), "structural stencil must be denser");
        assert!(a.diag_dominance_margin() > 0.0);
    }

    #[test]
    fn generators_are_deterministic() {
        let a1 = thermal_grid_2d(6, 6, 1.0, &mut Pcg64::new(5));
        let a2 = thermal_grid_2d(6, 6, 1.0, &mut Pcg64::new(5));
        assert_eq!(a1, a2);
    }
}
