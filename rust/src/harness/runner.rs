//! Shared measurement loop: (matrix, method) → fill ratio + timings.
//!
//! Kind-generic: each matrix is routed through the factorization its
//! symmetry calls for — Cholesky (supernodal/up-looking) for the SPD
//! classes, Gilbert–Peierls LU for the unsymmetric ones — so fill and
//! factor time are always measured on the factorization the paper's
//! golden criterion actually refers to.

use std::time::Instant;

use crate::coordinator::Method;
use crate::factor::lu::{self, LuOptions};
use crate::factor::supernodal;
use crate::factor::{cholesky_with_ws, fill_ratio, FactorContext, FactorKind};
use crate::gen::{ProblemClass, Symmetry, TestMatrix};
use crate::runtime::{PfmRuntime, Provenance};

/// One (matrix, method) measurement — a row fragment of every table.
#[derive(Clone, Debug)]
pub struct Record {
    pub method: &'static str,
    pub class: ProblemClass,
    pub matrix: String,
    pub n: usize,
    pub nnz: usize,
    /// Cholesky rows: the paper's Eq. 15 (fill-ins / nnz(A));
    /// LU rows: nnz(L+U) / nnz(A)
    pub fill_ratio: f64,
    /// structural factor nnz: nnz(L) for Cholesky, nnz(L+U) for LU
    pub lnnz: usize,
    /// seconds to compute the permutation
    pub ordering_time: f64,
    /// seconds for the numeric factorization of PAPᵀ (the paper's "LU time")
    pub factor_time: f64,
    /// numeric kernel the matrix selected
    /// ("up-looking" | "supernodal" | "lu-gp")
    pub kernel: &'static str,
    /// factorization kind ("cholesky" | "lu")
    pub factor_kind: &'static str,
    pub provenance: Option<Provenance>,
    /// ADMM outer iterations the native PFM optimizer ran for this
    /// ordering (0 for classical / network / fallback rows)
    pub opt_iters: usize,
}

/// Evaluate `methods` × `matrices`. Learned methods run through the PJRT
/// runtime (spectral fallback above the largest bucket, recorded in
/// provenance). Factorization failures (non-SPD after roundoff) surface as
/// `None` records and are skipped with a warning — they do not abort the
/// sweep.
pub fn evaluate_suite(
    matrices: &[TestMatrix],
    methods: &[Method],
    rt: &mut PfmRuntime,
    seed: u64,
) -> Vec<Record> {
    // One context for the whole sweep: scratch buffers are shared across
    // every (matrix, method) pair and repeated patterns hit the symbolic
    // cache instead of re-running analysis.
    let mut ctx = FactorContext::new();
    let mut out = Vec::with_capacity(matrices.len() * methods.len());
    for tm in matrices {
        for &method in methods {
            match evaluate_one_with(tm, method, rt, seed, &mut ctx) {
                Ok(rec) => out.push(rec),
                Err(e) => eprintln!(
                    "warn: {} on {} failed: {e} (skipped)",
                    method.label(),
                    tm.name
                ),
            }
        }
    }
    out
}

/// Measure one (matrix, method) pair with a throwaway context.
pub fn evaluate_one(
    tm: &TestMatrix,
    method: Method,
    rt: &mut PfmRuntime,
    seed: u64,
) -> Result<Record, String> {
    evaluate_one_with(tm, method, rt, seed, &mut FactorContext::new())
}

/// Measure one (matrix, method) pair, reusing a long-lived factorization
/// context (workspace + symbolic cache). The numeric kernel is selected
/// per pattern: supernodal when the fill structure has wide panels,
/// up-looking otherwise.
pub fn evaluate_one_with(
    tm: &TestMatrix,
    method: Method,
    rt: &mut PfmRuntime,
    seed: u64,
    ctx: &mut FactorContext,
) -> Result<Record, String> {
    let a = &tm.matrix;
    let t0 = Instant::now();
    let (order, provenance, opt_iters) = match method {
        Method::Classical(c) => (c.order(a), None, 0),
        Method::Learned(l) => {
            let out = l.order_detailed(rt, a, seed, None).map_err(|e| e.to_string())?;
            (out.order, Some(out.provenance), out.opt_iters)
        }
    };
    let ordering_time = t0.elapsed().as_secs_f64();

    // the class tag already knows the symmetry — no per-(matrix, method)
    // transpose/compare pass to re-derive what the generator guarantees
    let kind = match tm.class.symmetry() {
        Symmetry::Symmetric => FactorKind::Cholesky,
        Symmetry::Unsymmetric => FactorKind::Lu,
    };
    let pap = a.permute_sym(&order);
    let (fr, lnnz, kernel, factor_time) = match kind {
        FactorKind::Cholesky => {
            let analysis = ctx.cache.analyze(&pap);
            let fr = fill_ratio(&pap, &analysis.sym);
            let t1 = Instant::now();
            let kernel = match &analysis.ssym {
                Some(ssym) => {
                    supernodal::factorize(&pap, ssym.clone(), &mut ctx.workspace)
                        .map_err(|e| e.to_string())?;
                    "supernodal"
                }
                None => {
                    cholesky_with_ws(&pap, &analysis.sym, &mut ctx.workspace)
                        .map_err(|e| e.to_string())?;
                    "up-looking"
                }
            };
            (fr, analysis.sym.lnnz, kernel, t1.elapsed().as_secs_f64())
        }
        FactorKind::Lu => {
            let lsym = ctx.cache.analyze_lu(&pap);
            let t1 = Instant::now();
            let f = lu::factorize(&pap, &lsym, LuOptions::default(), &mut ctx.workspace)
                .map_err(|e| e.to_string())?;
            (
                lu::lu_fill_ratio(&pap, &f),
                f.lu_nnz(),
                "lu-gp",
                t1.elapsed().as_secs_f64(),
            )
        }
    };

    Ok(Record {
        method: method.label(),
        class: tm.class,
        matrix: tm.name.clone(),
        n: a.nrows(),
        nnz: a.nnz(),
        fill_ratio: fr,
        lnnz,
        ordering_time,
        factor_time,
        kernel,
        factor_kind: kind.label(),
        provenance,
        opt_iters,
    })
}

/// Mean of a projection over records matching a filter.
pub fn mean_where(
    records: &[Record],
    f: impl Fn(&Record) -> bool,
    proj: impl Fn(&Record) -> f64,
) -> Option<f64> {
    let vals: Vec<f64> = records.iter().filter(|r| f(r)).map(proj).collect();
    if vals.is_empty() {
        None
    } else {
        Some(vals.iter().sum::<f64>() / vals.len() as f64)
    }
}

/// CSV emitter (all records, one row each).
pub fn to_csv(records: &[Record]) -> String {
    let mut s = String::from(
        "method,class,matrix,n,nnz,fill_ratio,lnnz,ordering_time_s,factor_time_s,kernel,factor_kind,provenance,opt_iters\n",
    );
    for r in records {
        s.push_str(&format!(
            "{},{},{},{},{},{:.6},{},{:.6},{:.6},{},{},{},{}\n",
            r.method,
            r.class.label(),
            r.matrix,
            r.n,
            r.nnz,
            r.fill_ratio,
            r.lnnz,
            r.ordering_time,
            r.factor_time,
            r.kernel,
            r.factor_kind,
            r.provenance.map_or("classical", |p| p.label()),
            r.opt_iters,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::test_suite;
    use crate::order::Classical;

    #[test]
    fn evaluates_classical_suite() {
        let suite = test_suite(&[100], 1, 3);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok").unwrap();
        let methods = [
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
        ];
        let recs = evaluate_suite(&suite, &methods, &mut rt, 1);
        assert_eq!(recs.len(), suite.len() * 2);
        for r in &recs {
            assert!(r.fill_ratio >= 0.0, "{:?}", r);
            assert!(r.factor_time >= 0.0);
            assert!(r.lnnz >= r.nnz / 2);
        }
        // AMD must beat Natural on average
        let nat = mean_where(&recs, |r| r.method == "Natural", |r| r.fill_ratio).unwrap();
        let amd = mean_where(&recs, |r| r.method == "AMD", |r| r.fill_ratio).unwrap();
        assert!(amd < nat, "amd {amd} vs natural {nat}");
    }

    #[test]
    fn evaluates_unsymmetric_suite_through_lu() {
        let suite = crate::gen::unsymmetric_suite(&[120], 1, 5);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok3").unwrap();
        let methods = [
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
        ];
        let recs = evaluate_suite(&suite, &methods, &mut rt, 1);
        assert_eq!(recs.len(), suite.len() * 2);
        for r in &recs {
            assert_eq!(r.factor_kind, "lu", "{:?}", r);
            assert_eq!(r.kernel, "lu-gp");
            assert!(r.fill_ratio >= 1.0, "nnz(L+U) ≥ nnz(A): {:?}", r);
            assert!(r.lnnz >= r.nnz);
        }
        // AMD must reduce nnz(L+U) vs Natural on average (paper shape)
        let nat = mean_where(&recs, |r| r.method == "Natural", |r| r.fill_ratio).unwrap();
        let amd = mean_where(&recs, |r| r.method == "AMD", |r| r.fill_ratio).unwrap();
        assert!(amd < nat, "amd {amd} vs natural {nat}");
    }

    #[test]
    fn csv_has_all_rows() {
        let suite = test_suite(&[80], 1, 4);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok2").unwrap();
        let recs =
            evaluate_suite(&suite, &[Method::Classical(Classical::Rcm)], &mut rt, 1);
        let csv = to_csv(&recs);
        assert_eq!(csv.lines().count(), recs.len() + 1);
        assert!(csv.starts_with("method,class"));
    }
}
