//! Shared measurement loop: (matrix, method) → fill ratio + timings.

use std::time::Instant;

use crate::coordinator::Method;
use crate::factor::supernodal;
use crate::factor::{cholesky_with_ws, fill_ratio, FactorContext};
use crate::gen::{ProblemClass, TestMatrix};
use crate::runtime::{PfmRuntime, Provenance};

/// One (matrix, method) measurement — a row fragment of every table.
#[derive(Clone, Debug)]
pub struct Record {
    pub method: &'static str,
    pub class: ProblemClass,
    pub matrix: String,
    pub n: usize,
    pub nnz: usize,
    pub fill_ratio: f64,
    pub lnnz: usize,
    /// seconds to compute the permutation
    pub ordering_time: f64,
    /// seconds for numeric Cholesky of PAPᵀ (the paper's "LU time")
    pub factor_time: f64,
    /// numeric kernel the pattern selected ("up-looking" | "supernodal")
    pub kernel: &'static str,
    pub provenance: Option<Provenance>,
}

/// Evaluate `methods` × `matrices`. Learned methods run through the PJRT
/// runtime (spectral fallback above the largest bucket, recorded in
/// provenance). Factorization failures (non-SPD after roundoff) surface as
/// `None` records and are skipped with a warning — they do not abort the
/// sweep.
pub fn evaluate_suite(
    matrices: &[TestMatrix],
    methods: &[Method],
    rt: &mut PfmRuntime,
    seed: u64,
) -> Vec<Record> {
    // One context for the whole sweep: scratch buffers are shared across
    // every (matrix, method) pair and repeated patterns hit the symbolic
    // cache instead of re-running analysis.
    let mut ctx = FactorContext::new();
    let mut out = Vec::with_capacity(matrices.len() * methods.len());
    for tm in matrices {
        for &method in methods {
            match evaluate_one_with(tm, method, rt, seed, &mut ctx) {
                Ok(rec) => out.push(rec),
                Err(e) => eprintln!(
                    "warn: {} on {} failed: {e} (skipped)",
                    method.label(),
                    tm.name
                ),
            }
        }
    }
    out
}

/// Measure one (matrix, method) pair with a throwaway context.
pub fn evaluate_one(
    tm: &TestMatrix,
    method: Method,
    rt: &mut PfmRuntime,
    seed: u64,
) -> Result<Record, String> {
    evaluate_one_with(tm, method, rt, seed, &mut FactorContext::new())
}

/// Measure one (matrix, method) pair, reusing a long-lived factorization
/// context (workspace + symbolic cache). The numeric kernel is selected
/// per pattern: supernodal when the fill structure has wide panels,
/// up-looking otherwise.
pub fn evaluate_one_with(
    tm: &TestMatrix,
    method: Method,
    rt: &mut PfmRuntime,
    seed: u64,
    ctx: &mut FactorContext,
) -> Result<Record, String> {
    let a = &tm.matrix;
    let t0 = Instant::now();
    let (order, provenance) = match method {
        Method::Classical(c) => (c.order(a), None),
        Method::Learned(l) => {
            let (o, p) = l.order(rt, a, seed).map_err(|e| e.to_string())?;
            (o, Some(p))
        }
    };
    let ordering_time = t0.elapsed().as_secs_f64();

    let pap = a.permute_sym(&order);
    let analysis = ctx.cache.analyze(&pap);
    let fr = fill_ratio(&pap, &analysis.sym);

    let t1 = Instant::now();
    let kernel = match &analysis.ssym {
        Some(ssym) => {
            supernodal::factorize(&pap, ssym.clone(), &mut ctx.workspace)
                .map_err(|e| e.to_string())?;
            "supernodal"
        }
        None => {
            cholesky_with_ws(&pap, &analysis.sym, &mut ctx.workspace)
                .map_err(|e| e.to_string())?;
            "up-looking"
        }
    };
    let factor_time = t1.elapsed().as_secs_f64();

    Ok(Record {
        method: method.label(),
        class: tm.class,
        matrix: tm.name.clone(),
        n: a.nrows(),
        nnz: a.nnz(),
        fill_ratio: fr,
        lnnz: analysis.sym.lnnz,
        ordering_time,
        factor_time,
        kernel,
        provenance,
    })
}

/// Mean of a projection over records matching a filter.
pub fn mean_where(
    records: &[Record],
    f: impl Fn(&Record) -> bool,
    proj: impl Fn(&Record) -> f64,
) -> Option<f64> {
    let vals: Vec<f64> = records.iter().filter(|r| f(r)).map(proj).collect();
    if vals.is_empty() {
        None
    } else {
        Some(vals.iter().sum::<f64>() / vals.len() as f64)
    }
}

/// CSV emitter (all records, one row each).
pub fn to_csv(records: &[Record]) -> String {
    let mut s = String::from(
        "method,class,matrix,n,nnz,fill_ratio,lnnz,ordering_time_s,factor_time_s,kernel,provenance\n",
    );
    for r in records {
        s.push_str(&format!(
            "{},{},{},{},{},{:.6},{},{:.6},{:.6},{},{}\n",
            r.method,
            r.class.label(),
            r.matrix,
            r.n,
            r.nnz,
            r.fill_ratio,
            r.lnnz,
            r.ordering_time,
            r.factor_time,
            r.kernel,
            match r.provenance {
                Some(Provenance::Network) => "network",
                Some(Provenance::SpectralFallback) => "fallback",
                None => "classical",
            }
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::test_suite;
    use crate::order::Classical;

    #[test]
    fn evaluates_classical_suite() {
        let suite = test_suite(&[100], 1, 3);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok").unwrap();
        let methods = [
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
        ];
        let recs = evaluate_suite(&suite, &methods, &mut rt, 1);
        assert_eq!(recs.len(), suite.len() * 2);
        for r in &recs {
            assert!(r.fill_ratio >= 0.0, "{:?}", r);
            assert!(r.factor_time >= 0.0);
            assert!(r.lnnz >= r.nnz / 2);
        }
        // AMD must beat Natural on average
        let nat = mean_where(&recs, |r| r.method == "Natural", |r| r.fill_ratio).unwrap();
        let amd = mean_where(&recs, |r| r.method == "AMD", |r| r.fill_ratio).unwrap();
        assert!(amd < nat, "amd {amd} vs natural {nat}");
    }

    #[test]
    fn csv_has_all_rows() {
        let suite = test_suite(&[80], 1, 4);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok2").unwrap();
        let recs =
            evaluate_suite(&suite, &[Method::Classical(Classical::Rcm)], &mut rt, 1);
        let csv = to_csv(&recs);
        assert_eq!(csv.lines().count(), recs.len() + 1);
        assert!(csv.starts_with("method,class"));
    }
}
