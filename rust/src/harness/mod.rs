//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section on the synthetic SuiteSparse-class suites.
//!
//! * [`table1`] — ordering-time scaling (paper Table 1's complexity claims)
//! * [`table2`] — fill-in ratio + factorization time, 8 methods × 6 classes
//! * [`table3`] — ablation (spectral embedding / encoder / loss)
//! * [`fig4`]   — fill ratio, LU time, ordering time vs matrix size
//! * [`replay`] — traffic-replay load driver for the serving path
//!   (open-loop traces, per-class latency quantiles, SLO assertions,
//!   `BENCH_serving.json`)
//!
//! All emit markdown (paper-shaped rows) plus CSV for downstream plotting.

pub mod fig4;
pub mod replay;
pub mod runner;
pub mod table1;
pub mod table2;
pub mod table3;

pub use runner::{evaluate_suite, Record};
