//! Traffic-replay load driver: deterministic synthetic request traces
//! played open-loop against a live gateway (`pfm-reorder replay --addr`)
//! or an in-process service (`--inproc`), with per-class latency
//! quantiles and SLO assertions written to `BENCH_serving.json`.
//!
//! Open-loop means sends follow the trace's schedule regardless of how
//! fast responses come back — the driver measures the latency the
//! *offered* load experiences, instead of throttling itself to whatever
//! the server can absorb (closed-loop coordination omission). Completed
//! requests are classified by what actually served them, not by what the
//! trace intended: `warm_hit` (warm-store provenance), `cold` (any other
//! learned-path serve), `classical` (direct orderings). See DESIGN.md
//! §Observability for the trace format and the SLO contract.

use std::net::SocketAddr;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{Method, ReorderResponse, ReorderService, TrySubmitError};
use crate::gateway::{GatewayClient, Reply, WireRequest};
use crate::gen::ProblemClass;
use crate::obs::hist::exact_quantile;
use crate::order::Classical;
use crate::pfm::OptBudget;
use crate::runtime::Learned;
use crate::sparse::Csr;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Inter-arrival gap between consecutive trace events at 1× speed.
pub const BASE_INTERARRIVAL_S: f64 = 0.010;

/// Schema tag of the committed serving benchmark artifact.
pub const BENCH_SCHEMA: &str = "pfm-serving-bench/v1";

/// Synthetic trace families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// ~55% classical (AMD/RCM/Metis rotation), ~25% warm-pool repeats,
    /// ~20% unique cold native-PFM requests.
    Mixed,
    /// Pattern-repeat warm bursts: blocks of identical matrices from a
    /// small pool, so the warm-start store serves the steady state.
    Warm,
    /// Cold-miss storm: every request is a unique native-PFM matrix.
    ColdStorm,
}

impl TraceKind {
    pub fn parse(s: &str) -> Option<TraceKind> {
        match s.to_ascii_lowercase().as_str() {
            "mixed" => Some(TraceKind::Mixed),
            "warm" => Some(TraceKind::Warm),
            "coldstorm" | "cold-storm" | "cold" => Some(TraceKind::ColdStorm),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TraceKind::Mixed => "mixed",
            TraceKind::Warm => "warm",
            TraceKind::ColdStorm => "coldstorm",
        }
    }
}

/// What to replay: everything needed to regenerate the identical trace.
#[derive(Clone, Copy, Debug)]
pub struct ReplaySpec {
    pub kind: TraceKind,
    /// trace-time compression: 10.0 sends events at 10× their 1× rate
    pub speed: f64,
    pub requests: usize,
    pub seed: u64,
}

/// One scheduled request of a trace.
pub struct ReplayEvent {
    /// scheduled send offset from the run start, seconds (monotone in
    /// the event index — the open-loop schedule)
    pub at_s: f64,
    pub method: Method,
    pub seed: u64,
    pub opt_budget: Option<OptBudget>,
    pub matrix: Csr,
}

/// Serving budget every learned trace event carries, so a single slow
/// native run cannot wedge the tail of the replay.
fn learned_budget() -> OptBudget {
    OptBudget {
        outer: 1,
        refine: 6,
        level_refine: 2,
        adaptive_rho: true,
        time_ms: Some(250),
    }
}

/// Generate the deterministic trace for `spec`: same spec, same events,
/// byte-identical matrices (warm-pool repeats share one pattern, which
/// is what makes them warm-store hits on the server).
pub fn generate(spec: &ReplaySpec) -> Vec<ReplayEvent> {
    let speed = if spec.speed > 0.0 { spec.speed } else { 1.0 };
    let gap = BASE_INTERARRIVAL_S / speed;
    let mut rng = Pcg64::new(spec.seed ^ 0x5E18_41D0);
    let pool: Vec<Csr> = (0..3)
        .map(|i| ProblemClass::ALL[i].generate(80 + 16 * i, spec.seed))
        .collect();
    let classical = [Classical::Amd, Classical::Rcm, Classical::Metis];
    let budget = learned_budget();
    (0..spec.requests)
        .map(|i| {
            let (method, matrix, opt_budget) = match spec.kind {
                TraceKind::Warm => {
                    // bursts of 8 consecutive repeats of one pool pattern
                    let m = pool[(i / 8) % pool.len()].clone();
                    (Method::Learned(Learned::Pfm), m, Some(budget))
                }
                TraceKind::ColdStorm => {
                    let class = ProblemClass::ALL[rng.next_below(ProblemClass::ALL.len())];
                    let n = 64 + 8 * rng.next_below(16);
                    let m = class.generate(n, spec.seed.wrapping_add(1 + i as u64));
                    (Method::Learned(Learned::Pfm), m, Some(budget))
                }
                TraceKind::Mixed => {
                    let draw = rng.next_below(100);
                    if draw < 55 {
                        let class = ProblemClass::ALL[i % ProblemClass::ALL.len()];
                        let n = [100, 144, 196][i % 3];
                        let m = class.generate(n, spec.seed.wrapping_add(1 + i as u64));
                        (Method::Classical(classical[i % 3]), m, None)
                    } else if draw < 80 {
                        let m = pool[i % pool.len()].clone();
                        (Method::Learned(Learned::Pfm), m, Some(budget))
                    } else {
                        let class = ProblemClass::ALL[rng.next_below(ProblemClass::ALL.len())];
                        let n = 64 + 8 * (i % 10);
                        let m = class.generate(n, spec.seed.wrapping_add(0x900 + i as u64));
                        (Method::Learned(Learned::Pfm), m, Some(budget))
                    }
                }
            };
            ReplayEvent {
                at_s: i as f64 * gap,
                method,
                seed: spec.seed.wrapping_add(i as u64),
                opt_budget,
                matrix,
            }
        })
        .collect()
}

/// Request class a completed response lands in, judged by what actually
/// served it (so a warm-pool request that raced the store's first write
/// honestly counts as `cold`).
fn classify(learned: bool, provenance: Option<&str>) -> &'static str {
    if provenance == Some("warm") {
        "warm_hit"
    } else if learned {
        "cold"
    } else {
        "classical"
    }
}

// --------------------------------------------------------------- report

/// Exact latency summary of one request class (sorted-sample quantiles,
/// not histogram estimates — the driver holds every sample anyway).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassSummary {
    pub count: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    pub max_s: f64,
}

impl ClassSummary {
    fn from_latencies(mut v: Vec<f64>) -> ClassSummary {
        if v.is_empty() {
            return ClassSummary::default();
        }
        v.sort_by(f64::total_cmp);
        ClassSummary {
            count: v.len(),
            mean_s: v.iter().sum::<f64>() / v.len() as f64,
            p50_s: exact_quantile(&v, 0.50),
            p99_s: exact_quantile(&v, 0.99),
            p999_s: exact_quantile(&v, 0.999),
            max_s: v[v.len() - 1],
        }
    }

    fn stat(&self, name: &str) -> Option<f64> {
        match name {
            "p50" => Some(self.p50_s),
            "p99" => Some(self.p99_s),
            "p999" => Some(self.p999_s),
            "mean" => Some(self.mean_s),
            "max" => Some(self.max_s),
            _ => None,
        }
    }

    fn to_json(self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("mean_s", self.mean_s)
            .set("p50_s", self.p50_s)
            .set("p99_s", self.p99_s)
            .set("p999_s", self.p999_s)
            .set("max_s", self.max_s)
    }
}

/// What one replay run measured.
pub struct ReplayReport {
    pub mode: &'static str,
    pub trace: &'static str,
    pub speed: f64,
    pub requests: usize,
    /// explicit Busy replies / saturated submissions (not failures —
    /// the server shedding load is it keeping its latency contract)
    pub busy: usize,
    pub errors: usize,
    pub wall_s: f64,
    /// per-class summaries; `"all"` (every completed request) is first
    pub classes: Vec<(String, ClassSummary)>,
}

impl ReplayReport {
    fn build(
        mode: &'static str,
        spec: &ReplaySpec,
        samples: Vec<(&'static str, f64)>,
        busy: usize,
        errors: usize,
        wall_s: f64,
    ) -> ReplayReport {
        let mut classes: Vec<(String, ClassSummary)> = Vec::new();
        let all: Vec<f64> = samples.iter().map(|&(_, s)| s).collect();
        classes.push(("all".to_string(), ClassSummary::from_latencies(all)));
        for name in ["classical", "warm_hit", "cold"] {
            let v: Vec<f64> =
                samples.iter().filter(|&&(c, _)| c == name).map(|&(_, s)| s).collect();
            if !v.is_empty() {
                classes.push((name.to_string(), ClassSummary::from_latencies(v)));
            }
        }
        ReplayReport {
            mode,
            trace: spec.kind.label(),
            speed: spec.speed,
            requests: spec.requests,
            busy,
            errors,
            wall_s,
            classes,
        }
    }

    pub fn completed(&self) -> usize {
        self.classes.first().map(|(_, s)| s.count).unwrap_or(0)
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn summary(&self, class: &str) -> Option<&ClassSummary> {
        self.classes.iter().find(|(c, _)| c == class).map(|(_, s)| s)
    }

    /// Evaluate every SLO rule against the measured summaries. A rule
    /// naming a class with zero completed requests fails (an SLO you
    /// never exercised is not met).
    pub fn evaluate(&self, rules: &[SloRule]) -> Vec<SloOutcome> {
        rules
            .iter()
            .map(|r| {
                let class = r.class.as_deref().unwrap_or("all").to_string();
                let actual_s =
                    self.summary(&class).and_then(|s| s.stat(&r.stat)).filter(|_| {
                        self.summary(&class).map(|s| s.count > 0).unwrap_or(false)
                    });
                let pass = actual_s.map(|a| a <= r.limit_s).unwrap_or(false);
                SloOutcome {
                    rule: r.raw.clone(),
                    class,
                    stat: r.stat.clone(),
                    limit_s: r.limit_s,
                    actual_s,
                    pass,
                }
            })
            .collect()
    }

    /// Fail (with every violation listed) if any SLO outcome failed, any
    /// request errored, or — when `require_warm_faster` — the warm-hit
    /// p99 is not strictly below the cold p99.
    pub fn check(&self, outcomes: &[SloOutcome], require_warm_faster: bool) -> Result<(), String> {
        let mut violations: Vec<String> = Vec::new();
        for o in outcomes.iter().filter(|o| !o.pass) {
            match o.actual_s {
                Some(a) => violations.push(format!(
                    "SLO `{}` violated: {}.{} = {:.4}s > {:.4}s",
                    o.rule, o.class, o.stat, a, o.limit_s
                )),
                None => violations.push(format!(
                    "SLO `{}` unmeasurable: class `{}` completed no requests",
                    o.rule, o.class
                )),
            }
        }
        if self.errors > 0 {
            violations.push(format!("{} request(s) failed", self.errors));
        }
        if require_warm_faster {
            match (self.summary("warm_hit"), self.summary("cold")) {
                (Some(w), Some(c)) if w.count > 0 && c.count > 0 => {
                    if w.p99_s >= c.p99_s {
                        violations.push(format!(
                            "warm-hit p99 {:.4}s not below cold p99 {:.4}s",
                            w.p99_s, c.p99_s
                        ));
                    }
                }
                _ => violations.push(
                    "check-warm needs at least one warm_hit and one cold completion".to_string(),
                ),
            }
        }
        if violations.is_empty() {
            Ok(())
        } else {
            Err(violations.join("; "))
        }
    }

    /// The committed `BENCH_serving.json` document.
    pub fn to_json(&self, outcomes: &[SloOutcome]) -> Json {
        let mut classes = Json::obj();
        for (name, s) in &self.classes {
            classes = classes.set(name, s.to_json());
        }
        let slo: Vec<Json> = outcomes
            .iter()
            .map(|o| {
                Json::obj()
                    .set("rule", o.rule.as_str())
                    .set("class", o.class.as_str())
                    .set("stat", o.stat.as_str())
                    .set("limit_s", o.limit_s)
                    .set("actual_s", o.actual_s.map(Json::Num).unwrap_or(Json::Null))
                    .set("pass", o.pass)
            })
            .collect();
        Json::obj()
            .set("schema", BENCH_SCHEMA)
            .set("mode", self.mode)
            .set("trace", self.trace)
            .set("speed", self.speed)
            .set("requests", self.requests)
            .set("completed", self.completed())
            .set("busy", self.busy)
            .set("errors", self.errors)
            .set("wall_s", self.wall_s)
            .set("throughput_rps", self.throughput_rps())
            .set("classes", classes)
            .set("slo", Json::Arr(slo))
    }

    /// Human-readable run summary (stdout of the `replay` subcommand).
    pub fn render(&self, outcomes: &[SloOutcome]) -> String {
        let mut s = format!(
            "replay [{} / {}] speed {}x: {} sent, {} completed, {} busy, {} errors \
             in {:.2}s ({:.1} req/s)\n",
            self.mode,
            self.trace,
            self.speed,
            self.requests,
            self.completed(),
            self.busy,
            self.errors,
            self.wall_s,
            self.throughput_rps(),
        );
        for (name, c) in &self.classes {
            s.push_str(&format!(
                "  {name:<10} n={:<5} p50 {:>8.2}ms  p99 {:>8.2}ms  p999 {:>8.2}ms  \
                 mean {:>8.2}ms  max {:>8.2}ms\n",
                c.count,
                c.p50_s * 1e3,
                c.p99_s * 1e3,
                c.p999_s * 1e3,
                c.mean_s * 1e3,
                c.max_s * 1e3,
            ));
        }
        for o in outcomes {
            s.push_str(&format!(
                "  slo {:<20} {} ({}.{} {} <= {:.4}s)\n",
                o.rule,
                if o.pass { "PASS" } else { "FAIL" },
                o.class,
                o.stat,
                o.actual_s.map(|a| format!("{a:.4}s")).unwrap_or_else(|| "n/a".to_string()),
                o.limit_s,
            ));
        }
        s
    }
}

// ------------------------------------------------------------------ SLO

/// One `--slo` assertion: `[class:]stat=limit`, e.g. `p99=500ms`,
/// `warm_hit:p99=2s`, `cold:mean=0.5`. Bare numbers are seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct SloRule {
    pub class: Option<String>,
    pub stat: String,
    pub limit_s: f64,
    /// the spelling the user wrote, echoed in reports
    pub raw: String,
}

impl SloRule {
    pub fn parse(s: &str) -> Result<SloRule, String> {
        let (lhs, rhs) = s
            .split_once('=')
            .ok_or_else(|| format!("bad SLO `{s}`: expected [class:]stat=limit"))?;
        let (class, stat) = match lhs.split_once(':') {
            Some((c, st)) => (Some(c.trim().to_string()), st),
            None => (None, lhs),
        };
        let stat = stat.trim().to_ascii_lowercase();
        if !["p50", "p99", "p999", "mean", "max"].contains(&stat.as_str()) {
            return Err(format!("bad SLO `{s}`: stat must be p50|p99|p999|mean|max"));
        }
        if let Some(c) = &class {
            if !["all", "classical", "warm_hit", "cold"].contains(&c.as_str()) {
                return Err(format!(
                    "bad SLO `{s}`: class must be all|classical|warm_hit|cold"
                ));
            }
        }
        Ok(SloRule { class, stat, limit_s: parse_duration_s(rhs.trim())?, raw: s.to_string() })
    }
}

/// How one SLO rule fared against the measured report.
#[derive(Clone, Debug)]
pub struct SloOutcome {
    pub rule: String,
    pub class: String,
    pub stat: String,
    pub limit_s: f64,
    /// `None` when the class completed no requests
    pub actual_s: Option<f64>,
    pub pass: bool,
}

fn parse_duration_s(s: &str) -> Result<f64, String> {
    let parse = |v: &str| -> Result<f64, String> {
        v.trim().parse::<f64>().map_err(|_| format!("bad duration `{s}`"))
    };
    let secs = if let Some(ms) = s.strip_suffix("ms") {
        parse(ms)? / 1e3
    } else if let Some(sec) = s.strip_suffix('s') {
        parse(sec)?
    } else {
        parse(s)?
    };
    if secs.is_finite() && secs >= 0.0 {
        Ok(secs)
    } else {
        Err(format!("bad duration `{s}`: must be a non-negative number"))
    }
}

// -------------------------------------------------------------- drivers

/// Replay against an in-process [`ReorderService`] — no sockets, same
/// open-loop schedule. Saturated submissions count as `busy` exactly
/// like gateway `Busy` frames.
pub fn run_inproc(service: &ReorderService, spec: &ReplaySpec) -> ReplayReport {
    struct Pending {
        rx: mpsc::Receiver<ReorderResponse>,
        learned: bool,
        sent: Instant,
    }
    fn poll(
        pending: &mut Vec<Pending>,
        samples: &mut Vec<(&'static str, f64)>,
        errors: &mut usize,
    ) {
        let mut i = 0;
        while i < pending.len() {
            match pending[i].rx.try_recv() {
                Ok(resp) => {
                    let p = pending.swap_remove(i);
                    match resp.result {
                        Ok(res) => samples.push((
                            classify(p.learned, res.provenance.map(|pv| pv.label())),
                            p.sent.elapsed().as_secs_f64(),
                        )),
                        Err(_) => *errors += 1,
                    }
                }
                Err(mpsc::TryRecvError::Empty) => i += 1,
                Err(mpsc::TryRecvError::Disconnected) => {
                    pending.swap_remove(i);
                    *errors += 1;
                }
            }
        }
    }

    let events = generate(spec);
    let start = Instant::now();
    let mut pending: Vec<Pending> = Vec::new();
    let mut samples: Vec<(&'static str, f64)> = Vec::new();
    let (mut busy, mut errors) = (0usize, 0usize);
    for ev in events {
        loop {
            poll(&mut pending, &mut samples, &mut errors);
            let remaining = ev.at_s - start.elapsed().as_secs_f64();
            if remaining <= 0.0 {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64(remaining.min(0.001)));
        }
        let learned = matches!(ev.method, Method::Learned(_));
        let sent = Instant::now();
        match service.try_submit_with_budget(
            ev.matrix,
            ev.method,
            ev.seed,
            false,
            None,
            ev.opt_budget,
            None,
        ) {
            Ok(rx) => pending.push(Pending { rx, learned, sent }),
            Err(TrySubmitError::Saturated) => busy += 1,
            Err(TrySubmitError::ShutDown) => errors += 1,
        }
    }
    while !pending.is_empty() {
        poll(&mut pending, &mut samples, &mut errors);
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    ReplayReport::build("inproc", spec, samples, busy, errors, wall_s)
}

/// Replay against a live gateway over `conns` pipelined connections
/// (round-robin assignment; each connection runs a sender/receiver
/// thread pair, relying on the gateway's per-connection FIFO reply
/// order to correlate replies without ids).
pub fn run_gateway(
    addr: SocketAddr,
    spec: &ReplaySpec,
    conns: usize,
    timeout: Duration,
) -> Result<ReplayReport, String> {
    struct LaneMeta {
        learned: bool,
        sent: Instant,
    }
    #[derive(Default)]
    struct LaneOut {
        samples: Vec<(&'static str, f64)>,
        busy: usize,
        errors: usize,
    }

    let conns = conns.max(1);
    let events = generate(spec);
    let mut lanes: Vec<Vec<(u64, ReplayEvent)>> = (0..conns).map(|_| Vec::new()).collect();
    for (i, ev) in events.into_iter().enumerate() {
        lanes[i % conns].push((i as u64, ev));
    }

    let start = Instant::now();
    let mut senders = Vec::new();
    let mut receivers = Vec::new();
    for lane in lanes {
        if lane.is_empty() {
            continue;
        }
        let mut tx_client = GatewayClient::connect_timeout(&addr, Duration::from_secs(5))
            .map_err(|e| format!("connect {addr}: {e} (is `pfm-reorder serve` running?)"))?;
        tx_client.set_io_timeout(Some(timeout)).map_err(|e| e.to_string())?;
        let mut rx_client = tx_client.try_clone().map_err(|e| e.to_string())?;
        let (mtx, mrx) = mpsc::channel::<LaneMeta>();
        senders.push(std::thread::spawn(move || -> usize {
            let mut failed = 0usize;
            let total = lane.len();
            for (k, (id, ev)) in lane.into_iter().enumerate() {
                let target = start + Duration::from_secs_f64(ev.at_s);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let req = WireRequest {
                    id,
                    method: ev.method,
                    seed: ev.seed,
                    eval_fill: false,
                    factor_kind: None,
                    opt_budget: ev.opt_budget,
                    factor_threads: None,
                    matrix: ev.matrix,
                };
                let learned = matches!(req.method, Method::Learned(_));
                let sent = Instant::now();
                if tx_client.send_request(&req).is_ok() {
                    let _ = mtx.send(LaneMeta { learned, sent });
                } else {
                    // a failed send may have desynced the stream — stop
                    // the lane and charge its remaining events as errors
                    failed = total - k;
                    break;
                }
            }
            failed
        }));
        receivers.push(std::thread::spawn(move || -> LaneOut {
            let mut out = LaneOut::default();
            while let Ok(meta) = mrx.recv() {
                match rx_client.recv_reply() {
                    Ok(Reply::Result(res)) => out.samples.push((
                        classify(meta.learned, res.provenance.as_deref()),
                        meta.sent.elapsed().as_secs_f64(),
                    )),
                    Ok(Reply::Busy { .. }) => out.busy += 1,
                    Ok(Reply::Error { .. }) | Ok(Reply::Admin(_)) => out.errors += 1,
                    Err(_) => {
                        out.errors += 1;
                        break;
                    }
                }
            }
            out
        }));
    }

    let mut samples: Vec<(&'static str, f64)> = Vec::new();
    let (mut busy, mut errors) = (0usize, 0usize);
    for h in senders {
        errors += h.join().map_err(|_| "replay sender thread panicked".to_string())?;
    }
    for h in receivers {
        let out = h.join().map_err(|_| "replay receiver thread panicked".to_string())?;
        samples.extend(out.samples);
        busy += out.busy;
        errors += out.errors;
    }
    let wall_s = start.elapsed().as_secs_f64();
    Ok(ReplayReport::build("gateway", spec, samples, busy, errors, wall_s))
}

/// Write the benchmark document (one JSON object + trailing newline).
pub fn write_bench(path: &str, doc: &Json) -> Result<(), String> {
    std::fs::write(path, doc.to_string() + "\n").map_err(|e| format!("write {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_generation_is_deterministic_and_scheduled_open_loop() {
        let spec = ReplaySpec { kind: TraceKind::Mixed, speed: 10.0, requests: 60, seed: 42 };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 60);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.method.label(), y.method.label());
            assert_eq!(x.matrix, y.matrix);
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.seed, y.seed);
        }
        // open-loop schedule: strictly increasing at the compressed gap
        for w in a.windows(2) {
            assert!(w[1].at_s > w[0].at_s);
        }
        let gap = a[1].at_s - a[0].at_s;
        assert!((gap - BASE_INTERARRIVAL_S / 10.0).abs() < 1e-12, "gap {gap}");
        // the mix has all three intents
        assert!(a.iter().any(|e| matches!(e.method, Method::Classical(_))));
        assert!(a.iter().any(|e| matches!(e.method, Method::Learned(_))));
    }

    #[test]
    fn warm_trace_repeats_identical_patterns_in_bursts() {
        let spec = ReplaySpec { kind: TraceKind::Warm, speed: 100.0, requests: 24, seed: 7 };
        let ev = generate(&spec);
        // burst of 8: identical matrices (this is what makes them warm
        // hits — the store is keyed on the exact sparsity pattern)
        for i in 1..8 {
            assert_eq!(ev[i].matrix, ev[0].matrix);
        }
        assert_ne!(ev[8].matrix, ev[0].matrix, "next burst must switch patterns");
        assert!(ev.iter().all(|e| matches!(e.method, Method::Learned(Learned::Pfm))));
        assert!(ev.iter().all(|e| e.opt_budget.is_some()));
    }

    #[test]
    fn slo_rules_parse_units_classes_and_reject_garbage() {
        let r = SloRule::parse("p99=500ms").unwrap();
        assert_eq!((r.class.as_deref(), r.stat.as_str()), (None, "p99"));
        assert!((r.limit_s - 0.5).abs() < 1e-12);
        let r = SloRule::parse("warm_hit:p999=2s").unwrap();
        assert_eq!(r.class.as_deref(), Some("warm_hit"));
        assert!((r.limit_s - 2.0).abs() < 1e-12);
        let r = SloRule::parse("cold:mean=0.25").unwrap();
        assert!((r.limit_s - 0.25).abs() < 1e-12);
        for bad in ["p99", "p77=1s", "nope:p99=1s", "p99=fast", "p99=-1s"] {
            assert!(SloRule::parse(bad).is_err(), "`{bad}` parsed");
        }
    }

    #[test]
    fn report_classifies_summarizes_and_enforces_slos() {
        let spec = ReplaySpec { kind: TraceKind::Mixed, speed: 1.0, requests: 8, seed: 0 };
        let samples = vec![
            ("classical", 0.010),
            ("classical", 0.020),
            ("warm_hit", 0.001),
            ("warm_hit", 0.002),
            ("cold", 0.100),
            ("cold", 0.200),
        ];
        let rep = ReplayReport::build("inproc", &spec, samples, 1, 0, 0.5);
        assert_eq!(rep.completed(), 6);
        assert_eq!(rep.busy, 1);
        assert!((rep.throughput_rps() - 12.0).abs() < 1e-9);
        let warm = rep.summary("warm_hit").unwrap();
        let cold = rep.summary("cold").unwrap();
        assert_eq!((warm.count, cold.count), (2, 2));
        assert!(warm.p99_s < cold.p99_s);
        assert_eq!(warm.p50_s, 0.001);
        assert_eq!(cold.max_s, 0.200);

        // passing SLO + warm-vs-cold check
        let rules = vec![SloRule::parse("p99=1s").unwrap()];
        let outcomes = rep.evaluate(&rules);
        assert!(outcomes[0].pass);
        rep.check(&outcomes, true).unwrap();

        // violated SLO names the class and both numbers
        let tight = rep.evaluate(&[SloRule::parse("cold:p99=50ms").unwrap()]);
        assert!(!tight[0].pass);
        let err = rep.check(&tight, false).unwrap_err();
        assert!(err.contains("cold.p99"), "{err}");

        // a rule over a class that never completed is a failure
        let absent = rep.evaluate(&[SloRule::parse("p99=1s").unwrap()]);
        let empty = ReplayReport::build("inproc", &spec, Vec::new(), 0, 0, 0.5);
        let missing = empty.evaluate(&[SloRule::parse("warm_hit:p99=1s").unwrap()]);
        assert!(!missing[0].pass);
        assert!(empty.check(&missing, false).unwrap_err().contains("unmeasurable"));
        assert!(absent[0].pass);

        // JSON document carries the schema + per-class quantiles
        let doc = rep.to_json(&outcomes).to_string();
        assert!(doc.contains("\"schema\":\"pfm-serving-bench/v1\""), "{doc}");
        assert!(doc.contains("\"warm_hit\""), "{doc}");
        assert!(doc.contains("\"p999_s\""), "{doc}");
        assert!(doc.contains("\"throughput_rps\""), "{doc}");
    }

    #[test]
    fn duration_suffixes_are_understood() {
        assert!((parse_duration_s("250ms").unwrap() - 0.25).abs() < 1e-12);
        assert!((parse_duration_s("3s").unwrap() - 3.0).abs() < 1e-12);
        assert!((parse_duration_s("0.5").unwrap() - 0.5).abs() < 1e-12);
        assert!(parse_duration_s("").is_err());
        assert!(parse_duration_s("1m").is_err());
    }
}
