//! Table 2: performance comparison across ordering methods on the
//! SuiteSparse-class test suite — fill-in ratio and LU factorization time,
//! one column per problem class plus "All".
//!
//! Two sub-tables: the paper's symmetric suite (measured through the
//! Cholesky engine) and the unsymmetric extension (ConvDiff/Circuit
//! classes, measured through the Gilbert–Peierls LU engine — nnz(L+U)
//! fill, the quantity the paper's golden criterion actually names).

use crate::coordinator::Method;
use crate::gen::{test_suite, unsymmetric_suite, ProblemClass};
use crate::harness::runner::{evaluate_suite, mean_where, to_csv, Record};
use crate::runtime::PfmRuntime;

/// Configuration for the Table 2 run.
#[derive(Clone, Debug)]
pub struct Table2Config {
    pub sizes: Vec<usize>,
    pub per_class: usize,
    pub seed: u64,
}

impl Default for Table2Config {
    fn default() -> Self {
        // Laptop-scale stand-in for the paper's 10k–1M SuiteSparse subset
        // (see DESIGN.md §Substitutions): relative method behaviour is the
        // reproduction target, not absolute nnz.
        Table2Config { sizes: vec![256, 512, 1024], per_class: 2, seed: 0x7AB2E2 }
    }
}

/// Run the full Table 2 experiment (symmetric suite). Returns (records,
/// markdown).
pub fn run(cfg: &Table2Config, rt: &mut PfmRuntime) -> (Vec<Record>, String) {
    let suite = test_suite(&cfg.sizes, cfg.per_class, cfg.seed);
    let methods = Method::table2();
    let records = evaluate_suite(&suite, &methods, rt, cfg.seed);
    let md = render(&records, &methods);
    (records, md)
}

/// Run the unsymmetric-suite extension of Table 2: ConvDiff/Circuit
/// matrices evaluated through the LU engine under the classical orderings.
/// Returns (records, markdown).
pub fn run_unsymmetric(cfg: &Table2Config, rt: &mut PfmRuntime) -> (Vec<Record>, String) {
    let suite = unsymmetric_suite(&cfg.sizes, cfg.per_class, cfg.seed);
    let methods = Method::unsymmetric();
    let records = evaluate_suite(&suite, &methods, rt, cfg.seed);
    let md = render_unsymmetric(&records, &methods);
    (records, md)
}

/// Render the unsymmetric sub-table: per-class LU fill (nnz(L+U)/nnz(A))
/// and factor time, plus the All aggregate and a Natural-vs-best summary.
pub fn render_unsymmetric(records: &[Record], methods: &[Method]) -> String {
    let classes = ProblemClass::UNSYMMETRIC;
    let mut md = String::new();
    md.push_str("## Table 2 (unsymmetric suite) — LU fill nnz(L+U)/nnz(A) / factor time (ms)\n\n");
    md.push_str("| Method |");
    for c in classes {
        md.push_str(&format!(" {} LU-FR | {} ms |", c.label(), c.label()));
    }
    md.push_str(" All LU-FR | All ms |\n|---|");
    for _ in 0..(classes.len() * 2 + 2) {
        md.push_str("---|");
    }
    md.push('\n');
    for m in methods {
        md.push_str(&format!("| {} |", m.label()));
        for c in classes {
            let fr = mean_where(records, |r| r.method == m.label() && r.class == c, |r| r.fill_ratio);
            let ft = mean_where(
                records,
                |r| r.method == m.label() && r.class == c,
                |r| r.factor_time * 1e3,
            );
            md.push_str(&format!(
                " {} | {} |",
                fr.map_or("-".into(), |v| format!("{v:.2}")),
                ft.map_or("-".into(), |v| format!("{v:.1}")),
            ));
        }
        let fr = mean_where(records, |r| r.method == m.label(), |r| r.fill_ratio);
        let ft = mean_where(records, |r| r.method == m.label(), |r| r.factor_time * 1e3);
        md.push_str(&format!(
            " {} | {} |\n",
            fr.map_or("-".into(), |v| format!("{v:.2}")),
            ft.map_or("-".into(), |v| format!("{v:.1}")),
        ));
    }
    // summary: best reordering vs Natural (the paper's Table 2 shape —
    // fill-reducing orderings must beat the natural order on LU too)
    let nat = mean_where(records, |r| r.method == "Natural", |r| r.fill_ratio);
    let mut best: Option<(&str, f64)> = None;
    for m in methods {
        if m.label() == "Natural" {
            continue;
        }
        if let Some(v) = mean_where(records, |r| r.method == m.label(), |r| r.fill_ratio) {
            if best.map_or(true, |(_, b)| v < b) {
                best = Some((m.label(), v));
            }
        }
    }
    if let (Some(nfr), Some((bn, bfr))) = (nat, best) {
        md.push_str(&format!(
            "\n**Headline**: best ordering {bn} LU fill {bfr:.2} vs Natural {nfr:.2} ({:+.1}%).\n",
            (bfr / nfr - 1.0) * 100.0,
        ));
    }
    md
}

/// Render the paper-shaped markdown table: per-class fill ratio and factor
/// time, plus the All aggregate and a summary block comparing PFM to the
/// best baseline (the paper's headline numbers).
pub fn render(records: &[Record], methods: &[Method]) -> String {
    let classes = ProblemClass::ALL;
    let mut md = String::new();
    md.push_str("## Table 2 — fill-in ratio / factorization time (ms)\n\n");
    md.push_str("| Method |");
    for c in classes {
        md.push_str(&format!(" {} FR | {} ms |", c.label(), c.label()));
    }
    md.push_str(" All FR | All ms |\n|---|");
    for _ in 0..(classes.len() * 2 + 2) {
        md.push_str("---|");
    }
    md.push('\n');

    for m in methods {
        md.push_str(&format!("| {} |", m.label()));
        for c in classes {
            let fr = mean_where(records, |r| r.method == m.label() && r.class == c, |r| r.fill_ratio);
            let ft = mean_where(
                records,
                |r| r.method == m.label() && r.class == c,
                |r| r.factor_time * 1e3,
            );
            md.push_str(&format!(
                " {} | {} |",
                fr.map_or("-".into(), |v| format!("{v:.2}")),
                ft.map_or("-".into(), |v| format!("{v:.1}")),
            ));
        }
        let fr = mean_where(records, |r| r.method == m.label(), |r| r.fill_ratio);
        let ft = mean_where(records, |r| r.method == m.label(), |r| r.factor_time * 1e3);
        md.push_str(&format!(
            " {} | {} |\n",
            fr.map_or("-".into(), |v| format!("{v:.2}")),
            ft.map_or("-".into(), |v| format!("{v:.1}")),
        ));
    }

    // headline summary: PFM vs best non-PFM baseline on the All aggregate
    let pfm_fr = mean_where(records, |r| r.method == "PFM", |r| r.fill_ratio);
    let pfm_ft = mean_where(records, |r| r.method == "PFM", |r| r.factor_time);
    let mut best_base_fr: Option<(&str, f64)> = None;
    let mut best_base_ft: Option<(&str, f64)> = None;
    for m in methods {
        if m.label() == "PFM" || m.label() == "Natural" {
            continue;
        }
        if let Some(v) = mean_where(records, |r| r.method == m.label(), |r| r.fill_ratio) {
            if best_base_fr.map_or(true, |(_, b)| v < b) {
                best_base_fr = Some((m.label(), v));
            }
        }
        if let Some(v) = mean_where(records, |r| r.method == m.label(), |r| r.factor_time) {
            if best_base_ft.map_or(true, |(_, b)| v < b) {
                best_base_ft = Some((m.label(), v));
            }
        }
    }
    if let (Some(pfr), Some((bn, bfr)), Some(pft), Some((tn, bft))) =
        (pfm_fr, best_base_fr, pfm_ft, best_base_ft)
    {
        md.push_str(&format!(
            "\n**Headline**: PFM fill ratio {pfr:.2} vs best baseline {bn} {bfr:.2} \
             ({:+.1}%); PFM factor time {:.1} ms vs best baseline {tn} {:.1} ms ({:+.1}%).\n",
            (pfr / bfr - 1.0) * 100.0,
            pft * 1e3,
            bft * 1e3,
            (pft / bft - 1.0) * 100.0,
        ));
    }
    md
}

/// Write records + markdown to the results directory.
pub fn write_outputs(records: &[Record], md: &str, out_dir: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/table2.csv"), to_csv(records))?;
    std::fs::write(format!("{out_dir}/table2.md"), md)?;
    Ok(())
}

/// Write the unsymmetric-suite records + markdown to the results directory.
pub fn write_outputs_unsymmetric(
    records: &[Record],
    md: &str,
    out_dir: &str,
) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/table2_unsym.csv"), to_csv(records))?;
    std::fs::write(format!("{out_dir}/table2_unsym.md"), md)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::Classical;

    #[test]
    fn renders_shape() {
        // tiny synthetic records to exercise the renderer
        let records = vec![
            Record {
                method: "Natural",
                class: ProblemClass::Sp,
                matrix: "m1".into(),
                n: 100,
                nnz: 500,
                fill_ratio: 10.0,
                lnnz: 600,
                ordering_time: 0.0,
                factor_time: 0.01,
                kernel: "up-looking",
                factor_kind: "cholesky",
                provenance: None,
                opt_iters: 0,
            },
            Record {
                method: "PFM",
                class: ProblemClass::Sp,
                matrix: "m1".into(),
                n: 100,
                nnz: 500,
                fill_ratio: 2.0,
                lnnz: 300,
                ordering_time: 0.001,
                factor_time: 0.002,
                kernel: "up-looking",
                factor_kind: "cholesky",
                provenance: None,
                opt_iters: 0,
            },
            Record {
                method: "AMD",
                class: ProblemClass::Sp,
                matrix: "m1".into(),
                n: 100,
                nnz: 500,
                fill_ratio: 3.0,
                lnnz: 350,
                ordering_time: 0.0005,
                factor_time: 0.004,
                kernel: "up-looking",
                factor_kind: "cholesky",
                provenance: None,
                opt_iters: 0,
            },
        ];
        let methods = vec![
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
            Method::Learned(crate::runtime::Learned::Pfm),
        ];
        let md = render(&records, &methods);
        assert!(md.contains("| Natural |"));
        assert!(md.contains("| PFM |"));
        assert!(md.contains("**Headline**"));
        // PFM FR 2.0 vs AMD 3.0 → −33.3%
        assert!(md.contains("-33.3%"), "{md}");
    }

    #[test]
    fn native_pfm_beats_spectral_baseline_on_symmetric_suite() {
        // the PR's acceptance criterion: without artifacts, Learned::Pfm
        // must (a) report Provenance::NativeOptimizer on every row and
        // (b) achieve strictly lower mean nnz(L) than the spectral S_e
        // baseline — ≤ per matrix is guaranteed by the optimizer's
        // acceptance rule (S_e's ordering IS its init), so the mean is
        // strict as soon as any matrix improves.
        use crate::runtime::{Learned, Provenance};

        let cfg = Table2Config { sizes: vec![120, 150], per_class: 1, seed: 0x7AB2E2 };
        let suite = test_suite(&cfg.sizes, cfg.per_class, cfg.seed);
        let mut rt = PfmRuntime::new("nonexistent-dir-ok-pfm").unwrap();
        let methods = [Method::Learned(Learned::Se), Method::Learned(Learned::Pfm)];
        let records = evaluate_suite(&suite, &methods, &mut rt, cfg.seed);
        assert_eq!(records.len(), suite.len() * 2);
        for r in &records {
            match r.method {
                "PFM" => {
                    assert_eq!(r.provenance, Some(Provenance::NativeOptimizer), "{}", r.matrix);
                    assert!(r.opt_iters > 0, "{}: native PFM must run ADMM iterations", r.matrix);
                }
                _ => {
                    assert_eq!(r.provenance, Some(Provenance::SpectralFallback));
                    assert_eq!(r.opt_iters, 0);
                }
            }
        }
        // per-matrix: PFM never exceeds its spectral init
        for tm in &suite {
            let se = records
                .iter()
                .find(|r| r.method == "S_e" && r.matrix == tm.name)
                .unwrap();
            let pfm = records
                .iter()
                .find(|r| r.method == "PFM" && r.matrix == tm.name)
                .unwrap();
            assert!(
                pfm.lnnz <= se.lnnz,
                "{}: PFM lnnz {} above S_e {}",
                tm.name,
                pfm.lnnz,
                se.lnnz
            );
        }
        let se = mean_where(&records, |r| r.method == "S_e", |r| r.lnnz as f64).unwrap();
        let pfm = mean_where(&records, |r| r.method == "PFM", |r| r.lnnz as f64).unwrap();
        assert!(pfm < se, "mean nnz(L): PFM {pfm} not strictly below S_e {se}");
        // provenance lands in the CSV artifact
        let csv = to_csv(&records);
        assert!(csv.contains(",native,"), "native provenance missing from CSV:\n{csv}");
        assert!(csv.contains(",fallback,"));
    }

    #[test]
    fn unsymmetric_table_orderings_beat_natural_through_shared_context() {
        // the acceptance criterion: the unsymmetric suite, evaluated by
        // the LU path through one shared FactorContext, shows AMD/Metis
        // reducing nnz(L+U) vs Natural — and the steady state performs
        // zero scratch re-allocations across repeated LU factorization.
        use crate::factor::lu::{self, LuOptions};
        use crate::factor::FactorContext;

        let cfg = Table2Config { sizes: vec![196], per_class: 1, seed: 11 };
        let mut rt = PfmRuntime::new("nonexistent-dir-ok-t2u").unwrap();
        let (records, md) = run_unsymmetric(&cfg, &mut rt);
        assert!(!records.is_empty());
        assert!(records.iter().all(|r| r.factor_kind == "lu"));
        let nat = mean_where(&records, |r| r.method == "Natural", |r| r.fill_ratio).unwrap();
        for better in ["AMD", "Metis"] {
            let v = mean_where(&records, |r| r.method == better, |r| r.fill_ratio).unwrap();
            assert!(v < nat, "{better} LU fill {v} not below Natural {nat}");
        }
        assert!(md.contains("ConvDiff"));
        assert!(md.contains("Circuit"));
        assert!(md.contains("**Headline**"));

        // grow_events assertion extended to LU refactorization: re-factor
        // every suite matrix through a warmed shared context
        let suite = unsymmetric_suite(&cfg.sizes, cfg.per_class, cfg.seed);
        let mut ctx = FactorContext::new();
        let mut factors = Vec::new();
        for tm in &suite {
            let lsym = ctx.cache.analyze_lu(&tm.matrix);
            factors.push((
                lu::factorize(&tm.matrix, &lsym, LuOptions::default(), &mut ctx.workspace)
                    .unwrap(),
                &tm.matrix,
            ));
        }
        let grows = ctx.workspace.grow_events();
        let misses = ctx.cache.misses();
        for _ in 0..3 {
            for (f, a) in factors.iter_mut() {
                let _ = ctx.cache.analyze_lu(*a);
                lu::refactor_into(*a, LuOptions::default(), f, &mut ctx.workspace).unwrap();
            }
        }
        assert_eq!(ctx.cache.misses(), misses, "steady state must hit the LU cache");
        assert_eq!(
            ctx.workspace.grow_events(),
            grows,
            "steady-state LU refactorization must not allocate scratch"
        );
    }
}
