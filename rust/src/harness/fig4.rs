//! Figure 4: performance variation along matrix size — (a) fill-in ratio,
//! (b) LU factorization time, (c) ordering time, for each method over size
//! groups. This is the scalability claim of the paper: graph-theoretic
//! methods' ordering time blows up with n while GNN-score methods stay
//! flat.

use crate::coordinator::Method;
use crate::gen::test_suite;
use crate::harness::runner::{evaluate_suite, mean_where, to_csv, Record};
use crate::runtime::PfmRuntime;

/// Configuration for the Figure 4 sweep.
#[derive(Clone, Debug)]
pub struct Fig4Config {
    /// size groups (the paper uses five)
    pub sizes: Vec<usize>,
    pub per_class: usize,
    pub seed: u64,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config {
            sizes: vec![128, 256, 512, 1024, 2048],
            per_class: 1,
            seed: 0xF164,
        }
    }
}

/// Run the sweep. Returns (records, markdown).
pub fn run(cfg: &Fig4Config, rt: &mut PfmRuntime) -> (Vec<Record>, String) {
    let suite = test_suite(&cfg.sizes, cfg.per_class, cfg.seed);
    let methods = Method::table2();
    let records = evaluate_suite(&suite, &methods, rt, cfg.seed);
    let md = render(&records, &methods, &cfg.sizes);
    (records, md)
}

/// Size-group mean of a metric for one method. Groups by *target* size:
/// generated matrices land within ±30% of the target, so group = nearest
/// configured size.
fn group_of(n: usize, sizes: &[usize]) -> usize {
    *sizes
        .iter()
        .min_by_key(|&&s| (s as i64 - n as i64).unsigned_abs())
        .unwrap()
}

/// Markdown render: three series blocks (a/b/c), rows = methods, columns =
/// size groups.
pub fn render(records: &[Record], methods: &[Method], sizes: &[usize]) -> String {
    let mut md = String::new();
    let panels: [(&str, Box<dyn Fn(&Record) -> f64>); 3] = [
        ("Figure 4(a) — fill-in ratio", Box::new(|r: &Record| r.fill_ratio)),
        ("Figure 4(b) — factorization time (ms)", Box::new(|r: &Record| r.factor_time * 1e3)),
        ("Figure 4(c) — ordering time (ms)", Box::new(|r: &Record| r.ordering_time * 1e3)),
    ];
    for (title, proj) in panels {
        md.push_str(&format!("## {title}\n\n| Method |"));
        for s in sizes {
            md.push_str(&format!(" n≈{s} |"));
        }
        md.push_str("\n|---|");
        for _ in sizes {
            md.push_str("---|");
        }
        md.push('\n');
        for m in methods {
            md.push_str(&format!("| {} |", m.label()));
            for &s in sizes {
                let v = mean_where(
                    records,
                    |r| r.method == m.label() && group_of(r.n, sizes) == s,
                    &proj,
                );
                md.push_str(&format!(" {} |", v.map_or("-".into(), |x| format!("{x:.2}"))));
            }
            md.push('\n');
        }
        md.push('\n');
    }
    md
}

/// Write outputs.
pub fn write_outputs(records: &[Record], md: &str, out_dir: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/fig4.csv"), to_csv(records))?;
    std::fs::write(format!("{out_dir}/fig4.md"), md)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_assignment() {
        let sizes = [128, 256, 512];
        assert_eq!(group_of(130, &sizes), 128);
        assert_eq!(group_of(200, &sizes), 256);
        assert_eq!(group_of(1000, &sizes), 512);
    }
}
