//! Table 3: ablation study of PFM on the SP and CFD suites.
//!
//! Rows (matching the paper):
//!   S_e                      — spectral embedding scores alone
//!   randinit+MgGNN+FactLoss  — no spectral embedding
//!   S_e+MgGNN+PCE            — pairwise-cross-entropy loss (GPCE)
//!   S_e+MgGNN+UDNO           — expected-envelope loss
//!   S_e+GUnet+PFM            — GraphUnet-lite encoder
//!   S_e+MgGNN+FactLoss       — full PFM (the proposed method)

use crate::coordinator::Method;
use crate::gen::{ProblemClass, TestMatrix};
use crate::harness::runner::{evaluate_suite, mean_where, to_csv, Record};
use crate::runtime::{Learned, PfmRuntime};

/// The ablation variants, in the paper's row order, with paper-style
/// labels.
pub fn ablation_rows() -> Vec<(Learned, &'static str)> {
    vec![
        (Learned::Se, "S_e"),
        (Learned::PfmRandinit, "randinit+MgGNN+FactLoss"),
        (Learned::Gpce, "S_e+MgGNN+PCE"),
        (Learned::Udno, "S_e+MgGNN+UDNO"),
        (Learned::PfmGunet, "S_e+GUnet+PFM"),
        (Learned::Pfm, "S_e+MgGNN+FactLoss"),
    ]
}

/// Configuration for the Table 3 run.
#[derive(Clone, Debug)]
pub struct Table3Config {
    pub sizes: Vec<usize>,
    pub per_class: usize,
    pub seed: u64,
}

impl Default for Table3Config {
    fn default() -> Self {
        Table3Config { sizes: vec![256, 512], per_class: 3, seed: 0x7AB3E3 }
    }
}

/// Build the SP + CFD suite the paper's ablation uses.
pub fn ablation_suite(cfg: &Table3Config) -> Vec<TestMatrix> {
    let mut suite = Vec::new();
    for &n in &cfg.sizes {
        for &class in &[ProblemClass::Sp, ProblemClass::Cfd] {
            for rep in 0..cfg.per_class {
                let s = cfg
                    .seed
                    .wrapping_mul(0x9e3779b97f4a7c15)
                    .wrapping_add((n as u64) << 8)
                    .wrapping_add(rep as u64);
                suite.push(TestMatrix {
                    name: format!("{}_n{}_r{}", class.label().to_lowercase(), n, rep),
                    class,
                    matrix: class.generate(n, s),
                });
            }
        }
    }
    suite
}

/// Run the ablation. Returns (records, markdown).
pub fn run(cfg: &Table3Config, rt: &mut PfmRuntime) -> (Vec<Record>, String) {
    let suite = ablation_suite(cfg);
    let methods: Vec<Method> =
        ablation_rows().iter().map(|&(l, _)| Method::Learned(l)).collect();
    let records = evaluate_suite(&suite, &methods, rt, cfg.seed);
    let md = render(&records);
    (records, md)
}

/// Markdown render: fill ratio per SP / CFD / SP+CFD (the paper's columns).
pub fn render(records: &[Record]) -> String {
    let mut md = String::new();
    md.push_str("## Table 3 — ablation (fill-in ratio)\n\n");
    md.push_str("| Variant | SP | CFD | SP+CFD |\n|---|---|---|---|\n");
    for (l, label) in ablation_rows() {
        let sp = mean_where(
            records,
            |r| r.method == l.label() && r.class == ProblemClass::Sp,
            |r| r.fill_ratio,
        );
        let cfd = mean_where(
            records,
            |r| r.method == l.label() && r.class == ProblemClass::Cfd,
            |r| r.fill_ratio,
        );
        let both = mean_where(records, |r| r.method == l.label(), |r| r.fill_ratio);
        md.push_str(&format!(
            "| {label} | {} | {} | {} |\n",
            sp.map_or("-".into(), |v| format!("{v:.2}")),
            cfd.map_or("-".into(), |v| format!("{v:.2}")),
            both.map_or("-".into(), |v| format!("{v:.2}")),
        ));
    }
    md
}

/// Write outputs.
pub fn write_outputs(records: &[Record], md: &str, out_dir: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/table3.csv"), to_csv(records))?;
    std::fs::write(format!("{out_dir}/table3.md"), md)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_covers_sp_and_cfd() {
        let cfg = Table3Config { sizes: vec![100], per_class: 1, seed: 1 };
        let suite = ablation_suite(&cfg);
        assert_eq!(suite.len(), 2);
        assert!(suite.iter().any(|t| t.class == ProblemClass::Sp));
        assert!(suite.iter().any(|t| t.class == ProblemClass::Cfd));
    }

    #[test]
    fn rows_match_paper_count() {
        assert_eq!(ablation_rows().len(), 6);
    }
}
