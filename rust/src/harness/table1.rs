//! Table 1: empirical scaling of ordering time, validating the paper's
//! complexity table — AMD O(|E|·|V|)-ish, Metis O(|E|log|V|), Spectral
//! O(|V|³) worst case (Lanczos in practice super-linear), GNN methods
//! O(GNN) ≈ near-linear in the dense-panel work per bucket.
//!
//! We fit log(time) = α·log(n) + c per method over a size sweep of 2D3D
//! matrices and report α (the empirical exponent) plus the raw times.

use crate::coordinator::Method;
use crate::gen::{ProblemClass, TestMatrix};
use crate::harness::runner::{evaluate_suite, mean_where, Record};
use crate::runtime::PfmRuntime;

/// Configuration for the scaling sweep.
#[derive(Clone, Debug)]
pub struct Table1Config {
    pub sizes: Vec<usize>,
    pub seed: u64,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config { sizes: vec![128, 256, 512, 1024, 2048], seed: 0x7AB1E1 }
    }
}

/// Least-squares slope of y over x.
pub fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den.max(1e-300)
}

/// Run the sweep and fit exponents. Returns (records, markdown).
pub fn run(cfg: &Table1Config, rt: &mut PfmRuntime) -> (Vec<Record>, String) {
    let suite: Vec<TestMatrix> = cfg
        .sizes
        .iter()
        .map(|&n| TestMatrix {
            name: format!("2d3d_n{n}"),
            class: ProblemClass::TwoDThreeD,
            matrix: ProblemClass::TwoDThreeD.generate(n, cfg.seed),
        })
        .collect();
    let methods = Method::table2();
    let records = evaluate_suite(&suite, &methods, rt, cfg.seed);
    let md = render(&records, &methods, &cfg.sizes);
    (records, md)
}

/// Markdown: ordering time per size + fitted exponent per method.
pub fn render(records: &[Record], methods: &[Method], sizes: &[usize]) -> String {
    let mut md = String::new();
    md.push_str("## Table 1 — ordering-time scaling (empirical exponent α in t ∝ n^α)\n\n");
    md.push_str("| Method |");
    for s in sizes {
        md.push_str(&format!(" n={s} (ms) |"));
    }
    md.push_str(" α |\n|---|");
    for _ in 0..(sizes.len() + 1) {
        md.push_str("---|");
    }
    md.push('\n');
    for m in methods {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        md.push_str(&format!("| {} |", m.label()));
        for &s in sizes {
            let t = mean_where(
                records,
                |r| r.method == m.label() && r.n.abs_diff(s) <= s / 2,
                |r| r.ordering_time,
            );
            match t {
                Some(t) if t > 0.0 => {
                    md.push_str(&format!(" {:.2} |", t * 1e3));
                    xs.push((s as f64).ln());
                    ys.push(t.ln());
                }
                _ => md.push_str(" - |"),
            }
        }
        let alpha = if xs.len() >= 2 { format!("{:.2}", slope(&xs, &ys)) } else { "-".into() };
        md.push_str(&format!(" {alpha} |\n"));
    }
    md.push_str(
        "\nPaper's complexity classes: AMD O(|E||V|), Metis O(|E|log|V|), \
         Spectral O(|V|³) worst case, UDNO/PFM O(GNN) (high parallelizability).\n",
    );
    md
}

/// Write outputs.
pub fn write_outputs(records: &[Record], md: &str, out_dir: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(
        format!("{out_dir}/table1.csv"),
        crate::harness::runner::to_csv(records),
    )?;
    std::fs::write(format!("{out_dir}/table1.md"), md)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_fits_lines() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((slope(&xs, &ys) - 2.0).abs() < 1e-12);
    }
}
