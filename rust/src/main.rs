//! `pfm-reorder` CLI: experiment drivers (table1/table2/table3/fig4), a
//! one-shot `order` command, and the `serve` demo loop.
//!
//! No clap in the offline crate set — arguments are parsed by hand; every
//! subcommand documents itself via `pfm-reorder help`.

use std::process::ExitCode;

use pfm_reorder::coordinator::{Method, ReorderService, ServiceConfig};
use pfm_reorder::factor::{fill_ratio_of_order, lu_fill_ratio_of_order, FactorKind};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::harness::{fig4, table1, table2, table3};
use pfm_reorder::order::Classical;
use pfm_reorder::runtime::{Learned, PfmRuntime};
use pfm_reorder::sparse::io::read_matrix_market;

const USAGE: &str = "\
pfm-reorder — Factorization-in-Loop / Proximal Fill-in Minimization (AAAI'26 reproduction)

USAGE:
    pfm-reorder <COMMAND> [OPTIONS]

COMMANDS:
    table1                 ordering-time scaling sweep (paper Table 1)
    table2                 fill-in + factor-time comparison (paper Table 2)
    table3                 ablation study (paper Table 3)
    fig4                   size sweep for fill/LU/ordering time (paper Fig. 4)
    order <file.mtx>       reorder one MatrixMarket matrix and report fill
    serve                  run the reordering service demo (batching stats)
    help                   this message

COMMON OPTIONS:
    --artifacts <dir>      artifact directory  [default: artifacts]
    --out <dir>            results directory   [default: results]
    --sizes <a,b,c>        override matrix sizes
    --per-class <k>        matrices per class per size
    --seed <s>             RNG seed
    --method <name>        (order) Natural|RCM|AMD|Metis|Fiedler|Se|GPCE|UDNO|PFM
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let opts = Opts::parse(&args[1..]);
    let result = match cmd.as_str() {
        "table1" => cmd_table1(&opts),
        "table2" => cmd_table2(&opts),
        "table3" => cmd_table3(&opts),
        "fig4" => cmd_fig4(&opts),
        "order" => cmd_order(&opts),
        "serve" => cmd_serve(&opts),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Hand-rolled option bag.
struct Opts {
    artifacts: String,
    out: String,
    sizes: Option<Vec<usize>>,
    per_class: Option<usize>,
    seed: Option<u64>,
    method: Option<String>,
    positional: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut o = Opts {
            artifacts: "artifacts".into(),
            out: "results".into(),
            sizes: None,
            per_class: None,
            seed: None,
            method: None,
            positional: Vec::new(),
        };
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--artifacts" => o.artifacts = it.next().cloned().unwrap_or_default(),
                "--out" => o.out = it.next().cloned().unwrap_or_default(),
                "--sizes" => {
                    o.sizes = it.next().map(|s| {
                        s.split(',').filter_map(|t| t.trim().parse().ok()).collect()
                    })
                }
                "--per-class" => o.per_class = it.next().and_then(|s| s.parse().ok()),
                "--seed" => o.seed = it.next().and_then(|s| s.parse().ok()),
                "--method" => o.method = it.next().cloned(),
                other => o.positional.push(other.to_string()),
            }
        }
        o
    }

    fn runtime(&self) -> Result<PfmRuntime, String> {
        PfmRuntime::new(&self.artifacts).map_err(|e| e.to_string())
    }
}

fn cmd_table1(o: &Opts) -> Result<(), String> {
    let mut cfg = table1::Table1Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table1::run(&cfg, &mut rt);
    table1::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table1.csv)", records.len(), o.out);
    Ok(())
}

fn cmd_table2(o: &Opts) -> Result<(), String> {
    let mut cfg = table2::Table2Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table2::run(&cfg, &mut rt);
    table2::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table2.csv)", records.len(), o.out);
    // unsymmetric extension: ConvDiff/Circuit through the LU engine
    let (urecords, umd) = table2::run_unsymmetric(&cfg, &mut rt);
    table2::write_outputs_unsymmetric(&urecords, &umd, &o.out).map_err(|e| e.to_string())?;
    println!("{umd}");
    println!("({} records -> {}/table2_unsym.csv)", urecords.len(), o.out);
    Ok(())
}

fn cmd_table3(o: &Opts) -> Result<(), String> {
    let mut cfg = table3::Table3Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table3::run(&cfg, &mut rt);
    table3::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table3.csv)", records.len(), o.out);
    Ok(())
}

fn cmd_fig4(o: &Opts) -> Result<(), String> {
    let mut cfg = fig4::Fig4Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = fig4::run(&cfg, &mut rt);
    fig4::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/fig4.csv)", records.len(), o.out);
    Ok(())
}

fn parse_method(name: &str) -> Result<Method, String> {
    // single source of truth: labels live in Classical::label /
    // Learned::label, and Method::from_label inverts them (plus aliases)
    Method::from_label(name).ok_or_else(|| format!("unknown method `{name}`"))
}

fn cmd_order(o: &Opts) -> Result<(), String> {
    let path = o
        .positional
        .first()
        .ok_or("usage: pfm-reorder order <file.mtx> [--method PFM]")?;
    let a = read_matrix_market(path).map_err(|e| e.to_string())?;
    let kind = FactorKind::for_matrix(&a);
    // the fill is always measured on the original matrix (through the
    // factorization its symmetry calls for), but the ordering methods —
    // Fiedler's Lanczos and the learned networks in particular — assume
    // symmetric edge weights, so any unsymmetric input is ordered on its
    // symmetrized (A+Aᵀ)/2 proxy
    let proxy = match kind {
        FactorKind::Cholesky => None,
        FactorKind::Lu => Some(a.symmetrize()),
    };
    let graph = proxy.as_ref().unwrap_or(&a);
    let method = parse_method(o.method.as_deref().unwrap_or("pfm"))?;
    let mut rt = o.runtime()?;
    let t0 = std::time::Instant::now();
    let order = match method {
        Method::Classical(c) => c.order(graph),
        Method::Learned(l) => {
            l.order(&mut rt, graph, o.seed.unwrap_or(42)).map_err(|e| e.to_string())?.0
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    let natural_order: Vec<usize> = (0..a.nrows()).collect();
    // numeric LU fill with the same fallback policy as the service's
    // eval_fill: a singular pivot sequence degrades to the structural
    // A+Aᵀ bound instead of failing the whole command
    let lu_fill = |order: &[usize]| -> f64 {
        lu_fill_ratio_of_order(&a, order).unwrap_or_else(|_| {
            let pap = a.permute_sym(order);
            pfm_reorder::factor::analyze_lu(&pap).lu_nnz_bound as f64 / pap.nnz() as f64
        })
    };
    let (natural, reordered) = match kind {
        FactorKind::Cholesky => (
            fill_ratio_of_order(&a, &natural_order),
            fill_ratio_of_order(&a, &order),
        ),
        FactorKind::Lu => (lu_fill(&natural_order), lu_fill(&order)),
    };
    println!(
        "matrix {}x{} nnz={} [{}] | {}: fill ratio {:.3} (natural {:.3}) ordering {:.1} ms",
        a.nrows(),
        a.ncols(),
        a.nnz(),
        kind.label(),
        method.label(),
        reordered,
        natural,
        dt * 1e3
    );
    Ok(())
}

fn cmd_serve(o: &Opts) -> Result<(), String> {
    let service = ReorderService::start(ServiceConfig {
        artifact_dir: o.artifacts.clone(),
        ..Default::default()
    });
    // demo load: a burst of mixed requests over all classes
    let sizes = o.sizes.clone().unwrap_or_else(|| vec![100, 200, 400]);
    let seed = o.seed.unwrap_or(7);
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    let mut count = 0u64;
    for &n in &sizes {
        for &class in &ProblemClass::ALL {
            let a = class.generate(n, seed ^ n as u64);
            for &m in &[
                Method::Learned(Learned::Pfm),
                Method::Classical(Classical::Amd),
            ] {
                rxs.push(service.submit(a.clone(), m, seed + count));
                count += 1;
            }
        }
    }
    for rx in rxs {
        let resp = rx.recv().map_err(|e| e.to_string())?;
        resp.result?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {count} requests in {wall:.2}s ({:.1} req/s)",
        count as f64 / wall
    );
    println!("metrics: {}", service.metrics.to_json().to_string());
    Ok(())
}
