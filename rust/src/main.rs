//! `pfm-reorder` CLI: experiment drivers (table1/table2/table3/fig4), a
//! one-shot `order` command, the TCP gateway (`serve` / `admin` /
//! `remote`), and the in-process `demo` loop.
//!
//! No clap in the offline crate set — arguments are parsed by hand; every
//! subcommand documents itself via `pfm-reorder help`.

use std::net::{SocketAddr, ToSocketAddrs};
use std::process::ExitCode;
use std::time::Duration;

use pfm_reorder::coordinator::{Method, ReorderService, ServiceConfig};
use pfm_reorder::factor::{fill_ratio_of_order, lu_fill_ratio_of_order, FactorKind};
use pfm_reorder::gateway::{
    AdminCmd, Gateway, GatewayClient, GatewayConfig, Reply, WireRequest, DEFAULT_ADDR,
};
use pfm_reorder::gen::ProblemClass;
use pfm_reorder::harness::replay::{self, ReplaySpec, SloRule, TraceKind};
use pfm_reorder::harness::{fig4, table1, table2, table3};
use pfm_reorder::order::Classical;
use pfm_reorder::pfm::{OptBudget, PfmOptimizer, ScoreInit};
use pfm_reorder::runtime::{Learned, PfmRuntime};
use pfm_reorder::sparse::io::read_matrix_market;
use pfm_reorder::sparse::Csr;
use pfm_reorder::util::check::check_permutation;
use pfm_reorder::util::json::Json;

const USAGE: &str = "\
pfm-reorder — Factorization-in-Loop / Proximal Fill-in Minimization (AAAI'26 reproduction)

USAGE:
    pfm-reorder <COMMAND> [OPTIONS]

COMMANDS:
    table1                 ordering-time scaling sweep (paper Table 1)
    table2                 fill-in + factor-time comparison (paper Table 2)
    table3                 ablation study (paper Table 3)
    fig4                   size sweep for fill/LU/ordering time (paper Fig. 4)
    order <file.mtx>       reorder one MatrixMarket matrix and report fill
    pfm <file.mtx>         native PFM optimizer: permutation + fill report
    serve                  run the TCP reorder gateway (framed protocol)
    admin <cmd>            query a running gateway:
                           ping|metrics|throttle|snapshot|trace|shutdown
                           (metrics --text = Prometheus exposition)
    remote <file.mtx>      reorder one matrix through a running gateway
                           (--json adds the per-stage latency breakdown)
    replay                 open-loop traffic replay against a gateway (or
                           --inproc): per-class p50/p99/p999 + SLO checks,
                           writes BENCH_serving.json
    demo                   run the in-process service demo (batching stats)
    help                   this message

COMMON OPTIONS:
    --artifacts <dir>      artifact directory  [default: artifacts]
    --out <dir>            results directory   [default: results]
    --sizes <a,b,c>        override matrix sizes
    --per-class <k>        matrices per class per size
    --seed <s>             RNG seed
    --method <name>        (order) Natural|RCM|AMD|Metis|Fiedler|Se|GPCE|UDNO|PFM

PFM OPTIONS:
    --gen <class:n>        generate the input instead of reading a file
                           (class: SP|CFD|MRP|2D3D|TP|Other|ConvDiff|Circuit)
    --init <spectral|random>  score initialization  [default: spectral]
    --outer <k>            ADMM outer iterations   [default: 6]
    --refine <k>           refinement steps        [default: 60]
    --level-refine <k>     V-cycle per-level refinement steps [default: 8]
    --threads <k>          probe-pool workers (same ordering at any k) [default: 1]
    --factor-threads <k>   parallel-factorization width (bit-identical factors at
                           any k; also accepted by serve and remote) [default: 1]
    --adaptive-rho         residual-balancing ADMM penalty (mu=10, tau=2)
    --budget-ms <ms>       wall-clock cap
    --no-incremental       disable incremental probe evaluation (A/B runs;
                           same ordering, full-cost probes)
    --check-fill           exit nonzero unless optimized fill <= natural fill
    --check-incremental    exit nonzero unless incremental probes engaged
    --out <dir>            also write pfm_perm.txt + pfm_report.json

GATEWAY OPTIONS:
    --addr <host:port>     gateway address  [default: 127.0.0.1:7744]
    --rate <r>             per-client rate limit, requests/s (0 = off)  [default: 0]
    --burst <b>            token-bucket burst capacity  [default: 32]
    --persist-dir <dir>    (serve) crash-safe warm-start store: WAL + snapshots
                           under <dir>; repeat patterns are served from disk
                           across restarts  [default: off]
    --fsync <always|never> (serve) WAL durability policy  [default: always]
    --timeout-ms <ms>      (admin/remote/replay) read/write timeout on the
                           gateway connection  [default: 10000 admin,
                           60000 remote/replay]
    --text                 (admin metrics) Prometheus text exposition
    --json                 (remote) JSON output incl. per-stage breakdown

REPLAY OPTIONS:
    --gen <trace>          trace family: mixed|warm|coldstorm  [default: mixed]
    --requests <n>         trace length  [default: 200]
    --speed <x>            replay at x times the trace's 1x rate (10ms
                           inter-arrival): 1, 10, 100, ...  [default: 1]
    --conns <k>            pipelined gateway connections  [default: 4]
    --inproc               drive an in-process service instead of a gateway
                           (--persist-dir enables the warm-start path)
    --slo <rule>           assert `[class:]stat=limit` on exit, repeatable,
                           e.g. --slo p99=500ms --slo warm_hit:p99=50ms
                           (stat: p50|p99|p999|mean|max; ms/s suffixes)
    --check-warm           require warm-hit p99 strictly below cold p99
    --bench <file>         benchmark output path  [default: BENCH_serving.json]
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let opts = Opts::parse(&args[1..]);
    let result = match cmd.as_str() {
        "table1" => cmd_table1(&opts),
        "table2" => cmd_table2(&opts),
        "table3" => cmd_table3(&opts),
        "fig4" => cmd_fig4(&opts),
        "order" => cmd_order(&opts),
        "pfm" => cmd_pfm(&opts),
        "serve" => cmd_serve(&opts),
        "admin" => cmd_admin(&opts),
        "remote" => cmd_remote(&opts),
        "replay" => cmd_replay(&opts),
        "demo" => cmd_demo(&opts),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Hand-rolled option bag.
struct Opts {
    artifacts: String,
    out: String,
    out_given: bool,
    sizes: Option<Vec<usize>>,
    per_class: Option<usize>,
    seed: Option<u64>,
    method: Option<String>,
    gen: Option<String>,
    init: Option<String>,
    outer: Option<usize>,
    refine: Option<usize>,
    level_refine: Option<usize>,
    threads: Option<usize>,
    factor_threads: Option<usize>,
    adaptive_rho: bool,
    budget_ms: Option<u64>,
    no_incremental: bool,
    check_fill: bool,
    check_incremental: bool,
    addr: String,
    rate: Option<f64>,
    burst: Option<f64>,
    persist_dir: Option<String>,
    fsync: Option<String>,
    timeout_ms: Option<u64>,
    requests: Option<usize>,
    speed: Option<f64>,
    conns: Option<usize>,
    slo: Vec<String>,
    inproc: bool,
    check_warm: bool,
    text: bool,
    json: bool,
    bench: Option<String>,
    positional: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut o = Opts {
            artifacts: "artifacts".into(),
            out: "results".into(),
            out_given: false,
            sizes: None,
            per_class: None,
            seed: None,
            method: None,
            gen: None,
            init: None,
            outer: None,
            refine: None,
            level_refine: None,
            threads: None,
            factor_threads: None,
            adaptive_rho: false,
            budget_ms: None,
            no_incremental: false,
            check_fill: false,
            check_incremental: false,
            addr: DEFAULT_ADDR.to_string(),
            rate: None,
            burst: None,
            persist_dir: None,
            fsync: None,
            timeout_ms: None,
            requests: None,
            speed: None,
            conns: None,
            slo: Vec::new(),
            inproc: false,
            check_warm: false,
            text: false,
            json: false,
            bench: None,
            positional: Vec::new(),
        };
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--artifacts" => o.artifacts = it.next().cloned().unwrap_or_default(),
                "--out" => {
                    o.out = it.next().cloned().unwrap_or_default();
                    o.out_given = true;
                }
                "--sizes" => {
                    o.sizes = it.next().map(|s| {
                        s.split(',').filter_map(|t| t.trim().parse().ok()).collect()
                    })
                }
                "--per-class" => o.per_class = it.next().and_then(|s| s.parse().ok()),
                "--seed" => o.seed = it.next().and_then(|s| s.parse().ok()),
                "--method" => o.method = it.next().cloned(),
                "--gen" => o.gen = it.next().cloned(),
                "--init" => o.init = it.next().cloned(),
                "--outer" => o.outer = it.next().and_then(|s| s.parse().ok()),
                "--refine" => o.refine = it.next().and_then(|s| s.parse().ok()),
                "--level-refine" => o.level_refine = it.next().and_then(|s| s.parse().ok()),
                "--threads" => o.threads = it.next().and_then(|s| s.parse().ok()),
                "--factor-threads" => o.factor_threads = it.next().and_then(|s| s.parse().ok()),
                "--adaptive-rho" => o.adaptive_rho = true,
                "--budget-ms" => o.budget_ms = it.next().and_then(|s| s.parse().ok()),
                "--no-incremental" => o.no_incremental = true,
                "--check-fill" => o.check_fill = true,
                "--check-incremental" => o.check_incremental = true,
                "--addr" => o.addr = it.next().cloned().unwrap_or_else(|| DEFAULT_ADDR.into()),
                "--rate" => o.rate = it.next().and_then(|s| s.parse().ok()),
                "--burst" => o.burst = it.next().and_then(|s| s.parse().ok()),
                "--persist-dir" => o.persist_dir = it.next().cloned(),
                "--fsync" => o.fsync = it.next().cloned(),
                "--timeout-ms" => o.timeout_ms = it.next().and_then(|s| s.parse().ok()),
                "--requests" => o.requests = it.next().and_then(|s| s.parse().ok()),
                "--speed" => o.speed = it.next().and_then(|s| s.parse().ok()),
                "--conns" => o.conns = it.next().and_then(|s| s.parse().ok()),
                "--slo" => {
                    if let Some(rule) = it.next() {
                        o.slo.push(rule.clone());
                    }
                }
                "--inproc" => o.inproc = true,
                "--check-warm" => o.check_warm = true,
                "--text" => o.text = true,
                "--json" => o.json = true,
                "--bench" => o.bench = it.next().cloned(),
                other => o.positional.push(other.to_string()),
            }
        }
        o
    }

    fn runtime(&self) -> Result<PfmRuntime, String> {
        PfmRuntime::new(&self.artifacts).map_err(|e| e.to_string())
    }
}

fn cmd_table1(o: &Opts) -> Result<(), String> {
    let mut cfg = table1::Table1Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table1::run(&cfg, &mut rt);
    table1::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table1.csv)", records.len(), o.out);
    Ok(())
}

fn cmd_table2(o: &Opts) -> Result<(), String> {
    let mut cfg = table2::Table2Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table2::run(&cfg, &mut rt);
    table2::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table2.csv)", records.len(), o.out);
    // unsymmetric extension: ConvDiff/Circuit through the LU engine
    let (urecords, umd) = table2::run_unsymmetric(&cfg, &mut rt);
    table2::write_outputs_unsymmetric(&urecords, &umd, &o.out).map_err(|e| e.to_string())?;
    println!("{umd}");
    println!("({} records -> {}/table2_unsym.csv)", urecords.len(), o.out);
    Ok(())
}

fn cmd_table3(o: &Opts) -> Result<(), String> {
    let mut cfg = table3::Table3Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = table3::run(&cfg, &mut rt);
    table3::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/table3.csv)", records.len(), o.out);
    Ok(())
}

fn cmd_fig4(o: &Opts) -> Result<(), String> {
    let mut cfg = fig4::Fig4Config::default();
    if let Some(s) = &o.sizes {
        cfg.sizes = s.clone();
    }
    if let Some(k) = o.per_class {
        cfg.per_class = k;
    }
    if let Some(s) = o.seed {
        cfg.seed = s;
    }
    let mut rt = o.runtime()?;
    let (records, md) = fig4::run(&cfg, &mut rt);
    fig4::write_outputs(&records, &md, &o.out).map_err(|e| e.to_string())?;
    println!("{md}");
    println!("({} records -> {}/fig4.csv)", records.len(), o.out);
    Ok(())
}

fn parse_method(name: &str) -> Result<Method, String> {
    // single source of truth: labels live in Classical::label /
    // Learned::label, and Method::from_label inverts them (plus aliases)
    Method::from_label(name).ok_or_else(|| format!("unknown method `{name}`"))
}

fn cmd_order(o: &Opts) -> Result<(), String> {
    let path = o
        .positional
        .first()
        .ok_or("usage: pfm-reorder order <file.mtx> [--method PFM]")?;
    let a = read_matrix_market(path).map_err(|e| e.to_string())?;
    let kind = FactorKind::for_matrix(&a);
    // the fill is always measured on the original matrix (through the
    // factorization its symmetry calls for), but the ordering methods —
    // Fiedler's Lanczos and the learned networks in particular — assume
    // symmetric edge weights, so any unsymmetric input is ordered on its
    // symmetrized (A+Aᵀ)/2 proxy
    let proxy = match kind {
        FactorKind::Cholesky => None,
        FactorKind::Lu => Some(a.symmetrize()),
    };
    let graph = proxy.as_ref().unwrap_or(&a);
    let method = parse_method(o.method.as_deref().unwrap_or("pfm"))?;
    let mut rt = o.runtime()?;
    let t0 = std::time::Instant::now();
    let order = match method {
        Method::Classical(c) => c.order(graph),
        Method::Learned(l) => {
            l.order(&mut rt, graph, o.seed.unwrap_or(42)).map_err(|e| e.to_string())?.0
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    let natural_order: Vec<usize> = (0..a.nrows()).collect();
    // numeric LU fill with the same fallback policy as the service's
    // eval_fill: a singular pivot sequence degrades to the structural
    // A+Aᵀ bound instead of failing the whole command
    let lu_fill = |order: &[usize]| -> f64 {
        lu_fill_ratio_of_order(&a, order).unwrap_or_else(|_| {
            let pap = a.permute_sym(order);
            pfm_reorder::factor::analyze_lu(&pap).lu_nnz_bound as f64 / pap.nnz() as f64
        })
    };
    let (natural, reordered) = match kind {
        FactorKind::Cholesky => (
            fill_ratio_of_order(&a, &natural_order),
            fill_ratio_of_order(&a, &order),
        ),
        FactorKind::Lu => (lu_fill(&natural_order), lu_fill(&order)),
    };
    println!(
        "matrix {}x{} nnz={} [{}] | {}: fill ratio {:.3} (natural {:.3}) ordering {:.1} ms",
        a.nrows(),
        a.ncols(),
        a.nnz(),
        kind.label(),
        method.label(),
        reordered,
        natural,
        dt * 1e3
    );
    Ok(())
}

/// Parse `--gen class:n` into a generated matrix.
fn parse_gen(spec: &str, seed: u64) -> Result<(String, Csr), String> {
    let (cls, n) = spec
        .split_once(':')
        .ok_or("--gen expects <class:n>, e.g. --gen 2d3d:64")?;
    let class = ProblemClass::from_label(cls).ok_or_else(|| format!("unknown class `{cls}`"))?;
    let n: usize = n.parse().map_err(|_| format!("bad size `{n}` in --gen"))?;
    Ok((format!("{}_n{}", class.label().to_lowercase(), n), class.generate(n, seed)))
}

fn cmd_pfm(o: &Opts) -> Result<(), String> {
    let seed = o.seed.unwrap_or(42);
    let (name, a) = match (&o.gen, o.positional.first()) {
        (Some(spec), _) => parse_gen(spec, seed)?,
        (None, Some(path)) => {
            (path.clone(), read_matrix_market(path).map_err(|e| e.to_string())?)
        }
        (None, None) => return Err("usage: pfm-reorder pfm <file.mtx> | --gen <class:n>".into()),
    };
    if a.nrows() != a.ncols() {
        return Err(format!("matrix must be square, got {}x{}", a.nrows(), a.ncols()));
    }
    // start from the library default so the CLI never drifts from it;
    // flags override individual knobs
    let mut budget = OptBudget::default();
    if let Some(k) = o.outer {
        budget.outer = k;
    }
    if let Some(k) = o.refine {
        budget.refine = k;
    }
    if let Some(k) = o.level_refine {
        budget.level_refine = k;
    }
    budget.adaptive_rho |= o.adaptive_rho;
    budget.time_ms = o.budget_ms.or(budget.time_ms);
    let init = match o.init.as_deref() {
        None | Some("spectral") => ScoreInit::Spectral,
        Some("random") => ScoreInit::Random,
        Some(other) => return Err(format!("unknown init `{other}` (spectral|random)")),
    };
    let opt = PfmOptimizer::new(budget, seed)
        .with_init(init)
        .with_threads(o.threads.unwrap_or(1))
        .with_factor_threads(o.factor_threads.unwrap_or(1))
        .with_incremental(!o.no_incremental);
    let t0 = std::time::Instant::now();
    let rep = opt.optimize(&a);
    let dt = t0.elapsed().as_secs_f64();
    // the optimizer already evaluated the identity as its free candidate
    let natural = rep.natural_objective;
    println!(
        "matrix {} {}x{} nnz={} [{}] | native PFM ({:?} init, {} probe threads, \
         {} factor threads): \
         factor nnz {:.0} (init {:.0}, natural {:.0}) | {} ADMM iters{}, {} refine steps, \
         {} levels refined, {} evals ({} incremental / {} full, {} prepares), {:.1} ms",
        name,
        a.nrows(),
        a.ncols(),
        a.nnz(),
        rep.kind.label(),
        opt.init,
        rep.probe_threads,
        opt.factor_threads,
        rep.objective,
        rep.init_objective,
        natural,
        rep.outer_iters,
        rep.coarse_n.map(|cn| format!(" (coarse n={cn})")).unwrap_or_default(),
        rep.refine_steps,
        rep.levels_refined,
        rep.evals,
        rep.incremental_probes,
        rep.full_probes,
        rep.probe_prepares,
        dt * 1e3,
    );
    if o.out_given {
        std::fs::create_dir_all(&o.out).map_err(|e| e.to_string())?;
        let perm: String =
            rep.order.iter().map(|u| format!("{u}\n")).collect();
        std::fs::write(format!("{}/pfm_perm.txt", o.out), perm).map_err(|e| e.to_string())?;
        let json = Json::obj()
            .set("matrix", name.as_str())
            .set("n", a.nrows())
            .set("nnz", a.nnz())
            .set("factor_kind", rep.kind.label())
            .set("objective", rep.objective)
            .set("init_objective", rep.init_objective)
            .set("natural_objective", natural)
            .set("outer_iters", rep.outer_iters)
            .set("refine_steps", rep.refine_steps)
            .set("levels_refined", rep.levels_refined)
            .set("probe_threads", rep.probe_threads)
            .set("factor_threads", opt.factor_threads)
            .set("evals", rep.evals)
            .set("incremental_probes", rep.incremental_probes)
            .set("full_probes", rep.full_probes)
            .set("probe_prepares", rep.probe_prepares)
            .set("wall_ms", dt * 1e3);
        std::fs::write(format!("{}/pfm_report.json", o.out), json.to_string())
            .map_err(|e| e.to_string())?;
        println!("(permutation -> {}/pfm_perm.txt, report -> {}/pfm_report.json)", o.out, o.out);
    }
    if o.check_fill && rep.objective > natural {
        return Err(format!(
            "check-fill failed: optimized factor nnz {:.0} above natural {natural:.0}",
            rep.objective
        ));
    }
    if o.check_incremental && rep.incremental_probes == 0 {
        return Err(format!(
            "check-incremental failed: 0 of {} evals took the incremental path \
             (disabled, or no refinement batch engaged)",
            rep.evals
        ));
    }
    Ok(())
}

fn cmd_serve(o: &Opts) -> Result<(), String> {
    let persist = match &o.persist_dir {
        Some(dir) => {
            let mut pc = pfm_reorder::persist::PersistConfig::new(dir);
            if let Some(f) = &o.fsync {
                pc.fsync = pfm_reorder::persist::FsyncPolicy::parse(f)
                    .ok_or_else(|| format!("unknown --fsync policy `{f}` (always|never)"))?;
            }
            Some(pc)
        }
        None => {
            if o.fsync.is_some() {
                return Err("--fsync only makes sense together with --persist-dir".into());
            }
            None
        }
    };
    let gateway = Gateway::start(GatewayConfig {
        addr: o.addr.clone(),
        service: ServiceConfig {
            artifact_dir: o.artifacts.clone(),
            persist,
            factor_threads: o.factor_threads.unwrap_or(1),
            ..Default::default()
        },
        rate: o.rate.unwrap_or(0.0),
        burst: o.burst.unwrap_or(32.0),
        ..GatewayConfig::default()
    })
    .map_err(|e| format!("bind {}: {e}", o.addr))?;
    let addr = gateway.local_addr();
    println!("pfm-reorder gateway listening on {addr}");
    if let Some(dir) = &o.persist_dir {
        println!("(warm-start store: {dir})");
    }
    println!("(stop with: pfm-reorder admin shutdown --addr {addr})");
    // blocks until an admin `shutdown` frame arrives, then runs the
    // graceful drain: every accepted request is answered before exit
    gateway.serve_until_shutdown();
    println!("gateway shut down cleanly");
    println!("metrics: {}", gateway.metrics().to_json().to_string());
    Ok(())
}

/// Resolve `--addr` to one socket address.
fn resolve_addr(addr: &str) -> Result<SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("bad address `{addr}`: {e}"))?
        .next()
        .ok_or_else(|| format!("address `{addr}` resolved to nothing"))
}

fn cmd_admin(o: &Opts) -> Result<(), String> {
    let name = o.positional.first().map(String::as_str).unwrap_or("metrics");
    // `admin metrics --text` is the Prometheus exposition of the same
    // counters the JSON snapshot carries
    let cmd = if name == "metrics" && o.text {
        AdminCmd::MetricsText
    } else {
        AdminCmd::parse(name).ok_or_else(|| {
            format!(
                "unknown admin command `{name}` \
                 (ping|metrics|throttle|snapshot|trace|shutdown)"
            )
        })?
    };
    let addr = resolve_addr(&o.addr)?;
    let mut client = GatewayClient::connect_timeout(&addr, Duration::from_secs(5))
        .map_err(|e| format!("connect {addr}: {e} (is `pfm-reorder serve` running?)"))?;
    // admin replies are cheap; a wedged gateway should fail the CLI fast
    let timeout = Duration::from_millis(o.timeout_ms.unwrap_or(10_000));
    client.set_io_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    println!("{}", client.admin(cmd)?);
    Ok(())
}

fn cmd_remote(o: &Opts) -> Result<(), String> {
    let seed = o.seed.unwrap_or(42);
    let (name, a) = match (&o.gen, o.positional.first()) {
        (Some(spec), _) => parse_gen(spec, seed)?,
        (None, Some(path)) => {
            (path.clone(), read_matrix_market(path).map_err(|e| e.to_string())?)
        }
        (None, None) => {
            return Err("usage: pfm-reorder remote <file.mtx> | --gen <class:n>".into())
        }
    };
    let method = parse_method(o.method.as_deref().unwrap_or("amd"))?;
    let n = a.nrows();
    let req = WireRequest {
        id: seed,
        method,
        seed,
        eval_fill: true,
        factor_kind: None,
        opt_budget: None,
        factor_threads: o.factor_threads,
        matrix: a,
    };
    let addr = resolve_addr(&o.addr)?;
    let mut client = GatewayClient::connect_timeout(&addr, Duration::from_secs(5))
        .map_err(|e| format!("connect {addr}: {e} (is `pfm-reorder serve` running?)"))?;
    // a reorder can legitimately take a while on big matrices — default
    // generously, but never hang forever on a dead gateway
    let timeout = Duration::from_millis(o.timeout_ms.unwrap_or(60_000));
    client.set_io_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    match client.request(&req)? {
        Reply::Result(res) => {
            check_permutation(&res.order)?;
            if o.json {
                let stages: Vec<Json> = res
                    .stages
                    .iter()
                    .map(|(stage, secs)| {
                        Json::obj().set("stage", stage.as_str()).set("ms", secs * 1e3)
                    })
                    .collect();
                let doc = Json::obj()
                    .set("matrix", name.as_str())
                    .set("n", n)
                    .set("method", res.method.as_str())
                    .set(
                        "provenance",
                        res.provenance.as_deref().map(Json::from).unwrap_or(Json::Null),
                    )
                    .set("latency_ms", res.latency * 1e3)
                    .set("fill_ratio", res.fill_ratio.map(Json::Num).unwrap_or(Json::Null))
                    .set("batch_size", res.batch_size)
                    .set("stages", Json::Arr(stages));
                println!("{}", doc.to_string());
                return Ok(());
            }
            println!(
                "{name}: n={n} served by {} via {addr} | fill {} | latency {:.1} ms{}",
                res.method,
                res.fill_ratio.map(|f| format!("{f:.3}")).unwrap_or_else(|| "n/a".to_string()),
                res.latency * 1e3,
                res.provenance.map(|p| format!(" | provenance {p}")).unwrap_or_default(),
            );
            Ok(())
        }
        Reply::Busy { reason, .. } => Err(format!("gateway busy: {}", reason.label())),
        Reply::Error { message, .. } => Err(message),
        Reply::Admin(_) => Err("unexpected admin reply to a reorder request".into()),
    }
}

fn cmd_replay(o: &Opts) -> Result<(), String> {
    let trace = o.gen.as_deref().unwrap_or("mixed");
    let kind = TraceKind::parse(trace)
        .ok_or_else(|| format!("unknown trace `{trace}` (mixed|warm|coldstorm)"))?;
    let spec = ReplaySpec {
        kind,
        speed: o.speed.unwrap_or(1.0),
        requests: o.requests.unwrap_or(200),
        seed: o.seed.unwrap_or(42),
    };
    let rules: Vec<SloRule> =
        o.slo.iter().map(|s| SloRule::parse(s)).collect::<Result<_, _>>()?;
    let report = if o.inproc {
        let persist =
            o.persist_dir.as_ref().map(|d| pfm_reorder::persist::PersistConfig::new(d));
        let service = ReorderService::start(ServiceConfig {
            artifact_dir: o.artifacts.clone(),
            persist,
            ..Default::default()
        });
        let rep = replay::run_inproc(&service, &spec);
        service.shutdown();
        rep
    } else {
        let addr = resolve_addr(&o.addr)?;
        let timeout = Duration::from_millis(o.timeout_ms.unwrap_or(60_000));
        replay::run_gateway(addr, &spec, o.conns.unwrap_or(4), timeout)?
    };
    let outcomes = report.evaluate(&rules);
    print!("{}", report.render(&outcomes));
    let bench = o.bench.clone().unwrap_or_else(|| "BENCH_serving.json".to_string());
    replay::write_bench(&bench, &report.to_json(&outcomes))?;
    println!("(bench -> {bench})");
    // nonzero exit on SLO violations / errors / a warm path that is not
    // actually faster — this is the CI regression gate
    report.check(&outcomes, o.check_warm)
}

fn cmd_demo(o: &Opts) -> Result<(), String> {
    let service = ReorderService::start(ServiceConfig {
        artifact_dir: o.artifacts.clone(),
        ..Default::default()
    });
    // demo load: a burst of mixed requests over all classes
    let sizes = o.sizes.clone().unwrap_or_else(|| vec![100, 200, 400]);
    let seed = o.seed.unwrap_or(7);
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    let mut count = 0u64;
    for &n in &sizes {
        for &class in &ProblemClass::ALL {
            let a = class.generate(n, seed ^ n as u64);
            for &m in &[
                Method::Learned(Learned::Pfm),
                Method::Classical(Classical::Amd),
            ] {
                rxs.push(service.submit(a.clone(), m, seed + count));
                count += 1;
            }
        }
    }
    for rx in rxs {
        let resp = rx.recv().map_err(|e| e.to_string())?;
        resp.result?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {count} requests in {wall:.2}s ({:.1} req/s)",
        count as f64 / wall
    );
    println!("metrics: {}", service.metrics.to_json().to_string());
    Ok(())
}
