//! Request/response types of the reordering service.

use std::sync::mpsc;
use std::time::Instant;

use crate::factor::FactorKind;
use crate::obs::trace::{Span, StageLog};
use crate::order::Classical;
use crate::pfm::OptBudget;
use crate::runtime::{Learned, Provenance};
use crate::sparse::Csr;

/// Any ordering method the service can route.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Classical(Classical),
    Learned(Learned),
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Classical(c) => c.label(),
            Method::Learned(l) => l.label(),
        }
    }

    /// Parse a method from its table label (case-insensitive, plus the
    /// aliases the CLI documents). The label strings themselves live in
    /// `Classical::label` / `Learned::label` — this is the single other
    /// place that knows how to go back.
    pub fn from_label(s: &str) -> Option<Method> {
        if let Some(c) = Classical::from_label(s) {
            return Some(Method::Classical(c));
        }
        Learned::from_label(s).map(Method::Learned)
    }

    /// All methods of the paper's Table 2 (8 rows).
    pub fn table2() -> Vec<Method> {
        let mut v = vec![
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
            Method::Classical(Classical::Metis),
            Method::Classical(Classical::Fiedler),
        ];
        v.extend(Learned::TABLE2.iter().map(|&l| Method::Learned(l)));
        v
    }

    /// Methods evaluated on the unsymmetric (LU) suite: the pattern-based
    /// classical orderings. Fiedler is excluded — its Lanczos iteration
    /// assumes symmetric edge weights — and the learned methods are
    /// trained on SPD inputs only.
    pub fn unsymmetric() -> Vec<Method> {
        vec![
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Rcm),
            Method::Classical(Classical::Amd),
            Method::Classical(Classical::Metis),
        ]
    }
}

/// Why a non-blocking submission was refused. The gateway maps
/// `Saturated` to an explicit `Busy` frame (backpressure is always
/// answered, never a silent drop) and `ShutDown` to an error frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrySubmitError {
    /// The bounded submission queue is full right now — retry later.
    Saturated,
    /// The service's dispatcher is gone; no request will ever be served.
    ShutDown,
}

impl std::fmt::Display for TrySubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrySubmitError::Saturated => write!(f, "service saturated (bounded queue full)"),
            TrySubmitError::ShutDown => write!(f, "service shut down"),
        }
    }
}

/// A reorder request submitted to the coordinator.
pub struct ReorderRequest {
    pub id: u64,
    pub matrix: Csr,
    pub method: Method,
    pub seed: u64,
    /// also evaluate the fill ratio of the computed ordering (served from
    /// the worker's pattern-keyed symbolic cache in the steady state)
    pub eval_fill: bool,
    /// which factorization the fill evaluation must run: `None` lets the
    /// evaluating worker detect it from matrix symmetry (so plain submits
    /// pay nothing), `Some(..)` pins it. Either way fill is measured on
    /// the factorization the matrix actually calls for, not on a
    /// Cholesky proxy.
    pub factor_kind: Option<FactorKind>,
    /// budget for the native PFM optimizer when a learned request takes
    /// that path: `None` uses the service's configured serving budget, so
    /// serving latency stays bounded either way.
    pub opt_budget: Option<OptBudget>,
    /// parallel-factorization width for this request's native-optimizer
    /// path: `None` uses the service's configured `factor_threads`.
    /// Composed with the probe-pool width so their product never
    /// oversubscribes the machine (`util::sync::composed_threads`).
    pub factor_threads: Option<usize>,
    pub submitted: Instant,
    /// stage spans collected along the serving path — started by whoever
    /// accepted the request (gateway frame receipt or in-process submit)
    /// and appended to by the worker that serves it
    pub stages: StageLog,
    pub respond: mpsc::Sender<ReorderResponse>,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct ReorderResponse {
    pub id: u64,
    pub result: Result<ReorderResult, String>,
}

/// A successful ordering with provenance + timing.
#[derive(Clone, Debug)]
pub struct ReorderResult {
    pub order: Vec<usize>,
    pub method: &'static str,
    pub provenance: Option<Provenance>,
    /// queue wait + compute, seconds
    pub latency: f64,
    /// network batch size this request was served in (learned methods)
    pub batch_size: usize,
    /// fill ratio of the ordering (only when requested via `eval_fill`);
    /// Cholesky: fill-ins / nnz(A); LU: nnz(L+U) / nnz(A)
    pub fill_ratio: Option<f64>,
    /// factorization kind the fill evaluation ran ("cholesky" | "lu");
    /// `None` when no fill evaluation was requested
    pub factor_kind: Option<&'static str>,
    /// ADMM outer iterations the native PFM optimizer ran (0 for
    /// classical / network / fallback orderings)
    pub opt_iters: usize,
    /// probe-pool width the native optimizer's refinement ran with (0 when
    /// the native optimizer did not run; quality-neutral absent an
    /// expiring wall-clock deadline — see `pfm::probes`)
    pub probe_threads: usize,
    /// parallel-factorization width the request ran with (0 when the
    /// native optimizer did not run; bit-identical factors at any width —
    /// see `factor::sched`)
    pub factor_threads: usize,
    /// intermediate V-cycle levels the native optimizer refined (0 unless
    /// the multilevel path engaged with a per-level budget)
    pub levels_refined: usize,
    /// per-stage breakdown of where this request spent its time (see
    /// `obs::trace::Stage`); the sum of span durations is ≤ `latency`
    pub stages: Vec<Span>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_eight_methods() {
        let methods = Method::table2();
        assert_eq!(methods.len(), 8);
        let labels: Vec<_> = methods.iter().map(|m| m.label()).collect();
        for expect in ["Natural", "AMD", "Metis", "Fiedler", "S_e", "GPCE", "UDNO", "PFM"] {
            assert!(labels.contains(&expect), "{expect} missing from {labels:?}");
        }
    }

    #[test]
    fn labels_roundtrip_through_from_label() {
        for m in Method::table2().into_iter().chain(Method::unsymmetric()) {
            assert_eq!(Method::from_label(m.label()), Some(m), "{}", m.label());
            assert_eq!(
                Method::from_label(&m.label().to_ascii_lowercase()),
                Some(m),
                "{} (lowercase)",
                m.label()
            );
        }
        assert_eq!(Method::from_label("nope"), None);
    }
}
