//! Request/response types of the reordering service.

use std::sync::mpsc;
use std::time::Instant;

use crate::order::Classical;
use crate::runtime::{Learned, Provenance};
use crate::sparse::Csr;

/// Any ordering method the service can route.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Classical(Classical),
    Learned(Learned),
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Classical(c) => c.label(),
            Method::Learned(l) => l.label(),
        }
    }

    /// All methods of the paper's Table 2 (8 rows).
    pub fn table2() -> Vec<Method> {
        let mut v = vec![
            Method::Classical(Classical::Natural),
            Method::Classical(Classical::Amd),
            Method::Classical(Classical::Metis),
            Method::Classical(Classical::Fiedler),
        ];
        v.extend(Learned::TABLE2.iter().map(|&l| Method::Learned(l)));
        v
    }
}

/// A reorder request submitted to the coordinator.
pub struct ReorderRequest {
    pub id: u64,
    pub matrix: Csr,
    pub method: Method,
    pub seed: u64,
    /// also evaluate the fill ratio of the computed ordering (served from
    /// the worker's pattern-keyed symbolic cache in the steady state)
    pub eval_fill: bool,
    pub submitted: Instant,
    pub respond: mpsc::Sender<ReorderResponse>,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct ReorderResponse {
    pub id: u64,
    pub result: Result<ReorderResult, String>,
}

/// A successful ordering with provenance + timing.
#[derive(Clone, Debug)]
pub struct ReorderResult {
    pub order: Vec<usize>,
    pub method: &'static str,
    pub provenance: Option<Provenance>,
    /// queue wait + compute, seconds
    pub latency: f64,
    /// network batch size this request was served in (learned methods)
    pub batch_size: usize,
    /// fill ratio of the ordering (only when requested via `eval_fill`)
    pub fill_ratio: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_eight_methods() {
        let methods = Method::table2();
        assert_eq!(methods.len(), 8);
        let labels: Vec<_> = methods.iter().map(|m| m.label()).collect();
        for expect in ["Natural", "AMD", "Metis", "Fiedler", "S_e", "GPCE", "UDNO", "PFM"] {
            assert!(labels.contains(&expect), "{expect} missing from {labels:?}");
        }
    }
}
