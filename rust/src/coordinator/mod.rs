//! L3 coordinator: an async reordering service with a request router,
//! classical-ordering worker pool, and a bucket-batched PJRT executor for
//! the learned methods. See DESIGN.md §Coordinator.

pub mod metrics;
pub mod request;
pub mod service;

pub use metrics::{BusyKind, Metrics};
pub use request::{Method, ReorderRequest, ReorderResponse, ReorderResult, TrySubmitError};
pub use service::{ReorderService, ServiceConfig};
