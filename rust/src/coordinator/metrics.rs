//! Service metrics: counters and latency histograms, JSON-exportable.
//! Lock-coarse (one mutex) — the coordinator serves ordering requests, not
//! packets; contention is negligible next to the work per request. The
//! mutex is taken through `lock_unpoisoned`, so a panic inside any holder
//! (worker, network thread, gateway connection) can never make the metrics
//! sink itself start panicking.
//!
//! Memory is O(1) in request count: latencies go into fixed-bucket
//! [`Histogram`]s (one per method plus one for queue wait), batches into
//! a running sum/count, and completed request traces into a bounded
//! [`TraceRing`]. Nothing here grows per sample — asserted by
//! `memory_is_bounded_in_request_count` below.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::hist::Histogram;
use crate::obs::trace::{RequestTrace, TraceRing};
use crate::runtime::Provenance;
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// Why the gateway answered a request with a `Busy` frame instead of a
/// result: the service's bounded queue was full, or the client exceeded
/// its token bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BusyKind {
    QueueFull,
    RateLimited,
}

#[derive(Default)]
struct Inner {
    /// per-method latency histograms (seconds) — fixed memory per method
    latencies: HashMap<&'static str, Histogram>,
    /// submit → start-of-compute wait, separate from service time so
    /// queue saturation and slow optimization are distinguishable
    queue_wait: Histogram,
    /// per-method request counts
    completed: HashMap<&'static str, usize>,
    errors: usize,
    /// network-executor batch occupancy, as a running sum/count
    batch_sum: usize,
    batch_count: usize,
    fallbacks: usize,
    /// orderings served by the native in-Rust PFM optimizer — with
    /// `fallbacks` this makes spectral-fallback rows distinguishable from
    /// native-PFM rows in the exported JSON
    native_opts: usize,
    /// symbolic-cache outcomes for fill evaluations (serving steady state:
    /// hits ≫ misses)
    symbolic_hits: usize,
    symbolic_misses: usize,
    /// coarsening + symbolic analyses *saved* by the network thread's
    /// pattern-keyed batching (one per same-pattern request beyond the
    /// first in a drain)
    shared_analyses: usize,
    /// V-cycle intermediate levels refined by native-PFM requests (total)
    levels_refined: usize,
    /// native-PFM objective evaluations served by the incremental suffix
    /// re-walk (total across requests; `pfm::incremental`)
    incremental_probes: usize,
    /// native-PFM objective evaluations that ran a full symbolic/numeric
    /// pass (total across requests)
    full_probes: usize,
    /// probe-pool width the service runs native-PFM refinement with
    probe_threads: usize,
    /// parallel-factorization width the service runs with (effective —
    /// clamped against the machine at startup)
    factor_threads: usize,
    /// requests whose serving thread panicked (caught and answered with an
    /// error — the request is lost, the thread is not)
    worker_panics: usize,
    /// submissions currently sitting in the bounded queue (enqueued minus
    /// dispatched — an approximate live gauge, exported for admin)
    queue_depth: usize,
    /// TCP gateway counters (zero unless a gateway fronts this service)
    gw_connections: usize,
    gw_frames_rx: usize,
    gw_frames_tx: usize,
    gw_busy_queue: usize,
    gw_busy_throttled: usize,
    gw_malformed: usize,
    gw_admin: usize,
    /// warm-start persistence counters (zero unless `ServiceConfig::persist`
    /// is set)
    p_replayed: usize,
    p_warm_hits: usize,
    p_wal_appends: usize,
    p_snapshots: usize,
    p_torn_tails: usize,
    p_quarantined: usize,
    p_rejected: usize,
    p_errors: usize,
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// bounded ring of recent request traces (`admin trace`)
    traces: TraceRing,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request. `provenance` is `None` for classical
    /// methods; learned methods report where their ordering came from so
    /// the fallback / native-optimizer counters stay exact.
    pub fn record(
        &self,
        method: &'static str,
        latency: f64,
        batch: usize,
        provenance: Option<Provenance>,
    ) {
        let mut m = lock_unpoisoned(&self.inner);
        m.latencies.entry(method).or_default().record(latency);
        *m.completed.entry(method).or_default() += 1;
        if batch > 0 {
            m.batch_sum += batch;
            m.batch_count += 1;
        }
        match provenance {
            Some(Provenance::SpectralFallback) => m.fallbacks += 1,
            Some(Provenance::NativeOptimizer) => m.native_opts += 1,
            Some(Provenance::WarmStore) => m.p_warm_hits += 1,
            Some(Provenance::Network) | None => {}
        }
    }

    /// Record how long a request sat between submission and the start of
    /// its compute (dispatcher hop + pool channel included).
    pub fn record_queue_wait(&self, secs: f64) {
        lock_unpoisoned(&self.inner).queue_wait.record(secs);
    }

    pub fn record_error(&self) {
        lock_unpoisoned(&self.inner).errors += 1;
    }

    pub fn total_completed(&self) -> usize {
        lock_unpoisoned(&self.inner).completed.values().sum()
    }

    pub fn errors(&self) -> usize {
        lock_unpoisoned(&self.inner).errors
    }

    pub fn fallbacks(&self) -> usize {
        lock_unpoisoned(&self.inner).fallbacks
    }

    /// Orderings served by the native PFM optimizer.
    pub fn native_optimized(&self) -> usize {
        lock_unpoisoned(&self.inner).native_opts
    }

    /// Record one symbolic-cache lookup outcome (fill evaluation path).
    pub fn record_symbolic(&self, hit: bool) {
        let mut m = lock_unpoisoned(&self.inner);
        if hit {
            m.symbolic_hits += 1;
        } else {
            m.symbolic_misses += 1;
        }
    }

    pub fn symbolic_hits(&self) -> usize {
        lock_unpoisoned(&self.inner).symbolic_hits
    }

    pub fn symbolic_misses(&self) -> usize {
        lock_unpoisoned(&self.inner).symbolic_misses
    }

    /// Record analyses saved by pattern-keyed batch sharing (`k` = batch
    /// members beyond the group lead).
    pub fn record_shared_analyses(&self, k: usize) {
        lock_unpoisoned(&self.inner).shared_analyses += k;
    }

    pub fn shared_analyses(&self) -> usize {
        lock_unpoisoned(&self.inner).shared_analyses
    }

    /// Accumulate the V-cycle levels a native-PFM request refined.
    pub fn record_levels_refined(&self, k: usize) {
        lock_unpoisoned(&self.inner).levels_refined += k;
    }

    pub fn levels_refined(&self) -> usize {
        lock_unpoisoned(&self.inner).levels_refined
    }

    /// Accumulate a native-PFM request's probe split: evaluations served
    /// incrementally vs. by a full pass.
    pub fn record_probe_split(&self, incremental: usize, full: usize) {
        let mut g = lock_unpoisoned(&self.inner);
        g.incremental_probes += incremental;
        g.full_probes += full;
    }

    pub fn incremental_probes(&self) -> usize {
        lock_unpoisoned(&self.inner).incremental_probes
    }

    pub fn full_probes(&self) -> usize {
        lock_unpoisoned(&self.inner).full_probes
    }

    /// Record the service's configured probe-pool width (set once at
    /// startup; exported so the JSON snapshot documents how native-PFM
    /// requests were run).
    pub fn set_probe_threads(&self, threads: usize) {
        lock_unpoisoned(&self.inner).probe_threads = threads;
    }

    pub fn probe_threads(&self) -> usize {
        lock_unpoisoned(&self.inner).probe_threads
    }

    /// Record the service's *effective* parallel-factorization width (set
    /// once at startup, after clamping against the machine).
    pub fn set_factor_threads(&self, threads: usize) {
        lock_unpoisoned(&self.inner).factor_threads = threads;
    }

    pub fn factor_threads(&self) -> usize {
        lock_unpoisoned(&self.inner).factor_threads
    }

    /// Record a caught panic in a serving thread (the request was answered
    /// with an error; the thread kept running).
    pub fn record_worker_panic(&self) {
        lock_unpoisoned(&self.inner).worker_panics += 1;
    }

    pub fn worker_panics(&self) -> usize {
        lock_unpoisoned(&self.inner).worker_panics
    }

    /// A request entered the bounded submission queue.
    pub fn record_enqueued(&self) {
        lock_unpoisoned(&self.inner).queue_depth += 1;
    }

    /// The dispatcher pulled a request off the bounded submission queue.
    pub fn record_dequeued(&self) {
        let mut m = lock_unpoisoned(&self.inner);
        m.queue_depth = m.queue_depth.saturating_sub(1);
    }

    pub fn queue_depth(&self) -> usize {
        lock_unpoisoned(&self.inner).queue_depth
    }

    /// One accepted gateway connection.
    pub fn record_gateway_connection(&self) {
        lock_unpoisoned(&self.inner).gw_connections += 1;
    }

    /// One well-framed gateway frame read off a connection.
    pub fn record_gateway_frame_rx(&self) {
        lock_unpoisoned(&self.inner).gw_frames_rx += 1;
    }

    /// One gateway frame written to a connection.
    pub fn record_gateway_frame_tx(&self) {
        lock_unpoisoned(&self.inner).gw_frames_tx += 1;
    }

    /// One request answered `Busy` instead of being served.
    pub fn record_gateway_busy(&self, kind: BusyKind) {
        let mut m = lock_unpoisoned(&self.inner);
        match kind {
            BusyKind::QueueFull => m.gw_busy_queue += 1,
            BusyKind::RateLimited => m.gw_busy_throttled += 1,
        }
    }

    /// One malformed frame or payload rejected by the gateway codec.
    pub fn record_gateway_malformed(&self) {
        lock_unpoisoned(&self.inner).gw_malformed += 1;
    }

    /// One admin-protocol request served.
    pub fn record_gateway_admin(&self) {
        lock_unpoisoned(&self.inner).gw_admin += 1;
    }

    pub fn gateway_connections(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_connections
    }

    pub fn gateway_frames_rx(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_frames_rx
    }

    pub fn gateway_frames_tx(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_frames_tx
    }

    pub fn gateway_busy_queue(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_busy_queue
    }

    pub fn gateway_busy_throttled(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_busy_throttled
    }

    pub fn gateway_malformed(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_malformed
    }

    pub fn gateway_admin(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_admin
    }

    /// Copy what warm-store recovery found into the persist counters
    /// (called once at service startup when persistence is enabled).
    pub fn record_recovery(&self, stats: &crate::persist::RecoveryStats) {
        let mut m = lock_unpoisoned(&self.inner);
        m.p_replayed += stats.replayed;
        m.p_torn_tails += stats.torn_tails;
        m.p_quarantined += stats.quarantined;
        m.p_rejected += stats.rejected;
        m.p_errors += stats.errors;
    }

    /// One record durably appended to the warm-store WAL.
    pub fn record_wal_append(&self) {
        lock_unpoisoned(&self.inner).p_wal_appends += 1;
    }

    /// One warm-store snapshot written (auto or admin-triggered).
    pub fn record_persist_snapshot(&self) {
        lock_unpoisoned(&self.inner).p_snapshots += 1;
    }

    /// One persistence I/O failure absorbed (the store degraded to
    /// memory-only instead of crashing — the counter is the proof).
    pub fn record_persist_error(&self) {
        lock_unpoisoned(&self.inner).p_errors += 1;
    }

    pub fn persist_replayed(&self) -> usize {
        lock_unpoisoned(&self.inner).p_replayed
    }

    /// Requests short-circuited by the warm-start store.
    pub fn warm_hits(&self) -> usize {
        lock_unpoisoned(&self.inner).p_warm_hits
    }

    pub fn wal_appends(&self) -> usize {
        lock_unpoisoned(&self.inner).p_wal_appends
    }

    pub fn persist_snapshots(&self) -> usize {
        lock_unpoisoned(&self.inner).p_snapshots
    }

    pub fn persist_errors(&self) -> usize {
        lock_unpoisoned(&self.inner).p_errors
    }

    /// Per-method latency histograms, sorted by method label.
    pub fn latency_histograms(&self) -> Vec<(&'static str, Histogram)> {
        let m = lock_unpoisoned(&self.inner);
        let mut out: Vec<(&'static str, Histogram)> =
            m.latencies.iter().map(|(k, h)| (*k, h.clone())).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Per-method completion counts, sorted by method label.
    pub fn completed_by_method(&self) -> Vec<(&'static str, usize)> {
        let m = lock_unpoisoned(&self.inner);
        let mut out: Vec<(&'static str, usize)> =
            m.completed.iter().map(|(k, v)| (*k, *v)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// The submit→compute-start wait histogram.
    pub fn queue_wait_histogram(&self) -> Histogram {
        lock_unpoisoned(&self.inner).queue_wait.clone()
    }

    /// Mean network batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        let m = lock_unpoisoned(&self.inner);
        if m.batch_count == 0 {
            return 0.0;
        }
        m.batch_sum as f64 / m.batch_count as f64
    }

    /// Re-arm the trace ring from `ServiceConfig` (capacity + slow
    /// threshold), applied once at service start.
    pub fn configure_traces(&self, capacity: usize, slow_threshold: Duration) {
        self.traces.configure(capacity, slow_threshold);
    }

    /// Fold one completed request's stage spans into the trace ring.
    pub fn record_trace(&self, trace: RequestTrace) {
        self.traces.push(trace);
    }

    /// Late-append the gateway's encode span to an already-recorded
    /// trace (looked up by coordinator request id).
    pub fn annotate_trace_encode(&self, id: u64, secs: f64) {
        self.traces.annotate_encode(id, secs);
    }

    /// Recent traces, newest first (tests, debugging).
    pub fn recent_traces(&self) -> Vec<RequestTrace> {
        self.traces.recent()
    }

    /// The `admin trace` payload.
    pub fn traces_json(&self) -> Json {
        self.traces.to_json()
    }

    /// Prometheus text exposition of counters + histograms
    /// (`admin metrics --text`).
    pub fn prometheus_text(&self) -> String {
        crate::obs::export::prometheus_text(self)
    }

    /// Bytes of state whose size could conceivably scale with request
    /// count: the fixed-bucket histograms, the batch accumulators, and
    /// the bounded trace ring. The bounded-memory test records tens of
    /// thousands of samples and asserts this number stops moving.
    pub fn sample_state_bytes(&self) -> usize {
        let m = lock_unpoisoned(&self.inner);
        let hist = std::mem::size_of::<Histogram>();
        m.latencies.len() * hist                    // per-method histograms
            + hist                                  // queue_wait
            + 2 * std::mem::size_of::<usize>()      // batch sum/count
            + self.traces.state_bytes()             // bounded ring
    }

    /// Export everything as JSON.
    pub fn to_json(&self) -> Json {
        let mut per_method = Json::obj();
        for (name, h) in self.latency_histograms() {
            per_method = per_method.set(name, h.to_json());
        }
        let (gateway, persist, queue_wait) = {
            let m = lock_unpoisoned(&self.inner);
            let gateway = Json::obj()
                .set("connections", m.gw_connections)
                .set("frames_rx", m.gw_frames_rx)
                .set("frames_tx", m.gw_frames_tx)
                .set("busy_queue_full", m.gw_busy_queue)
                .set("busy_rate_limited", m.gw_busy_throttled)
                .set("malformed_frames", m.gw_malformed)
                .set("admin_requests", m.gw_admin);
            let persist = Json::obj()
                .set("replayed", m.p_replayed)
                .set("warm_hits", m.p_warm_hits)
                .set("wal_appends", m.p_wal_appends)
                .set("snapshots", m.p_snapshots)
                .set("torn_tails_recovered", m.p_torn_tails)
                .set("segments_quarantined", m.p_quarantined)
                .set("records_rejected", m.p_rejected)
                .set("persist_errors", m.p_errors);
            (gateway, persist, m.queue_wait.to_json())
        };
        Json::obj()
            .set("completed", self.total_completed())
            .set("errors", self.errors())
            .set("worker_panics", self.worker_panics())
            .set("queue_depth", self.queue_depth())
            .set("fallbacks", self.fallbacks())
            .set("native_optimizer", self.native_optimized())
            .set("mean_batch", self.mean_batch())
            .set("symbolic_cache_hits", self.symbolic_hits())
            .set("symbolic_cache_misses", self.symbolic_misses())
            .set("shared_analyses", self.shared_analyses())
            .set("levels_refined", self.levels_refined())
            .set("incremental_probes", self.incremental_probes())
            .set("full_probes", self.full_probes())
            .set("probe_threads", self.probe_threads())
            .set("factor_threads", self.factor_threads())
            .set("gateway", gateway)
            .set("persist", persist)
            .set("queue_wait", queue_wait)
            .set("latency", per_method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Stage, StageLog};

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record("PFM", 0.01, 4, Some(Provenance::NativeOptimizer));
        m.record("PFM", 0.02, 4, Some(Provenance::Network));
        m.record("AMD", 0.005, 0, None);
        m.record("S_e", 0.015, 2, Some(Provenance::SpectralFallback));
        m.record_error();

        assert_eq!(m.total_completed(), 4);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.fallbacks(), 1);
        assert_eq!(m.native_optimized(), 1);
        assert!((m.mean_batch() - 10.0 / 3.0).abs() < 1e-9);
        let hists = m.latency_histograms();
        assert_eq!(hists.len(), 3);
        let pfm = &hists.iter().find(|(k, _)| *k == "PFM").unwrap().1;
        assert_eq!(pfm.count(), 2);
        assert!((pfm.max() - 0.02).abs() < 1e-12);
        let json = m.to_json().to_string();
        // seed-era keys stay; the histogram summary adds the quantile ladder
        assert!(json.contains("\"completed\":4"));
        assert!(json.contains("\"native_optimizer\":1"));
        assert!(json.contains("PFM"));
        assert!(json.contains("\"mean_s\":"));
        assert!(json.contains("\"p95_s\":"));
        assert!(json.contains("\"p99_s\":"));
        assert!(json.contains("\"p999_s\":"));
        assert!(json.contains("\"max_s\":"));
        assert!(json.contains("\"queue_wait\":"));
    }

    #[test]
    fn queue_wait_is_tracked_separately_from_service_time() {
        let m = Metrics::new();
        m.record("PFM", 0.5, 0, None); // slow service…
        m.record_queue_wait(0.001); // …but an empty queue
        m.record_queue_wait(0.002);
        let qw = m.queue_wait_histogram();
        assert_eq!(qw.count(), 2);
        assert!(qw.max() < 0.01);
        let pfm = &m.latency_histograms()[0].1;
        assert!(pfm.max() >= 0.5);
    }

    #[test]
    fn memory_is_bounded_in_request_count() {
        let m = Metrics::new();
        m.configure_traces(16, Duration::from_millis(500));
        let methods = ["PFM", "AMD", "RCM"];
        let warm = |m: &Metrics, rounds: usize, salt: u64| {
            for i in 0..rounds {
                let method = methods[i % methods.len()];
                m.record(method, 1e-4 * ((i as u64 + salt) % 977) as f64, i % 5, None);
                m.record_queue_wait(1e-5 * (i % 131) as f64);
                let mut log = StageLog::new();
                log.add(Stage::QueueWait, 1e-5);
                log.add(Stage::Order, 1e-4);
                m.record_trace(log.finish(i as u64, method));
            }
        };
        warm(&m, 1_000, 1);
        let after_1k = m.sample_state_bytes();
        warm(&m, 50_000, 7);
        let after_51k = m.sample_state_bytes();
        assert_eq!(
            after_1k, after_51k,
            "metrics state grew with request count: {after_1k} -> {after_51k} bytes"
        );
        // sanity: everything was actually recorded
        assert_eq!(m.total_completed(), 51_000);
        assert_eq!(m.queue_wait_histogram().count(), 51_000);
        assert_eq!(m.recent_traces().len(), 16);
    }

    #[test]
    fn batching_and_vcycle_counters_export() {
        let m = Metrics::new();
        m.set_probe_threads(4);
        m.set_factor_threads(2);
        m.record_shared_analyses(3);
        m.record_shared_analyses(2);
        m.record_levels_refined(2);
        m.record_levels_refined(0);
        m.record_levels_refined(5);
        m.record_probe_split(40, 9);
        m.record_probe_split(0, 12);
        assert_eq!(m.shared_analyses(), 5);
        assert_eq!(m.levels_refined(), 7);
        assert_eq!(m.incremental_probes(), 40);
        assert_eq!(m.full_probes(), 21);
        assert_eq!(m.probe_threads(), 4);
        assert_eq!(m.factor_threads(), 2);
        let json = m.to_json().to_string();
        assert!(json.contains("\"shared_analyses\":5"));
        assert!(json.contains("\"levels_refined\":7"));
        assert!(json.contains("\"incremental_probes\":40"));
        assert!(json.contains("\"full_probes\":21"));
        assert!(json.contains("\"probe_threads\":4"));
        assert!(json.contains("\"factor_threads\":2"));
    }

    #[test]
    fn gateway_and_panic_counters_export() {
        let m = Metrics::new();
        m.record_worker_panic();
        m.record_enqueued();
        m.record_enqueued();
        m.record_dequeued();
        m.record_gateway_connection();
        m.record_gateway_frame_rx();
        m.record_gateway_frame_rx();
        m.record_gateway_frame_tx();
        m.record_gateway_busy(BusyKind::QueueFull);
        m.record_gateway_busy(BusyKind::RateLimited);
        m.record_gateway_busy(BusyKind::RateLimited);
        m.record_gateway_malformed();
        m.record_gateway_admin();
        assert_eq!(m.worker_panics(), 1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.gateway_connections(), 1);
        assert_eq!(m.gateway_frames_rx(), 2);
        assert_eq!(m.gateway_frames_tx(), 1);
        assert_eq!(m.gateway_busy_queue(), 1);
        assert_eq!(m.gateway_busy_throttled(), 2);
        assert_eq!(m.gateway_malformed(), 1);
        assert_eq!(m.gateway_admin(), 1);
        let json = m.to_json().to_string();
        assert!(json.contains("\"worker_panics\":1"));
        assert!(json.contains("\"queue_depth\":1"));
        assert!(json.contains("\"busy_queue_full\":1"));
        assert!(json.contains("\"busy_rate_limited\":2"));
        assert!(json.contains("\"malformed_frames\":1"));
        assert!(json.contains("\"admin_requests\":1"));
    }

    #[test]
    fn persist_counters_export() {
        let m = Metrics::new();
        m.record_recovery(&crate::persist::RecoveryStats {
            replayed: 3,
            torn_tails: 1,
            quarantined: 2,
            rejected: 1,
            errors: 0,
        });
        m.record("PFM", 0.001, 0, Some(Provenance::WarmStore));
        m.record_wal_append();
        m.record_wal_append();
        m.record_persist_snapshot();
        m.record_persist_error();
        assert_eq!(m.persist_replayed(), 3);
        assert_eq!(m.warm_hits(), 1);
        assert_eq!(m.wal_appends(), 2);
        assert_eq!(m.persist_snapshots(), 1);
        assert_eq!(m.persist_errors(), 1);
        // a warm hit is a completion, not a fallback or a native run
        assert_eq!(m.total_completed(), 1);
        assert_eq!(m.native_optimized(), 0);
        assert_eq!(m.fallbacks(), 0);
        let json = m.to_json().to_string();
        assert!(json.contains("\"warm_hits\":1"));
        assert!(json.contains("\"replayed\":3"));
        assert!(json.contains("\"wal_appends\":2"));
        assert!(json.contains("\"snapshots\":1"));
        assert!(json.contains("\"torn_tails_recovered\":1"));
        assert!(json.contains("\"segments_quarantined\":2"));
        assert!(json.contains("\"records_rejected\":1"));
        assert!(json.contains("\"persist_errors\":1"));
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::new();
        m.record_dequeued();
        assert_eq!(m.queue_depth(), 0);
    }

    #[test]
    fn trace_ring_and_text_exposition_surface() {
        let m = Metrics::new();
        m.record("AMD", 0.004, 0, None);
        m.record_queue_wait(0.0001);
        let mut log = StageLog::new();
        log.add(Stage::QueueWait, 0.0001);
        log.add(Stage::Order, 0.004);
        m.record_trace(log.finish(42, "AMD"));
        m.annotate_trace_encode(42, 0.0002);
        let tj = m.traces_json().to_string();
        assert!(tj.contains("\"id\":42"));
        assert!(tj.contains("\"queue_wait\""));
        assert!(tj.contains("\"encode\""));
        let text = m.prometheus_text();
        assert!(text.contains("pfm_request_latency_seconds_bucket"));
        assert!(text.contains("pfm_queue_wait_seconds_count 1"));
    }
}
