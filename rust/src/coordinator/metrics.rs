//! Service metrics: counters and latency histograms, JSON-exportable.
//! Lock-coarse (one mutex) — the coordinator serves ordering requests, not
//! packets; contention is negligible next to the work per request.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::runtime::Provenance;
use crate::util::json::Json;
use crate::util::timer::Stats;

#[derive(Default)]
struct Inner {
    /// per-method latency samples (seconds)
    latencies: HashMap<&'static str, Vec<f64>>,
    /// per-method request counts
    completed: HashMap<&'static str, usize>,
    errors: usize,
    /// batch sizes observed by the network executor
    batch_sizes: Vec<usize>,
    fallbacks: usize,
    /// orderings served by the native in-Rust PFM optimizer — with
    /// `fallbacks` this makes spectral-fallback rows distinguishable from
    /// native-PFM rows in the exported JSON
    native_opts: usize,
    /// symbolic-cache outcomes for fill evaluations (serving steady state:
    /// hits ≫ misses)
    symbolic_hits: usize,
    symbolic_misses: usize,
    /// coarsening + symbolic analyses *saved* by the network thread's
    /// pattern-keyed batching (one per same-pattern request beyond the
    /// first in a drain)
    shared_analyses: usize,
    /// V-cycle intermediate levels refined by native-PFM requests (total)
    levels_refined: usize,
    /// probe-pool width the service runs native-PFM refinement with
    probe_threads: usize,
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request. `provenance` is `None` for classical
    /// methods; learned methods report where their ordering came from so
    /// the fallback / native-optimizer counters stay exact.
    pub fn record(
        &self,
        method: &'static str,
        latency: f64,
        batch: usize,
        provenance: Option<Provenance>,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.latencies.entry(method).or_default().push(latency);
        *m.completed.entry(method).or_default() += 1;
        if batch > 0 {
            m.batch_sizes.push(batch);
        }
        match provenance {
            Some(Provenance::SpectralFallback) => m.fallbacks += 1,
            Some(Provenance::NativeOptimizer) => m.native_opts += 1,
            Some(Provenance::Network) | None => {}
        }
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn total_completed(&self) -> usize {
        self.inner.lock().unwrap().completed.values().sum()
    }

    pub fn errors(&self) -> usize {
        self.inner.lock().unwrap().errors
    }

    pub fn fallbacks(&self) -> usize {
        self.inner.lock().unwrap().fallbacks
    }

    /// Orderings served by the native PFM optimizer.
    pub fn native_optimized(&self) -> usize {
        self.inner.lock().unwrap().native_opts
    }

    /// Record one symbolic-cache lookup outcome (fill evaluation path).
    pub fn record_symbolic(&self, hit: bool) {
        let mut m = self.inner.lock().unwrap();
        if hit {
            m.symbolic_hits += 1;
        } else {
            m.symbolic_misses += 1;
        }
    }

    pub fn symbolic_hits(&self) -> usize {
        self.inner.lock().unwrap().symbolic_hits
    }

    pub fn symbolic_misses(&self) -> usize {
        self.inner.lock().unwrap().symbolic_misses
    }

    /// Record analyses saved by pattern-keyed batch sharing (`k` = batch
    /// members beyond the group lead).
    pub fn record_shared_analyses(&self, k: usize) {
        self.inner.lock().unwrap().shared_analyses += k;
    }

    pub fn shared_analyses(&self) -> usize {
        self.inner.lock().unwrap().shared_analyses
    }

    /// Accumulate the V-cycle levels a native-PFM request refined.
    pub fn record_levels_refined(&self, k: usize) {
        self.inner.lock().unwrap().levels_refined += k;
    }

    pub fn levels_refined(&self) -> usize {
        self.inner.lock().unwrap().levels_refined
    }

    /// Record the service's configured probe-pool width (set once at
    /// startup; exported so the JSON snapshot documents how native-PFM
    /// requests were run).
    pub fn set_probe_threads(&self, threads: usize) {
        self.inner.lock().unwrap().probe_threads = threads;
    }

    pub fn probe_threads(&self) -> usize {
        self.inner.lock().unwrap().probe_threads
    }

    /// Latency stats per method.
    pub fn latency_stats(&self) -> Vec<(&'static str, Stats)> {
        let m = self.inner.lock().unwrap();
        let mut out: Vec<(&'static str, Stats)> = m
            .latencies
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(k, v)| (*k, Stats::from_samples(v.clone())))
            .collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Mean network batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.batch_sizes.is_empty() {
            return 0.0;
        }
        m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
    }

    /// Export everything as JSON.
    pub fn to_json(&self) -> Json {
        let stats = self.latency_stats();
        let mut per_method = Json::obj();
        for (name, s) in stats {
            per_method = per_method.set(
                name,
                Json::obj()
                    .set("count", s.n)
                    .set("mean_s", s.mean)
                    .set("p95_s", s.p95)
                    .set("max_s", s.max),
            );
        }
        Json::obj()
            .set("completed", self.total_completed())
            .set("errors", self.errors())
            .set("fallbacks", self.fallbacks())
            .set("native_optimizer", self.native_optimized())
            .set("mean_batch", self.mean_batch())
            .set("symbolic_cache_hits", self.symbolic_hits())
            .set("symbolic_cache_misses", self.symbolic_misses())
            .set("shared_analyses", self.shared_analyses())
            .set("levels_refined", self.levels_refined())
            .set("probe_threads", self.probe_threads())
            .set("latency", per_method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record("PFM", 0.01, 4, Some(Provenance::NativeOptimizer));
        m.record("PFM", 0.02, 4, Some(Provenance::Network));
        m.record("AMD", 0.005, 0, None);
        m.record("S_e", 0.015, 2, Some(Provenance::SpectralFallback));
        m.record_error();

        assert_eq!(m.total_completed(), 4);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.fallbacks(), 1);
        assert_eq!(m.native_optimized(), 1);
        assert!((m.mean_batch() - 10.0 / 3.0).abs() < 1e-9);
        let stats = m.latency_stats();
        assert_eq!(stats.len(), 3);
        let json = m.to_json().to_string();
        assert!(json.contains("\"completed\":4"));
        assert!(json.contains("\"native_optimizer\":1"));
        assert!(json.contains("PFM"));
    }

    #[test]
    fn batching_and_vcycle_counters_export() {
        let m = Metrics::new();
        m.set_probe_threads(4);
        m.record_shared_analyses(3);
        m.record_shared_analyses(2);
        m.record_levels_refined(2);
        m.record_levels_refined(0);
        m.record_levels_refined(5);
        assert_eq!(m.shared_analyses(), 5);
        assert_eq!(m.levels_refined(), 7);
        assert_eq!(m.probe_threads(), 4);
        let json = m.to_json().to_string();
        assert!(json.contains("\"shared_analyses\":5"));
        assert!(json.contains("\"levels_refined\":7"));
        assert!(json.contains("\"probe_threads\":4"));
    }
}
