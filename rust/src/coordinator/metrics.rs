//! Service metrics: counters and latency histograms, JSON-exportable.
//! Lock-coarse (one mutex) — the coordinator serves ordering requests, not
//! packets; contention is negligible next to the work per request. The
//! mutex is taken through `lock_unpoisoned`, so a panic inside any holder
//! (worker, network thread, gateway connection) can never make the metrics
//! sink itself start panicking.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::runtime::Provenance;
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;
use crate::util::timer::Stats;

/// Why the gateway answered a request with a `Busy` frame instead of a
/// result: the service's bounded queue was full, or the client exceeded
/// its token bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BusyKind {
    QueueFull,
    RateLimited,
}

#[derive(Default)]
struct Inner {
    /// per-method latency samples (seconds)
    latencies: HashMap<&'static str, Vec<f64>>,
    /// per-method request counts
    completed: HashMap<&'static str, usize>,
    errors: usize,
    /// batch sizes observed by the network executor
    batch_sizes: Vec<usize>,
    fallbacks: usize,
    /// orderings served by the native in-Rust PFM optimizer — with
    /// `fallbacks` this makes spectral-fallback rows distinguishable from
    /// native-PFM rows in the exported JSON
    native_opts: usize,
    /// symbolic-cache outcomes for fill evaluations (serving steady state:
    /// hits ≫ misses)
    symbolic_hits: usize,
    symbolic_misses: usize,
    /// coarsening + symbolic analyses *saved* by the network thread's
    /// pattern-keyed batching (one per same-pattern request beyond the
    /// first in a drain)
    shared_analyses: usize,
    /// V-cycle intermediate levels refined by native-PFM requests (total)
    levels_refined: usize,
    /// probe-pool width the service runs native-PFM refinement with
    probe_threads: usize,
    /// parallel-factorization width the service runs with (effective —
    /// clamped against the machine at startup)
    factor_threads: usize,
    /// requests whose serving thread panicked (caught and answered with an
    /// error — the request is lost, the thread is not)
    worker_panics: usize,
    /// submissions currently sitting in the bounded queue (enqueued minus
    /// dispatched — an approximate live gauge, exported for admin)
    queue_depth: usize,
    /// TCP gateway counters (zero unless a gateway fronts this service)
    gw_connections: usize,
    gw_frames_rx: usize,
    gw_frames_tx: usize,
    gw_busy_queue: usize,
    gw_busy_throttled: usize,
    gw_malformed: usize,
    gw_admin: usize,
    /// warm-start persistence counters (zero unless `ServiceConfig::persist`
    /// is set)
    p_replayed: usize,
    p_warm_hits: usize,
    p_wal_appends: usize,
    p_snapshots: usize,
    p_torn_tails: usize,
    p_quarantined: usize,
    p_rejected: usize,
    p_errors: usize,
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request. `provenance` is `None` for classical
    /// methods; learned methods report where their ordering came from so
    /// the fallback / native-optimizer counters stay exact.
    pub fn record(
        &self,
        method: &'static str,
        latency: f64,
        batch: usize,
        provenance: Option<Provenance>,
    ) {
        let mut m = lock_unpoisoned(&self.inner);
        m.latencies.entry(method).or_default().push(latency);
        *m.completed.entry(method).or_default() += 1;
        if batch > 0 {
            m.batch_sizes.push(batch);
        }
        match provenance {
            Some(Provenance::SpectralFallback) => m.fallbacks += 1,
            Some(Provenance::NativeOptimizer) => m.native_opts += 1,
            Some(Provenance::WarmStore) => m.p_warm_hits += 1,
            Some(Provenance::Network) | None => {}
        }
    }

    pub fn record_error(&self) {
        lock_unpoisoned(&self.inner).errors += 1;
    }

    pub fn total_completed(&self) -> usize {
        lock_unpoisoned(&self.inner).completed.values().sum()
    }

    pub fn errors(&self) -> usize {
        lock_unpoisoned(&self.inner).errors
    }

    pub fn fallbacks(&self) -> usize {
        lock_unpoisoned(&self.inner).fallbacks
    }

    /// Orderings served by the native PFM optimizer.
    pub fn native_optimized(&self) -> usize {
        lock_unpoisoned(&self.inner).native_opts
    }

    /// Record one symbolic-cache lookup outcome (fill evaluation path).
    pub fn record_symbolic(&self, hit: bool) {
        let mut m = lock_unpoisoned(&self.inner);
        if hit {
            m.symbolic_hits += 1;
        } else {
            m.symbolic_misses += 1;
        }
    }

    pub fn symbolic_hits(&self) -> usize {
        lock_unpoisoned(&self.inner).symbolic_hits
    }

    pub fn symbolic_misses(&self) -> usize {
        lock_unpoisoned(&self.inner).symbolic_misses
    }

    /// Record analyses saved by pattern-keyed batch sharing (`k` = batch
    /// members beyond the group lead).
    pub fn record_shared_analyses(&self, k: usize) {
        lock_unpoisoned(&self.inner).shared_analyses += k;
    }

    pub fn shared_analyses(&self) -> usize {
        lock_unpoisoned(&self.inner).shared_analyses
    }

    /// Accumulate the V-cycle levels a native-PFM request refined.
    pub fn record_levels_refined(&self, k: usize) {
        lock_unpoisoned(&self.inner).levels_refined += k;
    }

    pub fn levels_refined(&self) -> usize {
        lock_unpoisoned(&self.inner).levels_refined
    }

    /// Record the service's configured probe-pool width (set once at
    /// startup; exported so the JSON snapshot documents how native-PFM
    /// requests were run).
    pub fn set_probe_threads(&self, threads: usize) {
        lock_unpoisoned(&self.inner).probe_threads = threads;
    }

    pub fn probe_threads(&self) -> usize {
        lock_unpoisoned(&self.inner).probe_threads
    }

    /// Record the service's *effective* parallel-factorization width (set
    /// once at startup, after clamping against the machine).
    pub fn set_factor_threads(&self, threads: usize) {
        lock_unpoisoned(&self.inner).factor_threads = threads;
    }

    pub fn factor_threads(&self) -> usize {
        lock_unpoisoned(&self.inner).factor_threads
    }

    /// Record a caught panic in a serving thread (the request was answered
    /// with an error; the thread kept running).
    pub fn record_worker_panic(&self) {
        lock_unpoisoned(&self.inner).worker_panics += 1;
    }

    pub fn worker_panics(&self) -> usize {
        lock_unpoisoned(&self.inner).worker_panics
    }

    /// A request entered the bounded submission queue.
    pub fn record_enqueued(&self) {
        lock_unpoisoned(&self.inner).queue_depth += 1;
    }

    /// The dispatcher pulled a request off the bounded submission queue.
    pub fn record_dequeued(&self) {
        let mut m = lock_unpoisoned(&self.inner);
        m.queue_depth = m.queue_depth.saturating_sub(1);
    }

    pub fn queue_depth(&self) -> usize {
        lock_unpoisoned(&self.inner).queue_depth
    }

    /// One accepted gateway connection.
    pub fn record_gateway_connection(&self) {
        lock_unpoisoned(&self.inner).gw_connections += 1;
    }

    /// One well-framed gateway frame read off a connection.
    pub fn record_gateway_frame_rx(&self) {
        lock_unpoisoned(&self.inner).gw_frames_rx += 1;
    }

    /// One gateway frame written to a connection.
    pub fn record_gateway_frame_tx(&self) {
        lock_unpoisoned(&self.inner).gw_frames_tx += 1;
    }

    /// One request answered `Busy` instead of being served.
    pub fn record_gateway_busy(&self, kind: BusyKind) {
        let mut m = lock_unpoisoned(&self.inner);
        match kind {
            BusyKind::QueueFull => m.gw_busy_queue += 1,
            BusyKind::RateLimited => m.gw_busy_throttled += 1,
        }
    }

    /// One malformed frame or payload rejected by the gateway codec.
    pub fn record_gateway_malformed(&self) {
        lock_unpoisoned(&self.inner).gw_malformed += 1;
    }

    /// One admin-protocol request served.
    pub fn record_gateway_admin(&self) {
        lock_unpoisoned(&self.inner).gw_admin += 1;
    }

    pub fn gateway_connections(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_connections
    }

    pub fn gateway_frames_rx(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_frames_rx
    }

    pub fn gateway_frames_tx(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_frames_tx
    }

    pub fn gateway_busy_queue(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_busy_queue
    }

    pub fn gateway_busy_throttled(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_busy_throttled
    }

    pub fn gateway_malformed(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_malformed
    }

    pub fn gateway_admin(&self) -> usize {
        lock_unpoisoned(&self.inner).gw_admin
    }

    /// Copy what warm-store recovery found into the persist counters
    /// (called once at service startup when persistence is enabled).
    pub fn record_recovery(&self, stats: &crate::persist::RecoveryStats) {
        let mut m = lock_unpoisoned(&self.inner);
        m.p_replayed += stats.replayed;
        m.p_torn_tails += stats.torn_tails;
        m.p_quarantined += stats.quarantined;
        m.p_rejected += stats.rejected;
        m.p_errors += stats.errors;
    }

    /// One record durably appended to the warm-store WAL.
    pub fn record_wal_append(&self) {
        lock_unpoisoned(&self.inner).p_wal_appends += 1;
    }

    /// One warm-store snapshot written (auto or admin-triggered).
    pub fn record_persist_snapshot(&self) {
        lock_unpoisoned(&self.inner).p_snapshots += 1;
    }

    /// One persistence I/O failure absorbed (the store degraded to
    /// memory-only instead of crashing — the counter is the proof).
    pub fn record_persist_error(&self) {
        lock_unpoisoned(&self.inner).p_errors += 1;
    }

    pub fn persist_replayed(&self) -> usize {
        lock_unpoisoned(&self.inner).p_replayed
    }

    /// Requests short-circuited by the warm-start store.
    pub fn warm_hits(&self) -> usize {
        lock_unpoisoned(&self.inner).p_warm_hits
    }

    pub fn wal_appends(&self) -> usize {
        lock_unpoisoned(&self.inner).p_wal_appends
    }

    pub fn persist_snapshots(&self) -> usize {
        lock_unpoisoned(&self.inner).p_snapshots
    }

    pub fn persist_errors(&self) -> usize {
        lock_unpoisoned(&self.inner).p_errors
    }

    /// Latency stats per method.
    pub fn latency_stats(&self) -> Vec<(&'static str, Stats)> {
        let m = lock_unpoisoned(&self.inner);
        let mut out: Vec<(&'static str, Stats)> = m
            .latencies
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(k, v)| (*k, Stats::from_samples(v.clone())))
            .collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Mean network batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        let m = lock_unpoisoned(&self.inner);
        if m.batch_sizes.is_empty() {
            return 0.0;
        }
        m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
    }

    /// Export everything as JSON.
    pub fn to_json(&self) -> Json {
        let stats = self.latency_stats();
        let mut per_method = Json::obj();
        for (name, s) in stats {
            per_method = per_method.set(
                name,
                Json::obj()
                    .set("count", s.n)
                    .set("mean_s", s.mean)
                    .set("p95_s", s.p95)
                    .set("max_s", s.max),
            );
        }
        let (gateway, persist) = {
            let m = lock_unpoisoned(&self.inner);
            let gateway = Json::obj()
                .set("connections", m.gw_connections)
                .set("frames_rx", m.gw_frames_rx)
                .set("frames_tx", m.gw_frames_tx)
                .set("busy_queue_full", m.gw_busy_queue)
                .set("busy_rate_limited", m.gw_busy_throttled)
                .set("malformed_frames", m.gw_malformed)
                .set("admin_requests", m.gw_admin);
            let persist = Json::obj()
                .set("replayed", m.p_replayed)
                .set("warm_hits", m.p_warm_hits)
                .set("wal_appends", m.p_wal_appends)
                .set("snapshots", m.p_snapshots)
                .set("torn_tails_recovered", m.p_torn_tails)
                .set("segments_quarantined", m.p_quarantined)
                .set("records_rejected", m.p_rejected)
                .set("persist_errors", m.p_errors);
            (gateway, persist)
        };
        Json::obj()
            .set("completed", self.total_completed())
            .set("errors", self.errors())
            .set("worker_panics", self.worker_panics())
            .set("queue_depth", self.queue_depth())
            .set("fallbacks", self.fallbacks())
            .set("native_optimizer", self.native_optimized())
            .set("mean_batch", self.mean_batch())
            .set("symbolic_cache_hits", self.symbolic_hits())
            .set("symbolic_cache_misses", self.symbolic_misses())
            .set("shared_analyses", self.shared_analyses())
            .set("levels_refined", self.levels_refined())
            .set("probe_threads", self.probe_threads())
            .set("factor_threads", self.factor_threads())
            .set("gateway", gateway)
            .set("persist", persist)
            .set("latency", per_method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record("PFM", 0.01, 4, Some(Provenance::NativeOptimizer));
        m.record("PFM", 0.02, 4, Some(Provenance::Network));
        m.record("AMD", 0.005, 0, None);
        m.record("S_e", 0.015, 2, Some(Provenance::SpectralFallback));
        m.record_error();

        assert_eq!(m.total_completed(), 4);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.fallbacks(), 1);
        assert_eq!(m.native_optimized(), 1);
        assert!((m.mean_batch() - 10.0 / 3.0).abs() < 1e-9);
        let stats = m.latency_stats();
        assert_eq!(stats.len(), 3);
        let json = m.to_json().to_string();
        assert!(json.contains("\"completed\":4"));
        assert!(json.contains("\"native_optimizer\":1"));
        assert!(json.contains("PFM"));
    }

    #[test]
    fn batching_and_vcycle_counters_export() {
        let m = Metrics::new();
        m.set_probe_threads(4);
        m.set_factor_threads(2);
        m.record_shared_analyses(3);
        m.record_shared_analyses(2);
        m.record_levels_refined(2);
        m.record_levels_refined(0);
        m.record_levels_refined(5);
        assert_eq!(m.shared_analyses(), 5);
        assert_eq!(m.levels_refined(), 7);
        assert_eq!(m.probe_threads(), 4);
        assert_eq!(m.factor_threads(), 2);
        let json = m.to_json().to_string();
        assert!(json.contains("\"shared_analyses\":5"));
        assert!(json.contains("\"levels_refined\":7"));
        assert!(json.contains("\"probe_threads\":4"));
        assert!(json.contains("\"factor_threads\":2"));
    }

    #[test]
    fn gateway_and_panic_counters_export() {
        let m = Metrics::new();
        m.record_worker_panic();
        m.record_enqueued();
        m.record_enqueued();
        m.record_dequeued();
        m.record_gateway_connection();
        m.record_gateway_frame_rx();
        m.record_gateway_frame_rx();
        m.record_gateway_frame_tx();
        m.record_gateway_busy(BusyKind::QueueFull);
        m.record_gateway_busy(BusyKind::RateLimited);
        m.record_gateway_busy(BusyKind::RateLimited);
        m.record_gateway_malformed();
        m.record_gateway_admin();
        assert_eq!(m.worker_panics(), 1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.gateway_connections(), 1);
        assert_eq!(m.gateway_frames_rx(), 2);
        assert_eq!(m.gateway_frames_tx(), 1);
        assert_eq!(m.gateway_busy_queue(), 1);
        assert_eq!(m.gateway_busy_throttled(), 2);
        assert_eq!(m.gateway_malformed(), 1);
        assert_eq!(m.gateway_admin(), 1);
        let json = m.to_json().to_string();
        assert!(json.contains("\"worker_panics\":1"));
        assert!(json.contains("\"queue_depth\":1"));
        assert!(json.contains("\"busy_queue_full\":1"));
        assert!(json.contains("\"busy_rate_limited\":2"));
        assert!(json.contains("\"malformed_frames\":1"));
        assert!(json.contains("\"admin_requests\":1"));
    }

    #[test]
    fn persist_counters_export() {
        let m = Metrics::new();
        m.record_recovery(&crate::persist::RecoveryStats {
            replayed: 3,
            torn_tails: 1,
            quarantined: 2,
            rejected: 1,
            errors: 0,
        });
        m.record("PFM", 0.001, 0, Some(Provenance::WarmStore));
        m.record_wal_append();
        m.record_wal_append();
        m.record_persist_snapshot();
        m.record_persist_error();
        assert_eq!(m.persist_replayed(), 3);
        assert_eq!(m.warm_hits(), 1);
        assert_eq!(m.wal_appends(), 2);
        assert_eq!(m.persist_snapshots(), 1);
        assert_eq!(m.persist_errors(), 1);
        // a warm hit is a completion, not a fallback or a native run
        assert_eq!(m.total_completed(), 1);
        assert_eq!(m.native_optimized(), 0);
        assert_eq!(m.fallbacks(), 0);
        let json = m.to_json().to_string();
        assert!(json.contains("\"warm_hits\":1"));
        assert!(json.contains("\"replayed\":3"));
        assert!(json.contains("\"wal_appends\":2"));
        assert!(json.contains("\"snapshots\":1"));
        assert!(json.contains("\"torn_tails_recovered\":1"));
        assert!(json.contains("\"segments_quarantined\":2"));
        assert!(json.contains("\"records_rejected\":1"));
        assert!(json.contains("\"persist_errors\":1"));
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::new();
        m.record_dequeued();
        assert_eq!(m.queue_depth(), 0);
    }
}
