//! The reordering service: router → per-class execution → response.
//!
//! Topology (vLLM-router-shaped, scaled to this problem):
//!
//! ```text
//!            submit()                 mpsc
//!   clients ────────► [dispatcher thread] ──► classical pool (N threads)
//!                         │
//!                         └──► [network thread: bucket batcher + PJRT]
//! ```
//!
//! * Classical methods (Natural/RCM/AMD/Metis/Fiedler) are CPU-bound pure
//!   Rust — they fan out over a worker pool.
//! * Learned methods need the PJRT executor. The `xla` crate's client is
//!   not Sync, so one network thread owns the `PfmRuntime` and drains its
//!   queue in **bucket-batched** order: pending requests are grouped by
//!   artifact bucket so consecutive executions reuse the same compiled
//!   executable (the artifacts are single-instance; batching amortizes
//!   executable lookup and keeps the instruction cache hot — see
//!   DESIGN.md §Coordinator). Native-PFM requests in one drain are
//!   additionally grouped by **matrix identity** (exact pattern + values):
//!   each group shares one coarsening hierarchy + one identity symbolic
//!   analysis (`pfm::prepare_shared`), while every request still runs
//!   under its own seed, budget, and deadline — hierarchies are
//!   seed-independent and the key is value-exact, so the shared result is
//!   bit-identical to a solo run.
//! * Backpressure: the submission queue is bounded; `submit` blocks when
//!   the service is saturated.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{
    Method, ReorderRequest, ReorderResponse, ReorderResult, TrySubmitError,
};
use crate::factor::lu::{self, LuOptions};
use crate::factor::symbolic::fill_ratio;
use crate::factor::{FactorContext, FactorKind};
use crate::obs::trace::{Stage, StageLog};
use crate::pfm::{prepare_shared, OptBudget, SharedPrep, DEFAULT_DENSE_CAP};
use crate::runtime::PfmRuntime;
use crate::sparse::Csr;
use crate::util::sync::{effective_threads, lock_unpoisoned};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// classical ordering worker threads
    pub workers: usize,
    /// max learned-method requests drained per batch
    pub max_batch: usize,
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
    /// bounded queue capacity (backpressure)
    pub queue_capacity: usize,
    /// artifact directory for the PJRT runtime
    pub artifact_dir: String,
    /// default budget for native-PFM orderings (requests may override via
    /// `ReorderRequest::opt_budget`); the serving default is bounded in
    /// both iterations and wall clock so one optimizer run can never
    /// stall the network thread
    pub opt_budget: OptBudget,
    /// probe-pool workers the native PFM optimizer's refinement passes fan
    /// out over (scoped threads inside the network thread's request).
    /// Quality-neutral: orderings are bit-identical at any width for a
    /// given budget, except when the request's `time_ms` deadline expires
    /// mid-run — deadline expiry makes results timing-dependent at any
    /// width (never worse than the init either way; see `pfm::probes`)
    pub probe_threads: usize,
    /// parallel-factorization width native-PFM requests may use
    /// (`factor::sched`; requests may override via
    /// `ReorderRequest::factor_threads`). Composed with `probe_threads`
    /// inside the optimizer so the product never oversubscribes the
    /// machine; bit-identical factors at any width.
    pub factor_threads: usize,
    /// Test-only fault injection: a request carrying exactly this seed
    /// panics inside its serving thread, exercising the panic-isolation
    /// path (the request is answered with an error, the thread survives,
    /// `Metrics::worker_panics` increments). `None` in production.
    pub fault_seed: Option<u64>,
    /// Crash-safe warm-start persistence (`crate::persist`): when set,
    /// accepted native-PFM orderings are written through a WAL under this
    /// config and the dispatcher short-circuits repeat patterns with the
    /// stored permutation ([`Provenance::WarmStore`]). `None` (the
    /// default) keeps the service fully stateless.
    ///
    /// [`Provenance::WarmStore`]: crate::runtime::Provenance
    pub persist: Option<crate::persist::PersistConfig>,
    /// How many recent request traces the bounded ring keeps for
    /// `admin trace` (`obs::trace::TraceRing`). Memory is O(capacity),
    /// never O(requests).
    pub trace_capacity: usize,
    /// Wall-time threshold above which a request's trace is flagged
    /// slow in the ring (and counted in the `slow` counter).
    pub slow_threshold: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_capacity: 256,
            artifact_dir: crate::runtime::DEFAULT_ARTIFACT_DIR.to_string(),
            opt_budget: OptBudget::serving(),
            probe_threads: 2,
            factor_threads: 1,
            fault_seed: None,
            persist: None,
            trace_capacity: crate::obs::trace::DEFAULT_TRACE_CAPACITY,
            slow_threshold: crate::obs::trace::DEFAULT_SLOW_THRESHOLD,
        }
    }
}

/// Handle to a running service. Cloneable; dropping the last handle shuts
/// the service down (workers drain and exit).
pub struct ReorderService {
    tx: mpsc::SyncSender<ReorderRequest>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// warm-start store (None unless `ServiceConfig::persist` was set)
    store: Option<Arc<Mutex<crate::persist::OrderingStore>>>,
}

impl ReorderService {
    /// Start dispatcher + workers + network thread.
    pub fn start(config: ServiceConfig) -> Arc<ReorderService> {
        let (tx, rx) = mpsc::sync_channel::<ReorderRequest>(config.queue_capacity);
        let metrics = Arc::new(Metrics::new());
        metrics.set_probe_threads(config.probe_threads.max(1));
        metrics.set_factor_threads(effective_threads(config.factor_threads));
        metrics.configure_traces(config.trace_capacity, config.slow_threshold);
        let shutdown = Arc::new(AtomicBool::new(false));

        // warm-start store: recover before serving, so the very first
        // request can already hit a permutation persisted by a previous
        // process (the crash-restart amortization this exists for)
        let store = config.persist.clone().map(|pc| {
            let (store, stats) = crate::persist::OrderingStore::open(pc);
            metrics.record_recovery(&stats);
            Arc::new(Mutex::new(store))
        });

        // classical pool channel — bounded like the submission queue, so
        // saturation propagates backwards (pool full → dispatcher blocks →
        // submission queue fills → `try_submit` reports `Saturated`)
        // instead of piling up in an unbounded buffer
        let (ctx, crx) = mpsc::sync_channel::<ReorderRequest>(config.queue_capacity.max(1));
        let crx = Arc::new(Mutex::new(crx));
        // network channel (bounded, same reasoning)
        let (ntx, nrx) = mpsc::sync_channel::<ReorderRequest>(config.queue_capacity.max(1));

        let mut threads = Vec::new();

        // dispatcher: route by method class, short-circuiting repeat
        // patterns through the warm-start store before any work is queued
        {
            let shutdown = shutdown.clone();
            let metrics = metrics.clone();
            let store = store.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("pfm-dispatch".into())
                    .spawn(move || {
                        while let Ok(mut req) = rx.recv() {
                            metrics.record_dequeued();
                            if shutdown.load(Ordering::Relaxed) {
                                // an already-received request must not be
                                // dropped silently: tell the caller and
                                // keep draining until the senders go away
                                let _ = req.respond.send(ReorderResponse {
                                    id: req.id,
                                    result: Err("service shutting down".to_string()),
                                });
                                continue;
                            }
                            if let Some(store) = &store {
                                if serve_warm_hit(store, &mut req, &metrics) {
                                    continue;
                                }
                            }
                            let target = match req.method {
                                Method::Classical(_) => ctx.send(req),
                                Method::Learned(_) => ntx.send(req),
                            };
                            if target.is_err() {
                                break; // downstream gone
                            }
                        }
                    })
                    .expect("spawn dispatcher"),
            );
        }

        // classical workers — each owns a FactorContext so fill
        // evaluations reuse scratch and hit the symbolic cache when the
        // same pattern repeats (the serving steady state)
        for w in 0..config.workers {
            let crx = crx.clone();
            let metrics = metrics.clone();
            let fault_seed = config.fault_seed;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pfm-worker-{w}"))
                    .spawn(move || {
                        let mut fctx = FactorContext::new();
                        loop {
                            let req = {
                                // poison-recovering: a panic elsewhere in
                                // the pool must not cascade through this
                                // shared receiver lock
                                let guard = lock_unpoisoned(&crx);
                                guard.recv()
                            };
                            let Ok(mut req) = req else { break };
                            let Method::Classical(method) = req.method else {
                                unreachable!("dispatcher routed learned to classical pool")
                            };
                            // queue wait ends where compute starts — the
                            // histogram is what makes saturation visible
                            // separately from slow ordering work
                            let wait = req.submitted.elapsed().as_secs_f64();
                            metrics.record_queue_wait(wait);
                            req.stages.add(Stage::QueueWait, wait);
                            // panic isolation: a fault while serving one
                            // request is answered as an error on that
                            // request; the worker (and its siblings) keep
                            // serving
                            let work = catch_unwind(AssertUnwindSafe(|| {
                                if fault_seed == Some(req.seed) {
                                    panic!("injected worker fault (ServiceConfig::fault_seed)");
                                }
                                let order =
                                    req.stages.time(Stage::Order, || method.order(&req.matrix));
                                // latency = queue wait + ordering compute;
                                // the optional fill evaluation is
                                // bookkeeping and must not skew
                                // method-vs-method latencies
                                let latency = req.submitted.elapsed().as_secs_f64();
                                let (fill, fill_kind) = if req.eval_fill {
                                    let (f, k) = eval_fill(
                                        &req.matrix,
                                        &order,
                                        req.factor_kind,
                                        &mut fctx,
                                        &metrics,
                                        &mut req.stages,
                                    );
                                    (Some(f), Some(k))
                                } else {
                                    (None, None)
                                };
                                (order, latency, fill, fill_kind)
                            }));
                            match work {
                                Ok((order, latency, fill, fill_kind)) => {
                                    metrics.record(method.label(), latency, 0, None);
                                    metrics.record_trace(
                                        req.stages.finish(req.id, method.label()),
                                    );
                                    let _ = req.respond.send(ReorderResponse {
                                        id: req.id,
                                        result: Ok(ReorderResult {
                                            order,
                                            method: method.label(),
                                            provenance: None,
                                            latency,
                                            batch_size: 0,
                                            fill_ratio: fill,
                                            factor_kind: fill_kind,
                                            opt_iters: 0,
                                            probe_threads: 0,
                                            factor_threads: 0,
                                            levels_refined: 0,
                                            stages: req.stages.spans().to_vec(),
                                        }),
                                    });
                                }
                                Err(p) => {
                                    metrics.record_worker_panic();
                                    metrics.record_error();
                                    // the interrupted request may have left
                                    // scratch/cache mid-mutation — rebuild
                                    fctx = FactorContext::new();
                                    let _ = req.respond.send(ReorderResponse {
                                        id: req.id,
                                        result: Err(format!(
                                            "worker panicked while serving request: {}",
                                            panic_message(p.as_ref())
                                        )),
                                    });
                                }
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // network thread: bucket batcher + PJRT runtime
        {
            let metrics = metrics.clone();
            let cfg = config.clone();
            let store = store.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("pfm-network".into())
                    .spawn(move || network_loop(nrx, cfg, metrics, store))
                    .expect("spawn network thread"),
            );
        }

        Arc::new(ReorderService {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            shutdown,
            threads: Mutex::new(threads),
            store,
        })
    }

    /// Compact the warm-start store into one snapshot (the gateway's
    /// `snapshot` admin command). Returns the number of records written,
    /// or an error when persistence is disabled / the write failed. A
    /// successful snapshot also re-enables a store that degraded to
    /// memory-only after an earlier I/O failure.
    pub fn persist_snapshot(&self) -> Result<usize, String> {
        let store = self
            .store
            .as_ref()
            .ok_or_else(|| "persistence is not enabled (start with --persist-dir)".to_string())?;
        let n = lock_unpoisoned(store).snapshot()?;
        self.metrics.record_persist_snapshot();
        Ok(n)
    }

    /// Orderings currently held by the warm-start store (0 when
    /// persistence is disabled).
    pub fn warm_store_len(&self) -> usize {
        self.store.as_ref().map_or(0, |s| lock_unpoisoned(s).len())
    }

    /// Submit a reorder request; returns a receiver for the response.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
    ) -> mpsc::Receiver<ReorderResponse> {
        self.submit_with_fill(matrix, method, seed, false)
    }

    /// Like [`submit`](Self::submit), optionally asking the worker to also
    /// evaluate the ordering's fill ratio (cached symbolic analysis). The
    /// factorization kind for the fill evaluation is detected from matrix
    /// symmetry by the evaluating worker — the submit path pays nothing;
    /// use [`submit_with_kind`](Self::submit_with_kind) to pin it.
    pub fn submit_with_fill(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
    ) -> mpsc::Receiver<ReorderResponse> {
        self.submit_with_kind(matrix, method, seed, eval_fill, None)
    }

    /// Fully explicit submission: the caller chooses which factorization
    /// the fill evaluation runs (callers with out-of-band knowledge skip
    /// the worker-side symmetry check).
    pub fn submit_with_kind(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
        factor_kind: Option<FactorKind>,
    ) -> mpsc::Receiver<ReorderResponse> {
        self.submit_with_budget(matrix, method, seed, eval_fill, factor_kind, None)
    }

    /// Fullest submission: additionally pins the native-PFM optimizer
    /// budget for this request (`None` uses the service's configured
    /// serving budget). Lets latency-sensitive callers trade ordering
    /// quality for response time per request.
    pub fn submit_with_budget(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
        factor_kind: Option<FactorKind>,
        opt_budget: Option<OptBudget>,
    ) -> mpsc::Receiver<ReorderResponse> {
        self.submit_with_threads(matrix, method, seed, eval_fill, factor_kind, opt_budget, None)
    }

    /// [`submit_with_budget`](Self::submit_with_budget) plus a per-request
    /// parallel-factorization width (`None` uses the service's configured
    /// `factor_threads`).
    #[allow(clippy::too_many_arguments)]
    pub fn submit_with_threads(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
        factor_kind: Option<FactorKind>,
        opt_budget: Option<OptBudget>,
        factor_threads: Option<usize>,
    ) -> mpsc::Receiver<ReorderResponse> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = ReorderRequest {
            id,
            matrix,
            method,
            seed,
            eval_fill,
            factor_kind,
            opt_budget,
            factor_threads,
            submitted: Instant::now(),
            stages: StageLog::new(),
            respond: rtx,
        };
        if self.tx.send(req).is_ok() {
            self.metrics.record_enqueued();
        }
        // on error the service shut down: respond channel dropped →
        // receiver errors
        rrx
    }

    /// Non-blocking submission: like
    /// [`submit_with_budget`](Self::submit_with_budget), but when the
    /// bounded queue is full it returns [`TrySubmitError::Saturated`]
    /// immediately instead of blocking the caller. This is the gateway's
    /// entry point — saturation becomes an explicit `Busy` frame on the
    /// wire rather than an unbounded pile-up of reader threads.
    #[allow(clippy::too_many_arguments)]
    pub fn try_submit_with_budget(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
        factor_kind: Option<FactorKind>,
        opt_budget: Option<OptBudget>,
        factor_threads: Option<usize>,
    ) -> Result<mpsc::Receiver<ReorderResponse>, TrySubmitError> {
        self.try_submit_traced(
            matrix,
            method,
            seed,
            eval_fill,
            factor_kind,
            opt_budget,
            factor_threads,
            StageLog::new(),
        )
    }

    /// [`try_submit_with_budget`](Self::try_submit_with_budget) with a
    /// caller-provided stage log. The gateway starts the log at frame
    /// receipt (decode + rate-limit spans already recorded), so the
    /// resulting trace covers the whole wire round-trip, not just the
    /// coordinator's part.
    #[allow(clippy::too_many_arguments)]
    pub fn try_submit_traced(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
        eval_fill: bool,
        factor_kind: Option<FactorKind>,
        opt_budget: Option<OptBudget>,
        factor_threads: Option<usize>,
        stages: StageLog,
    ) -> Result<mpsc::Receiver<ReorderResponse>, TrySubmitError> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = ReorderRequest {
            id,
            matrix,
            method,
            seed,
            eval_fill,
            factor_kind,
            opt_budget,
            factor_threads,
            submitted: Instant::now(),
            stages,
            respond: rtx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.record_enqueued();
                Ok(rrx)
            }
            Err(mpsc::TrySendError::Full(_)) => Err(TrySubmitError::Saturated),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(TrySubmitError::ShutDown),
        }
    }

    /// Convenience: submit and wait.
    pub fn reorder_blocking(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
    ) -> Result<ReorderResult, String> {
        let rx = self.submit(matrix, method, seed);
        match rx.recv() {
            Ok(resp) => resp.result,
            Err(_) => Err("service shut down before responding".to_string()),
        }
    }

    /// Convenience: submit with fill evaluation and wait.
    pub fn reorder_blocking_with_fill(
        &self,
        matrix: Csr,
        method: Method,
        seed: u64,
    ) -> Result<ReorderResult, String> {
        let rx = self.submit_with_fill(matrix, method, seed, true);
        match rx.recv() {
            Ok(resp) => resp.result,
            Err(_) => Err("service shut down before responding".to_string()),
        }
    }

    /// Signal shutdown and join all threads (idempotent).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // dropping tx unblocks dispatcher only when all handles drop; we
        // instead rely on queue drain: send nothing further. Join what we
        // can without deadlocking on ourselves.
        let mut threads = lock_unpoisoned(&self.threads);
        // Close the pipeline by dropping our sender clone — achieved by
        // replacing it is not possible (owned); threads exit when channels
        // disconnect at Drop. Here we only join already-finished threads.
        threads.retain(|t| !t.is_finished());
    }
}

/// Best-effort human-readable panic payload (panics carry `&str` or
/// `String` in practice).
fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Evaluate the fill ratio of `order` on `a` through a worker-local
/// symbolic cache, on the factorization the request's kind names —
/// `None` resolves from matrix symmetry here, on the worker: symbolic
/// Cholesky fill for symmetric matrices, numeric Gilbert–Peierls LU fill
/// (pivoting included) for unsymmetric ones, with the structural A+Aᵀ
/// bound as the fallback if the numeric phase hits a singular column.
/// Records the cache hit/miss in the service metrics. Returns the fill
/// and the label of the kind that ran. The symbolic analysis and (for
/// LU) the numeric factorization are timed into `stages` so the trace
/// shows whether fill evaluation rode the cache or paid for analysis.
fn eval_fill(
    a: &Csr,
    order: &[usize],
    kind: Option<FactorKind>,
    fctx: &mut FactorContext,
    metrics: &Metrics,
    stages: &mut StageLog,
) -> (f64, &'static str) {
    let kind = kind.unwrap_or_else(|| FactorKind::for_matrix(a));
    let pap = a.permute_sym(order);
    let hits_before = fctx.cache.hits();
    let symbolic_stage = |fctx: &FactorContext| {
        if fctx.cache.hits() > hits_before {
            Stage::SymbolicHit
        } else {
            Stage::SymbolicMiss
        }
    };
    let fill = match kind {
        FactorKind::Cholesky => {
            let t0 = Instant::now();
            let analysis = fctx.cache.analyze(&pap);
            let fill = fill_ratio(&pap, &analysis.sym);
            stages.add(symbolic_stage(fctx), t0.elapsed().as_secs_f64());
            fill
        }
        FactorKind::Lu => {
            let t0 = Instant::now();
            let lsym = fctx.cache.analyze_lu(&pap);
            stages.add(symbolic_stage(fctx), t0.elapsed().as_secs_f64());
            let t1 = Instant::now();
            let factored = lu::factorize(&pap, &lsym, LuOptions::default(), &mut fctx.workspace);
            stages.add(Stage::NumericFactor, t1.elapsed().as_secs_f64());
            match factored {
                Ok(f) => lu::lu_fill_ratio(&pap, &f),
                Err(_) => lsym.lu_nnz_bound as f64 / pap.nnz() as f64,
            }
        }
    };
    metrics.record_symbolic(fctx.cache.hits() > hits_before);
    (fill, kind.label())
}

/// Try to answer `req` from the warm-start store. Returns `true` when the
/// request was served (response sent, metrics recorded). Only variants
/// with a native path are ever stored, so only those are looked up; the
/// request's seed is deliberately not part of the key — amortizing the
/// optimizer across seeds and restarts is the point of the store.
fn serve_warm_hit(
    store: &Arc<Mutex<crate::persist::OrderingStore>>,
    req: &mut ReorderRequest,
    metrics: &Metrics,
) -> bool {
    let Method::Learned(l) = req.method else { return false };
    if !l.has_native_path() {
        return false;
    }
    let wait = req.submitted.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let hit = {
        let guard = lock_unpoisoned(store);
        guard
            .lookup(l.variant(), &req.matrix)
            .map(|rec| (rec.order.clone(), rec.factor_kind, rec.fill_ratio))
    };
    let lookup_secs = t0.elapsed().as_secs_f64();
    let Some((order, kind, fill)) = hit else { return false };
    // spans only materialize on a hit: a miss continues into a worker,
    // which records its own queue wait at compute start
    metrics.record_queue_wait(wait);
    req.stages.add(Stage::QueueWait, wait);
    req.stages.add(Stage::WarmLookup, lookup_secs);
    let latency = req.submitted.elapsed().as_secs_f64();
    metrics.record(l.label(), latency, 0, Some(crate::runtime::Provenance::WarmStore));
    metrics.record_trace(req.stages.finish(req.id, l.label()));
    // the stored fill evaluation is reused only when the request would
    // accept it: fill was asked for, a stored value exists, and the
    // request didn't pin a different factorization kind
    let kind_ok = req.factor_kind.is_none() || req.factor_kind == kind;
    let (fill_ratio, factor_kind) = if req.eval_fill && kind_ok && fill.is_some() {
        (fill, kind.map(|k| k.label()))
    } else {
        (None, None)
    };
    let _ = req.respond.send(ReorderResponse {
        id: req.id,
        result: Ok(ReorderResult {
            order,
            method: l.label(),
            provenance: Some(crate::runtime::Provenance::WarmStore),
            latency,
            batch_size: 0,
            fill_ratio,
            factor_kind,
            opt_iters: 0,
            probe_threads: 0,
            factor_threads: 0,
            levels_refined: 0,
            stages: req.stages.spans().to_vec(),
        }),
    });
    true
}

/// Network executor: drains the queue, groups by bucket, executes.
fn network_loop(
    rx: mpsc::Receiver<ReorderRequest>,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
    store: Option<Arc<Mutex<crate::persist::OrderingStore>>>,
) {
    let mut runtime = match PfmRuntime::new(&cfg.artifact_dir) {
        Ok(rt) => rt,
        Err(e) => {
            // Without a runtime every learned request fails fast.
            eprintln!("pfm-network: no PJRT runtime: {e}");
            while let Ok(req) = rx.recv() {
                metrics.record_error();
                let _ = req.respond.send(ReorderResponse {
                    id: req.id,
                    result: Err(format!("runtime unavailable: {e}")),
                });
            }
            return;
        }
    };

    let mut pending: VecDeque<ReorderRequest> = VecDeque::new();
    let mut fctx = FactorContext::new();
    loop {
        // blocking wait for at least one request
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push_back(r),
                Err(_) => break, // all senders gone
            }
        }
        // opportunistically fill the batch window
        let deadline = Instant::now() + cfg.max_wait;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push_back(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // group by (variant, bucket) so consecutive runs share an executable
        let batch: Vec<ReorderRequest> = pending.drain(..).collect();
        let mut groups: Vec<(String, usize, Vec<ReorderRequest>)> = Vec::new();
        for req in batch {
            let Method::Learned(l) = req.method else { unreachable!() };
            let variant = l.variant().to_string();
            let bucket = runtime
                .bucket_for(&variant, req.matrix.nrows())
                .map(Some)
                .unwrap_or(None);
            let key_bucket = bucket.unwrap_or(usize::MAX); // MAX = fallback group
            match groups.iter_mut().find(|(v, b, _)| *v == variant && *b == key_bucket) {
                Some((_, _, reqs)) => reqs.push(req),
                None => groups.push((variant, key_bucket, vec![req])),
            }
        }
        for (_variant, bucket, reqs) in groups {
            let batch_size = reqs.len();
            // Shared preparation: requests headed for the native
            // optimizer (no artifact bucket, PFM-family variant) that
            // carry an identical matrix within this drain get one
            // coarsening hierarchy + one identity symbolic analysis
            // between them. Hierarchies are seed-independent and the key
            // is value-exact, so sharing is bit-transparent; each request
            // still runs its own seed, init, and `OptBudget` (deadline
            // included).
            let native = bucket == usize::MAX
                && matches!(reqs[0].method, Method::Learned(l) if l.has_native_path());
            let mut pgroup_of: Vec<usize> = Vec::new();
            let mut preps: Vec<Option<SharedPrep>> = Vec::new();
            if native && batch_size >= 2 {
                let mut leads: Vec<usize> = Vec::new();
                for i in 0..reqs.len() {
                    match leads
                        .iter()
                        .position(|&l| same_matrix(&reqs[l].matrix, &reqs[i].matrix))
                    {
                        Some(g) => pgroup_of.push(g),
                        None => {
                            leads.push(i);
                            pgroup_of.push(leads.len() - 1);
                        }
                    }
                }
                let mut counts = vec![0usize; leads.len()];
                for &g in &pgroup_of {
                    counts[g] += 1;
                }
                for (&lead, &count) in leads.iter().zip(&counts) {
                    if count >= 2 {
                        let (h0, m0) = (fctx.cache.hits(), fctx.cache.misses());
                        // panic isolation: a fault in the shared prep only
                        // costs the group its sharing (each request then
                        // prepares solo), never the network thread
                        let prep = catch_unwind(AssertUnwindSafe(|| {
                            prepare_shared(
                                &reqs[lead].matrix,
                                DEFAULT_DENSE_CAP,
                                Some(&mut fctx.cache),
                            )
                        }));
                        let Ok(prep) = prep else {
                            metrics.record_worker_panic();
                            fctx = FactorContext::new();
                            preps.push(None);
                            continue;
                        };
                        if fctx.cache.hits() > h0 {
                            metrics.record_symbolic(true);
                        } else if fctx.cache.misses() > m0 {
                            metrics.record_symbolic(false);
                        }
                        // an empty prep (small unsymmetric matrix: LU
                        // natural objective is per-request, no hierarchy
                        // under the cap) shares nothing — don't report
                        // savings that never happened
                        if prep.natural_objective.is_some() || prep.hierarchy.is_some() {
                            metrics.record_shared_analyses(count - 1);
                            preps.push(Some(prep));
                        } else {
                            preps.push(None);
                        }
                    } else {
                        preps.push(None);
                    }
                }
            }
            for (i, mut req) in reqs.into_iter().enumerate() {
                let Method::Learned(l) = req.method else { unreachable!() };
                let budget = req.opt_budget.unwrap_or(cfg.opt_budget);
                let fthreads = req.factor_threads.unwrap_or(cfg.factor_threads).max(1);
                let prep = pgroup_of.get(i).and_then(|&g| preps[g].as_ref());
                // queue wait ends here — batching delay included, which is
                // exactly what the separate histogram is for
                let wait = req.submitted.elapsed().as_secs_f64();
                metrics.record_queue_wait(wait);
                req.stages.add(Stage::QueueWait, wait);
                // panic isolation, same contract as the classical pool: a
                // fault while serving one learned request becomes an error
                // reply on that request; the network thread keeps draining
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if cfg.fault_seed == Some(req.seed) {
                        panic!("injected network-thread fault (ServiceConfig::fault_seed)");
                    }
                    let t0 = Instant::now();
                    l.order_detailed_shared(
                        &mut runtime,
                        &req.matrix,
                        req.seed,
                        Some(budget),
                        cfg.probe_threads.max(1),
                        fthreads,
                        prep,
                    )
                    .map(|out| {
                        let order_secs = t0.elapsed().as_secs_f64();
                        // native runs expose their optimizer phases; the
                        // un-phased remainder (init, prolongation, identity
                        // evals) stays visible as an `order` span so the
                        // spans still account for the whole ordering time
                        let ph = out.phases;
                        let phased = ph.coarsen_s + ph.admm_s + ph.refine_s;
                        if phased > 0.0 {
                            if ph.coarsen_s > 0.0 {
                                req.stages.add(Stage::Coarsen, ph.coarsen_s);
                            }
                            if ph.admm_s > 0.0 {
                                req.stages.add(Stage::Admm, ph.admm_s);
                            }
                            // refine time splits into the incremental-
                            // engaged portion and the full-evaluation
                            // remainder, so `admin trace` shows what the
                            // suffix re-walks actually cost vs. saved
                            let incr = ph.refine_incr_s.min(ph.refine_s);
                            if incr > 0.0 {
                                req.stages.add(Stage::RefineIncremental, incr);
                            }
                            if ph.refine_s > incr {
                                req.stages.add(Stage::Refine, ph.refine_s - incr);
                            }
                            if order_secs > phased {
                                req.stages.add(Stage::Order, order_secs - phased);
                            }
                        } else {
                            req.stages.add(Stage::Order, order_secs);
                        }
                        // latency before fill evaluation (see worker note)
                        let latency = req.submitted.elapsed().as_secs_f64();
                        let (fill, fill_kind) = if req.eval_fill {
                            let (f, k) = eval_fill(
                                &req.matrix,
                                &out.order,
                                req.factor_kind,
                                &mut fctx,
                                &metrics,
                                &mut req.stages,
                            );
                            (Some(f), Some(k))
                        } else {
                            (None, None)
                        };
                        (out, latency, fill, fill_kind)
                    })
                }));
                let computed = match outcome {
                    Ok(computed) => computed,
                    Err(p) => {
                        metrics.record_worker_panic();
                        metrics.record_error();
                        fctx = FactorContext::new();
                        let _ = req.respond.send(ReorderResponse {
                            id: req.id,
                            result: Err(format!(
                                "network thread panicked while serving request: {}",
                                panic_message(p.as_ref())
                            )),
                        });
                        continue;
                    }
                };
                match computed {
                    Ok((out, latency, fill, fill_kind)) => {
                        metrics.record(l.label(), latency, batch_size, Some(out.provenance));
                        metrics.record_levels_refined(out.levels_refined);
                        metrics.record_probe_split(out.incremental_probes, out.full_probes);
                        metrics.record_trace(req.stages.finish(req.id, l.label()));
                        let native_run =
                            out.provenance == crate::runtime::Provenance::NativeOptimizer;
                        // persist accepted native results *before* the
                        // response is sent: an acknowledged ordering is
                        // already on disk (under FsyncPolicy::Always), so
                        // kill -9 right after the reply still warm-starts
                        if native_run {
                            if let Some(store) = &store {
                                let kind = match fill_kind {
                                    Some("cholesky") => Some(FactorKind::Cholesky),
                                    Some("lu") => Some(FactorKind::Lu),
                                    _ => None,
                                };
                                let rec = crate::persist::StoredOrdering::new(
                                    l.variant(),
                                    &req.matrix,
                                    out.order.clone(),
                                    kind,
                                    fill,
                                );
                                let persisted = lock_unpoisoned(store).insert(rec);
                                if persisted.appended {
                                    metrics.record_wal_append();
                                }
                                if persisted.snapshotted {
                                    metrics.record_persist_snapshot();
                                }
                                for e in &persisted.errors {
                                    eprintln!("pfm-network: persist degraded: {e}");
                                    metrics.record_persist_error();
                                }
                            }
                        }
                        let _ = req.respond.send(ReorderResponse {
                            id: req.id,
                            result: Ok(ReorderResult {
                                order: out.order,
                                method: l.label(),
                                provenance: Some(out.provenance),
                                latency,
                                batch_size,
                                fill_ratio: fill,
                                factor_kind: fill_kind,
                                opt_iters: out.opt_iters,
                                probe_threads: if native_run {
                                    cfg.probe_threads.max(1)
                                } else {
                                    0
                                },
                                factor_threads: if native_run { fthreads } else { 0 },
                                levels_refined: out.levels_refined,
                                stages: req.stages.spans().to_vec(),
                            }),
                        });
                    }
                    Err(e) => {
                        metrics.record_error();
                        let _ = req.respond.send(ReorderResponse {
                            id: req.id,
                            result: Err(e.to_string()),
                        });
                    }
                }
            }
        }
    }
}

/// Exact matrix equality (pattern *and* values) — the batching key. The
/// hierarchy a prep carries is built from edge weights, so sharing across
/// same-pattern-but-different-value matrices would make a request's
/// ordering depend on what it was co-batched with; value-exact keying is
/// what keeps the shared path bit-identical to solo runs (the serving
/// steady state — repeated requests for one topology — shares either
/// way). The nnz check makes distinct-pattern misses O(1); drains are
/// bounded by `max_batch`, so the worst case is a handful of full
/// comparisons.
fn same_matrix(a: &Csr, b: &Csr) -> bool {
    a.nrows() == b.nrows()
        && a.nnz() == b.nnz()
        && a.indptr() == b.indptr()
        && a.indices() == b.indices()
        && a.data() == b.data()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::order::Classical;
    use crate::runtime::Learned;
    use crate::util::check::check_permutation;

    fn svc() -> Arc<ReorderService> {
        ReorderService::start(ServiceConfig {
            workers: 2,
            artifact_dir: "artifacts".into(),
            ..Default::default()
        })
    }

    #[test]
    fn classical_requests_roundtrip() {
        let service = svc();
        let a = laplacian_2d(8, 8);
        let res = service
            .reorder_blocking(a, Method::Classical(Classical::Amd), 1)
            .unwrap();
        check_permutation(&res.order).unwrap();
        assert_eq!(res.method, "AMD");
        assert!(res.latency >= 0.0);
        assert_eq!(service.metrics.total_completed(), 1);
    }

    #[test]
    fn requests_carry_stage_breakdowns_and_land_in_the_trace_ring() {
        let service = ReorderService::start(ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-svc-trace".into(),
            ..Default::default()
        });
        let a = laplacian_2d(9, 9);
        let t0 = Instant::now();
        let res = service
            .reorder_blocking_with_fill(a, Method::Classical(Classical::Amd), 1)
            .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let labels: Vec<&str> = res.stages.iter().map(|s| s.stage.label()).collect();
        assert!(labels.contains(&"queue_wait"), "stages: {labels:?}");
        assert!(labels.contains(&"order"), "stages: {labels:?}");
        assert!(
            labels.contains(&"symbolic_hit") || labels.contains(&"symbolic_miss"),
            "fill evaluation must surface a symbolic span: {labels:?}"
        );
        let sum: f64 = res.stages.iter().map(|s| s.secs).sum();
        assert!(sum <= wall + 1e-9, "span sum {sum} exceeds wall {wall}");
        assert!(sum <= res.latency + 1.0, "span sum should be near latency");
        // the same spans are visible through the trace ring
        let traces = service.metrics.recent_traces();
        assert_eq!(traces.len(), 1);
        assert!(traces[0].spans.iter().any(|s| s.stage.label() == "order"));
        assert!(traces[0].spans.iter().map(|s| s.secs).sum::<f64>() <= traces[0].wall_s + 1e-9);
        // queue wait went into its own histogram, separate from latency
        assert_eq!(service.metrics.queue_wait_histogram().count(), 1);
        // a learned request reports optimizer-phase spans
        let budget = OptBudget { outer: 1, refine: 4, time_ms: None, ..OptBudget::default() };
        let rx = service.submit_with_budget(
            laplacian_2d(18, 18),
            Method::Learned(crate::runtime::Learned::Pfm),
            1,
            false,
            None,
            Some(budget),
        );
        let res = rx.recv().unwrap().result.unwrap();
        let labels: Vec<&str> = res.stages.iter().map(|s| s.stage.label()).collect();
        assert!(
            labels.contains(&"admm") && labels.contains(&"refine"),
            "native run must expose optimizer phases: {labels:?}"
        );
        let sum: f64 = res.stages.iter().map(|s| s.secs).sum();
        assert!(sum <= res.latency + 1e-6, "span sum {sum} exceeds latency {}", res.latency);
    }

    #[test]
    fn concurrent_mixed_requests() {
        let service = svc();
        let mut rxs = Vec::new();
        for i in 0..12 {
            let a = laplacian_2d(6 + (i % 3), 6);
            let method = match i % 3 {
                0 => Method::Classical(Classical::Rcm),
                1 => Method::Classical(Classical::Fiedler),
                _ => Method::Learned(Learned::Pfm),
            };
            rxs.push((i, a.nrows(), service.submit(a, method, i as u64)));
        }
        for (_, n, rx) in rxs {
            let resp = rx.recv().expect("response");
            let result = resp.result.expect("ok");
            assert_eq!(result.order.len(), n);
            check_permutation(&result.order).unwrap();
        }
        assert_eq!(service.metrics.total_completed(), 12);
    }

    #[test]
    fn fill_evaluation_hits_symbolic_cache() {
        let service = svc();
        let a = laplacian_2d(9, 9);
        let r1 = service
            .reorder_blocking_with_fill(a.clone(), Method::Classical(Classical::Amd), 1)
            .unwrap();
        let f1 = r1.fill_ratio.expect("fill requested");
        assert!(f1 >= 0.0);
        // identical matrix + method → identical permuted pattern → cache hit
        let r2 = service
            .reorder_blocking_with_fill(a, Method::Classical(Classical::Amd), 1)
            .unwrap();
        assert_eq!(r2.fill_ratio, Some(f1));
        assert_eq!(
            service.metrics.symbolic_hits() + service.metrics.symbolic_misses(),
            2
        );
        // both requests may land on different workers (separate caches), so
        // only assert at least one analysis happened and none were lost
        assert!(service.metrics.symbolic_misses() >= 1);
    }

    #[test]
    fn fill_evaluation_uses_lu_on_unsymmetric_matrices() {
        let service = svc();
        let mut rng = crate::util::rng::Pcg64::new(17);
        let a = crate::gen::grid::convection_diffusion_2d(8, 8, 2.0, &mut rng);
        let r = service
            .reorder_blocking_with_fill(a.clone(), Method::Classical(Classical::Amd), 1)
            .unwrap();
        assert_eq!(r.factor_kind, Some("lu"), "unsymmetric matrix must evaluate LU fill");
        assert!(r.fill_ratio.expect("fill requested") >= 1.0, "nnz(L+U)/nnz(A) ≥ 1");
        // symmetric request on the same service still reports cholesky
        let s = laplacian_2d(8, 8);
        let r2 = service
            .reorder_blocking_with_fill(s, Method::Classical(Classical::Amd), 1)
            .unwrap();
        assert_eq!(r2.factor_kind, Some("cholesky"));
        // plain submits never evaluate a kind
        let r3 = service
            .reorder_blocking(laplacian_2d(6, 6), Method::Classical(Classical::Amd), 1)
            .unwrap();
        assert_eq!(r3.factor_kind, None);
    }

    #[test]
    fn pfm_requests_run_native_optimizer_within_budget() {
        // the serving-budget semantics of the native path: a PFM request
        // without artifacts must be served by the native optimizer, honor
        // the per-request budget, and come back within a bounded latency.
        // A nonexistent artifact dir pins the no-artifact path even on
        // checkouts where `make artifacts` has run.
        let service = ReorderService::start(ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-svc-pfm".into(),
            ..Default::default()
        });
        let a = laplacian_2d(18, 18); // n = 324 → multilevel path
        // iteration-bounded only: a wall-clock cap here would make the
        // levels_refined assertion timing-dependent on slow CI (the
        // deadline path is pinned by `time_budget_bounds_the_run` and the
        // probe-overshoot test instead)
        let budget = OptBudget { outer: 2, refine: 8, time_ms: None, ..OptBudget::default() };
        let t0 = Instant::now();
        let rx = service.submit_with_budget(
            a,
            Method::Learned(Learned::Pfm),
            1,
            true,
            None,
            Some(budget),
        );
        let res = rx.recv().expect("response").result.expect("ok");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(res.provenance, Some(crate::runtime::Provenance::NativeOptimizer));
        assert!(res.opt_iters <= 2, "budget capped outer iters at 2, ran {}", res.opt_iters);
        check_permutation(&res.order).unwrap();
        assert!(res.fill_ratio.expect("fill requested") >= 0.0);
        // the native run reports the service's probe-pool width and the
        // V-cycle's per-level refinement work (324 → ≥ 2 coarse levels)
        assert_eq!(res.probe_threads, 2, "default config runs 2 probe threads");
        assert_eq!(res.factor_threads, 1, "default config runs 1 factor thread");
        assert!(res.levels_refined >= 1, "V-cycle must refine an intermediate level");
        // latency cap: the compute is iteration-bounded (2 outer + 8
        // refine steps at n=324); the assertion is generous for slow CI
        assert!(wall < 10.0, "budget-bounded PFM request took {wall:.2}s");
        assert_eq!(service.metrics.native_optimized(), 1);
        assert_eq!(service.metrics.fallbacks(), 0);
        assert_eq!(service.metrics.levels_refined(), res.levels_refined);
        // the probe split is attributed (incremental engagement itself is
        // matrix/seed-dependent; its accounting is pinned in pfm::)
        assert!(service.metrics.full_probes() > 0, "native run recorded no full probes");
    }

    #[test]
    fn incremental_refinement_is_observable_in_metrics_and_trace() {
        // a larger request with a real refinement budget: the incremental
        // path must engage, surface in the metrics split, and carve a
        // refine_incremental span out of (not in addition to) refine time
        let service = ReorderService::start(ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-svc-incr".into(),
            ..Default::default()
        });
        let a = laplacian_2d(24, 24); // n = 576
        let budget = OptBudget { outer: 1, refine: 24, time_ms: None, ..OptBudget::default() };
        let rx = service.submit_with_budget(
            a,
            Method::Learned(Learned::Pfm),
            9,
            false,
            None,
            Some(budget),
        );
        let res = rx.recv().expect("response").result.expect("ok");
        assert_eq!(res.provenance, Some(crate::runtime::Provenance::NativeOptimizer));
        assert!(
            service.metrics.incremental_probes() > 0,
            "incremental probes must engage at n=576 with refine=24"
        );
        assert!(service.metrics.full_probes() > 0);
        let incr: f64 = res
            .stages
            .iter()
            .filter(|s| s.stage == Stage::RefineIncremental)
            .map(|s| s.secs)
            .sum();
        assert!(incr > 0.0, "no refine_incremental span recorded");
        let json = service.metrics.to_json().to_string();
        assert!(json.contains("\"incremental_probes\""));
    }

    #[test]
    fn learned_requests_batch() {
        let service = svc();
        let mut rxs = Vec::new();
        for i in 0..6 {
            let a = laplacian_2d(7, 7);
            rxs.push(service.submit(a, Method::Learned(Learned::Pfm), i));
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            let res = resp.result.unwrap();
            check_permutation(&res.order).unwrap();
        }
        // batching must have grouped at least some requests
        assert!(service.metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn same_matrix_native_pfm_burst_shares_coarsening_and_analysis() {
        // 12 identical native-PFM requests: the first drain may serve one
        // alone, but while it computes the rest queue up, so at least one
        // later drain holds an identical-matrix group ≥ 2 — that group
        // must share one prep (shared_analyses > 0, and the repeated
        // identity analysis is a SymbolicCache hit), while every request
        // keeps its own budget and seed.
        let service = ReorderService::start(ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-svc-share".into(),
            ..Default::default()
        });
        let a = laplacian_2d(18, 18); // n = 324 → hierarchy in the prep
        // per-request budget with its own deadline: sharing the prep must
        // not pool the wall-clock budgets
        let budget = OptBudget {
            outer: 1,
            refine: 4,
            level_refine: 2,
            time_ms: Some(2_000),
            ..OptBudget::default()
        };
        let mut rxs = Vec::new();
        for i in 0..12u64 {
            rxs.push(service.submit_with_budget(
                a.clone(),
                Method::Learned(Learned::Pfm),
                i,
                false,
                None,
                Some(budget),
            ));
        }
        let mut orders = Vec::new();
        for rx in rxs {
            let res = rx.recv().expect("response").result.expect("ok");
            assert_eq!(res.provenance, Some(crate::runtime::Provenance::NativeOptimizer));
            assert!(res.opt_iters <= 1, "per-request budget must hold in the batch");
            check_permutation(&res.order).unwrap();
            orders.push((res.order, res.batch_size));
        }
        assert_eq!(service.metrics.native_optimized(), 12);
        assert!(
            service.metrics.shared_analyses() >= 1,
            "no drain shared a prep across the same-pattern burst"
        );
        // different seeds produce (generally) different orderings — sharing
        // the prep must not collapse requests onto one result
        assert!(orders.iter().any(|(o, _)| *o != orders[0].0));
        // at least one drain actually batched
        assert!(orders.iter().any(|(_, b)| *b >= 2));
        let json = service.metrics.to_json().to_string();
        assert!(json.contains("\"shared_analyses\""));
    }

    #[test]
    fn injected_worker_panic_is_answered_and_service_survives() {
        // regression: pre-fix, a panicking worker died silently (its
        // request was dropped) and could poison the shared receiver lock,
        // cascading into the whole pool. Now the panicking request is
        // answered with an error and every thread keeps serving.
        let service = ReorderService::start(ServiceConfig {
            workers: 2,
            artifact_dir: "nonexistent-dir-ok-svc-panic".into(),
            fault_seed: Some(0xDEAD_BEEF),
            ..Default::default()
        });
        let a = laplacian_2d(8, 8);
        let err = service
            .reorder_blocking(a.clone(), Method::Classical(Classical::Amd), 0xDEAD_BEEF)
            .expect_err("panicking request must surface an error, not a dropped channel");
        assert!(err.contains("panic"), "error should name the panic: {err}");
        // the pool keeps serving: more requests than workers, all answered
        for i in 0..8 {
            let res = service
                .reorder_blocking(a.clone(), Method::Classical(Classical::Amd), i)
                .expect("post-panic requests must still be served");
            check_permutation(&res.order).unwrap();
        }
        // the network thread recovers the same way
        let err2 = service
            .reorder_blocking(a.clone(), Method::Learned(Learned::Pfm), 0xDEAD_BEEF)
            .expect_err("panicking learned request must surface an error");
        assert!(err2.contains("panic"), "error should name the panic: {err2}");
        let res = service
            .reorder_blocking(a, Method::Learned(Learned::Pfm), 3)
            .expect("network thread must survive the panic");
        check_permutation(&res.order).unwrap();
        assert_eq!(service.metrics.worker_panics(), 2);
        let json = service.metrics.to_json().to_string();
        assert!(json.contains("\"worker_panics\":2"));
    }

    #[test]
    fn warm_store_short_circuits_repeats_and_survives_restart_bit_identically() {
        let dir = std::env::temp_dir()
            .join(format!("pfm_svc_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-svc-warm".into(),
            persist: Some(crate::persist::PersistConfig::new(&dir)),
            ..Default::default()
        };
        let budget = OptBudget { outer: 1, refine: 4, time_ms: None, ..OptBudget::default() };
        let a = laplacian_2d(12, 12);

        let service = ReorderService::start(cfg.clone());
        let rx = service.submit_with_budget(
            a.clone(),
            Method::Learned(Learned::Pfm),
            7,
            true,
            None,
            Some(budget),
        );
        let first = rx.recv().expect("response").result.expect("ok");
        assert_eq!(first.provenance, Some(crate::runtime::Provenance::NativeOptimizer));
        assert_eq!(service.metrics.wal_appends(), 1, "accepted native result must hit the WAL");
        // a repeat of the same pattern — different seed on purpose: the
        // store amortizes the optimizer across seeds — is served warm
        let rx = service.submit_with_budget(
            a.clone(),
            Method::Learned(Learned::Pfm),
            8,
            true,
            None,
            Some(budget),
        );
        let warm = rx.recv().expect("response").result.expect("ok");
        assert_eq!(warm.provenance, Some(crate::runtime::Provenance::WarmStore));
        assert_eq!(warm.order, first.order, "warm hit must be bit-identical");
        assert_eq!(warm.fill_ratio, first.fill_ratio, "stored fill evaluation is reused");
        assert_eq!(warm.factor_kind, Some("cholesky"));
        assert_eq!(service.metrics.warm_hits(), 1);
        assert_eq!(service.metrics.native_optimized(), 1, "the optimizer ran exactly once");
        // a different pattern is a miss, never a false hit
        let miss = service
            .reorder_blocking(laplacian_2d(12, 13), Method::Learned(Learned::Pfm), 7)
            .unwrap();
        assert_ne!(miss.provenance, Some(crate::runtime::Provenance::WarmStore));
        drop(service);

        // "restart": a fresh service on the same directory replays the WAL
        // and serves the original permutation without re-optimizing
        let service = ReorderService::start(cfg);
        assert!(service.metrics.persist_replayed() >= 1, "restart must replay the store");
        let rx = service.submit_with_budget(
            a,
            Method::Learned(Learned::Pfm),
            9,
            true,
            None,
            Some(budget),
        );
        let revived = rx.recv().expect("response").result.expect("ok");
        assert_eq!(revived.provenance, Some(crate::runtime::Provenance::WarmStore));
        assert_eq!(revived.order, first.order, "restart must replay bit-identically");
        assert_eq!(service.metrics.native_optimized(), 0, "no re-optimization after restart");
        let json = service.metrics.to_json().to_string();
        assert!(json.contains("\"warm_hits\":1"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persist_fault_degrades_to_memory_only_without_failing_requests() {
        let dir = std::env::temp_dir()
            .join(format!("pfm_svc_persist_fault_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut persist = crate::persist::PersistConfig::new(&dir);
        // every append fails: the disk is dead from the first insert
        persist.fault = Some(crate::persist::PersistFault { period: 1, torn: false });
        let service = ReorderService::start(ServiceConfig {
            workers: 1,
            artifact_dir: "nonexistent-dir-ok-svc-pfault".into(),
            persist: Some(persist),
            ..Default::default()
        });
        let budget = OptBudget { outer: 1, refine: 4, time_ms: None, ..OptBudget::default() };
        let a = laplacian_2d(10, 10);
        let rx = service.submit_with_budget(
            a.clone(),
            Method::Learned(Learned::Pfm),
            1,
            false,
            None,
            Some(budget),
        );
        let res = rx.recv().expect("response").result.expect("a dead disk must not fail requests");
        assert_eq!(res.provenance, Some(crate::runtime::Provenance::NativeOptimizer));
        assert_eq!(service.metrics.persist_errors(), 1, "the absorbed I/O failure is counted");
        assert_eq!(service.metrics.wal_appends(), 0);
        // the in-memory half keeps serving warm hits
        let rx = service.submit_with_budget(
            a,
            Method::Learned(Learned::Pfm),
            2,
            false,
            None,
            Some(budget),
        );
        let warm = rx.recv().expect("response").result.expect("ok");
        assert_eq!(warm.provenance, Some(crate::runtime::Provenance::WarmStore));
        assert_eq!(warm.order, res.order);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn try_submit_reports_saturation_instead_of_blocking() {
        // 1-slot queue + 1-slot pool channel + 1 worker wedged on slow
        // requests: the non-blocking path must answer `Saturated` quickly
        // instead of blocking the caller — this is the precondition for
        // the gateway's `Busy` frame.
        let service = ReorderService::start(ServiceConfig {
            workers: 1,
            queue_capacity: 1,
            artifact_dir: "nonexistent-dir-ok-svc-sat".into(),
            ..Default::default()
        });
        let a = laplacian_2d(30, 30); // Fiedler on n=900: a few ms per request
        let mut accepted = Vec::new();
        let mut saturated = 0usize;
        for i in 0..50u64 {
            match service.try_submit_with_budget(
                a.clone(),
                Method::Classical(Classical::Fiedler),
                i,
                false,
                None,
                None,
                None,
            ) {
                Ok(rx) => accepted.push(rx),
                Err(TrySubmitError::Saturated) => saturated += 1,
                Err(TrySubmitError::ShutDown) => panic!("service must still be up"),
            }
        }
        assert!(
            saturated >= 1,
            "50 instant submissions into a 1-slot queue must saturate at least once"
        );
        assert!(!accepted.is_empty(), "some submissions must get through");
        // accepted requests are all answered — saturation never drops work
        for rx in accepted {
            let res = rx.recv().expect("response").result.expect("ok");
            check_permutation(&res.order).unwrap();
        }
    }
}
