//! Observability: bounded latency histograms, per-request stage
//! tracing, and Prometheus-style text exposition.
//!
//! - [`hist`] — fixed-memory log-bucketed histograms (the store behind
//!   every latency figure the coordinator exports).
//! - [`trace`] — stage spans on a monotonic clock, collected per
//!   request and kept in a bounded ring for `admin trace`.
//! - [`export`] — Prometheus text rendering of counters + histograms
//!   for `admin metrics --text`.
//!
//! Zero-dependency like the rest of the crate; see DESIGN.md
//! §Observability for the span taxonomy and histogram layout.

pub mod export;
pub mod hist;
pub mod trace;
