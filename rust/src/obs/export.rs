//! Prometheus-style text exposition of the coordinator metrics.
//!
//! `admin metrics` keeps its JSON snapshot; `admin metrics --text`
//! renders the same counters plus the latency histograms in the
//! Prometheus text format (`# TYPE` lines, cumulative `_bucket{le=...}`
//! series, `_sum`/`_count`), so a scraper pointed at a sidecar that
//! shells out to the admin protocol needs no translation layer. All
//! metric names carry a `pfm_` prefix. Bucket series are sparse: only
//! buckets that hold samples are emitted (plus the mandatory `+Inf`),
//! which keeps the 128-bucket grid from bloating the page.

use std::fmt::Write as _;

use crate::coordinator::Metrics;
use crate::obs::hist::Histogram;

fn counter(out: &mut String, name: &str, help: &str, value: usize) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {value}");
}

fn gauge(out: &mut String, name: &str, help: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {value}");
}

/// Emit one histogram's cumulative bucket series. `labels` is either
/// empty or a ready-made `key="value"` list without braces.
fn histogram(out: &mut String, name: &str, help: &str, labels: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let sep = if labels.is_empty() { "" } else { "," };
    for (upper, cum) in h.cumulative_buckets() {
        if upper.is_infinite() {
            continue; // folded into the +Inf series below
        }
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{upper}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count());
    let tail = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
    let _ = writeln!(out, "{name}_sum{tail} {}", h.sum());
    let _ = writeln!(out, "{name}_count{tail} {}", h.count());
}

/// Render the full metrics surface as Prometheus text.
pub fn prometheus_text(m: &Metrics) -> String {
    let mut out = String::new();

    // request counters
    let _ = writeln!(out, "# HELP pfm_requests_completed_total completed ordering requests");
    let _ = writeln!(out, "# TYPE pfm_requests_completed_total counter");
    for (method, n) in m.completed_by_method() {
        let _ = writeln!(out, "pfm_requests_completed_total{{method=\"{method}\"}} {n}");
    }
    counter(&mut out, "pfm_errors_total", "requests answered with an error", m.errors());
    counter(
        &mut out,
        "pfm_worker_panics_total",
        "serving-thread panics caught and answered as errors",
        m.worker_panics(),
    );
    gauge(
        &mut out,
        "pfm_queue_depth",
        "submissions sitting in the bounded queue",
        m.queue_depth() as f64,
    );
    counter(
        &mut out,
        "pfm_fallbacks_total",
        "learned requests served by the spectral fallback",
        m.fallbacks(),
    );
    counter(
        &mut out,
        "pfm_native_optimizer_total",
        "learned requests served by the native PFM optimizer",
        m.native_optimized(),
    );
    counter(&mut out, "pfm_symbolic_cache_hits_total", "symbolic-cache hits", m.symbolic_hits());
    counter(
        &mut out,
        "pfm_symbolic_cache_misses_total",
        "symbolic-cache misses",
        m.symbolic_misses(),
    );
    counter(
        &mut out,
        "pfm_shared_analyses_total",
        "analyses saved by pattern-keyed batch sharing",
        m.shared_analyses(),
    );
    counter(
        &mut out,
        "pfm_levels_refined_total",
        "V-cycle levels refined by native-PFM requests",
        m.levels_refined(),
    );
    gauge(&mut out, "pfm_probe_threads", "configured probe-pool width", m.probe_threads() as f64);
    gauge(
        &mut out,
        "pfm_factor_threads",
        "effective parallel-factorization width",
        m.factor_threads() as f64,
    );
    gauge(&mut out, "pfm_mean_batch", "mean network-executor batch occupancy", m.mean_batch());

    // gateway counters
    counter(
        &mut out,
        "pfm_gateway_connections_total",
        "accepted gateway connections",
        m.gateway_connections(),
    );
    counter(
        &mut out,
        "pfm_gateway_frames_rx_total",
        "well-framed gateway frames read",
        m.gateway_frames_rx(),
    );
    counter(
        &mut out,
        "pfm_gateway_frames_tx_total",
        "gateway frames written",
        m.gateway_frames_tx(),
    );
    counter(
        &mut out,
        "pfm_gateway_busy_queue_full_total",
        "requests answered Busy: bounded queue full",
        m.gateway_busy_queue(),
    );
    counter(
        &mut out,
        "pfm_gateway_busy_rate_limited_total",
        "requests answered Busy: token bucket exceeded",
        m.gateway_busy_throttled(),
    );
    counter(
        &mut out,
        "pfm_gateway_malformed_frames_total",
        "malformed frames rejected",
        m.gateway_malformed(),
    );
    counter(
        &mut out,
        "pfm_gateway_admin_requests_total",
        "admin-protocol requests served",
        m.gateway_admin(),
    );

    // warm-start persistence counters
    counter(
        &mut out,
        "pfm_persist_replayed_total",
        "orderings recovered at startup",
        m.persist_replayed(),
    );
    counter(
        &mut out,
        "pfm_persist_warm_hits_total",
        "requests short-circuited by the warm store",
        m.warm_hits(),
    );
    counter(
        &mut out,
        "pfm_persist_wal_appends_total",
        "records durably appended to the WAL",
        m.wal_appends(),
    );
    counter(
        &mut out,
        "pfm_persist_snapshots_total",
        "warm-store snapshots written",
        m.persist_snapshots(),
    );
    counter(
        &mut out,
        "pfm_persist_errors_total",
        "persistence I/O failures absorbed",
        m.persist_errors(),
    );

    // latency histograms
    for (method, h) in m.latency_histograms() {
        histogram(
            &mut out,
            "pfm_request_latency_seconds",
            "submit-to-respond request latency",
            &format!("method=\"{method}\""),
            &h,
        );
    }
    histogram(
        &mut out,
        "pfm_queue_wait_seconds",
        "submit to start-of-compute wait",
        "",
        &m.queue_wait_histogram(),
    );

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    #[test]
    fn exposition_has_counters_buckets_and_inf_series() {
        let m = Metrics::new();
        m.record("AMD", 0.004, 0, None);
        m.record("AMD", 0.008, 0, None);
        m.record("PFM", 0.120, 2, None);
        m.record_queue_wait(0.0003);
        m.record_error();
        let text = prometheus_text(&m);
        assert!(text.contains("pfm_requests_completed_total{method=\"AMD\"} 2"));
        assert!(text.contains("pfm_requests_completed_total{method=\"PFM\"} 1"));
        assert!(text.contains("pfm_errors_total 1"));
        assert!(text.contains("# TYPE pfm_request_latency_seconds histogram"));
        assert!(text.contains("pfm_request_latency_seconds_bucket{method=\"AMD\",le=\"+Inf\"} 2"));
        assert!(text.contains("pfm_request_latency_seconds_count{method=\"AMD\"} 2"));
        assert!(text.contains("pfm_queue_wait_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("pfm_queue_wait_seconds_sum 0.0003"));
        assert!(text.contains("pfm_queue_wait_seconds_count 1"));
        // sparse: far fewer bucket lines than the 128-bucket grid
        let bucket_lines = text.lines().filter(|l| l.contains("_bucket{")).count();
        assert!(bucket_lines < 20, "bucket series not sparse: {bucket_lines} lines");
        // cumulative within a series: AMD's two samples land in two
        // buckets whose cumulative counts are 1 then 2
        let amd: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("pfm_request_latency_seconds_bucket{method=\"AMD\""))
            .collect();
        assert_eq!(amd.len(), 3); // two sample buckets + +Inf
        assert!(amd[0].ends_with(" 1"));
        assert!(amd[1].ends_with(" 2"));
    }
}
