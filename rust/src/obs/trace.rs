//! Per-request stage spans on a monotonic clock.
//!
//! Each serving request carries a [`StageLog`] from the moment the
//! gateway reads its frame (or the coordinator accepts the submit) to
//! the moment the reply is encoded. Workers append non-overlapping
//! leaf [`Span`]s — decode, rate-limit, queue wait, warm-store lookup,
//! symbolic analysis, optimizer phases, numeric factor, encode — so
//! the sum of span durations is always ≤ the request's wall time (the
//! gaps are untimed glue: channel hops, result assembly).
//!
//! Completed logs are folded into a bounded [`TraceRing`] of the most
//! recent N request traces with a slow-request threshold, surfaced
//! through `admin trace`; the same spans ride the wire result so
//! `remote --json` can show the breakdown client-side.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// The span taxonomy. One label per distinct place a request spends
/// time; see DESIGN.md §Observability for the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Gateway: wire payload → `WireRequest` (CSR bounds checks included).
    Decode,
    /// Gateway: token-bucket admission check.
    RateLimit,
    /// Coordinator: submit → start of compute (submission queue + pool channel).
    QueueWait,
    /// Dispatcher: warm-ordering-store probe that hit.
    WarmLookup,
    /// Classical ordering, or the un-phased remainder of a native PFM run.
    Order,
    /// PFM: coarsening-hierarchy construction.
    Coarsen,
    /// PFM: ADMM on the dense or coarsest window.
    Admm,
    /// PFM: V-cycle + native-scale refinement passes (full-evaluation
    /// portion).
    Refine,
    /// PFM: the portion of refinement spent in incremental-engaged probe
    /// batches (base preparation + suffix re-walks; `pfm::incremental`).
    RefineIncremental,
    /// Fill evaluation: symbolic analysis served from the cache.
    SymbolicHit,
    /// Fill evaluation: symbolic analysis computed fresh.
    SymbolicMiss,
    /// Fill evaluation: LU numeric factorization.
    NumericFactor,
    /// Gateway: result → wire payload.
    Encode,
}

impl Stage {
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Decode => "decode",
            Stage::RateLimit => "rate_limit",
            Stage::QueueWait => "queue_wait",
            Stage::WarmLookup => "warm_lookup",
            Stage::Order => "order",
            Stage::Coarsen => "coarsen",
            Stage::Admm => "admm",
            Stage::Refine => "refine",
            Stage::RefineIncremental => "refine_incremental",
            Stage::SymbolicHit => "symbolic_hit",
            Stage::SymbolicMiss => "symbolic_miss",
            Stage::NumericFactor => "numeric_factor",
            Stage::Encode => "encode",
        }
    }
}

/// One timed stage of one request.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub stage: Stage,
    pub secs: f64,
}

/// The in-flight span collector a request carries from acceptance to
/// completion. `started` anchors wall time on the monotonic clock.
#[derive(Clone, Debug)]
pub struct StageLog {
    started: Instant,
    spans: Vec<Span>,
}

impl Default for StageLog {
    fn default() -> Self {
        StageLog::new()
    }
}

impl StageLog {
    /// Start the clock now (frame receipt / submit time).
    pub fn new() -> Self {
        StageLog { started: Instant::now(), spans: Vec::new() }
    }

    /// Append a span measured by the caller.
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.spans.push(Span { stage, secs: secs.max(0.0) });
    }

    /// Time a closure as one span.
    pub fn time<T>(&mut self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Sum of recorded span durations — by construction ≤ `wall()`.
    pub fn sum(&self) -> f64 {
        self.spans.iter().map(|s| s.secs).sum()
    }

    /// Wall time since the log was started.
    pub fn wall(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Seal the log into a ring entry for a completed request.
    pub fn finish(&self, id: u64, method: &'static str) -> RequestTrace {
        RequestTrace {
            id,
            method,
            started: self.started,
            wall_s: self.wall(),
            slow: false, // the ring applies its threshold on push
            spans: self.spans.clone(),
        }
    }
}

/// A completed request's trace as held by the ring.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    pub method: &'static str,
    /// Monotonic start, kept so a post-hoc encode annotation can extend
    /// `wall_s` and preserve the spans-≤-wall invariant.
    pub started: Instant,
    pub wall_s: f64,
    pub slow: bool,
    pub spans: Vec<Span>,
}

impl RequestTrace {
    /// Bytes of heap + inline state this entry holds (bounded: spans
    /// are capped by the stage taxonomy, the ring by its capacity).
    pub fn state_bytes(&self) -> usize {
        std::mem::size_of::<RequestTrace>() + self.spans.capacity() * std::mem::size_of::<Span>()
    }
}

/// Default ring capacity (`ServiceConfig::trace_capacity` overrides).
pub const DEFAULT_TRACE_CAPACITY: usize = 64;

/// Default slow-request threshold (`ServiceConfig::slow_threshold`).
pub const DEFAULT_SLOW_THRESHOLD: Duration = Duration::from_millis(500);

struct RingInner {
    buf: VecDeque<RequestTrace>,
    cap: usize,
    slow_threshold_s: f64,
    recorded: u64,
    slow: u64,
}

/// Bounded ring of the most recent request traces.
pub struct TraceRing {
    inner: Mutex<RingInner>,
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new(DEFAULT_TRACE_CAPACITY, DEFAULT_SLOW_THRESHOLD)
    }
}

impl TraceRing {
    pub fn new(cap: usize, slow_threshold: Duration) -> Self {
        TraceRing {
            inner: Mutex::new(RingInner {
                buf: VecDeque::new(),
                cap: cap.max(1),
                slow_threshold_s: slow_threshold.as_secs_f64(),
                recorded: 0,
                slow: 0,
            }),
        }
    }

    /// Re-arm capacity and threshold (service start applies its config;
    /// existing entries are trimmed to the new capacity).
    pub fn configure(&self, cap: usize, slow_threshold: Duration) {
        let mut g = lock_unpoisoned(&self.inner);
        g.cap = cap.max(1);
        g.slow_threshold_s = slow_threshold.as_secs_f64();
        while g.buf.len() > g.cap {
            g.buf.pop_front();
        }
    }

    /// Push a completed trace, evicting the oldest past capacity.
    pub fn push(&self, mut trace: RequestTrace) {
        let mut g = lock_unpoisoned(&self.inner);
        trace.slow = trace.wall_s >= g.slow_threshold_s;
        g.recorded += 1;
        if trace.slow {
            g.slow += 1;
        }
        g.buf.push_back(trace);
        if g.buf.len() > g.cap {
            g.buf.pop_front();
        }
    }

    /// Append an encode span to the ring entry for `id` (the gateway
    /// writer learns the encode duration only after the coordinator's
    /// trace was recorded). Wall time is extended to now so the
    /// invariant `sum(spans) ≤ wall` survives the late append. No-op
    /// if the entry has already been evicted.
    pub fn annotate_encode(&self, id: u64, secs: f64) {
        let mut g = lock_unpoisoned(&self.inner);
        let threshold = g.slow_threshold_s;
        let mut became_slow = false;
        if let Some(t) = g.buf.iter_mut().rev().find(|t| t.id == id) {
            t.spans.push(Span { stage: Stage::Encode, secs: secs.max(0.0) });
            t.wall_s = t.started.elapsed().as_secs_f64();
            if !t.slow && t.wall_s >= threshold {
                t.slow = true;
                became_slow = true;
            }
        }
        if became_slow {
            g.slow += 1;
        }
    }

    /// Newest-first copy of the ring (tests, JSON).
    pub fn recent(&self) -> Vec<RequestTrace> {
        let g = lock_unpoisoned(&self.inner);
        g.buf.iter().rev().cloned().collect()
    }

    /// Bytes held by the ring — bounded by `cap × per-trace bound`,
    /// independent of how many requests have passed through.
    pub fn state_bytes(&self) -> usize {
        let g = lock_unpoisoned(&self.inner);
        g.buf.iter().map(|t| t.state_bytes()).sum()
    }

    /// The `admin trace` payload: ring config, counters, and the most
    /// recent traces newest-first with per-span milliseconds.
    pub fn to_json(&self) -> Json {
        let g = lock_unpoisoned(&self.inner);
        let traces: Vec<Json> = g
            .buf
            .iter()
            .rev()
            .map(|t| {
                let spans: Vec<Json> = t
                    .spans
                    .iter()
                    .map(|s| Json::obj().set("stage", s.stage.label()).set("ms", s.secs * 1e3))
                    .collect();
                Json::obj()
                    .set("id", t.id as usize)
                    .set("method", t.method)
                    .set("wall_ms", t.wall_s * 1e3)
                    .set("slow", t.slow)
                    .set("stages", spans)
            })
            .collect();
        Json::obj()
            .set("capacity", g.cap)
            .set("slow_threshold_ms", g.slow_threshold_s * 1e3)
            .set("recorded", g.recorded as usize)
            .set("slow", g.slow as usize)
            .set("traces", traces)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    #[test]
    fn spans_are_ordered_and_cover_at_most_wall_time() {
        let mut log = StageLog::new();
        log.time(Stage::Decode, || sleep(Duration::from_millis(2)));
        log.time(Stage::QueueWait, || sleep(Duration::from_millis(3)));
        log.time(Stage::Order, || sleep(Duration::from_millis(2)));
        sleep(Duration::from_millis(1)); // untimed glue
        let wall = log.wall();
        assert!(log.sum() <= wall + 1e-9, "sum {} > wall {}", log.sum(), wall);
        assert!(log.sum() > 0.0);
        // recorded in call order
        let stages: Vec<&str> = log.spans().iter().map(|s| s.stage.label()).collect();
        assert_eq!(stages, ["decode", "queue_wait", "order"]);
        // durations are monotone w.r.t. the sleeps (coarse check: each ≥ its sleep)
        assert!(log.spans()[0].secs >= 0.002);
        assert!(log.spans()[1].secs >= 0.003);
    }

    #[test]
    fn negative_durations_are_clamped() {
        let mut log = StageLog::new();
        log.add(Stage::Order, -1.0);
        assert_eq!(log.spans()[0].secs, 0.0);
        assert!(log.sum() <= log.wall() + 1e-9);
    }

    #[test]
    fn ring_is_bounded_and_newest_first() {
        let ring = TraceRing::new(4, Duration::from_millis(500));
        for i in 0..10u64 {
            let log = StageLog::new();
            ring.push(log.finish(i, "AMD"));
        }
        let recent = ring.recent();
        assert_eq!(recent.len(), 4);
        let ids: Vec<u64> = recent.iter().map(|t| t.id).collect();
        assert_eq!(ids, [9, 8, 7, 6]);
        let s = ring.to_json().to_string();
        assert!(s.contains("\"recorded\":10"));
        assert!(s.contains("\"capacity\":4"));
    }

    #[test]
    fn slow_threshold_flags_requests() {
        let ring = TraceRing::new(8, Duration::from_millis(1));
        let log = StageLog::new();
        sleep(Duration::from_millis(3));
        ring.push(log.finish(1, "PFM"));
        let fast = StageLog::new();
        ring.push(fast.finish(2, "PFM"));
        let recent = ring.recent();
        assert!(recent.iter().find(|t| t.id == 1).unwrap().slow);
        assert!(!recent.iter().find(|t| t.id == 2).unwrap().slow);
        assert!(ring.to_json().to_string().contains("\"slow\":1"));
    }

    #[test]
    fn encode_annotation_appends_span_and_extends_wall() {
        let ring = TraceRing::new(8, Duration::from_millis(500));
        let mut log = StageLog::new();
        log.time(Stage::Order, || sleep(Duration::from_millis(2)));
        ring.push(log.finish(7, "RCM"));
        sleep(Duration::from_millis(2));
        ring.annotate_encode(7, 0.0015);
        let t = ring.recent().into_iter().find(|t| t.id == 7).unwrap();
        assert_eq!(t.spans.last().unwrap().stage, Stage::Encode);
        let sum: f64 = t.spans.iter().map(|s| s.secs).sum();
        assert!(sum <= t.wall_s + 1e-9, "sum {} > wall {}", sum, t.wall_s);
        // unknown id: no panic, no change
        ring.annotate_encode(999, 0.1);
        assert_eq!(ring.recent().len(), 1);
    }
}
