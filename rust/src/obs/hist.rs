//! Fixed-memory log-bucketed latency histograms.
//!
//! The serving metrics used to keep every latency sample in a
//! `Vec<f64>` — a slow leak on a long-running `serve` (every request
//! forever). A [`Histogram`] replaces that with a fixed array of
//! `BUCKETS` counters on a logarithmic grid: ten buckets per decade
//! starting at 1 µs, so bucket width is a constant ~26% relative error
//! anywhere in the range and the whole structure is ~1 KiB regardless
//! of how many samples it has absorbed.
//!
//! Quantiles are estimated by walking the cumulative counts to the
//! bucket containing the requested rank and reporting that bucket's
//! upper bound (clamped to the exact observed `min`/`max`, which are
//! tracked alongside). Because the bucket index is a monotone function
//! of the value, the estimate is guaranteed to land in the same bucket
//! as the exact sorted-sample quantile — "within one bucket" accuracy,
//! asserted by the property tests below.
//!
//! Histograms merge by elementwise addition, so per-worker or
//! per-shard instances can be combined without losing accuracy — merge
//! is associative and identical to having recorded all samples into
//! one instance (also asserted below).

use crate::util::json::Json;

/// Number of buckets. Bucket 0 is the underflow bucket `[0, MIN]`, the
/// last bucket is the overflow bucket; the 126 in between cover
/// `(MIN·G^(i-1), MIN·G^i]`. At 10 buckets/decade that spans 12.6
/// decades: 1 µs up to ~46 days, far past any plausible request.
pub const BUCKETS: usize = 128;

/// Lower edge of the grid in seconds: nothing we time resolves below
/// a microsecond.
const MIN_S: f64 = 1e-6;

/// Buckets per decade — the grid growth factor is `10^(1/PER_DECADE)`.
const PER_DECADE: f64 = 10.0;

/// A mergeable latency histogram with O(1) memory in sample count.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    /// Bucket index for a value in seconds. Monotone non-decreasing in
    /// `x`, which is what makes the quantile estimate bucket-exact.
    pub fn bucket_index(x: f64) -> usize {
        if !(x > MIN_S) {
            // NaN, negatives and everything up to MIN_S land in the
            // underflow bucket.
            return 0;
        }
        let i = ((x / MIN_S).log10() * PER_DECADE).ceil() as isize;
        i.clamp(1, BUCKETS as isize - 1) as usize
    }

    /// Inclusive upper bound of a bucket in seconds (`+inf` for the
    /// overflow bucket).
    pub fn bucket_upper(i: usize) -> f64 {
        if i == 0 {
            MIN_S
        } else if i >= BUCKETS - 1 {
            f64::INFINITY
        } else {
            MIN_S * 10f64.powf(i as f64 / PER_DECADE)
        }
    }

    /// Record one sample (seconds). Non-finite and negative values are
    /// clamped to zero rather than dropped so `count` stays in step
    /// with the number of requests observed.
    pub fn record(&mut self, secs: f64) {
        let x = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        self.counts[Self::bucket_index(x)] += 1;
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Fold another histogram into this one. Equivalent to having
    /// recorded all of `other`'s samples here.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate for `q` in `[0, 1]`: the upper bound of the
    /// bucket containing the sample of rank `ceil(q·n)`, clamped to the
    /// exact observed extrema. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Index of the bucket containing the sample of rank `ceil(q·n)` —
    /// the bucket `quantile(q)` reports from. Used by the accuracy
    /// tests to assert bucket-exactness against sorted samples.
    pub fn quantile_bucket(&self, q: f64) -> usize {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return i;
            }
        }
        BUCKETS - 1
    }

    /// Non-empty buckets as `(upper_bound_s, cumulative_count)` pairs in
    /// ascending order — the shape Prometheus text exposition wants.
    /// The final `+Inf` bucket is the caller's to emit (it equals
    /// `count()`).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            out.push((Self::bucket_upper(i), cum));
        }
        out
    }

    /// Summary block used by the metrics JSON. Keeps the seed-era keys
    /// (`count`, `mean_s`, `p95_s`, `max_s`) and adds the rest of the
    /// quantile ladder.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count as usize)
            .set("mean_s", self.mean())
            .set("min_s", self.min())
            .set("p50_s", self.quantile(0.50))
            .set("p95_s", self.quantile(0.95))
            .set("p99_s", self.quantile(0.99))
            .set("p999_s", self.quantile(0.999))
            .set("max_s", self.max())
    }
}

/// Exact quantile of a sorted sample set at rank `ceil(q·n)` — the
/// reference the histogram estimate is tested against, and what the
/// replay driver (which holds its full sample set anyway) reports.
pub fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Log-uniform samples spanning the interesting serving range
    /// (~1 µs to ~100 s) plus occasional out-of-range extremes.
    fn random_workload(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match rng.next_below(20) {
                0 => 0.0,
                1 => -1.0,
                2 => 1e-9,
                3 => 1e5,
                _ => 10f64.powf(-6.0 + 8.0 * rng.next_f64()),
            })
            .collect()
    }

    fn hist_of(samples: &[f64]) -> Histogram {
        let mut h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        h
    }

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut rng = Pcg64::new(7);
        let mut xs = random_workload(&mut rng, 4000);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = 0usize;
        for &x in &xs {
            let i = Histogram::bucket_index(x);
            assert!(i < BUCKETS);
            assert!(i >= prev, "bucket index not monotone at {x}");
            // the value must actually lie under its bucket's bound
            assert!(x.max(0.0) <= Histogram::bucket_upper(i));
            prev = i;
        }
    }

    #[test]
    fn quantile_lands_in_the_exact_samples_bucket() {
        // proptest-style: many random workloads, each checked at the
        // whole quantile ladder against exact sorted-sample quantiles.
        for seed in 0..40u64 {
            let mut rng = Pcg64::new(seed * 31 + 1);
            let n = 1 + rng.next_below(3000) as usize;
            let samples = random_workload(&mut rng, n);
            let h = hist_of(&samples);
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &q in &[0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
                let exact = exact_quantile(&sorted, q).max(0.0);
                let exact_bucket = Histogram::bucket_index(exact);
                assert_eq!(
                    h.quantile_bucket(q),
                    exact_bucket,
                    "seed {seed} q {q}: estimate bucket != exact sample's bucket"
                );
                // and the reported value bounds the exact one from
                // above within the bucket (clamped to observed max)
                let est = h.quantile(q);
                assert!(
                    est >= exact || (est - exact).abs() < 1e-12,
                    "seed {seed} q {q}: estimate {est} below exact {exact}"
                );
                assert!(est <= h.max() + 1e-12);
            }
        }
    }

    #[test]
    fn merge_is_associative_and_matches_single_recording() {
        for seed in 0..10u64 {
            let mut rng = Pcg64::new(seed + 100);
            let a = random_workload(&mut rng, 500);
            let b = random_workload(&mut rng, 700);
            let c = random_workload(&mut rng, 300);
            let (ha, hb, hc) = (hist_of(&a), hist_of(&b), hist_of(&c));

            // (a ⊕ b) ⊕ c
            let mut left = ha.clone();
            left.merge(&hb);
            left.merge(&hc);
            // a ⊕ (b ⊕ c)
            let mut bc = hb.clone();
            bc.merge(&hc);
            let mut right = ha.clone();
            right.merge(&bc);

            // single pass over the concatenation
            let mut all = a.clone();
            all.extend_from_slice(&b);
            all.extend_from_slice(&c);
            let one = hist_of(&all);

            for h in [&left, &right] {
                assert_eq!(h.counts, one.counts);
                assert_eq!(h.count, one.count);
                assert!((h.sum - one.sum).abs() < 1e-9 * one.sum.abs().max(1.0));
                assert_eq!(h.min, one.min);
                assert_eq!(h.max, one.max);
            }
        }
    }

    #[test]
    fn empty_and_single_sample_edges() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert!(h.cumulative_buckets().is_empty());

        let mut h = Histogram::new();
        h.record(0.125);
        assert_eq!(h.count(), 1);
        // every quantile of one sample is that sample's bucket, and the
        // clamp to observed extrema makes the estimate exact
        for &q in &[0.0, 0.5, 0.999, 1.0] {
            assert!((h.quantile(q) - 0.125).abs() < 1e-12);
        }
        let cum = h.cumulative_buckets();
        assert_eq!(cum.len(), 1);
        assert_eq!(cum[0].1, 1);
        assert!(cum[0].0 >= 0.125);
    }

    #[test]
    fn overflow_and_underflow_buckets() {
        let mut h = Histogram::new();
        h.record(1e9); // > top of grid → overflow bucket
        h.record(0.0); // underflow
        h.record(-3.0); // clamped to 0, underflow
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), 1e9);
        assert_eq!(h.min(), 0.0);
        // p99 of 3 samples is rank 3 → the overflow bucket, clamped to max
        assert_eq!(h.quantile(0.99), 1e9);
        assert_eq!(Histogram::bucket_index(1e9), BUCKETS - 1);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
    }

    #[test]
    fn json_summary_has_seed_era_and_new_keys() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let s = h.to_json().to_string();
        let keys = [
            "\"count\":100",
            "\"mean_s\":",
            "\"p95_s\":",
            "\"max_s\":",
            "\"p50_s\":",
            "\"p99_s\":",
            "\"p999_s\":",
            "\"min_s\":",
        ];
        for key in keys {
            assert!(s.contains(key), "missing {key} in {s}");
        }
    }
}
