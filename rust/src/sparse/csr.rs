//! Compressed sparse row matrix — the workhorse format for every layer of
//! the system: orderings read its pattern, the factorizer consumes it, the
//! coordinator densifies it for the PFM network.

use crate::sparse::coo::Coo;

/// Compressed sparse row matrix with sorted column indices per row.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    data: Vec<f64>,
}

impl Csr {
    /// Build from raw parts. Column indices must be sorted and in range;
    /// validated in debug builds (untrusted inputs must go through
    /// [`validate_parts`](Csr::validate_parts) first — these checks are
    /// compiled out of release binaries).
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        data: Vec<f64>,
    ) -> Csr {
        debug_assert_eq!(indices.len(), data.len());
        #[cfg(debug_assertions)]
        if let Err(e) = Csr::validate_parts(nrows, ncols, &indptr, &indices) {
            panic!("Csr::from_parts: {e}");
        }
        Csr { nrows, ncols, indptr, indices, data }
    }

    /// Structural validation of *untrusted* CSR parts: `indptr` has
    /// `nrows + 1` entries running monotonically from 0 to `indices.len()`,
    /// and every row's column indices are strictly increasing and below
    /// `ncols`. This is the single audited implementation shared by the
    /// gateway wire decoder and the persistence replay path —
    /// [`from_parts`](Csr::from_parts) only runs it in debug builds, so it
    /// must never be the last line of defense on hostile or on-disk bytes.
    /// Squareness and dimension caps are context-specific and stay with
    /// the caller.
    pub fn validate_parts(
        nrows: usize,
        ncols: usize,
        indptr: &[usize],
        indices: &[usize],
    ) -> Result<(), String> {
        if indptr.len() != nrows + 1 {
            return Err(format!(
                "indptr has {} entries, expected nrows + 1 = {}",
                indptr.len(),
                nrows + 1
            ));
        }
        if indptr[0] != 0 || indptr[nrows] != indices.len() {
            return Err("indptr must run from 0 to nnz".to_string());
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("indptr must be non-decreasing".to_string());
        }
        for row in 0..nrows {
            let cols = &indices[indptr[row]..indptr[row + 1]];
            if cols.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("row {row}: column indices not strictly increasing"));
            }
            if cols.last().is_some_and(|&c| c >= ncols) {
                return Err(format!("row {row}: column index out of range"));
            }
        }
        Ok(())
    }

    /// n×n identity.
    pub fn identity(n: usize) -> Csr {
        Csr::from_parts(n, n, (0..=n).collect(), (0..n).collect(), vec![1.0; n])
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// (column indices, values) of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// Value at (r, c); zero if not stored. O(log nnz(row)).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&c) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Structural degree of row r excluding the diagonal.
    pub fn off_diag_degree(&self, r: usize) -> usize {
        let (cols, _) = self.row(r);
        cols.iter().filter(|&&c| c != r).count()
    }

    /// Transpose (also converts CSR→CSC views).
    pub fn transpose(&self) -> Csr {
        let (mut indptr, mut indices, mut data) = (Vec::new(), Vec::new(), Vec::new());
        self.transpose_into(&mut indptr, &mut indices, &mut data);
        Csr::from_parts(self.ncols, self.nrows, indptr, indices, data)
    }

    /// Transpose into caller-owned buffers (the CSC view serving paths
    /// reuse across factorizations): allocation-free when the buffers'
    /// capacities already suffice. Output rows are sorted, as
    /// [`from_parts`](Csr::from_parts) requires.
    pub fn transpose_into(
        &self,
        indptr: &mut Vec<usize>,
        indices: &mut Vec<usize>,
        data: &mut Vec<f64>,
    ) {
        indptr.clear();
        indptr.resize(self.ncols + 1, 0);
        indices.clear();
        indices.resize(self.nnz(), 0);
        data.clear();
        data.resize(self.nnz(), 0.0);
        for &c in &self.indices {
            indptr[c + 1] += 1;
        }
        for i in 0..self.ncols {
            indptr[i + 1] += indptr[i];
        }
        // scatter using indptr[c] as the running insert position (rows
        // arrive in ascending order, so each output row stays sorted) …
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let p = indptr[c];
                indices[p] = r;
                data[p] = v;
                indptr[c] += 1;
            }
        }
        // … which leaves indptr shifted one column left; shift it back
        for c in (1..=self.ncols).rev() {
            indptr[c] = indptr[c - 1];
        }
        indptr[0] = 0;
    }

    /// Pattern-and-value symmetry check (|a_ij − a_ji| ≤ tol·max(1,|a_ij|)).
    ///
    /// Allocation-free: every stored entry binary-searches its mirror
    /// (present-with-matching-value, explicit zeros included), which is
    /// equivalent to comparing against the full transpose — kind dispatch
    /// runs this on serving paths, so it must not touch the allocator.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if c == r {
                    continue;
                }
                let (mcols, mvals) = self.row(c);
                match mcols.binary_search(&r) {
                    // negated `<=` so a NaN anywhere fails the check (as
                    // the old compare-against-transpose version did)
                    Ok(k) => {
                        if !((v - mvals[k]).abs() <= tol * 1.0_f64.max(v.abs())) {
                            return false;
                        }
                    }
                    Err(_) => return false, // mirror entry structurally absent
                }
            }
        }
        true
    }

    /// Symmetrize: (A + Aᵀ)/2 on the union pattern.
    pub fn symmetrize(&self) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        let mut coo = Coo::square(self.nrows);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(r, c, v / 2.0);
                coo.push(c, r, v / 2.0);
            }
        }
        coo.to_csr()
    }

    /// y = A·x (dense vector).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            y[r] = acc;
        }
        y
    }

    /// Symmetric permutation B = P A Pᵀ where `order[k]` is the original
    /// index placed at position k (i.e. B[i][j] = A[order[i]][order[j]]).
    pub fn permute_sym(&self, order: &[usize]) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(order.len(), self.nrows);
        let n = self.nrows;
        // inverse: old index -> new position
        let mut inv = vec![usize::MAX; n];
        for (newi, &old) in order.iter().enumerate() {
            assert!(old < n && inv[old] == usize::MAX, "order is not a permutation");
            inv[old] = newi;
        }
        let mut indptr = vec![0usize; n + 1];
        for newr in 0..n {
            indptr[newr + 1] = indptr[newr] + (self.indptr[order[newr] + 1] - self.indptr[order[newr]]);
        }
        let nnz = self.nnz();
        let mut indices = vec![0usize; nnz];
        let mut data = vec![0.0f64; nnz];
        // scratch reused per row to sort (new_col, val) pairs
        let mut rowbuf: Vec<(usize, f64)> = Vec::new();
        for newr in 0..n {
            let oldr = order[newr];
            let (cols, vals) = self.row(oldr);
            rowbuf.clear();
            rowbuf.extend(cols.iter().zip(vals).map(|(&c, &v)| (inv[c], v)));
            rowbuf.sort_unstable_by_key(|&(c, _)| c);
            let s = indptr[newr];
            for (k, &(c, v)) in rowbuf.iter().enumerate() {
                indices[s + k] = c;
                data[s + k] = v;
            }
        }
        Csr::from_parts(n, n, indptr, indices, data)
    }

    /// Dense copy (small matrices only — tests, network input panels).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                d[r][c] = v;
            }
        }
        d
    }

    /// Flattened row-major dense f32 copy, zero-padded to `pad` columns/rows
    /// (PFM network input; `pad >= n`).
    pub fn to_dense_padded_f32(&self, pad: usize) -> Vec<f32> {
        assert!(pad >= self.nrows.max(self.ncols));
        let mut d = vec![0.0f32; pad * pad];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                d[r * pad + c] = v as f32;
            }
        }
        d
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ii| scale-free diagonal-dominance margin: min_i (|a_ii| - Σ_{j≠i}|a_ij|).
    pub fn diag_dominance_margin(&self) -> f64 {
        let mut margin = f64::INFINITY;
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c == r {
                    diag = v.abs();
                } else {
                    off += v.abs();
                }
            }
            margin = margin.min(diag - off);
        }
        margin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        // [2 1 0]
        // [1 3 0]
        // [0 0 4]
        let mut c = Coo::square(3);
        c.push(0, 0, 2.0);
        c.push_sym(0, 1, 1.0);
        c.push(1, 1, 3.0);
        c.push(2, 2, 4.0);
        c.to_csr()
    }

    #[test]
    fn get_and_row() {
        let a = example();
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
        assert_eq!(a.get(2, 0), 0.0);
        assert_eq!(a.row(1).0, &[0, 1]);
    }

    #[test]
    fn transpose_involution() {
        let a = example();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetry_checks() {
        let a = example();
        assert!(a.is_symmetric(1e-12));
        let mut c = Coo::square(2);
        c.push(0, 1, 1.0);
        c.push(0, 0, 1.0);
        c.push(1, 1, 1.0);
        assert!(!c.to_csr().is_symmetric(1e-12));
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut c = Coo::square(3);
        c.push(0, 1, 2.0);
        c.push(1, 2, 4.0);
        for i in 0..3 {
            c.push(i, i, 5.0);
        }
        let s = c.to_csr().symmetrize();
        assert!(s.is_symmetric(1e-12));
        assert_eq!(s.get(0, 1), 1.0);
        assert_eq!(s.get(1, 0), 1.0);
        assert_eq!(s.get(2, 2), 5.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![4.0, 7.0, 12.0]);
    }

    #[test]
    fn permute_sym_reorders() {
        let a = example();
        // order [2,0,1]: new0=old2, new1=old0, new2=old1
        let b = a.permute_sym(&[2, 0, 1]);
        assert_eq!(b.get(0, 0), 4.0);
        assert_eq!(b.get(1, 1), 2.0);
        assert_eq!(b.get(1, 2), 1.0);
        assert_eq!(b.get(2, 1), 1.0);
        assert!(b.is_symmetric(1e-12));
    }

    #[test]
    fn permute_identity_is_noop() {
        let a = example();
        assert_eq!(a.permute_sym(&[0, 1, 2]), a);
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn permute_rejects_invalid() {
        example().permute_sym(&[0, 0, 1]);
    }

    #[test]
    fn dense_padded() {
        let a = example();
        let d = a.to_dense_padded_f32(4);
        assert_eq!(d.len(), 16);
        assert_eq!(d[0], 2.0);
        assert_eq!(d[1], 1.0);
        assert_eq!(d[15], 0.0);
        assert_eq!(d[2 * 4 + 2], 4.0);
    }

    #[test]
    fn validate_parts_accepts_good_and_rejects_bad() {
        let a = example();
        assert!(Csr::validate_parts(a.nrows(), a.ncols(), a.indptr(), a.indices()).is_ok());
        // wrong indptr length
        assert!(Csr::validate_parts(3, 3, &[0, 1, 2], &[0, 1]).is_err());
        // indptr not ending at nnz
        assert!(Csr::validate_parts(2, 2, &[0, 1, 3], &[0, 1]).is_err());
        // non-monotone indptr
        let e = Csr::validate_parts(2, 2, &[0, 2, 1], &[0; 1]).unwrap_err();
        assert!(e.contains("non-decreasing") || e.contains("0 to nnz"), "{e}");
        // duplicate column in a row
        let e = Csr::validate_parts(1, 3, &[0, 2], &[1, 1]).unwrap_err();
        assert!(e.contains("strictly increasing"), "{e}");
        // column index out of range
        let e = Csr::validate_parts(1, 2, &[0, 1], &[2]).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
    }

    #[test]
    fn dominance_margin() {
        let a = example();
        // rows: 2-1=1, 3-1=2, 4-0=4 → min 1
        assert_eq!(a.diag_dominance_margin(), 1.0);
    }
}
