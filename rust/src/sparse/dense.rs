//! Small dense-matrix reference kernels.
//!
//! Used only by tests and tiny reference computations (dense Cholesky as an
//! oracle for the sparse factorizer, dense eigen-iteration checks for the
//! Lanczos module). Row-major `Vec<f64>` with explicit dimension — not a
//! performance path.

/// Row-major dense square matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Dense {
    pub fn zeros(n: usize) -> Dense {
        Dense { n, a: vec![0.0; n * n] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Dense {
        let n = rows.len();
        let mut d = Dense::zeros(n);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), n);
            d.a[i * n..(i + 1) * n].copy_from_slice(row);
        }
        d
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    /// Dense Cholesky A = L·Lᵀ. Returns lower-triangular L (including the
    /// diagonal). Errors if the matrix is not positive definite.
    pub fn cholesky(&self) -> Result<Dense, String> {
        let n = self.n;
        let mut l = Dense::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(format!("not SPD: pivot {s} at column {i}"));
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Ok(l)
    }

    /// Dense LU without pivoting (Doolittle): A = L·U with unit-lower L.
    /// Reference oracle for the sparse LU's no-pivot path. Errors on a
    /// (near-)zero pivot.
    pub fn lu_nopivot(&self) -> Result<(Dense, Dense), String> {
        let n = self.n;
        let mut l = Dense::zeros(n);
        let mut u = Dense::zeros(n);
        for i in 0..n {
            l.set(i, i, 1.0);
        }
        for j in 0..n {
            for i in 0..=j {
                let mut s = self.get(i, j);
                for k in 0..i {
                    s -= l.get(i, k) * u.get(k, j);
                }
                u.set(i, j, s);
            }
            let piv = u.get(j, j);
            if piv.abs() < 1e-300 {
                return Err(format!("zero pivot at column {j}"));
            }
            for i in (j + 1)..n {
                let mut s = self.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * u.get(k, j);
                }
                l.set(i, j, s / piv);
            }
        }
        Ok((l, u))
    }

    /// Dense LU with partial pivoting: P·A = L·U. Returns (L, U, perm)
    /// with `perm[k]` = original row pivoted at step k. Reference oracle
    /// for the sparse LU under `tau = 1.0`.
    pub fn lu_partial_pivot(&self) -> Result<(Dense, Dense, Vec<usize>), String> {
        let n = self.n;
        let mut a = self.clone(); // working copy, row-swapped in place
        let mut perm: Vec<usize> = (0..n).collect();
        for j in 0..n {
            // pivot search in column j at/below the diagonal
            let mut best = j;
            for i in (j + 1)..n {
                if a.get(i, j).abs() > a.get(best, j).abs() {
                    best = i;
                }
            }
            if a.get(best, j).abs() < 1e-300 {
                return Err(format!("singular at column {j}"));
            }
            if best != j {
                perm.swap(j, best);
                for c in 0..n {
                    let t = a.get(j, c);
                    a.set(j, c, a.get(best, c));
                    a.set(best, c, t);
                }
            }
            let piv = a.get(j, j);
            for i in (j + 1)..n {
                let m = a.get(i, j) / piv;
                a.set(i, j, m);
                for c in (j + 1)..n {
                    a.set(i, c, a.get(i, c) - m * a.get(j, c));
                }
            }
        }
        let mut l = Dense::zeros(n);
        let mut u = Dense::zeros(n);
        for i in 0..n {
            l.set(i, i, 1.0);
            for c in 0..i {
                l.set(i, c, a.get(i, c));
            }
            for c in i..n {
                u.set(i, c, a.get(i, c));
            }
        }
        Ok((l, u, perm))
    }

    /// Count entries of the lower triangle (incl. diagonal) with |x| > tol.
    pub fn tril_nnz(&self, tol: f64) -> usize {
        let mut count = 0;
        for i in 0..self.n {
            for j in 0..=i {
                if self.get(i, j).abs() > tol {
                    count += 1;
                }
            }
        }
        count
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        (0..self.n)
            .map(|i| (0..self.n).map(|j| self.get(i, j) * x[j]).sum())
            .collect()
    }

    /// Solve L·y = b (forward substitution), L lower-triangular.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = b.to_vec();
        for i in 0..n {
            for j in 0..i {
                let lij = self.get(i, j);
                y[i] -= lij * y[j];
            }
            y[i] /= self.get(i, i);
        }
        y
    }

    /// Solve Lᵀ·x = y (backward substitution using the stored lower factor).
    pub fn solve_lower_transpose(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                x[i] -= self.get(j, i) * x[j];
            }
            x[i] /= self.get(i, i);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_vec_close;

    fn spd3() -> Dense {
        Dense::from_rows(&[
            vec![4.0, 2.0, 0.0],
            vec![2.0, 5.0, 1.0],
            vec![0.0, 1.0, 3.0],
        ])
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd3();
        let l = a.cholesky().unwrap();
        // check L Lᵀ = A
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l.get(i, k) * l.get(j, k);
                }
                assert!((s - a.get(i, j)).abs() < 1e-12, "({i},{j})");
            }
        }
        // upper triangle of L is zero
        assert_eq!(l.get(0, 1), 0.0);
        assert_eq!(l.get(0, 2), 0.0);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Dense::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(m.cholesky().is_err());
    }

    #[test]
    fn solve_roundtrip() {
        let a = spd3();
        let l = a.cholesky().unwrap();
        let b = vec![1.0, 2.0, 3.0];
        let y = l.solve_lower(&b);
        let x = l.solve_lower_transpose(&y);
        assert_vec_close(&a.matvec(&x), &b, 1e-12);
    }

    #[test]
    fn lu_nopivot_reconstructs() {
        let a = Dense::from_rows(&[
            vec![4.0, 2.0, 1.0],
            vec![-1.0, 5.0, 0.5],
            vec![0.0, 1.5, 3.0],
        ]);
        let (l, u) = a.lu_nopivot().unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l.get(i, k) * u.get(k, j);
                }
                assert!((s - a.get(i, j)).abs() < 1e-12, "({i},{j})");
            }
        }
        assert_eq!(l.get(0, 0), 1.0);
        assert_eq!(u.get(1, 0), 0.0);
    }

    #[test]
    fn lu_partial_pivot_reconstructs_permuted() {
        let a = Dense::from_rows(&[
            vec![0.0, 2.0, 1.0],
            vec![3.0, 1.0, 0.5],
            vec![1.0, 1.5, 3.0],
        ]);
        let (l, u, perm) = a.lu_partial_pivot().unwrap();
        assert_ne!(perm, vec![0, 1, 2], "pivoting must fire (zero a00)");
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l.get(i, k) * u.get(k, j);
                }
                assert!((s - a.get(perm[i], j)).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn tril_nnz_counts() {
        let a = spd3();
        assert_eq!(a.tril_nnz(0.0), 5); // 3 diagonal + (1,0) + (2,1)
    }
}
