//! Coordinate-format sparse matrix builder.
//!
//! COO is the assembly format: generators and Matrix Market readers push
//! `(row, col, value)` triplets, duplicates summed on conversion to CSR.

use crate::sparse::csr::Csr;

/// Coordinate-format sparse matrix (assembly only; convert to [`Csr`] for
/// computation).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    nrows: usize,
    ncols: usize,
    entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    /// Empty n×n builder.
    pub fn square(n: usize) -> Coo {
        Coo { nrows: n, ncols: n, entries: Vec::new() }
    }

    /// Empty rectangular builder.
    pub fn new(nrows: usize, ncols: usize) -> Coo {
        Coo { nrows, ncols, entries: Vec::new() }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of (possibly duplicate) stored triplets.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Push a triplet. Duplicates are summed at conversion time.
    #[inline]
    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        debug_assert!(row < self.nrows && col < self.ncols, "({row},{col}) out of bounds");
        self.entries.push((row, col, val));
    }

    /// Push `val` at (row, col) and (col, row) (off-diagonal symmetric pair);
    /// pushes once if row == col.
    #[inline]
    pub fn push_sym(&mut self, row: usize, col: usize, val: f64) {
        self.push(row, col, val);
        if row != col {
            self.push(col, row, val);
        }
    }

    /// Access raw triplets (for tests / IO).
    pub fn entries(&self) -> &[(usize, usize, f64)] {
        &self.entries
    }

    /// Convert to CSR: sort triplets, sum duplicates, drop explicit zeros
    /// produced by cancellation only if `drop_zeros` (structural zeros from
    /// input are preserved by default — fill-in analysis is pattern-based).
    pub fn to_csr(&self) -> Csr {
        let mut trip = self.entries.clone();
        // STABLE sort: duplicate (row, col) triplets must accumulate in
        // insertion order so mirrored cells of a symmetric assembly sum in
        // the same order and land on bit-identical values
        trip.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; self.nrows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(trip.len());
        let mut data: Vec<f64> = Vec::with_capacity(trip.len());
        let mut prev: Option<(usize, usize)> = None;
        for &(r, c, v) in &trip {
            if prev == Some((r, c)) {
                *data.last_mut().unwrap() += v; // duplicate triplet → sum
            } else {
                indices.push(c);
                data.push(v);
                indptr[r + 1] += 1;
                prev = Some((r, c));
            }
        }
        for i in 0..self.nrows {
            indptr[i + 1] += indptr[i];
        }
        Csr::from_parts(self.nrows, self.ncols, indptr, indices, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_convert() {
        let mut c = Coo::square(3);
        c.push(0, 0, 2.0);
        c.push(1, 2, 3.0);
        c.push(2, 1, 3.0);
        c.push(1, 1, 4.0);
        let a = c.to_csr();
        assert_eq!(a.nrows(), 3);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(1, 2), 3.0);
        assert_eq!(a.get(0, 1), 0.0);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut c = Coo::square(2);
        c.push(0, 1, 1.0);
        c.push(0, 1, 2.5);
        c.push(0, 0, 1.0);
        let a = c.to_csr();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(0, 1), 3.5);
    }

    #[test]
    fn push_sym_mirrors() {
        let mut c = Coo::square(3);
        c.push_sym(0, 2, 5.0);
        c.push_sym(1, 1, 7.0);
        let a = c.to_csr();
        assert_eq!(a.get(0, 2), 5.0);
        assert_eq!(a.get(2, 0), 5.0);
        assert_eq!(a.get(1, 1), 7.0);
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn empty_rows_ok() {
        let mut c = Coo::square(4);
        c.push(3, 3, 1.0);
        let a = c.to_csr();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.row(0).0.len(), 0);
        assert_eq!(a.row(3).0, &[3]);
    }
}
