//! Matrix Market exchange-format I/O.
//!
//! Supports the subset covering SuiteSparse matrices the paper evaluates on:
//! `matrix coordinate real {general|symmetric}` and
//! `matrix coordinate pattern {general|symmetric}` (pattern entries get
//! value 1.0). Symmetric files store the lower triangle; the reader mirrors
//! it. Writers emit `symmetric` when the matrix is numerically symmetric.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;

/// Errors from Matrix Market parsing.
#[derive(Debug)]
pub enum MmError {
    Io(std::io::Error),
    Header(String),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for MmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmError::Io(e) => write!(f, "io error: {e}"),
            MmError::Header(h) => write!(f, "bad MatrixMarket header: {h}"),
            MmError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
        }
    }
}

impl std::error::Error for MmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MmError {
    fn from(e: std::io::Error) -> Self {
        MmError::Io(e)
    }
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Field {
    Real,
    Pattern,
    Integer,
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Symmetry {
    General,
    Symmetric,
}

/// Read a Matrix Market file into CSR.
pub fn read_matrix_market(path: impl AsRef<Path>) -> Result<Csr, MmError> {
    let file = std::fs::File::open(path)?;
    read_matrix_market_from(std::io::BufReader::new(file))
}

/// Read Matrix Market content from any reader.
pub fn read_matrix_market_from(reader: impl BufRead) -> Result<Csr, MmError> {
    let mut lines = reader.lines().enumerate();

    // header line
    let (_, header) = lines
        .next()
        .ok_or_else(|| MmError::Header("empty file".into()))?;
    let header = header?;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_lowercase()).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" || h[2] != "coordinate" {
        return Err(MmError::Header(format!("unsupported header: {header}")));
    }
    let field = match h[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        f => return Err(MmError::Header(format!("unsupported field type: {f}"))),
    };
    let symmetry = match h[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        s => return Err(MmError::Header(format!("unsupported symmetry: {s}"))),
    };

    // size line (skipping comments)
    let mut size_line = None;
    for (lineno, line) in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some((lineno, t.to_string()));
        break;
    }
    let (lineno, size_line) =
        size_line.ok_or_else(|| MmError::Header("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| MmError::Parse { line: lineno + 1, msg: e.to_string() })?;
    if dims.len() != 3 {
        return Err(MmError::Parse { line: lineno + 1, msg: "size line needs 3 fields".into() });
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(nrows, ncols);
    let mut seen = 0usize;
    for (lineno, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse_idx = |s: Option<&str>, lineno: usize| -> Result<usize, MmError> {
            s.ok_or(MmError::Parse { line: lineno + 1, msg: "missing index".into() })?
                .parse::<usize>()
                .map_err(|e| MmError::Parse { line: lineno + 1, msg: e.to_string() })
        };
        let r = parse_idx(it.next(), lineno)? - 1; // 1-based in the format
        let c = parse_idx(it.next(), lineno)? - 1;
        let v = match field {
            Field::Pattern => 1.0,
            Field::Real | Field::Integer => it
                .next()
                .ok_or(MmError::Parse { line: lineno + 1, msg: "missing value".into() })?
                .parse::<f64>()
                .map_err(|e| MmError::Parse { line: lineno + 1, msg: e.to_string() })?,
        };
        if r >= nrows || c >= ncols {
            return Err(MmError::Parse {
                line: lineno + 1,
                msg: format!("index ({},{}) out of bounds {}x{}", r + 1, c + 1, nrows, ncols),
            });
        }
        match symmetry {
            Symmetry::General => coo.push(r, c, v),
            Symmetry::Symmetric => coo.push_sym(r, c, v),
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(MmError::Parse {
            line: 0,
            msg: format!("expected {nnz} entries, found {seen}"),
        });
    }
    Ok(coo.to_csr())
}

/// Write a CSR matrix in Matrix Market format. Symmetric matrices are
/// stored as `symmetric` (lower triangle only).
pub fn write_matrix_market(path: impl AsRef<Path>, a: &Csr) -> Result<(), MmError> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    // exact equality: symmetric storage drops the upper triangle, so a
    // 1-ulp asymmetry would not survive the roundtrip
    let symmetric = a.nrows() == a.ncols() && a.is_symmetric(0.0);
    let sym = if symmetric { "symmetric" } else { "general" };
    writeln!(w, "%%MatrixMarket matrix coordinate real {sym}")?;
    writeln!(w, "% generated by pfm-reorder")?;
    let mut entries: Vec<(usize, usize, f64)> = Vec::new();
    for r in 0..a.nrows() {
        let (cols, vals) = a.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            if !symmetric || c <= r {
                entries.push((r, c, v));
            }
        }
    }
    writeln!(w, "{} {} {}", a.nrows(), a.ncols(), entries.len())?;
    for (r, c, v) in entries {
        writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn sym_example() -> Csr {
        let mut c = Coo::square(3);
        c.push(0, 0, 2.0);
        c.push_sym(0, 1, -1.0);
        c.push(1, 1, 2.0);
        c.push(2, 2, 1.5);
        c.to_csr()
    }

    #[test]
    fn roundtrip_symmetric() {
        let a = sym_example();
        let dir = std::env::temp_dir().join(format!("pfm_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sym.mtx");
        write_matrix_market(&path, &a).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reads_general_and_pattern() {
        let content = "%%MatrixMarket matrix coordinate real general\n% c\n2 2 3\n1 1 1.0\n1 2 2.0\n2 2 3.0\n";
        let a = read_matrix_market_from(content.as_bytes()).unwrap();
        assert_eq!(a.get(0, 1), 2.0);
        assert_eq!(a.get(1, 0), 0.0);

        let content = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 2\n1 1\n2 1\n";
        let a = read_matrix_market_from(content.as_bytes()).unwrap();
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_matrix_market_from("%%MatrixMarket tensor x y z\n".as_bytes()).is_err());
        assert!(read_matrix_market_from(
            "%%MatrixMarket matrix coordinate complex general\n1 1 0\n".as_bytes()
        )
        .is_err());
    }

    #[test]
    fn rejects_out_of_bounds_and_count_mismatch() {
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market_from(oob.as_bytes()).is_err());
        let short = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(short.as_bytes()).is_err());
    }
}
