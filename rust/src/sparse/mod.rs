//! Sparse-matrix substrate: COO assembly, CSR compute format, dense
//! reference kernels, and Matrix Market I/O.
//!
//! Every experiment in the paper operates on sparse symmetric matrices;
//! this module is the foundation the graph, ordering, and factorization
//! layers build on.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod io;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
