//! L3 ↔ L2 bridge: load AOT-compiled HLO artifacts and execute them on the
//! PJRT CPU client. Python never runs at request time — the artifacts under
//! `artifacts/` are the only thing the coordinator needs.

pub mod executor;
pub mod pfm_order;
pub mod xla_compat;

pub use executor::{parse_artifact_name, BucketExecutable, PfmRuntime, RuntimeError};
pub use pfm_order::{Learned, OrderOutcome, Provenance};

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
