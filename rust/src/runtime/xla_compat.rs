//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The production build links the real `xla` crate (PJRT CPU plugin); the
//! offline crate set does not ship it, so this module mirrors the exact API
//! surface `runtime::executor` consumes. Construction of a client succeeds
//! (so artifact-directory scanning, bucket selection, and the service all
//! work), but `compile`/`from_text_file` report the backend as unavailable.
//! Every learned-method call then takes the deterministic spectral-fallback
//! path, which is also what the paper's harness does above the largest
//! exported bucket — no caller needs to distinguish the two situations.
//!
//! Swapping the real crate back in is a one-line change in
//! `runtime::executor` (`use xla;` instead of `use …::xla_compat as xla`).

use std::fmt;

/// Mirrors `xla::Error`: an opaque backend error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("xla backend not available in this build (offline crate set)".to_string())
}

/// Mirrors `xla::Literal`: a host tensor handed to/from an executable.
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal(()))
    }

    /// Device→host transfer (no-op stub).
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::HloModuleProto`.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text-proto artifact. Always unavailable offline.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::XlaComputation`.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Unreachable in the stub
    /// (no executable can ever be compiled), but keeps call sites typed.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<Literal>>, Error> {
        Err(unavailable())
    }
}

/// Mirrors `xla::PjRtClient`.
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client: constructible offline so the registry/scanning layer and
    /// the coordinator run; only compilation is gated.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_is_gated() {
        let client = PjRtClient::cpu().expect("stub client");
        let proto = HloModuleProto::from_text_file("x.hlo.txt");
        assert!(proto.is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        let exe = client.compile(&comp);
        assert!(exe.is_err());
        assert!(exe.err().unwrap().to_string().contains("not available"));
    }
}
