//! Learned-method orderings: run an AOT artifact through the PJRT runtime
//! when one covers the matrix; otherwise the PFM variants run the native
//! in-Rust optimizer (`crate::pfm`) and the surrogate-objective variants
//! (S_e, GPCE, UDNO — trained networks with no native equivalent) fall
//! back to the deterministic spectral ordering. Where the ordering came
//! from is always recorded in the returned provenance.

use crate::order::{fiedler_order_with, order_from_scores_f32};
use crate::pfm::{OptBudget, PfmOptimizer, PhaseTimes, ScoreInit, SharedPrep, SPECTRAL_INIT_ITERS};
use crate::runtime::executor::{PfmRuntime, RuntimeError};
use crate::sparse::Csr;

/// Where an ordering came from (for metrics / experiment bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Network artifact executed via PJRT.
    Network,
    /// Native in-Rust ADMM + proximal fill-in minimization (`crate::pfm`).
    NativeOptimizer,
    /// Spectral fallback (no artifact covered the size and the variant has
    /// no native optimizer path).
    SpectralFallback,
    /// Served from the crash-safe warm-start store (`crate::persist`) —
    /// a previously accepted native result replayed for the same pattern.
    WarmStore,
}

impl Provenance {
    /// Stable short label used in CSV/JSON artifacts.
    pub fn label(&self) -> &'static str {
        match self {
            Provenance::Network => "network",
            Provenance::NativeOptimizer => "native",
            Provenance::SpectralFallback => "fallback",
            Provenance::WarmStore => "warm",
        }
    }
}

/// An ordering plus where it came from and what it cost — what the
/// harness records per (matrix, method) and the coordinator reports per
/// request.
#[derive(Clone, Debug)]
pub struct OrderOutcome {
    pub order: Vec<usize>,
    pub provenance: Provenance,
    /// ADMM outer iterations the native optimizer ran (0 otherwise)
    pub opt_iters: usize,
    /// discrete objective evaluations the native optimizer spent
    pub opt_evals: usize,
    /// evaluations served by the incremental suffix re-walk
    /// (`pfm::incremental`); `incremental_probes + full_probes ==
    /// opt_evals` on the native path, both 0 otherwise
    pub incremental_probes: usize,
    /// evaluations that ran a full symbolic/numeric pass
    pub full_probes: usize,
    /// intermediate V-cycle levels the native optimizer refined
    pub levels_refined: usize,
    /// wall-clock split of the native optimizer's coarsen / ADMM / refine
    /// phases (all zero on the network and fallback paths)
    pub phases: PhaseTimes,
}

/// The learned reordering methods of the paper's Table 2 / Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Learned {
    /// Spectral embedding scores (Gatti et al. 2021 S_e).
    Se,
    /// GPCE: pairwise-cross-entropy-trained GNN.
    Gpce,
    /// UDNO: expected-envelope-trained GNN.
    Udno,
    /// PFM: the paper's proximal fill-in minimization.
    Pfm,
    /// Ablation: PFM without the spectral embedding.
    PfmRandinit,
    /// Ablation: PFM with the GraphUnet-lite encoder.
    PfmGunet,
}

impl Learned {
    pub const TABLE2: [Learned; 4] = [Learned::Se, Learned::Gpce, Learned::Udno, Learned::Pfm];

    /// Every learned variant (table rows + ablations) — the single list
    /// `from_label` and the consistency tests iterate, so adding a
    /// variant without updating it is a compile error here, not a silent
    /// parse failure.
    pub const ALL: [Learned; 6] = [
        Learned::Se,
        Learned::Gpce,
        Learned::Udno,
        Learned::Pfm,
        Learned::PfmRandinit,
        Learned::PfmGunet,
    ];

    /// Artifact file prefix.
    pub fn variant(&self) -> &'static str {
        match self {
            Learned::Se => "se",
            Learned::Gpce => "gpce",
            Learned::Udno => "udno",
            Learned::Pfm => "pfm",
            Learned::PfmRandinit => "pfm_randinit",
            Learned::PfmGunet => "pfm_gunet",
        }
    }

    /// Table label (matches the paper's rows).
    pub fn label(&self) -> &'static str {
        match self {
            Learned::Se => "S_e",
            Learned::Gpce => "GPCE",
            Learned::Udno => "UDNO",
            Learned::Pfm => "PFM",
            Learned::PfmRandinit => "randinit+MgGNN+FactLoss",
            Learned::PfmGunet => "S_e+GUnet+PFM",
        }
    }

    /// Parse from the table label or the artifact variant name
    /// (case-insensitive; accepts the `se` CLI alias for `S_e`). Inverse
    /// of [`label`](Self::label)/[`variant`](Self::variant) — the strings
    /// live only there.
    pub fn from_label(s: &str) -> Option<Learned> {
        Learned::ALL
            .into_iter()
            .find(|l| l.label().eq_ignore_ascii_case(s) || l.variant().eq_ignore_ascii_case(s))
    }

    /// The native optimizer's score init for this variant, when the
    /// variant has a native path (the factorization-in-loop rows of
    /// Table 3). Surrogate-objective variants (and the GUnet-encoder
    /// ablation, which needs a trained encoder) return `None`.
    fn native_init(&self) -> Option<ScoreInit> {
        match self {
            Learned::Pfm => Some(ScoreInit::Spectral),
            Learned::PfmRandinit => Some(ScoreInit::Random),
            _ => None,
        }
    }

    /// Whether this variant runs the native in-Rust optimizer when no
    /// artifact covers a matrix (the coordinator's batched path only
    /// prepares shared work for such variants).
    pub fn has_native_path(&self) -> bool {
        self.native_init().is_some()
    }

    /// Compute the ordering with full provenance. Artifact-covered sizes
    /// run the network; PFM variants without artifact coverage run the
    /// native optimizer under `budget` (default budget when `None`);
    /// everything else falls back to the spectral ordering.
    pub fn order_detailed(
        &self,
        rt: &mut PfmRuntime,
        a: &Csr,
        seed: u64,
        budget: Option<OptBudget>,
    ) -> Result<OrderOutcome, RuntimeError> {
        self.order_detailed_shared(rt, a, seed, budget, 1, 1, None)
    }

    /// [`order_detailed`](Self::order_detailed) with the coordinator's
    /// extra levers: a probe-pool width for the native optimizer's
    /// refinement passes (quality-neutral — results are bit-identical at
    /// any width unless a wall-clock deadline expires mid-run), a
    /// parallel-factorization width per probe (composed with the pool
    /// width so their product never oversubscribes the machine; see
    /// `PfmOptimizer::factor_threads`), and an optional [`SharedPrep`]
    /// computed once for an identical-matrix batch.
    pub fn order_detailed_shared(
        &self,
        rt: &mut PfmRuntime,
        a: &Csr,
        seed: u64,
        budget: Option<OptBudget>,
        probe_threads: usize,
        factor_threads: usize,
        prep: Option<&SharedPrep>,
    ) -> Result<OrderOutcome, RuntimeError> {
        if rt.covers(self.variant(), a.nrows()) {
            let scores = rt.scores(self.variant(), a, seed)?;
            return Ok(OrderOutcome {
                order: order_from_scores_f32(&scores),
                provenance: Provenance::Network,
                opt_iters: 0,
                opt_evals: 0,
                incremental_probes: 0,
                full_probes: 0,
                levels_refined: 0,
                phases: PhaseTimes::default(),
            });
        }
        if let Some(init) = self.native_init() {
            let opt = PfmOptimizer::new(budget.unwrap_or_default(), seed)
                .with_init(init)
                .with_threads(probe_threads)
                .with_factor_threads(factor_threads);
            let rep = opt.optimize_shared(a, prep);
            return Ok(OrderOutcome {
                order: rep.order,
                provenance: Provenance::NativeOptimizer,
                opt_iters: rep.outer_iters,
                opt_evals: rep.evals,
                incremental_probes: rep.incremental_probes,
                full_probes: rep.full_probes,
                levels_refined: rep.levels_refined,
                phases: rep.phases,
            });
        }
        // Surrogate-objective methods approximate a spectral ordering;
        // Lanczos budget matches the S_e baseline.
        Ok(OrderOutcome {
            order: fiedler_order_with(a, SPECTRAL_INIT_ITERS, seed),
            provenance: Provenance::SpectralFallback,
            opt_iters: 0,
            opt_evals: 0,
            incremental_probes: 0,
            full_probes: 0,
            levels_refined: 0,
            phases: PhaseTimes::default(),
        })
    }

    /// Compute the ordering; returns (order, provenance). Thin wrapper
    /// over [`order_detailed`](Self::order_detailed) with the default
    /// optimizer budget, for callers that don't track iteration counts.
    pub fn order(
        &self,
        rt: &mut PfmRuntime,
        a: &Csr,
        seed: u64,
    ) -> Result<(Vec<usize>, Provenance), RuntimeError> {
        let out = self.order_detailed(rt, a, seed, None)?;
        Ok((out.order, out.provenance))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::fill_ratio_of_order;
    use crate::gen::grid::laplacian_2d;
    use crate::util::check::check_permutation;

    #[test]
    fn labels_and_variants_are_consistent() {
        for m in Learned::ALL {
            assert!(!m.variant().is_empty());
            assert!(!m.label().is_empty());
            assert_eq!(Learned::from_label(m.label()), Some(m));
            assert_eq!(Learned::from_label(m.variant()), Some(m));
        }
    }

    #[test]
    fn provenance_labels_are_distinct() {
        let labels = [
            Provenance::Network.label(),
            Provenance::NativeOptimizer.label(),
            Provenance::SpectralFallback.label(),
            Provenance::WarmStore.label(),
        ];
        assert_eq!(labels, ["network", "native", "fallback", "warm"]);
    }

    #[test]
    fn pfm_runs_native_optimizer_without_artifacts() {
        let dir = std::env::temp_dir().join(format!("pfm_po_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = PfmRuntime::new(&dir).unwrap();
        let a = laplacian_2d(9, 9);
        let budget = Some(OptBudget { outer: 2, refine: 10, ..OptBudget::default() });
        let out = Learned::Pfm.order_detailed(&mut rt, &a, 1, budget).unwrap();
        assert_eq!(out.provenance, Provenance::NativeOptimizer);
        check_permutation(&out.order).unwrap();
        assert!(out.opt_evals > 0, "native path must spend objective evaluations");
        // the optimized ordering never exceeds the spectral fallback's fill
        let spectral = fiedler_order_with(&a, SPECTRAL_INIT_ITERS, 1);
        assert!(
            fill_ratio_of_order(&a, &out.order) <= fill_ratio_of_order(&a, &spectral) + 1e-12,
            "native PFM worse than its spectral init"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn surrogate_methods_still_fall_back_to_spectral() {
        let dir = std::env::temp_dir().join(format!("pfm_po_se_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = PfmRuntime::new(&dir).unwrap();
        let a = laplacian_2d(8, 8);
        for m in [Learned::Se, Learned::Gpce, Learned::Udno, Learned::PfmGunet] {
            let (order, prov) = m.order(&mut rt, &a, 1).unwrap();
            assert_eq!(prov, Provenance::SpectralFallback, "{}", m.label());
            check_permutation(&order).unwrap();
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn randinit_ablation_differs_from_pfm_on_seeded_grid() {
        let dir = std::env::temp_dir().join(format!("pfm_po_ri_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = PfmRuntime::new(&dir).unwrap();
        // shuffled grid: the identity is a poor ordering, so neither
        // variant collapses onto it and the init difference shows
        let base = laplacian_2d(10, 10);
        let shuffle = crate::util::rng::Pcg64::new(40).permutation(100);
        let a = base.permute_sym(&shuffle);
        let budget = Some(OptBudget { outer: 2, refine: 8, ..OptBudget::default() });
        let pfm = Learned::Pfm.order_detailed(&mut rt, &a, 5, budget).unwrap();
        let ri = Learned::PfmRandinit.order_detailed(&mut rt, &a, 5, budget).unwrap();
        assert_eq!(pfm.provenance, Provenance::NativeOptimizer);
        assert_eq!(ri.provenance, Provenance::NativeOptimizer);
        check_permutation(&ri.order).unwrap();
        assert_ne!(pfm.order, ri.order, "randinit must differ from the spectral-init path");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
