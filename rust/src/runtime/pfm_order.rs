//! Learned-method orderings: run an AOT artifact through the PJRT runtime,
//! sort the scores, fall back to the in-Rust spectral ordering when no
//! artifact covers the matrix (paper's learned methods are trained on
//! n ≤ 500 and *applied* to much larger matrices; our artifacts cover the
//! exported buckets and everything larger uses the deterministic fallback,
//! recorded in the returned provenance).

use crate::order::{fiedler_order_with, order_from_scores_f32};
use crate::runtime::executor::{PfmRuntime, RuntimeError};
use crate::sparse::Csr;

/// Where an ordering came from (for metrics / experiment bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Network artifact executed via PJRT.
    Network,
    /// Spectral fallback (no artifact covered the size).
    SpectralFallback,
}

/// The learned reordering methods of the paper's Table 2 / Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Learned {
    /// Spectral embedding scores (Gatti et al. 2021 S_e).
    Se,
    /// GPCE: pairwise-cross-entropy-trained GNN.
    Gpce,
    /// UDNO: expected-envelope-trained GNN.
    Udno,
    /// PFM: the paper's proximal fill-in minimization.
    Pfm,
    /// Ablation: PFM without the spectral embedding.
    PfmRandinit,
    /// Ablation: PFM with the GraphUnet-lite encoder.
    PfmGunet,
}

impl Learned {
    pub const TABLE2: [Learned; 4] = [Learned::Se, Learned::Gpce, Learned::Udno, Learned::Pfm];

    /// Every learned variant (table rows + ablations) — the single list
    /// `from_label` and the consistency tests iterate, so adding a
    /// variant without updating it is a compile error here, not a silent
    /// parse failure.
    pub const ALL: [Learned; 6] = [
        Learned::Se,
        Learned::Gpce,
        Learned::Udno,
        Learned::Pfm,
        Learned::PfmRandinit,
        Learned::PfmGunet,
    ];

    /// Artifact file prefix.
    pub fn variant(&self) -> &'static str {
        match self {
            Learned::Se => "se",
            Learned::Gpce => "gpce",
            Learned::Udno => "udno",
            Learned::Pfm => "pfm",
            Learned::PfmRandinit => "pfm_randinit",
            Learned::PfmGunet => "pfm_gunet",
        }
    }

    /// Table label (matches the paper's rows).
    pub fn label(&self) -> &'static str {
        match self {
            Learned::Se => "S_e",
            Learned::Gpce => "GPCE",
            Learned::Udno => "UDNO",
            Learned::Pfm => "PFM",
            Learned::PfmRandinit => "randinit+MgGNN+FactLoss",
            Learned::PfmGunet => "S_e+GUnet+PFM",
        }
    }

    /// Parse from the table label or the artifact variant name
    /// (case-insensitive; accepts the `se` CLI alias for `S_e`). Inverse
    /// of [`label`](Self::label)/[`variant`](Self::variant) — the strings
    /// live only there.
    pub fn from_label(s: &str) -> Option<Learned> {
        Learned::ALL
            .into_iter()
            .find(|l| l.label().eq_ignore_ascii_case(s) || l.variant().eq_ignore_ascii_case(s))
    }

    /// Compute the ordering; returns (order, provenance).
    pub fn order(
        &self,
        rt: &mut PfmRuntime,
        a: &Csr,
        seed: u64,
    ) -> Result<(Vec<usize>, Provenance), RuntimeError> {
        if rt.covers(self.variant(), a.nrows()) {
            let scores = rt.scores(self.variant(), a, seed)?;
            Ok((order_from_scores_f32(&scores), Provenance::Network))
        } else {
            // Fallback mirrors what the learned methods approximate: a
            // spectral ordering. Lanczos budget matches the baseline.
            Ok((fiedler_order_with(a, 60, seed), Provenance::SpectralFallback))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::util::check::check_permutation;

    #[test]
    fn labels_and_variants_are_consistent() {
        for m in Learned::ALL {
            assert!(!m.variant().is_empty());
            assert!(!m.label().is_empty());
            assert_eq!(Learned::from_label(m.label()), Some(m));
            assert_eq!(Learned::from_label(m.variant()), Some(m));
        }
    }

    #[test]
    fn fallback_used_without_artifacts() {
        let dir = std::env::temp_dir().join(format!("pfm_po_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = PfmRuntime::new(&dir).unwrap();
        let a = laplacian_2d(9, 9);
        let (order, prov) = Learned::Pfm.order(&mut rt, &a, 1).unwrap();
        assert_eq!(prov, Provenance::SpectralFallback);
        check_permutation(&order).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
