//! PJRT execution of AOT-compiled PFM artifacts.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`. One compiled executable
//! per (variant, bucket); the registry picks the smallest bucket that fits
//! a request and the executor pads/unpads around the fixed-shape artifact.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// Offline builds use the in-tree PJRT stub; swap for `use xla;` when the
// real bindings are present (see runtime::xla_compat docs).
use crate::runtime::xla_compat as xla;
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Error type for runtime operations.
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    NoBucket { n: usize, max: usize },
    NoArtifacts(PathBuf, String),
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::NoBucket { n, max } => {
                write!(f, "no artifact bucket fits matrix of size {n} (max bucket {max})")
            }
            RuntimeError::NoArtifacts(dir, variant) => {
                write!(f, "artifact dir {} has no artifacts for variant {variant}", dir.display())
            }
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A compiled score network for one fixed bucket size.
pub struct BucketExecutable {
    pub bucket: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl BucketExecutable {
    /// Run the network on a padded dense panel. `adj` is row-major
    /// `bucket×bucket`, `x0`/`mask` length `bucket`. Returns `bucket`
    /// scores (padding scores included; caller slices).
    pub fn run(&self, adj: &[f32], x0: &[f32], mask: &[f32]) -> Result<Vec<f32>, RuntimeError> {
        let b = self.bucket;
        assert_eq!(adj.len(), b * b);
        assert_eq!(x0.len(), b);
        assert_eq!(mask.len(), b);
        let a_lit = xla::Literal::vec1(adj).reshape(&[b as i64, b as i64])?;
        let x_lit = xla::Literal::vec1(x0);
        let m_lit = xla::Literal::vec1(mask);
        let result = self.exe.execute::<xla::Literal>(&[a_lit, x_lit, m_lit])?[0][0]
            .to_literal_sync()?;
        // lowered with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Registry of compiled executables: variant → sorted bucket list.
pub struct PfmRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    compiled: HashMap<(String, usize), Arc<BucketExecutable>>,
    /// buckets available per variant (sorted ascending)
    available: HashMap<String, Vec<usize>>,
}

impl PfmRuntime {
    /// Scan `artifact_dir` for `<variant>_n<bucket>.hlo.txt` files and set
    /// up a CPU PJRT client. Compilation is lazy (first use per bucket).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        let dir = artifact_dir.as_ref().to_path_buf();
        let mut available: HashMap<String, Vec<usize>> = HashMap::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(&dir)? {
                let name = entry?.file_name().to_string_lossy().to_string();
                if let Some((variant, bucket)) = parse_artifact_name(&name) {
                    available.entry(variant).or_default().push(bucket);
                }
            }
        }
        for buckets in available.values_mut() {
            buckets.sort_unstable();
            buckets.dedup();
        }
        Ok(PfmRuntime { client, artifact_dir: dir, compiled: HashMap::new(), available })
    }

    /// Variants discovered in the artifact directory.
    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.available.keys().cloned().collect();
        v.sort();
        v
    }

    /// Buckets available for a variant.
    pub fn buckets(&self, variant: &str) -> &[usize] {
        self.available.get(variant).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Does any artifact cover matrices of size n for this variant?
    pub fn covers(&self, variant: &str, n: usize) -> bool {
        self.buckets(variant).iter().any(|&b| b >= n)
    }

    /// Smallest bucket ≥ n for the variant.
    pub fn bucket_for(&self, variant: &str, n: usize) -> Result<usize, RuntimeError> {
        let buckets = self.buckets(variant);
        buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or(RuntimeError::NoBucket { n, max: buckets.last().copied().unwrap_or(0) })
    }

    /// Get (compiling if needed) the executable for (variant, bucket).
    pub fn executable(
        &mut self,
        variant: &str,
        bucket: usize,
    ) -> Result<Arc<BucketExecutable>, RuntimeError> {
        let key = (variant.to_string(), bucket);
        if let Some(exe) = self.compiled.get(&key) {
            return Ok(exe.clone());
        }
        let path = self.artifact_dir.join(format!("{variant}_n{bucket}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::NoArtifacts(self.artifact_dir.clone(), variant.into()));
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let wrapped = Arc::new(BucketExecutable { bucket, exe });
        self.compiled.insert(key, wrapped.clone());
        Ok(wrapped)
    }

    /// Full inference path: pad the matrix into the smallest covering
    /// bucket, run the network, return scores for the real nodes only.
    pub fn scores(
        &mut self,
        variant: &str,
        a: &Csr,
        seed: u64,
    ) -> Result<Vec<f32>, RuntimeError> {
        let n = a.nrows();
        let bucket = self.bucket_for(variant, n)?;
        let exe = self.executable(variant, bucket)?;
        let adj = a.to_dense_padded_f32(bucket);
        let mut rng = Pcg64::new(seed);
        let x0: Vec<f32> = (0..bucket).map(|_| rng.next_gaussian() as f32).collect();
        let mut mask = vec![0.0f32; bucket];
        for m in mask.iter_mut().take(n) {
            *m = 1.0;
        }
        let mut scores = exe.run(&adj, &x0, &mask)?;
        scores.truncate(n);
        Ok(scores)
    }
}

/// Parse `<variant>_n<bucket>.hlo.txt` → (variant, bucket).
pub fn parse_artifact_name(name: &str) -> Option<(String, usize)> {
    let stem = name.strip_suffix(".hlo.txt")?;
    let idx = stem.rfind("_n")?;
    let bucket: usize = stem[idx + 2..].parse().ok()?;
    Some((stem[..idx].to_string(), bucket))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_parsing() {
        assert_eq!(parse_artifact_name("pfm_n64.hlo.txt"), Some(("pfm".into(), 64)));
        assert_eq!(
            parse_artifact_name("pfm_randinit_n128.hlo.txt"),
            Some(("pfm_randinit".into(), 128))
        );
        assert_eq!(parse_artifact_name("manifest.json"), None);
        assert_eq!(parse_artifact_name("pfm_nXY.hlo.txt"), None);
    }

    #[test]
    fn registry_scans_empty_dir() {
        let dir = std::env::temp_dir().join(format!("pfm_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rt = PfmRuntime::new(&dir).unwrap();
        assert!(rt.variants().is_empty());
        assert!(!rt.covers("pfm", 10));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bucket_selection_logic() {
        let dir = std::env::temp_dir().join(format!("pfm_rt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // fake artifact files (never compiled in this test)
        for b in [64usize, 128, 256] {
            std::fs::write(dir.join(format!("pfm_n{b}.hlo.txt")), "stub").unwrap();
        }
        let rt = PfmRuntime::new(&dir).unwrap();
        assert_eq!(rt.buckets("pfm"), &[64, 128, 256]);
        assert_eq!(rt.bucket_for("pfm", 10).unwrap(), 64);
        assert_eq!(rt.bucket_for("pfm", 64).unwrap(), 64);
        assert_eq!(rt.bucket_for("pfm", 65).unwrap(), 128);
        assert!(rt.bucket_for("pfm", 300).is_err());
        assert!(rt.bucket_for("udno", 10).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
