//! Factorization substrate: elimination trees, symbolic analysis (the exact
//! fill-in count — the paper's golden criterion), numeric up-looking
//! Cholesky, and a packaged direct solver.

pub mod etree;
pub mod numeric;
pub mod solver;
pub mod symbolic;

pub use numeric::{cholesky, cholesky_with, CholFactor, FactorError};
pub use solver::{DirectSolver, SolveStats};
pub use symbolic::{analyze, fill_ratio, fill_ratio_of_order, Symbolic};
