//! Factorization substrate — the hottest layer in the repo: the benchmark
//! harness times numeric Cholesky under every candidate ordering, so every
//! Table 2 / Fig 4 number is a measurement of this module.
//!
//! # Architecture
//!
//! ```text
//!               Csr (permuted PAPᵀ)
//!                      │
//!            symbolic::analyze            etree + exact row/col counts
//!                      │                  (Gilbert–Ng–Peyton, O(nnz(L)))
//!          ┌───────────┴──────────────┐
//!          │ fundamental_supernodes   │   partition columns into panels
//!          │ + supernodal::profitable │   (flop-weighted width heuristic)
//!          └───────┬─────────┬────────┘
//!        wide panels│         │chains/trees (e.g. tridiagonal)
//!                   ▼         ▼
//!      supernodal::factorize  numeric::cholesky_with_ws
//!      (blocked, right-       (scalar, up-looking)
//!       looking panels)               │
//!                   │                 │
//!            SupernodalFactor    CholFactor
//!                   └── to_chol() ────┘      identical row-compressed L
//! ```
//!
//! **Two numeric kernels, one factor.** `numeric` is the scalar up-looking
//! kernel (row-by-row sparse triangular solves with indexed gathers).
//! `supernodal` stores runs of columns with identical sub-diagonal pattern
//! as dense column-major panels and factors them with a small dense
//! Cholesky + blocked triangular solve + rank-k scatter updates — all
//! contiguous inner loops. Both produce the same L (verified entrywise to
//! 1e-12 in `tests/proptests.rs`); `SupernodalFactor::to_chol()` converts
//! to the row-compressed layout so downstream consumers never care which
//! kernel ran.
//!
//! **Fallback.** Supernodes of width 1 (chains, trees, tridiagonal) make
//! panel bookkeeping pure overhead, so `supernodal::profitable` gates the
//! blocked kernel on the *flop-weighted* mean supernode width ≥ 2 (and
//! n ≥ 48). The solver and harness layers consult it via
//! [`SymbolicCache::analyze`], which returns `ssym: None` for fallback
//! patterns.
//!
//! **Workspace / cache lifecycle (the serving steady state).** Repeated
//! factorization of matrices whose pattern doesn't change — the
//! coordinator's steady state — is allocation-free end to end:
//! [`FactorWorkspace`] owns all O(n) scratch and only ever grows (its
//! `grow_events` counter lets tests assert "zero re-allocations"), the
//! pattern-keyed [`SymbolicCache`] skips symbolic analysis entirely on a
//! hit, and `numeric::refactor_into` / `SupernodalFactor::refactor`
//! rewrite the factor's values in place. See DESIGN.md §Factor for the
//! measured effect.

pub mod etree;
pub mod numeric;
pub mod solver;
pub mod supernodal;
pub mod symbolic;
pub mod workspace;

pub use numeric::{cholesky, cholesky_with, cholesky_with_ws, refactor_into, CholFactor, FactorError};
pub use solver::{DirectSolver, FactorKind, SolveStats};
pub use supernodal::{SupernodalFactor, SupernodalSymbolic};
pub use symbolic::{
    analyze, factor_flops, fill_ratio, fill_ratio_of_order, fundamental_supernodes, Symbolic,
};
pub use workspace::{FactorContext, FactorWorkspace, PatternAnalysis, SymbolicCache};
