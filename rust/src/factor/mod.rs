//! Factorization substrate — the hottest layer in the repo: the benchmark
//! harness times numeric factorization under every candidate ordering, so
//! every Table 2 / Fig 4 number is a measurement of this module.
//!
//! # Architecture
//!
//! The engine is **kind-generic**: [`FactorKind::for_matrix`] routes
//! symmetric matrices to the Cholesky engine and general (unsymmetric)
//! ones to the Gilbert–Peierls LU engine; both sides share the etree /
//! exact-column-count symbolic machinery, the [`FactorWorkspace`] scratch,
//! and the pattern-keyed [`SymbolicCache`].
//!
//! ```text
//!                  Csr (permuted PAPᵀ)
//!                          │
//!              FactorKind::for_matrix (is_symmetric)
//!              ┌───────────┴────────────────┐
//!    symmetric │                            │ unsymmetric
//!              ▼                            ▼
//!     symbolic::analyze              lu::analyze_lu
//!     etree + exact row/col          chol analysis of A+Aᵀ
//!     counts (Gilbert–Ng–            (structural bound on
//!     Peyton, O(nnz(L)))              nnz(L+U), exact w/o pivots)
//!              │                            │
//!   ┌──────────┴─────────────┐              ▼
//!   │ fundamental_supernodes │      lu::factorize
//!   │ + supernodal::         │      (left-looking Gilbert–
//!   │   profitable           │       Peierls, DFS reach +
//!   └──────┬─────────┬───────┘       threshold partial pivoting)
//!     wide │         │ chains/trees         │
//!   panels ▼         ▼ (e.g. tridiagonal)   ▼
//!  supernodal::   numeric::             LuFactor
//!  factorize      cholesky_with_ws     {L, U, row_perm}
//!  (blocked,      (scalar, up-looking)      │
//!   right-looking)     │                    │
//!          │           │                    │
//!   SupernodalFactor  CholFactor            │
//!          └─ to_chol() ─┘                  │
//!              └───────── Factorization ────┘     one enum downstream
//! ```
//!
//! **Three numeric kernels, one `Factorization`.** `numeric` is the scalar
//! up-looking Cholesky kernel (row-by-row sparse triangular solves with
//! indexed gathers). `supernodal` stores runs of columns with identical
//! sub-diagonal pattern as dense column-major panels and factors them with
//! a small dense Cholesky + blocked triangular solve + rank-k scatter
//! updates — all contiguous inner loops. `lu` is the left-looking
//! Gilbert–Peierls kernel for general matrices: per-column DFS
//! reachability over the partially-built L, a sparse triangular solve in
//! topological order, and threshold partial pivoting (`tau = 0.1` by
//! default — the SuperLU policy). The Cholesky kernels produce the same L
//! (verified entrywise to 1e-12 in `tests/proptests.rs`); the LU kernel is
//! verified entrywise against dense reference LUs — the no-pivot oracle to
//! 1e-10 on every problem class, and the partial-pivoting oracle (same
//! pivot sequence, same factors) on matrices that force row swaps.
//!
//! **Parallel supernodal scheduling.** `sched` partitions the supernode
//! tree into flop-balanced independent subtrees factored concurrently on
//! scoped threads, with cross-boundary rank-k updates staged per source
//! and replayed in ascending supernode order at the join — the parallel
//! factor is bit-identical to the sequential one at any thread count (see
//! the `sched` module docs for the argument). `Schedule::build` declines
//! (returns `None`) on small or path-etree structures, so serving-sized
//! requests never pay a spawn.
//!
//! **Fallback.** Supernodes of width 1 (chains, trees, tridiagonal) make
//! panel bookkeeping pure overhead, so `supernodal::profitable` gates the
//! blocked kernel on the *flop-weighted* mean supernode width ≥ 2 (and
//! n ≥ 48). The solver and harness layers consult it via
//! [`SymbolicCache::analyze`], which returns `ssym: None` for fallback
//! patterns.
//!
//! **Workspace / cache lifecycle (the serving steady state).** Repeated
//! factorization of matrices whose pattern doesn't change — the
//! coordinator's steady state — is allocation-free end to end for both
//! kinds: [`FactorWorkspace`] owns all O(n) scratch and only ever grows
//! (its `grow_events` counter lets tests assert "zero re-allocations"),
//! the pattern-keyed [`SymbolicCache`] skips symbolic analysis entirely on
//! a hit (Cholesky and LU analyses cached side by side), and
//! `numeric::refactor_into` / `SupernodalFactor::refactor` /
//! `lu::refactor_into` rewrite the factor's values in place. See DESIGN.md
//! §Factor for the measured effect.

pub mod etree;
pub mod lu;
pub mod numeric;
pub mod sched;
pub mod solver;
pub mod supernodal;
pub mod symbolic;
pub mod workspace;

pub use lu::{
    analyze_lu, lu_fill_ratio, lu_fill_ratio_of_order, LuFactor, LuOptions, LuSymbolic,
};
pub use numeric::{cholesky, cholesky_with, cholesky_with_ws, refactor_into, CholFactor, FactorError};
pub use sched::{factorize_into_parallel, factorize_parallel, Schedule};
pub use solver::{DirectSolver, FactorKind, Factorization, SolveStats, SYMMETRY_TOL};
pub use supernodal::{SupernodalFactor, SupernodalSymbolic};
pub use symbolic::{
    analyze, factor_flops, fill_ratio, fill_ratio_of_order, fundamental_supernodes, Symbolic,
};
pub use workspace::{FactorContext, FactorWorkspace, PatternAnalysis, SymbolicCache};
