//! Supernodal (blocked) sparse Cholesky.
//!
//! Columns with identical sub-diagonal pattern — detected from the exact
//! Gilbert–Ng–Peyton column counts, see
//! [`symbolic::fundamental_supernodes`] — are factored together as one
//! dense column-major *panel*:
//!
//! ```text
//!        w cols
//!      ┌───────┐
//!    w │ diag  │  dense lower-triangular block  (small dense Cholesky)
//!      ├───────┤
//!  |R| │ sub-  │  shared sub-diagonal rows R    (blocked triangular solve)
//!      │ panel │
//!      └───────┘
//! ```
//!
//! After a panel is factored, its rank-w outer product is scatter-
//! subtracted into the panels of ancestor supernodes (right-looking
//! update). All inner loops run over contiguous panel columns — no indexed
//! gathers — which is where the speedup over the scalar up-looking kernel
//! comes from on fill-heavy (3D/AMD) problems. Matrices without useful
//! supernodes (chains, trees) should keep using the up-looking kernel; the
//! [`profitable`] heuristic makes that call and the solver/harness layers
//! respect it.
//!
//! The factor is numerically identical to [`numeric::cholesky_with`] (same
//! elimination order, same flops modulo re-association), and
//! [`SupernodalFactor::to_chol`] converts to the row-compressed
//! [`CholFactor`] so every existing consumer keeps working.

use std::sync::Arc;

use crate::factor::etree::NONE;
use crate::factor::numeric::{CholFactor, FactorError};
use crate::factor::symbolic::{analyze, fundamental_supernodes, Symbolic};
use crate::factor::workspace::FactorWorkspace;
use crate::sparse::Csr;

/// Supernodal elimination structure: the supernode partition plus, per
/// supernode, the shared sub-diagonal row set and packed panel layout.
#[derive(Clone, Debug)]
pub struct SupernodalSymbolic {
    n: usize,
    /// supernode column boundaries (CSR-style, len nsuper+1)
    pub sn_ptr: Vec<usize>,
    /// column → owning supernode
    pub sn_of: Vec<usize>,
    /// per-supernode offsets into `rows` (len nsuper+1)
    pub rows_ptr: Vec<usize>,
    /// concatenated sub-diagonal row indices (ascending per supernode,
    /// all ≥ the supernode's past-the-end column)
    pub rows: Vec<usize>,
    /// per-supernode offsets into the packed value array (len nsuper+1);
    /// supernode s's panel is `val[panel_ptr[s]..panel_ptr[s+1]]`,
    /// column-major with leading dimension `width + |rows|`
    pub panel_ptr: Vec<usize>,
    /// nnz of each row of L (kept for `to_chol`)
    pub row_nnz: Vec<usize>,
    /// structural nnz(L) including the diagonal
    pub lnnz: usize,
}

impl SupernodalSymbolic {
    /// Build the supernodal structure for `a` given its symbolic analysis
    /// and a supernode partition (usually from
    /// [`fundamental_supernodes`]).
    pub fn build(a: &Csr, sym: &Symbolic, sn_ptr: Vec<usize>) -> SupernodalSymbolic {
        let n = a.nrows();
        debug_assert_eq!(*sn_ptr.last().expect("non-empty partition"), n);
        let nsuper = sn_ptr.len() - 1;
        let mut sn_of = vec![0usize; n];
        for s in 0..nsuper {
            for j in sn_ptr[s]..sn_ptr[s + 1] {
                sn_of[j] = s;
            }
        }
        // Sub-diagonal rows of each supernode = rows of its first column
        // below the block. |rows(s)| is known from the exact column count,
        // so offsets come first and one row-subtree sweep fills in order.
        let mut rows_ptr = vec![0usize; nsuper + 1];
        for s in 0..nsuper {
            let w = sn_ptr[s + 1] - sn_ptr[s];
            rows_ptr[s + 1] = rows_ptr[s] + (sym.col_nnz[sn_ptr[s]] - w);
        }
        let mut rows = vec![0usize; rows_ptr[nsuper]];
        let mut cursor = rows_ptr.clone();
        let mut mark = vec![NONE; n];
        for i in 0..n {
            mark[i] = i;
            let (cols, _) = a.row(i);
            for &j in cols {
                if j >= i {
                    break;
                }
                let mut node = j;
                while mark[node] != i {
                    mark[node] = i;
                    let s = sn_of[node];
                    // l_i,node ≠ 0; record i only for the supernode's first
                    // column and only below its block (the shared pattern)
                    if node == sn_ptr[s] && i >= sn_ptr[s + 1] {
                        rows[cursor[s]] = i;
                        cursor[s] += 1;
                    }
                    if sym.parent[node] == NONE || sym.parent[node] >= i {
                        break;
                    }
                    node = sym.parent[node];
                }
            }
        }
        debug_assert!((0..nsuper).all(|s| cursor[s] == rows_ptr[s + 1]));
        let mut panel_ptr = vec![0usize; nsuper + 1];
        for s in 0..nsuper {
            let w = sn_ptr[s + 1] - sn_ptr[s];
            let ld = w + (rows_ptr[s + 1] - rows_ptr[s]);
            panel_ptr[s + 1] = panel_ptr[s] + ld * w;
        }
        SupernodalSymbolic {
            n,
            sn_ptr,
            sn_of,
            rows_ptr,
            rows,
            panel_ptr,
            row_nnz: sym.row_nnz.clone(),
            lnnz: sym.lnnz,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn nsuper(&self) -> usize {
        self.sn_ptr.len() - 1
    }

    /// Length of the packed value array.
    pub fn values_len(&self) -> usize {
        *self.panel_ptr.last().unwrap()
    }

    /// Mean supernode width.
    pub fn avg_width(&self) -> f64 {
        self.n as f64 / self.nsuper().max(1) as f64
    }
}

/// Should the supernodal kernel be used for this pattern? Width is what
/// amortizes the panel bookkeeping, and what matters is the width where
/// the *flops* are, so the heuristic is the flop-weighted mean supernode
/// width (weight cⱼ² per column). Chains/trees score 1 and fall back;
/// AMD-ordered 2D/3D problems score ≫ 2.
pub fn profitable(sym: &Symbolic, sn_ptr: &[usize]) -> bool {
    let n = sym.parent.len();
    if n < 48 {
        return false;
    }
    let mut weighted: u128 = 0;
    let mut total: u128 = 0;
    for s in 0..sn_ptr.len() - 1 {
        let w = (sn_ptr[s + 1] - sn_ptr[s]) as u128;
        let f: u128 = sym.col_nnz[sn_ptr[s]..sn_ptr[s + 1]]
            .iter()
            .map(|&c| (c as u128) * (c as u128))
            .sum();
        weighted += f * w;
        total += f;
    }
    total > 0 && weighted >= 2 * total
}

/// A factored matrix in packed-panel form.
#[derive(Clone, Debug)]
pub struct SupernodalFactor {
    ssym: Arc<SupernodalSymbolic>,
    val: Vec<f64>,
}

/// Factor `a` using a prebuilt supernodal structure. The structure must
/// have been built for exactly `a`'s pattern.
pub fn factorize(
    a: &Csr,
    ssym: Arc<SupernodalSymbolic>,
    ws: &mut FactorWorkspace,
) -> Result<SupernodalFactor, FactorError> {
    let mut val = vec![0.0f64; ssym.values_len()];
    factorize_into(a, &ssym, &mut val, ws)?;
    Ok(SupernodalFactor { ssym, val })
}

/// Convenience: full pipeline (symbolic analysis → supernode partition →
/// numeric) with a throwaway workspace. Works on any SPD matrix, wide
/// supernodes or not — callers that care about the fallback decision use
/// [`profitable`] and the solver layer instead.
pub fn cholesky(a: &Csr) -> Result<SupernodalFactor, FactorError> {
    if a.nrows() != a.ncols() {
        return Err(FactorError::NotSquare { nrows: a.nrows(), ncols: a.ncols() });
    }
    let sym = analyze(a);
    let sn_ptr = fundamental_supernodes(&sym);
    let ssym = Arc::new(SupernodalSymbolic::build(a, &sym, sn_ptr));
    factorize(a, ssym, &mut FactorWorkspace::new())
}

/// Assembly: scatter A's lower columns into the packed panels. `val` must
/// already be zeroed; `map` is the n-sized global→local scratch.
pub(crate) fn assemble(a: &Csr, ssym: &SupernodalSymbolic, val: &mut [f64], map: &mut [usize]) {
    for s in 0..ssym.nsuper() {
        let (js, je) = (ssym.sn_ptr[s], ssym.sn_ptr[s + 1]);
        let w = je - js;
        let rows_s = &ssym.rows[ssym.rows_ptr[s]..ssym.rows_ptr[s + 1]];
        let ld = w + rows_s.len();
        for g in js..je {
            map[g] = g - js;
        }
        for (kk, &g) in rows_s.iter().enumerate() {
            map[g] = w + kk;
        }
        let base = ssym.panel_ptr[s];
        for j in js..je {
            // symmetric A: column j below the diagonal == row j to the right
            let (cols, vals) = a.row(j);
            for (&i, &v) in cols.iter().zip(vals) {
                if i < j {
                    continue;
                }
                val[base + (j - js) * ld + map[i]] = v;
            }
        }
    }
}

/// Dense panel factorization of one supernode (`w` columns starting at
/// global column `js`, leading dimension `ld`): for column k, subtract the
/// contributions of block columns t < k (one contiguous axpy each), then
/// pivot and scale — this factors the diagonal block and performs the
/// blocked triangular solve of the sub-panel at once.
///
/// Shared verbatim by the sequential kernel and the parallel scheduler
/// (`factor::sched`): identical code on identical inputs is what makes the
/// parallel factor bit-identical to the sequential one.
pub(crate) fn factor_panel(
    panel: &mut [f64],
    ld: usize,
    w: usize,
    js: usize,
) -> Result<(), FactorError> {
    for k in 0..w {
        let (done, cur) = panel.split_at_mut(k * ld);
        let colk = &mut cur[..ld];
        for t in 0..k {
            let lkt = done[t * ld + k];
            if lkt != 0.0 {
                let colt = &done[t * ld..t * ld + ld];
                for rr in k..ld {
                    colk[rr] -= lkt * colt[rr];
                }
            }
        }
        let piv = colk[k];
        if piv <= 0.0 {
            return Err(FactorError::NotPositiveDefinite { row: js + k, pivot: piv });
        }
        let d = piv.sqrt();
        colk[k] = d;
        let inv = 1.0 / d;
        for rr in k + 1..ld {
            colk[rr] *= inv;
        }
    }
    Ok(())
}

/// Rank-w scatter updates of one factored supernode: C = Lsub·Lsubᵀ hits
/// ancestor panels at (rows_s[p], rows_s[q]). Target columns are grouped
/// by their owning supernode so the global→local map is built once per
/// target; every contribution is handed to `sink(t, pos, v)` meaning
/// "subtract `v` from position `pos` (relative to `panel_ptr[t]`) of
/// panel `t`", in a fixed order that does not depend on who the sink is.
///
/// The sequential kernel's sink subtracts directly; the parallel
/// scheduler's sink routes to the worker's own panels or to its staging
/// log. Same accumulation (`update column` loop), same order, same values
/// — only the destination differs.
pub(crate) fn apply_updates<F: FnMut(usize, usize, f64)>(
    ssym: &SupernodalSymbolic,
    s: usize,
    spanel: &[f64],
    map: &mut [usize],
    ucol: &mut [f64],
    loc: &mut [usize],
    mut sink: F,
) {
    let (js, je) = (ssym.sn_ptr[s], ssym.sn_ptr[s + 1]);
    let w = je - js;
    let rows_s = &ssym.rows[ssym.rows_ptr[s]..ssym.rows_ptr[s + 1]];
    let r = rows_s.len();
    let ld = w + r;
    let mut q0 = 0usize;
    while q0 < r {
        let t = ssym.sn_of[rows_s[q0]];
        let (ts, te) = (ssym.sn_ptr[t], ssym.sn_ptr[t + 1]);
        let wt = te - ts;
        let rows_t = &ssym.rows[ssym.rows_ptr[t]..ssym.rows_ptr[t + 1]];
        let ld_t = wt + rows_t.len();
        let mut q1 = q0 + 1;
        while q1 < r && rows_s[q1] < te {
            q1 += 1;
        }
        for g in ts..te {
            map[g] = g - ts;
        }
        for (kk, &g) in rows_t.iter().enumerate() {
            map[g] = wt + kk;
        }
        for p in q0..r {
            loc[p] = map[rows_s[p]];
        }
        for q in q0..q1 {
            // ucol[p] = Σ_k Lsub[p][k]·Lsub[q][k], p = q..r — one
            // contiguous axpy per panel column k
            for u in ucol[q..r].iter_mut() {
                *u = 0.0;
            }
            for k in 0..w {
                let colk = &spanel[k * ld + w..k * ld + w + r];
                let lqk = colk[q];
                if lqk != 0.0 {
                    for p in q..r {
                        ucol[p] += colk[p] * lqk;
                    }
                }
            }
            let cbase = (rows_s[q] - ts) * ld_t;
            for p in q..r {
                sink(t, cbase + loc[p], ucol[p]);
            }
        }
        q0 = q1;
    }
}

/// Numeric phase into caller-owned storage (`val.len() == values_len()`).
pub fn factorize_into(
    a: &Csr,
    ssym: &SupernodalSymbolic,
    val: &mut [f64],
    ws: &mut FactorWorkspace,
) -> Result<(), FactorError> {
    if a.nrows() != a.ncols() {
        return Err(FactorError::NotSquare { nrows: a.nrows(), ncols: a.ncols() });
    }
    let n = ssym.n;
    assert_eq!(a.nrows(), n, "matrix/symbolic size mismatch");
    assert_eq!(val.len(), ssym.values_len(), "value storage size mismatch");
    ws.acquire(n);
    let (map, ucol, loc) = ws.supernodal_buffers();
    val.fill(0.0);
    let nsuper = ssym.nsuper();

    // ---- assembly: scatter A's lower columns into the panels ----
    assemble(a, ssym, val, map);

    // ---- factor each supernode, then push its updates right ----
    for s in 0..nsuper {
        let (js, je) = (ssym.sn_ptr[s], ssym.sn_ptr[s + 1]);
        let w = je - js;
        let r = ssym.rows_ptr[s + 1] - ssym.rows_ptr[s];
        let ld = w + r;
        let base = ssym.panel_ptr[s];
        factor_panel(&mut val[base..base + ld * w], ld, w, js)?;
        if r == 0 {
            continue;
        }
        let (lo, hi) = val.split_at_mut(ssym.panel_ptr[s + 1]);
        let spanel = &lo[base..];
        let off = ssym.panel_ptr[s + 1];
        apply_updates(ssym, s, spanel, map, ucol, loc, |t, pos, v| {
            hi[ssym.panel_ptr[t] - off + pos] -= v;
        });
    }
    Ok(())
}

impl SupernodalFactor {
    /// Assemble a factor from a symbolic handle and a packed value array
    /// (the parallel scheduler's constructor).
    pub(crate) fn from_parts(ssym: Arc<SupernodalSymbolic>, val: Vec<f64>) -> SupernodalFactor {
        debug_assert_eq!(val.len(), ssym.values_len());
        SupernodalFactor { ssym, val }
    }

    pub fn n(&self) -> usize {
        self.ssym.n
    }

    /// nnz(L) including the diagonal (structural).
    pub fn lnnz(&self) -> usize {
        self.ssym.lnnz
    }

    pub fn symbolic(&self) -> &SupernodalSymbolic {
        &self.ssym
    }

    /// Entrywise ℓ₁ norm of L. The never-written upper-triangle panel
    /// positions are exactly 0.0, so summing the packed storage is exact.
    pub fn l1_norm(&self) -> f64 {
        self.val.iter().map(|v| v.abs()).sum()
    }

    /// Re-run the numeric phase in place for a matrix with the same
    /// pattern but new values. No allocation at all.
    pub fn refactor(&mut self, a: &Csr, ws: &mut FactorWorkspace) -> Result<(), FactorError> {
        let ssym = self.ssym.clone();
        factorize_into(a, &ssym, &mut self.val, ws)
    }

    /// Like [`refactor`](Self::refactor), but through the task-DAG
    /// scheduler (`sched` must have been built for this factor's
    /// symbolic structure). Bit-identical to the sequential refactor.
    pub fn refactor_parallel(
        &mut self,
        a: &Csr,
        ws: &mut FactorWorkspace,
        sched: &crate::factor::sched::Schedule,
    ) -> Result<(), FactorError> {
        let ssym = self.ssym.clone();
        crate::factor::sched::factorize_into_parallel(a, &ssym, &mut self.val, ws, sched)
    }

    /// Solve L·y = b.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.ssym.n);
        let mut y = b.to_vec();
        let ss = &*self.ssym;
        for s in 0..ss.nsuper() {
            let (js, je) = (ss.sn_ptr[s], ss.sn_ptr[s + 1]);
            let w = je - js;
            let rows_s = &ss.rows[ss.rows_ptr[s]..ss.rows_ptr[s + 1]];
            let ld = w + rows_s.len();
            let base = ss.panel_ptr[s];
            for k in 0..w {
                let col = &self.val[base + k * ld..base + (k + 1) * ld];
                let t = y[js + k] / col[k];
                y[js + k] = t;
                for rr in k + 1..w {
                    y[js + rr] -= t * col[rr];
                }
                for (kk, &g) in rows_s.iter().enumerate() {
                    y[g] -= t * col[w + kk];
                }
            }
        }
        y
    }

    /// Solve Lᵀ·x = y.
    pub fn solve_upper(&self, yin: &[f64]) -> Vec<f64> {
        assert_eq!(yin.len(), self.ssym.n);
        let mut x = yin.to_vec();
        let ss = &*self.ssym;
        for s in (0..ss.nsuper()).rev() {
            let (js, je) = (ss.sn_ptr[s], ss.sn_ptr[s + 1]);
            let w = je - js;
            let rows_s = &ss.rows[ss.rows_ptr[s]..ss.rows_ptr[s + 1]];
            let ld = w + rows_s.len();
            let base = ss.panel_ptr[s];
            for k in (0..w).rev() {
                let col = &self.val[base + k * ld..base + (k + 1) * ld];
                let mut acc = x[js + k];
                for rr in k + 1..w {
                    acc -= col[rr] * x[js + rr];
                }
                for (kk, &g) in rows_s.iter().enumerate() {
                    acc -= col[w + kk] * x[g];
                }
                x[js + k] = acc / col[k];
            }
        }
        x
    }

    /// Solve A·x = b given A = L·Lᵀ.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Convert to the row-compressed [`CholFactor`] (columns ascending,
    /// diagonal last — identical layout to the up-looking kernel's
    /// output).
    pub fn to_chol(&self) -> CholFactor {
        let ss = &*self.ssym;
        let n = ss.n;
        let mut indptr = vec![0usize; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + ss.row_nnz[i];
        }
        let lnnz = indptr[n];
        let mut cursor = indptr[..n].to_vec();
        let mut indices = vec![0usize; lnnz];
        let mut data = vec![0.0f64; lnnz];
        // sweep columns ascending: each row receives its entries in
        // ascending column order, so rows come out sorted, diagonal last
        for s in 0..ss.nsuper() {
            let (js, je) = (ss.sn_ptr[s], ss.sn_ptr[s + 1]);
            let w = je - js;
            let rows_s = &ss.rows[ss.rows_ptr[s]..ss.rows_ptr[s + 1]];
            let ld = w + rows_s.len();
            let base = ss.panel_ptr[s];
            for k in 0..w {
                let j = js + k;
                let col = &self.val[base + k * ld..base + (k + 1) * ld];
                for rr in k..w {
                    let i = js + rr;
                    indices[cursor[i]] = j;
                    data[cursor[i]] = col[rr];
                    cursor[i] += 1;
                }
                for (kk, &g) in rows_s.iter().enumerate() {
                    indices[cursor[g]] = j;
                    data[cursor[g]] = col[w + kk];
                    cursor[g] += 1;
                }
            }
        }
        debug_assert!((0..n).all(|i| cursor[i] == indptr[i + 1]));
        CholFactor::from_parts_unchecked(n, indptr, indices, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::numeric;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::sparse::Coo;
    use crate::util::check::assert_vec_close;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut coo = Coo::square(n);
        let mut diag = vec![1.0; n];
        for _ in 0..(3 * n) {
            let i = rng.next_below(n);
            let j = rng.next_below(n);
            if i == j {
                continue;
            }
            let w = 0.1 + rng.next_f64();
            coo.push_sym(i, j, -w);
            diag[i] += w;
            diag[j] += w;
        }
        for (i, d) in diag.iter().enumerate() {
            coo.push(i, i, *d + 0.5);
        }
        coo.to_csr()
    }

    /// Both kernels must produce the same factor: identical structure,
    /// values to tight tolerance.
    fn assert_kernels_agree(a: &Csr, tol: f64) {
        let up = numeric::cholesky(a).expect("up-looking");
        let sn = cholesky(a).expect("supernodal").to_chol();
        assert_eq!(up.lnnz(), sn.lnnz(), "structural nnz");
        for i in 0..a.nrows() {
            let (uc, uv) = up.row(i);
            let (sc, sv) = sn.row(i);
            assert_eq!(uc, sc, "row {i} pattern");
            for (k, (&x, &y)) in uv.iter().zip(sv).enumerate() {
                assert!(
                    (x - y).abs() <= tol * 1.0_f64.max(x.abs()),
                    "row {i} entry {k} (col {}): {x} vs {y}",
                    uc[k]
                );
            }
        }
    }

    #[test]
    fn agrees_with_uplooking_on_grids() {
        assert_kernels_agree(&laplacian_2d(7, 6), 1e-12);
        assert_kernels_agree(&laplacian_3d(4, 4, 3), 1e-12);
    }

    #[test]
    fn agrees_with_uplooking_on_random_spd() {
        for seed in 0..10 {
            assert_kernels_agree(&random_spd(30 + 3 * seed as usize, seed), 1e-12);
        }
    }

    #[test]
    fn agrees_under_amd_ordering() {
        let a = laplacian_3d(6, 6, 6);
        let order = crate::order::amd(&a);
        assert_kernels_agree(&a.permute_sym(&order), 1e-12);
    }

    #[test]
    fn handles_width1_chain() {
        // tridiagonal: every supernode is a single column — the kernel
        // must still be exact (the solver would normally fall back here)
        let mut coo = Coo::square(20);
        for i in 0..19 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..20 {
            coo.push(i, i, 2.5);
        }
        assert_kernels_agree(&coo.to_csr(), 1e-13);
    }

    #[test]
    fn handles_width_capped_dense_block() {
        // hub-first arrow (n=40): dense L split by MAX_SUPERNODE_WIDTH
        let n = 40;
        let mut coo = Coo::square(n);
        for i in 1..n {
            coo.push_sym(0, i, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 64.0);
        }
        assert_kernels_agree(&coo.to_csr(), 1e-12);
    }

    #[test]
    fn solve_roundtrip() {
        let a = random_spd(50, 11);
        let f = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(12);
        let xtrue: Vec<f64> = (0..50).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xtrue);
        let x = f.solve(&b);
        assert_vec_close(&x, &xtrue, 1e-8);
    }

    #[test]
    fn refactor_reuses_everything() {
        let a = laplacian_3d(5, 5, 4);
        let order = crate::order::amd(&a);
        let pap = a.permute_sym(&order);
        let mut ws = FactorWorkspace::new();
        let sym = analyze(&pap);
        let sn_ptr = fundamental_supernodes(&sym);
        let ssym = Arc::new(SupernodalSymbolic::build(&pap, &sym, sn_ptr));
        let mut f = factorize(&pap, ssym, &mut ws).unwrap();
        let grows = ws.grow_events();
        let before = f.to_chol();
        // same values → identical result; and no scratch growth
        f.refactor(&pap, &mut ws).unwrap();
        assert_eq!(ws.grow_events(), grows, "refactor must not grow scratch");
        let after = f.to_chol();
        for i in 0..pap.nrows() {
            assert_eq!(before.row(i).0, after.row(i).0);
            assert_vec_close(before.row(i).1, after.row(i).1, 1e-15);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut coo = Coo::square(2);
        coo.push(0, 0, 1.0);
        coo.push_sym(0, 1, 2.0);
        coo.push(1, 1, 1.0);
        let res = cholesky(&coo.to_csr());
        assert!(matches!(res, Err(FactorError::NotPositiveDefinite { .. })));
    }

    #[test]
    fn l1_and_lnnz_match_uplooking() {
        let a = random_spd(40, 21);
        let up = numeric::cholesky(&a).unwrap();
        let sn = cholesky(&a).unwrap();
        assert_eq!(sn.lnnz(), up.lnnz());
        assert!((sn.l1_norm() - up.l1_norm()).abs() < 1e-9 * up.l1_norm().max(1.0));
    }
}
