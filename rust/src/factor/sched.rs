//! Etree task-DAG scheduling for the supernodal Cholesky kernel:
//! subtree-parallel numeric factorization that is **bit-identical to the
//! sequential kernel at every thread count**.
//!
//! # The schedule
//!
//! The column elimination tree collapses to a *supernode tree*: supernode
//! `s`'s parent is the supernode owning its first sub-diagonal row (every
//! rank-k update target of `s` is an ancestor in this tree). The builder
//! partitions that tree into independent subtree *tasks* balanced by exact
//! per-supernode flop weight (column `js+k` of a panel with leading
//! dimension `ld` costs `(ld-k)²` — summing over the factor reproduces
//! `symbolic::factor_flops` exactly), then packs tasks onto workers with
//! LPT (heaviest task first onto the least-loaded worker, ties to the
//! lowest index — deterministic). Whatever is not inside a task — the
//! shared top of the tree — is the **trunk**.
//!
//! ```text
//!            trunk (sequential join)        owner[s] = TRUNK
//!              ▲    ▲       ▲
//!          ┌───┴┐ ┌─┴──┐ ┌──┴───┐
//!          │task│ │task│ │ task │ …        owner[s] = worker w
//!          └────┘ └────┘ └──────┘
//!         subtrees, factored concurrently
//! ```
//!
//! # Why the result is bit-identical
//!
//! A panel's factorization is a pure function of its assembled input, and
//! an entry's final value depends only on the *sequence of subtractions*
//! applied to it. The parallel schedule preserves the sequential sequence
//! everywhere:
//!
//! * **Inside a subtree** every update source is a descendant in the same
//!   subtree (subtree closure, asserted at build time), and each worker
//!   processes its supernodes in ascending index order — the sequential
//!   order restricted to the subtree.
//! * **Across the boundary** a worker never touches the trunk: it stages
//!   `(position, value)` pairs per source supernode into its own log. At
//!   the join, one sequential *replay* walks supernodes in ascending
//!   order: a worker-owned supernode contributes its staged group, a trunk
//!   supernode is panel-factored and its updates applied directly — so
//!   every trunk entry receives exactly the subtractions the sequential
//!   kernel would have applied, in the same order, with the same values
//!   (each staged value was computed from a bit-identical source panel by
//!   the shared [`supernodal::apply_updates`] code path).
//!
//! No atomics, no reductions in nondeterministic order, no per-thread-
//! count variation: `assert_eq!` on the packed value arrays holds at any
//! worker count, which is what the equivalence proptests pin.
//!
//! # When it engages
//!
//! Parallelism needs *tree width*. Fill-reducing orderings (AMD, nested
//! dissection) give wide supernode trees; natural orderings of banded
//! problems give a **path** (parent(j) = j+1) with zero subtree
//! parallelism — the builder then finds fewer than two tasks and
//! [`Schedule::build`] returns `None`, as it does below the flop cutoff
//! ([`PAR_MIN_FLOPS`]) where spawn/join costs exceed the win. Callers fall
//! back to the sequential kernel; serving-sized requests never pay a
//! spawn. See DESIGN.md §Task-DAG scheduling.

use std::sync::Arc;

use crate::factor::numeric::FactorError;
use crate::factor::supernodal::{
    self, apply_updates, assemble, factor_panel, SupernodalFactor, SupernodalSymbolic,
};
use crate::factor::workspace::{FactorWorkspace, WorkerScratch};
use crate::sparse::Csr;

/// `owner` value for supernodes factored by the sequential join phase.
pub const TRUNK: usize = usize::MAX;

/// Subtree tasks per requested worker: over-decomposing lets LPT balance
/// uneven subtree weights (one task per worker would pin the makespan to
/// the single heaviest subtree).
const OVERDECOMP: usize = 4;

/// Minimum total factor flops for which subtree parallelism is worth the
/// spawn/join cost; below this [`Schedule::build`] stays sequential.
pub const PAR_MIN_FLOPS: f64 = 1_000_000.0;

/// A worker assignment for one supernodal structure: which worker owns
/// each supernode (or [`TRUNK`]), and each worker's ascending work list.
/// Build once per (pattern, thread count); reuse across refactorizations.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// supernode → worker index, or [`TRUNK`]
    owner: Vec<usize>,
    /// per-worker owned supernodes, ascending (the phase-1 work lists)
    worker_sns: Vec<Vec<usize>>,
    /// supernode → position in its owner's work list (trunk: unused)
    local_pos: Vec<usize>,
}

impl Schedule {
    /// Build a schedule for `threads` workers, or `None` when the
    /// structure has too little subtree parallelism (or too little work —
    /// [`PAR_MIN_FLOPS`]) to beat the sequential kernel.
    pub fn build(ssym: &SupernodalSymbolic, threads: usize) -> Option<Schedule> {
        Schedule::build_with(ssym, threads, PAR_MIN_FLOPS)
    }

    /// [`build`](Self::build) with an explicit flop cutoff (tests force
    /// parallelism on small matrices with `min_flops = 0.0`).
    pub fn build_with(
        ssym: &SupernodalSymbolic,
        threads: usize,
        min_flops: f64,
    ) -> Option<Schedule> {
        let nsuper = ssym.nsuper();
        if threads <= 1 || nsuper < 4 {
            return None;
        }
        // supernode tree: parent = supernode of the first sub-diagonal row
        // (ancestors of s in this tree are exactly s's update targets)
        let mut parent = vec![TRUNK; nsuper];
        let mut weight = vec![0.0f64; nsuper];
        for s in 0..nsuper {
            if ssym.rows_ptr[s + 1] > ssym.rows_ptr[s] {
                parent[s] = ssym.sn_of[ssym.rows[ssym.rows_ptr[s]]];
            }
            let w = ssym.sn_ptr[s + 1] - ssym.sn_ptr[s];
            let ld = w + (ssym.rows_ptr[s + 1] - ssym.rows_ptr[s]);
            weight[s] = (0..w).map(|k| ((ld - k) * (ld - k)) as f64).sum();
        }
        let total: f64 = weight.iter().sum();
        if total < min_flops {
            return None;
        }
        // subtree weights: ascending pass works because parent(s) > s
        // (a supernode's sub-diagonal rows lie past its last column)
        let mut subw = weight;
        for s in 0..nsuper {
            if parent[s] != TRUNK {
                subw[parent[s]] += subw[s];
            }
        }
        // children lists (CSR-style), ascending per parent
        let mut child_ptr = vec![0usize; nsuper + 1];
        for s in 0..nsuper {
            if parent[s] != TRUNK {
                child_ptr[parent[s] + 1] += 1;
            }
        }
        for s in 0..nsuper {
            child_ptr[s + 1] += child_ptr[s];
        }
        let mut children = vec![0usize; child_ptr[nsuper]];
        let mut cursor = child_ptr.clone();
        for s in 0..nsuper {
            if parent[s] != TRUNK {
                children[cursor[parent[s]]] = s;
                cursor[parent[s]] += 1;
            }
        }
        // carve tasks: descend from the roots, stopping at the first node
        // whose whole subtree fits the target (or at a leaf); everything
        // passed through on the way down is trunk
        let target = total / (threads * OVERDECOMP) as f64;
        let mut task_roots: Vec<usize> = Vec::new();
        let mut is_trunk = vec![false; nsuper];
        let mut stack: Vec<usize> =
            (0..nsuper).rev().filter(|&s| parent[s] == TRUNK).collect();
        while let Some(node) = stack.pop() {
            let kids = &children[child_ptr[node]..child_ptr[node + 1]];
            if subw[node] <= target || kids.is_empty() {
                task_roots.push(node);
            } else {
                is_trunk[node] = true;
                for &c in kids.iter().rev() {
                    stack.push(c);
                }
            }
        }
        if task_roots.len() < 2 {
            return None; // a path etree or one dominant subtree: no parallelism
        }
        // supernode → task: descending pass so parents resolve first
        let mut task_of = vec![TRUNK; nsuper];
        for (t, &root) in task_roots.iter().enumerate() {
            task_of[root] = t;
        }
        for s in (0..nsuper).rev() {
            if task_of[s] == TRUNK && !is_trunk[s] && parent[s] != TRUNK {
                task_of[s] = task_of[parent[s]];
            }
        }
        // LPT: heaviest task first onto the least-loaded worker
        // (ties → lowest worker index: fully deterministic)
        let workers = threads.min(task_roots.len());
        let mut order: Vec<usize> = (0..task_roots.len()).collect();
        order.sort_by(|&x, &y| {
            subw[task_roots[y]]
                .partial_cmp(&subw[task_roots[x]])
                .expect("finite weights")
                .then(task_roots[x].cmp(&task_roots[y]))
        });
        let mut load = vec![0.0f64; workers];
        let mut task_worker = vec![0usize; task_roots.len()];
        for t in order {
            let mut best = 0usize;
            for k in 1..workers {
                if load[k] < load[best] {
                    best = k;
                }
            }
            task_worker[t] = best;
            load[best] += subw[task_roots[t]];
        }
        let mut owner = vec![TRUNK; nsuper];
        let mut worker_sns: Vec<Vec<usize>> = vec![Vec::new(); workers];
        let mut local_pos = vec![0usize; nsuper];
        for s in 0..nsuper {
            if task_of[s] != TRUNK {
                let w = task_worker[task_of[s]];
                owner[s] = w;
                local_pos[s] = worker_sns[w].len();
                worker_sns[w].push(s);
            }
        }
        // invariants: subtree closure + trunk upward-closure
        debug_assert!((0..nsuper).all(|s| {
            parent[s] == TRUNK
                || if owner[s] == TRUNK {
                    owner[parent[s]] == TRUNK
                } else {
                    owner[parent[s]] == TRUNK || owner[parent[s]] == owner[s]
                }
        }));
        // ownership safety, checked directly against the update targets:
        // a worker's updates must land in its own subtree or the trunk,
        // and trunk updates must stay in the trunk. The supernode-tree
        // ancestry argument guarantees this; verifying it per pattern
        // makes release-mode correctness unconditional — any violation
        // falls back to the sequential kernel instead of staging into
        // another worker's panel.
        for s in 0..nsuper {
            let o = owner[s];
            let mut q = ssym.rows_ptr[s];
            while q < ssym.rows_ptr[s + 1] {
                let t = ssym.sn_of[ssym.rows[q]];
                let ot = owner[t];
                if !(ot == TRUNK || (o != TRUNK && ot == o)) {
                    debug_assert!(false, "update target outside owner chain");
                    return None;
                }
                let te = ssym.sn_ptr[t + 1];
                while q < ssym.rows_ptr[s + 1] && ssym.rows[q] < te {
                    q += 1;
                }
            }
        }
        Some(Schedule { owner, worker_sns, local_pos })
    }

    /// Number of phase-1 workers (≥ 2 for any built schedule).
    pub fn workers(&self) -> usize {
        self.worker_sns.len()
    }

    /// Worker owning supernode `s`, or `None` for the trunk.
    pub fn owner_of(&self, s: usize) -> Option<usize> {
        let o = self.owner[s];
        (o != TRUNK).then_some(o)
    }

    /// Supernodes factored sequentially in the join phase.
    pub fn trunk_len(&self) -> usize {
        self.owner.iter().filter(|&&o| o == TRUNK).count()
    }
}

/// Parallel counterpart of [`supernodal::factorize`]: factor through the
/// task-DAG schedule into a fresh factor.
pub fn factorize_parallel(
    a: &Csr,
    ssym: Arc<SupernodalSymbolic>,
    ws: &mut FactorWorkspace,
    sched: &Schedule,
) -> Result<SupernodalFactor, FactorError> {
    let mut val = vec![0.0f64; ssym.values_len()];
    factorize_into_parallel(a, &ssym, &mut val, ws, sched)?;
    Ok(SupernodalFactor::from_parts(ssym, val))
}

/// Parallel counterpart of [`supernodal::factorize_into`]. Bit-identical
/// output (see the module docs for the argument); on a non-positive pivot
/// the run is redone sequentially so the reported error — which row, which
/// pivot — is exactly the sequential kernel's.
pub fn factorize_into_parallel(
    a: &Csr,
    ssym: &SupernodalSymbolic,
    val: &mut [f64],
    ws: &mut FactorWorkspace,
    sched: &Schedule,
) -> Result<(), FactorError> {
    let nw = sched.workers();
    if nw <= 1 {
        return supernodal::factorize_into(a, ssym, val, ws);
    }
    if a.nrows() != a.ncols() {
        return Err(FactorError::NotSquare { nrows: a.nrows(), ncols: a.ncols() });
    }
    let n = ssym.n();
    assert_eq!(a.nrows(), n, "matrix/symbolic size mismatch");
    assert_eq!(val.len(), ssym.values_len(), "value storage size mismatch");
    assert_eq!(sched.owner.len(), ssym.nsuper(), "schedule/symbolic mismatch");
    ws.acquire(n);
    ws.acquire_workers(n, nw);
    let run = {
        let (map, ucol, loc, wscr) = ws.parallel_buffers();
        run_phases(a, ssym, val, sched, map, ucol, loc, &mut wscr[..nw])
    };
    match run {
        Ok(()) => Ok(()),
        // A panel hit a non-positive pivot. Rerun sequentially: inputs are
        // bit-identical, so this fails too — at exactly the first failing
        // column the sequential kernel would report (a concurrent run may
        // discover a *later* subtree's failure first).
        Err(_) => supernodal::factorize_into(a, ssym, val, ws),
    }
}

/// Assembly, concurrent subtree phase, and ascending replay. Split from
/// [`factorize_into_parallel`] so the workspace borrows end before the
/// sequential error fallback reborrows the workspace.
#[allow(clippy::too_many_arguments)]
fn run_phases(
    a: &Csr,
    ssym: &SupernodalSymbolic,
    val: &mut [f64],
    sched: &Schedule,
    map: &mut [usize],
    ucol: &mut [f64],
    loc: &mut [usize],
    wscr: &mut [WorkerScratch],
) -> Result<(), FactorError> {
    let nw = sched.workers();
    let nsuper = ssym.nsuper();
    val.fill(0.0);

    // ---- assembly (sequential, same as the sequential kernel) ----
    assemble(a, ssym, val, map);

    // ---- phase 1: workers factor their subtrees concurrently ----
    // Panels tile `val` contiguously in supernode order, so a single
    // split_at_mut walk hands each worker exclusive &mut slices of exactly
    // the panels it owns — no locks, no unsafe, trunk panels untouched.
    {
        let mut lists: Vec<Vec<&mut [f64]>> = (0..nw).map(|_| Vec::new()).collect();
        let mut rest: &mut [f64] = val;
        for s in 0..nsuper {
            let len = ssym.panel_ptr[s + 1] - ssym.panel_ptr[s];
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            if sched.owner[s] != TRUNK {
                lists[sched.owner[s]].push(head);
            }
        }
        let results: Vec<Result<(), FactorError>> = std::thread::scope(|sc| {
            let handles: Vec<_> = lists
                .into_iter()
                .zip(wscr.iter_mut())
                .enumerate()
                .map(|(wid, (panels, scratch))| {
                    sc.spawn(move || worker_run(ssym, sched, wid, panels, scratch))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("factor worker panicked")).collect()
        });
        for r in results {
            r?;
        }
    }

    // ---- phase 2: the join — ascending-index replay restores the
    // sequential update order on the trunk ----
    for s in 0..nsuper {
        let o = sched.owner[s];
        if o == TRUNK {
            let js = ssym.sn_ptr[s];
            let w = ssym.sn_ptr[s + 1] - js;
            let r = ssym.rows_ptr[s + 1] - ssym.rows_ptr[s];
            let ld = w + r;
            let base = ssym.panel_ptr[s];
            factor_panel(&mut val[base..base + ld * w], ld, w, js)?;
            if r == 0 {
                continue;
            }
            let (lo, hi) = val.split_at_mut(ssym.panel_ptr[s + 1]);
            let spanel = &lo[base..];
            let off = ssym.panel_ptr[s + 1];
            apply_updates(ssym, s, spanel, map, ucol, loc, |t, pos, v| {
                debug_assert_eq!(sched.owner[t], TRUNK, "trunk update left the trunk");
                hi[ssym.panel_ptr[t] - off + pos] -= v;
            });
        } else {
            // this supernode was factored in phase 1; apply its staged
            // cross-boundary updates now, exactly where the sequential
            // kernel would have applied them
            let scratch = &mut wscr[o];
            if scratch.st_cursor < scratch.st_groups.len()
                && scratch.st_groups[scratch.st_cursor].0 == s
            {
                let end = scratch.st_groups[scratch.st_cursor].1;
                for k in scratch.st_start..end {
                    val[scratch.st_pos[k]] -= scratch.st_val[k];
                }
                scratch.st_start = end;
                scratch.st_cursor += 1;
            }
        }
    }
    debug_assert!(
        wscr.iter().all(|sc| sc.st_start == sc.st_pos.len()),
        "unapplied staged updates"
    );
    Ok(())
}

/// Phase-1 body for one worker: factor the owned supernodes in ascending
/// index order; updates landing in the worker's own subtree are applied
/// directly (the target panel is in `panels`), updates crossing into the
/// trunk are staged per source supernode for the replay.
fn worker_run(
    ssym: &SupernodalSymbolic,
    sched: &Schedule,
    wid: usize,
    mut panels: Vec<&mut [f64]>,
    scratch: &mut WorkerScratch,
) -> Result<(), FactorError> {
    let WorkerScratch { map, ucol, loc, st_pos, st_val, st_groups, .. } = scratch;
    let sns = &sched.worker_sns[wid];
    debug_assert_eq!(sns.len(), panels.len());
    for i in 0..sns.len() {
        let s = sns[i];
        let js = ssym.sn_ptr[s];
        let w = ssym.sn_ptr[s + 1] - js;
        let r = ssym.rows_ptr[s + 1] - ssym.rows_ptr[s];
        let ld = w + r;
        let (head, tail) = panels.split_at_mut(i + 1);
        let cur = &mut *head[i];
        factor_panel(cur, ld, w, js)?;
        if r == 0 {
            continue;
        }
        let spanel: &[f64] = cur;
        let mark = st_pos.len();
        apply_updates(ssym, s, spanel, map, ucol, loc, |t, pos, v| {
            if sched.owner[t] == wid {
                // target list position is ahead of i: the work list is
                // ascending and every target has a larger supernode index
                tail[sched.local_pos[t] - i - 1][pos] -= v;
            } else {
                debug_assert_eq!(sched.owner[t], TRUNK, "update crossed workers");
                st_pos.push(ssym.panel_ptr[t] + pos);
                st_val.push(v);
            }
        });
        if st_pos.len() > mark {
            st_groups.push((s, st_pos.len()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::symbolic::{analyze, fundamental_supernodes};
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::sparse::Coo;
    use crate::util::rng::Pcg64;

    fn ssym_for(a: &Csr) -> Arc<SupernodalSymbolic> {
        let sym = analyze(a);
        let sn_ptr = fundamental_supernodes(&sym);
        Arc::new(SupernodalSymbolic::build(a, &sym, sn_ptr))
    }

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut coo = Coo::square(n);
        let mut diag = vec![1.0; n];
        for _ in 0..(3 * n) {
            let i = rng.next_below(n);
            let j = rng.next_below(n);
            if i == j {
                continue;
            }
            let w = 0.1 + rng.next_f64();
            coo.push_sym(i, j, -w);
            diag[i] += w;
            diag[j] += w;
        }
        for (i, d) in diag.iter().enumerate() {
            coo.push(i, i, *d + 0.5);
        }
        coo.to_csr()
    }

    #[test]
    fn small_matrices_stay_sequential() {
        // below the flop cutoff the builder must decline: serving-sized
        // requests never pay a spawn
        let a = laplacian_2d(8, 8);
        let pap = a.permute_sym(&crate::order::amd(&a));
        assert!(Schedule::build(&ssym_for(&pap), 8).is_none());
    }

    #[test]
    fn path_etree_stays_sequential() {
        // a banded matrix under the natural order has a path etree:
        // every non-root supernode has exactly one child, so there is at
        // most one task no matter the cutoff
        let a = laplacian_2d(32, 32);
        assert!(Schedule::build_with(&ssym_for(&a), 4, 0.0).is_none());
    }

    #[test]
    fn forest_engages_independent_blocks() {
        // two disconnected grids: a forest with two roots → two tasks even
        // though each block alone is a path
        let b = laplacian_2d(12, 12);
        let n = b.nrows();
        let mut coo = Coo::square(2 * n);
        for i in 0..n {
            let (cols, vals) = b.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                coo.push(i, j, v);
                coo.push(i + n, j + n, v);
            }
        }
        let a = coo.to_csr();
        let sched = Schedule::build_with(&ssym_for(&a), 2, 0.0).expect("forest must engage");
        assert_eq!(sched.workers(), 2);
        assert_eq!(sched.trunk_len(), 0, "disconnected blocks need no trunk");
    }

    #[test]
    fn partition_is_valid_and_deterministic() {
        let a = laplacian_3d(8, 8, 8);
        let pap = a.permute_sym(&crate::order::amd(&a));
        let ssym = ssym_for(&pap);
        let sched = Schedule::build_with(&ssym, 4, 0.0).expect("AMD 3D must engage");
        assert!(sched.workers() >= 2 && sched.workers() <= 4);
        // work lists ascending, local_pos consistent, owners in range
        for (w, sns) in sched.worker_sns.iter().enumerate() {
            for (i, &s) in sns.iter().enumerate() {
                assert_eq!(sched.owner[s], w);
                assert_eq!(sched.local_pos[s], i);
                if i > 0 {
                    assert!(sns[i - 1] < s, "work list must ascend");
                }
            }
        }
        // every supernode is either trunk or on exactly one work list
        let listed: usize = sched.worker_sns.iter().map(Vec::len).sum();
        assert_eq!(listed + sched.trunk_len(), ssym.nsuper());
        // deterministic: an identical build yields an identical schedule
        let again = Schedule::build_with(&ssym, 4, 0.0).unwrap();
        assert_eq!(sched.owner, again.owner);
        assert_eq!(sched.worker_sns, again.worker_sns);
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = laplacian_3d(6, 6, 6);
        let amd = crate::order::amd(&g);
        let cases = [g.permute_sym(&amd), random_spd(150, 5)];
        for a in &cases {
            let ssym = ssym_for(a);
            let mut ws = FactorWorkspace::new();
            let mut seq = vec![0.0f64; ssym.values_len()];
            supernodal::factorize_into(a, &ssym, &mut seq, &mut ws).unwrap();
            for threads in [2, 3, 4, 8] {
                let Some(sched) = Schedule::build_with(&ssym, threads, 0.0) else {
                    continue;
                };
                let mut par = vec![0.0f64; ssym.values_len()];
                factorize_into_parallel(a, &ssym, &mut par, &mut ws, &sched).unwrap();
                let same = seq
                    .iter()
                    .zip(&par)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "threads={threads}: parallel factor must be bit-identical");
            }
        }
    }

    #[test]
    fn parallel_steady_state_performs_zero_allocations() {
        let g = laplacian_3d(7, 7, 7);
        let a = g.permute_sym(&crate::order::amd(&g));
        let ssym = ssym_for(&a);
        let sched = Schedule::build_with(&ssym, 4, 0.0).expect("must engage");
        let mut ws = FactorWorkspace::new();
        let mut f = factorize_parallel(&a, ssym, &mut ws, &sched).unwrap();
        let grows = ws.grow_events();
        for _ in 0..3 {
            f.refactor_parallel(&a, &mut ws, &sched).unwrap();
        }
        assert_eq!(ws.grow_events(), grows, "parallel refactor must not allocate");
    }

    #[test]
    fn indefinite_reports_the_sequential_error() {
        let g = laplacian_3d(6, 6, 6);
        let a = g.permute_sym(&crate::order::amd(&g));
        let n = a.nrows();
        // poison one diagonal entry near the middle of the elimination
        let bad = n / 2;
        let mut data = a.data().to_vec();
        for (k, &j) in a.indices()[a.indptr()[bad]..a.indptr()[bad + 1]]
            .iter()
            .enumerate()
        {
            if j == bad {
                data[a.indptr()[bad] + k] = -100.0;
            }
        }
        let poisoned =
            Csr::from_parts(n, n, a.indptr().to_vec(), a.indices().to_vec(), data);
        let ssym = ssym_for(&poisoned);
        let mut ws = FactorWorkspace::new();
        let mut seq = vec![0.0f64; ssym.values_len()];
        let e_seq = supernodal::factorize_into(&poisoned, &ssym, &mut seq, &mut ws)
            .expect_err("poisoned diagonal must fail");
        let sched = Schedule::build_with(&ssym, 4, 0.0).expect("must engage");
        let mut par = vec![0.0f64; ssym.values_len()];
        let e_par = factorize_into_parallel(&poisoned, &ssym, &mut par, &mut ws, &sched)
            .expect_err("parallel must fail identically");
        match (e_seq, e_par) {
            (
                FactorError::NotPositiveDefinite { row: r1, pivot: p1 },
                FactorError::NotPositiveDefinite { row: r2, pivot: p2 },
            ) => {
                assert_eq!(r1, r2, "same failing row");
                assert_eq!(p1.to_bits(), p2.to_bits(), "same pivot value");
            }
            other => panic!("unexpected error pair: {other:?}"),
        }
    }
}
