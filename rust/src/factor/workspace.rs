//! Reusable factorization scratch and the serving-path symbolic cache.
//!
//! The serving steady state re-factors matrices whose *values* change but
//! whose *sparsity pattern* does not (time-stepping, Newton iterations,
//! repeated requests for the same topology). Two pieces make that path
//! allocation-free end to end:
//!
//! * [`FactorWorkspace`] owns every O(n) scratch buffer the numeric kernels
//!   need (dense accumulator, visit marks, row-pattern stack, supernodal
//!   scatter map / update column / local-offset buffers). Buffers only ever
//!   grow; [`FactorWorkspace::grow_events`] counts how often any buffer had
//!   to be (re)allocated, so tests can assert the steady state performs
//!   **zero** scratch allocations.
//! * [`SymbolicCache`] memoizes symbolic analysis keyed by the exact
//!   sparsity pattern (hash + full `indptr`/`indices` comparison — never
//!   trust the hash alone). A hit returns shared [`Symbolic`] /
//!   [`SupernodalSymbolic`] handles and skips analysis entirely;
//!   [`SymbolicCache::hits`] makes the steady state observable.

use std::sync::Arc;

use crate::factor::etree::NONE;
use crate::factor::lu::{analyze_lu, LuSymbolic};
use crate::factor::supernodal::{self, SupernodalSymbolic};
use crate::factor::symbolic::{analyze, fundamental_supernodes, Symbolic};
use crate::sparse::Csr;

/// Scratch buffers shared by the up-looking and supernodal kernels.
///
/// Create once per thread/solver and pass to every factorization; repeated
/// use with matrices of non-increasing size performs no allocations.
#[derive(Debug, Default)]
pub struct FactorWorkspace {
    /// dense accumulator for the current row (up-looking kernel)
    pub(crate) x: Vec<f64>,
    /// row-subtree visit marks (up-looking kernel)
    pub(crate) mark: Vec<usize>,
    /// row pattern scratch (up-looking kernel)
    pub(crate) pattern: Vec<usize>,
    /// global row → local panel position map (supernodal scatter)
    pub(crate) map: Vec<usize>,
    /// rank-k update column accumulator (supernodal)
    pub(crate) ucol: Vec<f64>,
    /// per-group local row offsets (supernodal scatter)
    pub(crate) loc: Vec<usize>,
    /// original row → pivot step (LU kernel; NONE = not yet pivoted)
    pub(crate) lu_pinv: Vec<usize>,
    /// DFS node stack (LU reachability)
    pub(crate) lu_stack: Vec<usize>,
    /// DFS per-depth resume position (LU reachability)
    pub(crate) lu_pstack: Vec<usize>,
    /// per-worker scratch for the parallel supernodal scheduler
    /// (`factor::sched`); empty until a parallel factorization runs
    pub(crate) workers: Vec<WorkerScratch>,
    /// candidate inverse ordering (incremental symbolic eval)
    pub(crate) inc_inv: Vec<usize>,
    /// partial etree parents (incremental symbolic eval)
    pub(crate) inc_parent: Vec<usize>,
    /// Liu path-compression ancestors (incremental symbolic eval)
    pub(crate) inc_ancestor: Vec<usize>,
    /// row-subtree visit marks (incremental symbolic eval; distinct from
    /// `mark` so a probe can never clobber a numeric kernel's state)
    pub(crate) inc_mark: Vec<usize>,
    grow_events: u64,
    factorizations: u64,
}

/// Scratch owned by one task-DAG worker: its own scatter buffers (map /
/// ucol / loc, same roles as the sequential kernel's) plus the staging
/// log for rank-k updates that cross the subtree boundary into the trunk.
/// The log is `(position, value)` pairs in the packed value array, grouped
/// by source supernode (`st_groups` records `(source, end offset)` in
/// ascending source order) so the join can replay each group exactly when
/// the sequential schedule would have applied it.
///
/// Buffers grow on first use and are only cleared — never shrunk —
/// afterwards, so the steady state (repeated refactorization of one
/// pattern at one thread count) allocates nothing: the staging log's
/// size is a function of pattern + schedule alone, so once grown its
/// capacity is always sufficient.
#[derive(Debug, Default)]
pub(crate) struct WorkerScratch {
    pub(crate) map: Vec<usize>,
    pub(crate) ucol: Vec<f64>,
    pub(crate) loc: Vec<usize>,
    pub(crate) st_pos: Vec<usize>,
    pub(crate) st_val: Vec<f64>,
    pub(crate) st_groups: Vec<(usize, usize)>,
    /// replay cursor into `st_groups` (reset per acquire)
    pub(crate) st_cursor: usize,
    /// replay start offset into `st_pos`/`st_val` (reset per acquire)
    pub(crate) st_start: usize,
}

/// The probe pool hands each scoped worker an exclusive
/// `&mut FactorWorkspace`; that requires `FactorWorkspace: Send` (all
/// buffers are plain `Vec`s, so this holds by construction — the assertion
/// turns an accidental non-Send field into a compile error here instead of
/// an opaque one at the spawn site).
const _: fn() = || {
    fn assert_send<T: Send>() {}
    assert_send::<FactorWorkspace>();
};

impl FactorWorkspace {
    pub fn new() -> FactorWorkspace {
        FactorWorkspace::default()
    }

    /// One workspace per parallel worker (see `pfm::probes::ProbePool`):
    /// created once, each scoped thread borrows exactly one, so repeated
    /// batches reuse the grown buffers without locking.
    pub fn pool(workers: usize) -> Vec<FactorWorkspace> {
        (0..workers.max(1)).map(|_| FactorWorkspace::new()).collect()
    }

    /// Make every buffer usable for an n×n factorization and reset the
    /// per-run state. O(n) fills, allocation only when n exceeds every
    /// previous acquire (counted in [`grow_events`](Self::grow_events)).
    pub(crate) fn acquire(&mut self, n: usize) {
        let mut grew = false;
        if self.x.len() < n {
            grew = true;
            self.x.resize(n, 0.0);
            self.mark.resize(n, NONE);
            self.map.resize(n, 0);
            self.ucol.resize(n, 0.0);
            self.loc.resize(n, 0);
            self.lu_pinv.resize(n, NONE);
            self.lu_stack.resize(n, 0);
            self.lu_pstack.resize(n, 0);
        }
        // clear BEFORE reserving so `reserve(n)` (which guarantees
        // capacity ≥ len + n) can never leave capacity short of n — a
        // short reserve would let the kernel reallocate mid-run without
        // the grow_events counter noticing.
        self.pattern.clear();
        if self.pattern.capacity() < n {
            grew = true;
            self.pattern.reserve(n);
        }
        if grew {
            self.grow_events += 1;
        }
        // per-run invariants: x all-zero, mark all-NONE below n. (map/ucol/
        // loc are always refilled before use by the supernodal kernel.)
        for v in self.x[..n].iter_mut() {
            *v = 0.0;
        }
        for m in self.mark[..n].iter_mut() {
            *m = NONE;
        }
        self.factorizations += 1;
    }

    /// Disjoint borrows of the supernodal scatter buffers
    /// (map, ucol, loc). Call [`acquire`](Self::acquire) first.
    pub(crate) fn supernodal_buffers(
        &mut self,
    ) -> (&mut [usize], &mut [f64], &mut [usize]) {
        (&mut self.map, &mut self.ucol, &mut self.loc)
    }

    /// Make `count` worker scratches usable for an n×n parallel
    /// factorization: grow what's missing (counted in
    /// [`grow_events`](Self::grow_events)) and reset every staging log.
    /// Clearing keeps capacity, so repeating the same (pattern, schedule)
    /// stages into already-grown logs — zero allocations in steady state.
    pub(crate) fn acquire_workers(&mut self, n: usize, count: usize) {
        let mut grew = false;
        if self.workers.len() < count {
            grew = true;
            self.workers.resize_with(count, WorkerScratch::default);
        }
        for wsc in self.workers[..count].iter_mut() {
            if wsc.map.len() < n {
                grew = true;
                wsc.map.resize(n, 0);
                wsc.ucol.resize(n, 0.0);
                wsc.loc.resize(n, 0);
            }
            wsc.st_pos.clear();
            wsc.st_val.clear();
            wsc.st_groups.clear();
            wsc.st_cursor = 0;
            wsc.st_start = 0;
        }
        if grew {
            self.grow_events += 1;
        }
    }

    /// Make the incremental-symbolic scratch usable for an n-row walk
    /// (`pfm::incremental`). Grows at most once per high-water n (counted
    /// in [`grow_events`](Self::grow_events)); per-candidate resets are
    /// the caller's O(n) fills, so the probe-pool steady state performs
    /// zero scratch allocations.
    pub(crate) fn acquire_incremental(&mut self, n: usize) {
        if self.inc_inv.len() < n {
            self.inc_inv.resize(n, 0);
            self.inc_parent.resize(n, NONE);
            self.inc_ancestor.resize(n, NONE);
            self.inc_mark.resize(n, NONE);
            self.grow_events += 1;
        }
    }

    /// Disjoint borrows for the parallel driver: the main scatter buffers
    /// (assembly + trunk replay) alongside the per-worker scratches.
    /// Call [`acquire`](Self::acquire) and
    /// [`acquire_workers`](Self::acquire_workers) first.
    pub(crate) fn parallel_buffers(
        &mut self,
    ) -> (&mut [usize], &mut [f64], &mut [usize], &mut [WorkerScratch]) {
        (&mut self.map, &mut self.ucol, &mut self.loc, &mut self.workers)
    }

    /// Disjoint borrows of the up-looking buffers (x, mark, pattern).
    /// Call [`acquire`](Self::acquire) first.
    pub(crate) fn uplooking_buffers(
        &mut self,
    ) -> (&mut [f64], &mut [usize], &mut Vec<usize>) {
        (&mut self.x, &mut self.mark, &mut self.pattern)
    }

    /// Disjoint borrows of the LU buffers
    /// (x, mark, pattern, pinv, stack, pstack).
    /// Call [`acquire`](Self::acquire) first.
    pub(crate) fn lu_buffers(
        &mut self,
    ) -> (
        &mut [f64],
        &mut [usize],
        &mut Vec<usize>,
        &mut [usize],
        &mut [usize],
        &mut [usize],
    ) {
        (
            &mut self.x,
            &mut self.mark,
            &mut self.pattern,
            &mut self.lu_pinv,
            &mut self.lu_stack,
            &mut self.lu_pstack,
        )
    }

    /// How many times any scratch buffer had to be allocated or grown.
    /// Stays constant across repeated factorizations of same-size (or
    /// smaller) matrices — the "zero scratch re-allocation" assertion.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    /// Total factorizations served by this workspace.
    pub fn factorizations(&self) -> u64 {
        self.factorizations
    }
}

/// Shared result of analyzing one sparsity pattern.
#[derive(Clone)]
pub struct PatternAnalysis {
    /// Row/column counts + etree.
    pub sym: Arc<Symbolic>,
    /// Supernodal structure — `Some` iff the supernodal kernel is expected
    /// to beat the up-looking kernel on this pattern (see
    /// [`supernodal::profitable`]).
    pub ssym: Option<Arc<SupernodalSymbolic>>,
}

/// One pattern-keyed cache entry: the FNV hash plus the full pattern for
/// exact verification, carrying an arbitrary analysis payload.
struct Keyed<T> {
    hash: u64,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    payload: T,
}

/// MRU probe shared by both analysis kinds: a hash match is verified
/// against the exact pattern, and a hit rotates the entry to the front.
fn cache_lookup<T: Clone>(entries: &mut Vec<Keyed<T>>, a: &Csr, hash: u64) -> Option<T> {
    let k = entries.iter().position(|e| {
        e.hash == hash && e.indptr == a.indptr() && e.indices == a.indices()
    })?;
    let entry = entries.remove(k);
    let payload = entry.payload.clone();
    entries.insert(0, entry);
    Some(payload)
}

/// Insert at MRU position and evict beyond `capacity` (shared discipline).
fn cache_insert<T>(entries: &mut Vec<Keyed<T>>, capacity: usize, a: &Csr, hash: u64, payload: T) {
    entries.insert(
        0,
        Keyed { hash, indptr: a.indptr().to_vec(), indices: a.indices().to_vec(), payload },
    );
    entries.truncate(capacity);
}

/// Pattern-keyed LRU cache of symbolic analyses. Cholesky and LU analyses
/// are cached side by side — a symmetric pattern may legitimately hold
/// both — in distinct entry lists sharing one probe/MRU/eviction
/// discipline; each kind holds up to `capacity` entries, and hits/misses
/// count across both kinds.
pub struct SymbolicCache {
    entries: Vec<Keyed<PatternAnalysis>>,
    lu_entries: Vec<Keyed<Arc<LuSymbolic>>>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl Default for SymbolicCache {
    fn default() -> Self {
        SymbolicCache::new(8)
    }
}

impl SymbolicCache {
    pub fn new(capacity: usize) -> SymbolicCache {
        SymbolicCache {
            entries: Vec::new(),
            lu_entries: Vec::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    /// Analyze `a`'s pattern, reusing a cached analysis when the pattern is
    /// bit-identical to a recent one. MRU-ordered; exact pattern equality
    /// is verified on every hash match.
    pub fn analyze(&mut self, a: &Csr) -> PatternAnalysis {
        let hash = pattern_hash(a);
        if let Some(analysis) = cache_lookup(&mut self.entries, a, hash) {
            self.hits += 1;
            return analysis;
        }
        self.misses += 1;
        let sym = Arc::new(analyze(a));
        let sn_ptr = fundamental_supernodes(&sym);
        let ssym = if supernodal::profitable(&sym, &sn_ptr) {
            Some(Arc::new(SupernodalSymbolic::build(a, &sym, sn_ptr)))
        } else {
            None
        };
        let analysis = PatternAnalysis { sym, ssym };
        cache_insert(&mut self.entries, self.capacity, a, hash, analysis.clone());
        analysis
    }

    /// Analyze `a`'s pattern for LU (the A+Aᵀ symbolic bound), reusing a
    /// cached analysis when the pattern is bit-identical to a recent one.
    /// Same MRU/verification discipline as [`analyze`](Self::analyze).
    pub fn analyze_lu(&mut self, a: &Csr) -> Arc<LuSymbolic> {
        let hash = pattern_hash(a);
        if let Some(lsym) = cache_lookup(&mut self.lu_entries, a, hash) {
            self.hits += 1;
            return lsym;
        }
        self.misses += 1;
        let lsym = Arc::new(analyze_lu(a));
        cache_insert(&mut self.lu_entries, self.capacity, a, hash, lsym.clone());
        lsym
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn len(&self) -> usize {
        self.entries.len() + self.lu_entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.lu_entries.is_empty()
    }
}

/// FNV-1a over the pattern (shape + indptr + indices). Collisions are
/// harmless — every hash match is followed by an exact comparison.
fn pattern_hash(a: &Csr) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |v: u64| {
        for shift in [0u32, 16, 32, 48] {
            h ^= (v >> shift) & 0xffff;
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(a.nrows() as u64);
    eat(a.nnz() as u64);
    for &p in a.indptr() {
        eat(p as u64);
    }
    for &c in a.indices() {
        eat(c as u64);
    }
    h
}

/// Everything a long-lived solver/worker needs to keep factorization
/// allocation-free: scratch buffers + the pattern-keyed symbolic cache.
#[derive(Default)]
pub struct FactorContext {
    pub workspace: FactorWorkspace,
    pub cache: SymbolicCache,
}

impl FactorContext {
    pub fn new() -> FactorContext {
        FactorContext::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};

    #[test]
    fn cache_hits_on_identical_pattern() {
        let mut cache = SymbolicCache::new(4);
        let a = laplacian_2d(8, 8);
        let first = cache.analyze(&a);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 0);
        // identical pattern (the key ignores values) → hit
        let b = a.clone();
        let second = cache.analyze(&b);
        assert_eq!(cache.hits(), 1);
        assert!(Arc::ptr_eq(&first.sym, &second.sym), "must share the analysis");
        // different pattern → miss
        let c = laplacian_2d(8, 9);
        cache.analyze(&c);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn cache_evicts_lru() {
        let mut cache = SymbolicCache::new(2);
        let a = laplacian_2d(4, 4);
        let b = laplacian_2d(5, 4);
        let c = laplacian_2d(6, 4);
        cache.analyze(&a);
        cache.analyze(&b);
        cache.analyze(&c); // evicts a
        assert_eq!(cache.len(), 2);
        cache.analyze(&a); // miss again
        assert_eq!(cache.misses(), 4);
    }

    #[test]
    fn lu_cache_hits_on_identical_pattern_and_coexists_with_chol() {
        let mut cache = SymbolicCache::new(4);
        let a = laplacian_2d(8, 8);
        let l1 = cache.analyze_lu(&a);
        assert_eq!(cache.misses(), 1);
        let l2 = cache.analyze_lu(&a.clone());
        assert_eq!(cache.hits(), 1);
        assert!(Arc::ptr_eq(&l1, &l2), "must share the LU analysis");
        // a Cholesky analysis of the same pattern is a separate entry,
        // not a hit on the LU one
        cache.analyze(&a);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
        // symmetric pattern: the A+Aᵀ bound equals the Cholesky count
        assert_eq!(l1.lu_nnz_bound, 2 * cache.analyze(&a).sym.lnnz - a.nrows());
    }

    #[test]
    fn workspace_grows_once() {
        let mut ws = FactorWorkspace::new();
        ws.acquire(100);
        assert_eq!(ws.grow_events(), 1);
        ws.acquire(100);
        ws.acquire(60); // smaller: no growth
        assert_eq!(ws.grow_events(), 1);
        assert_eq!(ws.factorizations(), 3);
        ws.acquire(200);
        assert_eq!(ws.grow_events(), 2);
    }

    #[test]
    fn worker_scratch_grows_once() {
        let mut ws = FactorWorkspace::new();
        ws.acquire(100);
        assert_eq!(ws.grow_events(), 1);
        ws.acquire_workers(100, 4);
        assert_eq!(ws.grow_events(), 2);
        ws.workers[0].st_pos.push(7); // a staged entry from a "run"
        ws.acquire_workers(100, 4);
        ws.acquire_workers(60, 2); // smaller: no growth
        assert_eq!(ws.grow_events(), 2, "repeat acquires must not grow");
        assert!(ws.workers[0].st_pos.is_empty(), "staging log must reset");
        ws.acquire_workers(100, 8); // more workers: grows
        assert_eq!(ws.grow_events(), 3);
    }

    #[test]
    fn incremental_scratch_grows_once() {
        let mut ws = FactorWorkspace::new();
        ws.acquire_incremental(100);
        assert_eq!(ws.grow_events(), 1);
        ws.acquire_incremental(100);
        ws.acquire_incremental(40); // smaller: no growth
        assert_eq!(ws.grow_events(), 1, "repeat acquires must not grow");
        ws.acquire_incremental(250);
        assert_eq!(ws.grow_events(), 2);
        assert!(ws.inc_inv.len() >= 250 && ws.inc_mark.len() >= 250);
    }

    #[test]
    fn profitability_split_matches_structure() {
        // 3D AMD-ordered problems are the supernodal target; tiny or chain
        // matrices fall back
        let mut cache = SymbolicCache::default();
        let tri = {
            use crate::sparse::Coo;
            let mut coo = Coo::square(100);
            for i in 0..99 {
                coo.push_sym(i, i + 1, -1.0);
            }
            for i in 0..100 {
                coo.push(i, i, 2.5);
            }
            coo.to_csr()
        };
        assert!(cache.analyze(&tri).ssym.is_none(), "tridiagonal must fall back");

        let g3 = laplacian_3d(8, 8, 8);
        let amd = crate::order::amd(&g3);
        let pap = g3.permute_sym(&amd);
        let analysis = cache.analyze(&pap);
        assert!(
            analysis.ssym.is_some(),
            "3D AMD-ordered laplacian must take the supernodal path"
        );
    }
}
