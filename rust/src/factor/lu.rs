//! Left-looking Gilbert–Peierls sparse LU with threshold partial pivoting —
//! the unsymmetric side of the factorization engine.
//!
//! The paper's golden criterion is the fill-in of the **L+U** factors; for
//! SPD inputs Cholesky is a faithful proxy, but general (unsymmetric-value)
//! matrices — convection–diffusion, circuit-style systems — need a genuine
//! LU. This module provides it with the same layering as the Cholesky side:
//!
//! * **Symbolic** ([`analyze_lu`] → [`LuSymbolic`]): Cholesky analysis of
//!   the symmetrized pattern A+Aᵀ through the existing etree / exact
//!   column-count machinery. `2·lnnz(chol(A+Aᵀ)) − n` is a structural
//!   upper bound on nnz(L+U) that is *exact* when no pivoting fires (the
//!   common case on the diagonally dominant workloads the generators
//!   produce); the numeric phase uses it to pre-size the factor arrays.
//! * **Numeric** ([`factorize`] / [`refactor_into`]): per column, a DFS
//!   over the columns of the partially-built L discovers the exact row
//!   pattern (Gilbert–Peierls reachability), a sparse triangular solve in
//!   reverse-finish (topological) order computes the column, and a
//!   threshold test picks the pivot: the diagonal is kept whenever
//!   `|x[j]| ≥ tau·max|x|` over the unpivoted candidates, otherwise the
//!   largest-magnitude row wins. `tau = 1.0` is classic partial pivoting,
//!   `tau = 0` keeps any nonzero diagonal; the default 0.1 trades a
//!   bounded growth factor for sparsity (the SuperLU policy).
//!
//! All O(n) scratch lives in [`FactorWorkspace`] (dense accumulator, DFS
//! marks + stacks, the pivot-position map), so steady-state
//! re-factorization of an unchanged pattern performs zero scratch
//! allocations — the same `grow_events` contract the Cholesky kernels
//! honour. The factor's own arrays — L, U, `row_perm`, *and* the CSC
//! view of A the column sweep reads — are rebuilt in place by
//! [`refactor_into`], so the whole refactorization path touches the
//! allocator not at all.
//!
//! Algorithm validated against a numpy/scipy dense-LU oracle via a Python
//! mirror of the exact index logic (diagonally dominant ⇒ identity row
//! permutation; pivoting cases reconstruct P·A = L·U; SPD inputs reproduce
//! 2·nnz(chol) − n) before porting.

use crate::factor::etree::NONE;
use crate::factor::numeric::FactorError;
use crate::factor::symbolic::{analyze, Symbolic};
use crate::factor::workspace::FactorWorkspace;
use crate::sparse::Csr;

/// Pivoting policy for the numeric phase.
#[derive(Clone, Copy, Debug)]
pub struct LuOptions {
    /// Threshold partial-pivoting tolerance `tau ∈ [0, 1]`: the diagonal
    /// is accepted whenever it is nonzero and `|a_jj| ≥ tau · max_i
    /// |a_ij|` over the unpivoted candidates of column j (so `tau = 0`
    /// keeps any nonzero diagonal, never a zero one).
    pub pivot_tolerance: f64,
}

impl Default for LuOptions {
    fn default() -> Self {
        LuOptions { pivot_tolerance: 0.1 }
    }
}

/// Symbolic analysis for LU: the Cholesky analysis of the A+Aᵀ pattern and
/// the structural bound it implies.
#[derive(Clone, Debug)]
pub struct LuSymbolic {
    pub n: usize,
    /// etree + exact row/column counts of the symmetrized pattern.
    pub sym: Symbolic,
    /// Upper bound on nnz(L+U) (diagonal counted once) absent pivoting:
    /// `2·lnnz − n` of the symmetrized pattern. Exact when every pivot
    /// stays on the diagonal and the pattern of A is symmetric.
    pub lu_nnz_bound: usize,
}

/// Analyze the A+Aᵀ pattern of `a` for LU factorization.
pub fn analyze_lu(a: &Csr) -> LuSymbolic {
    // `symmetrize` produces the union pattern (values are irrelevant here;
    // cancellation keeps entries structurally — see Coo::to_csr).
    let aat = a.symmetrize();
    let sym = analyze(&aat);
    let lu_nnz_bound = 2 * sym.lnnz - a.nrows();
    LuSymbolic { n: a.nrows(), sym, lu_nnz_bound }
}

/// Sparse LU factors of a permuted system: `P_r · A = L·U` with unit-lower
/// L and the row permutation chosen by threshold partial pivoting.
///
/// Storage is column-compressed on both factors: `l_*` holds the strictly
/// sub-diagonal entries of L (the unit diagonal is implicit, row indices in
/// pivoted coordinates), `u_*` the strictly super-diagonal entries of U
/// (row = pivot step), and `udiag` the pivots.
#[derive(Clone, Debug)]
pub struct LuFactor {
    n: usize,
    l_indptr: Vec<usize>,
    l_rows: Vec<usize>,
    l_vals: Vec<f64>,
    u_indptr: Vec<usize>,
    u_rows: Vec<usize>,
    u_vals: Vec<f64>,
    udiag: Vec<f64>,
    /// `row_perm[k]` = original row index pivoted at step k.
    row_perm: Vec<usize>,
    // CSC view of A (Aᵀ in CSR terms), rebuilt in place each
    // (re)factorization so the steady state never re-allocates it
    at_indptr: Vec<usize>,
    at_indices: Vec<usize>,
    at_data: Vec<f64>,
}

impl LuFactor {
    pub fn n(&self) -> usize {
        self.n
    }

    /// nnz(L+U) with the diagonal counted once (unit diagonal of L merged
    /// with U's pivots) — the paper's golden criterion for general
    /// matrices. Equals `2·lnnz(chol) − n` on SPD inputs when no pivoting
    /// fires.
    pub fn lu_nnz(&self) -> usize {
        self.l_rows.len() + self.u_rows.len() + self.n
    }

    /// Row permutation chosen by pivoting: `row_perm()[k]` is the original
    /// row eliminated at step k. Identity iff no pivoting fired.
    pub fn row_perm(&self) -> &[usize] {
        &self.row_perm
    }

    /// True iff threshold pivoting never moved a row off the diagonal.
    pub fn no_pivoting(&self) -> bool {
        self.row_perm.iter().enumerate().all(|(k, &r)| k == r)
    }

    /// Entrywise ℓ₁ norm of L+U including L's implicit unit diagonal —
    /// the LU analogue of the paper's ‖L‖₁ surrogate.
    pub fn l1_norm(&self) -> f64 {
        self.l_vals.iter().map(|v| v.abs()).sum::<f64>()
            + self.u_vals.iter().map(|v| v.abs()).sum::<f64>()
            + self.udiag.iter().map(|v| v.abs()).sum::<f64>()
            + self.n as f64
    }

    /// Column j of L below the diagonal: (pivoted row indices, values).
    pub fn l_col(&self, j: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.l_indptr[j], self.l_indptr[j + 1]);
        (&self.l_rows[s..e], &self.l_vals[s..e])
    }

    /// Column j of U above the diagonal: (pivot-step rows, values); the
    /// diagonal itself is `udiag()[j]`.
    pub fn u_col(&self, j: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.u_indptr[j], self.u_indptr[j + 1]);
        (&self.u_rows[s..e], &self.u_vals[s..e])
    }

    pub fn udiag(&self) -> &[f64] {
        &self.udiag
    }

    /// Solve A·x = b through the factors (applies the pivoting row
    /// permutation internally).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        // y = L \ (P_r · b)
        let mut y: Vec<f64> = self.row_perm.iter().map(|&r| b[r]).collect();
        for j in 0..self.n {
            let yj = y[j];
            if yj != 0.0 {
                let (rows, vals) = self.l_col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    y[i] -= v * yj;
                }
            }
        }
        // x = U \ y
        for j in (0..self.n).rev() {
            y[j] /= self.udiag[j];
            let yj = y[j];
            if yj != 0.0 {
                let (rows, vals) = self.u_col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    y[i] -= v * yj;
                }
            }
        }
        y
    }
}

/// nnz(L+U) normalized by nnz(A) — the LU fill ratio the unsymmetric
/// harness tables report.
pub fn lu_fill_ratio(a: &Csr, f: &LuFactor) -> f64 {
    f.lu_nnz() as f64 / a.nnz() as f64
}

/// Convenience: LU fill ratio of A under ordering `order` (numeric
/// factorization, pivoting included). The LU analogue of
/// `symbolic::fill_ratio_of_order`.
pub fn lu_fill_ratio_of_order(a: &Csr, order: &[usize]) -> Result<f64, FactorError> {
    let pap = a.permute_sym(order);
    let f = lu(&pap)?;
    Ok(lu_fill_ratio(&pap, &f))
}

/// One-shot LU with internal symbolic analysis and a throwaway workspace
/// (tests / examples; serving paths hold a [`FactorWorkspace`] and a cached
/// [`LuSymbolic`] and call [`factorize`]).
pub fn lu(a: &Csr) -> Result<LuFactor, FactorError> {
    let lsym = analyze_lu(a);
    factorize(a, &lsym, LuOptions::default(), &mut FactorWorkspace::new())
}

/// Numeric LU with a precomputed symbolic bound and caller-owned scratch.
pub fn factorize(
    a: &Csr,
    lsym: &LuSymbolic,
    opts: LuOptions,
    ws: &mut FactorWorkspace,
) -> Result<LuFactor, FactorError> {
    let n = a.nrows();
    // the bound covers strict-L and strict-U *combined*; each side needs
    // half of it (exactly half on pattern-symmetric inputs without
    // pivoting, where the bound is tight)
    let per_side = (lsym.lu_nnz_bound.saturating_sub(n) + 1) / 2;
    let mut f = LuFactor {
        n,
        l_indptr: Vec::new(),
        l_rows: Vec::with_capacity(per_side),
        l_vals: Vec::with_capacity(per_side),
        u_indptr: Vec::new(),
        u_rows: Vec::with_capacity(per_side),
        u_vals: Vec::with_capacity(per_side),
        udiag: Vec::new(),
        row_perm: Vec::new(),
        at_indptr: Vec::new(),
        at_indices: Vec::new(),
        at_data: Vec::new(),
    };
    lu_core(a, opts, &mut f, ws)?;
    Ok(f)
}

/// Numeric re-factorization in place: `f` must come from a previous
/// factorization of a matrix with the same sparsity pattern as `a`. The
/// factor's buffers are reused; new values may change the pivot sequence
/// (and therefore the fill), but with an unchanged pattern and comparable
/// magnitudes the arrays stay within capacity and the refactorization is
/// allocation-free end to end.
pub fn refactor_into(
    a: &Csr,
    opts: LuOptions,
    f: &mut LuFactor,
    ws: &mut FactorWorkspace,
) -> Result<(), FactorError> {
    assert_eq!(f.n, a.nrows(), "lu::refactor_into: factor/matrix size mismatch");
    lu_core(a, opts, f, ws)
}

/// Shared numeric core writing into caller-owned factor storage.
///
/// Works column-by-column on the CSC view of `a` (rows of Aᵀ). For each
/// column j:
/// 1. DFS from the rows of A(:,j) through the columns of the
///    partially-built L (edges i → rows(L(:, pinv\[i\])) for already
///    pivoted i), marking visited rows and emitting *finish order* into
///    `pattern` — reverse finish order is a topological order of the
///    update dependencies.
/// 2. Sparse triangular solve x = L⁻¹·A(:,j) processing `pattern` in
///    reverse.
/// 3. Threshold pivot selection over the unpivoted rows of the pattern.
/// 4. Scatter: pivoted rows → U(:,j), unpivoted rows → L(:,j)/pivot.
///
/// L's row indices are kept in original coordinates during factorization
/// (the DFS needs them) and remapped through `pinv` at the end.
fn lu_core(
    a: &Csr,
    opts: LuOptions,
    f: &mut LuFactor,
    ws: &mut FactorWorkspace,
) -> Result<(), FactorError> {
    if a.nrows() != a.ncols() {
        return Err(FactorError::NotSquare { nrows: a.nrows(), ncols: a.ncols() });
    }
    let n = a.nrows();
    let tau = opts.pivot_tolerance.clamp(0.0, 1.0);
    ws.acquire(n);
    let (x, mark, pattern, pinv, stack, pstack) = ws.lu_buffers();
    for p in pinv[..n].iter_mut() {
        *p = NONE;
    }

    let LuFactor {
        l_indptr,
        l_rows,
        l_vals,
        u_indptr,
        u_rows,
        u_vals,
        udiag,
        row_perm,
        at_indptr,
        at_indices,
        at_data,
        ..
    } = f;
    // CSC view: row j of Aᵀ is column j of A. Rebuilt into the factor's
    // own buffers — refactorization reuses their capacity.
    a.transpose_into(at_indptr, at_indices, at_data);
    l_indptr.clear();
    l_indptr.push(0);
    l_rows.clear();
    l_vals.clear();
    u_indptr.clear();
    u_indptr.push(0);
    u_rows.clear();
    u_vals.clear();
    udiag.clear();
    udiag.resize(n, 0.0);
    row_perm.clear();
    row_perm.resize(n, NONE);

    for j in 0..n {
        // column j of A
        let acols = &at_indices[at_indptr[j]..at_indptr[j + 1]];
        let avals = &at_data[at_indptr[j]..at_indptr[j + 1]];
        // ----- symbolic: reach of A(:,j) through the columns of L -----
        pattern.clear();
        for &b in acols {
            if mark[b] == j {
                continue;
            }
            mark[b] = j;
            let mut depth = 0usize;
            stack[0] = b;
            pstack[0] = if pinv[b] != NONE { l_indptr[pinv[b]] } else { 0 };
            loop {
                let i = stack[depth];
                let mut descended = false;
                if pinv[i] != NONE {
                    let col = pinv[i];
                    let end = l_indptr[col + 1];
                    let mut p = pstack[depth];
                    while p < end {
                        let r = l_rows[p];
                        if mark[r] != j {
                            mark[r] = j;
                            pstack[depth] = p + 1;
                            depth += 1;
                            stack[depth] = r;
                            pstack[depth] =
                                if pinv[r] != NONE { l_indptr[pinv[r]] } else { 0 };
                            descended = true;
                            break;
                        }
                        p += 1;
                    }
                    if !descended {
                        pstack[depth] = end;
                    }
                }
                if descended {
                    continue;
                }
                pattern.push(i); // finished
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
        }

        // ----- numeric: x = L⁻¹·A(:,j) in reverse finish order -----
        for (&r, &v) in acols.iter().zip(avals) {
            x[r] = v;
        }
        for t in (0..pattern.len()).rev() {
            let i = pattern[t];
            let k = pinv[i];
            if k == NONE {
                continue;
            }
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for p in l_indptr[k]..l_indptr[k + 1] {
                x[l_rows[p]] -= l_vals[p] * xi;
            }
        }

        // ----- threshold partial pivoting -----
        let mut pivot_row = NONE;
        let mut best = 0.0f64;
        let mut diag_abs = -1.0f64; // −1 ⇒ diagonal not an eligible candidate
        for &i in pattern.iter() {
            if pinv[i] != NONE {
                continue;
            }
            let m = x[i].abs();
            if m > best {
                best = m;
                pivot_row = i;
            }
            if i == j {
                diag_abs = m;
            }
        }
        if pivot_row == NONE || best == 0.0 {
            return Err(FactorError::Singular { col: j });
        }
        // the diagonal must be genuinely nonzero to win: with tau = 0 an
        // explicit zero diagonal would otherwise pass `0 ≥ 0·best` and
        // poison the factor with infinities
        if diag_abs > 0.0 && diag_abs >= tau * best {
            pivot_row = j;
        }
        let piv = x[pivot_row];
        pinv[pivot_row] = j;
        row_perm[j] = pivot_row;
        udiag[j] = piv;

        // ----- scatter into U (pivoted rows) and L (the rest) -----
        for &i in pattern.iter() {
            if i != pivot_row {
                let k = pinv[i];
                if k != NONE {
                    u_rows.push(k);
                    u_vals.push(x[i]);
                } else {
                    l_rows.push(i); // original index; remapped below
                    l_vals.push(x[i] / piv);
                }
            }
            x[i] = 0.0;
        }
        l_indptr.push(l_rows.len());
        u_indptr.push(u_rows.len());
    }

    // remap L's rows into pivoted coordinates (strictly lower triangular)
    for r in l_rows.iter_mut() {
        *r = pinv[*r];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::cholesky;
    use crate::gen::grid::laplacian_2d;
    use crate::sparse::{Coo, Dense};
    use crate::util::check::{assert_vec_close, check_permutation};
    use crate::util::rng::Pcg64;

    /// Random pattern-symmetric, value-unsymmetric, diagonally dominant.
    fn random_unsym(n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut coo = Coo::square(n);
        let mut rowsum = vec![0.0f64; n];
        for _ in 0..(3 * n) {
            let i = rng.next_below(n);
            let j = rng.next_below(n);
            if i == j {
                continue;
            }
            let (a, b) = (rng.next_gaussian(), rng.next_gaussian());
            coo.push(i, j, a);
            coo.push(j, i, b);
            rowsum[i] += a.abs();
            rowsum[j] += b.abs();
        }
        for (i, s) in rowsum.iter().enumerate() {
            coo.push(i, i, s + 1.0);
        }
        coo.to_csr()
    }

    fn check_reconstruction(a: &Csr, tau: f64, tol: f64) -> LuFactor {
        let lsym = analyze_lu(a);
        let f = factorize(a, &lsym, LuOptions { pivot_tolerance: tau }, &mut FactorWorkspace::new())
            .expect("lu");
        check_permutation(f.row_perm()).expect("row_perm");
        let n = a.nrows();
        // densify L, U and check L·U == P·A
        let mut l = vec![vec![0.0; n]; n];
        let mut u = vec![vec![0.0; n]; n];
        for j in 0..n {
            l[j][j] = 1.0;
            u[j][j] = f.udiag()[j];
            let (rows, vals) = f.l_col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                assert!(i > j, "L entry ({i},{j}) not strictly lower");
                l[i][j] = v;
            }
            let (rows, vals) = f.u_col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                assert!(i < j, "U entry ({i},{j}) not strictly upper");
                u[i][j] = v;
            }
        }
        let scale = a.data().iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for i in 0..n {
            for jj in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i][k] * u[k][jj];
                }
                let pa = a.get(f.row_perm()[i], jj);
                assert!(
                    (s - pa).abs() <= tol * scale,
                    "LU mismatch at ({i},{jj}): {s} vs {pa}"
                );
            }
        }
        f
    }

    #[test]
    fn reconstructs_unsymmetric_random() {
        for seed in 0..8 {
            check_reconstruction(&random_unsym(25, seed), 0.1, 1e-10);
        }
    }

    #[test]
    fn dominant_matrices_never_pivot() {
        for seed in 0..6 {
            let a = random_unsym(30, 100 + seed);
            let f = check_reconstruction(&a, 0.1, 1e-10);
            assert!(f.no_pivoting(), "pivoting fired on a dominant matrix");
        }
    }

    #[test]
    fn pivoting_fires_and_reconstructs() {
        // a matrix that demands pivoting: tiny diagonal under a large
        // off-diagonal in the same column
        let mut coo = Coo::square(3);
        coo.push(0, 0, 1e-8);
        coo.push(1, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 1, 1e-8);
        coo.push(2, 2, 1.0);
        coo.push(0, 2, 0.5);
        coo.push(2, 0, 0.5);
        let a = coo.to_csr();
        let f = check_reconstruction(&a, 1.0, 1e-12);
        assert!(!f.no_pivoting(), "partial pivoting must swap rows here");
    }

    #[test]
    fn spd_lu_nnz_matches_cholesky_fill() {
        // without pivoting, nnz(L+U) == 2·lnnz(chol) − n on SPD inputs
        let a = laplacian_2d(7, 6);
        let f = lu(&a).unwrap();
        assert!(f.no_pivoting());
        let c = cholesky(&a).unwrap();
        assert_eq!(f.lu_nnz(), 2 * c.lnnz() - a.nrows());
        // and the symbolic bound is tight
        let lsym = analyze_lu(&a);
        assert_eq!(f.lu_nnz(), lsym.lu_nnz_bound);
    }

    #[test]
    fn solve_roundtrip() {
        let a = random_unsym(40, 9);
        let f = lu(&a).unwrap();
        let mut rng = Pcg64::new(10);
        let xt: Vec<f64> = (0..40).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = f.solve(&b);
        assert_vec_close(&x, &xt, 1e-8);
    }

    #[test]
    fn matches_dense_lu_oracle() {
        let a = random_unsym(20, 42);
        let f = lu(&a).unwrap();
        assert!(f.no_pivoting());
        let (dl, du) = Dense::from_rows(&a.to_dense()).lu_nopivot().unwrap();
        for j in 0..20 {
            let (rows, vals) = f.l_col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                assert!((v - dl.get(i, j)).abs() < 1e-9, "L[{i}][{j}] {v}");
            }
            assert!((f.udiag()[j] - du.get(j, j)).abs() < 1e-9, "U diag {j}");
            let (rows, vals) = f.u_col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                assert!((v - du.get(i, j)).abs() < 1e-9, "U[{i}][{j}] {v}");
            }
        }
    }

    #[test]
    fn refactor_reuses_buffers_without_scratch_growth() {
        let a = random_unsym(35, 3);
        let lsym = analyze_lu(&a);
        let mut ws = FactorWorkspace::new();
        let mut f = factorize(&a, &lsym, LuOptions::default(), &mut ws).unwrap();
        let scaled = Csr::from_parts(
            a.nrows(),
            a.ncols(),
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.data().iter().map(|v| v * 2.0).collect(),
        );
        let grows = ws.grow_events();
        refactor_into(&scaled, LuOptions::default(), &mut f, &mut ws).unwrap();
        assert_eq!(ws.grow_events(), grows, "LU refactor must not grow scratch");
        let fresh = lu(&scaled).unwrap();
        assert_eq!(f.lu_nnz(), fresh.lu_nnz());
        let mut rng = Pcg64::new(4);
        let xt: Vec<f64> = (0..35).map(|_| rng.next_gaussian()).collect();
        let b = scaled.matvec(&xt);
        assert_vec_close(&f.solve(&b), &xt, 1e-8);
    }

    /// Random pattern-symmetric matrix with a *weak* diagonal, so classic
    /// partial pivoting (tau = 1) genuinely swaps rows.
    fn random_weak_diag(n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut coo = Coo::square(n);
        for _ in 0..(3 * n) {
            let i = rng.next_below(n);
            let j = rng.next_below(n);
            if i != j {
                coo.push(i, j, rng.next_gaussian());
                coo.push(j, i, rng.next_gaussian());
            }
        }
        for i in 0..n {
            coo.push(i, i, 0.3 * rng.next_gaussian());
        }
        coo.to_csr()
    }

    #[test]
    fn full_partial_pivoting_matches_dense_oracle() {
        // tau = 1.0 is classic partial pivoting: the sparse kernel must
        // choose the exact same pivot sequence and produce the same
        // factors as the dense reference (ties are measure-zero with
        // gaussian values; validated over 60/60 random draws in the
        // Python mirror before porting)
        let mut pivoted = 0;
        for seed in 0..6 {
            let a = random_weak_diag(14, 1000 + seed);
            let lsym = analyze_lu(&a);
            let Ok(f) = factorize(
                &a,
                &lsym,
                LuOptions { pivot_tolerance: 1.0 },
                &mut FactorWorkspace::new(),
            ) else {
                continue; // singular draw
            };
            let Ok((dl, du, dperm)) = Dense::from_rows(&a.to_dense()).lu_partial_pivot() else {
                continue;
            };
            assert_eq!(f.row_perm(), &dperm[..], "seed {seed}: pivot sequences differ");
            if !f.no_pivoting() {
                pivoted += 1;
            }
            for j in 0..a.nrows() {
                assert!(
                    (f.udiag()[j] - du.get(j, j)).abs() <= 1e-9 * 1.0f64.max(du.get(j, j).abs()),
                    "seed {seed}: U diag {j}"
                );
                let (rows, vals) = f.l_col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    assert!(
                        (v - dl.get(i, j)).abs() <= 1e-9 * 1.0f64.max(v.abs()),
                        "seed {seed}: L[{i}][{j}]"
                    );
                }
                let (rows, vals) = f.u_col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    assert!(
                        (v - du.get(i, j)).abs() <= 1e-9 * 1.0f64.max(v.abs()),
                        "seed {seed}: U[{i}][{j}]"
                    );
                }
            }
        }
        assert!(pivoted >= 3, "partial pivoting fired on only {pivoted} draws");
    }

    #[test]
    fn zero_diagonal_never_chosen_as_pivot() {
        // explicit zero diagonal: even tau = 0 must not divide by it
        let mut coo = Coo::square(2);
        coo.push(0, 0, 0.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 0.0);
        let a = coo.to_csr();
        let lsym = analyze_lu(&a);
        let f = factorize(
            &a,
            &lsym,
            LuOptions { pivot_tolerance: 0.0 },
            &mut FactorWorkspace::new(),
        )
        .unwrap();
        assert!(!f.no_pivoting(), "must swap rows off the zero diagonal");
        assert_vec_close(&f.solve(&[2.0, 3.0]), &[3.0, 2.0], 1e-12);
    }

    #[test]
    fn rejects_singular() {
        let mut coo = Coo::square(2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        // row 1 entirely zero → column 1's candidates all zero
        coo.push(1, 1, 0.0);
        let res = lu(&coo.to_csr());
        assert!(matches!(res, Err(FactorError::Singular { .. })), "{res:?}");
    }

    #[test]
    fn structurally_unsymmetric_pattern_ok() {
        // pattern of A itself unsymmetric; A+Aᵀ analysis still bounds it
        let mut coo = Coo::square(5);
        for i in 0..5 {
            coo.push(i, i, 4.0);
        }
        coo.push(0, 3, 1.0);
        coo.push(2, 0, -1.5);
        coo.push(4, 1, 0.5);
        coo.push(1, 2, 2.0);
        let a = coo.to_csr();
        let f = check_reconstruction(&a, 0.1, 1e-12);
        let lsym = analyze_lu(&a);
        assert!(f.lu_nnz() <= lsym.lu_nnz_bound, "bound violated without pivoting");
    }
}
