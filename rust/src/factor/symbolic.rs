//! Symbolic Cholesky analysis: the exact fill-in count — the paper's golden
//! criterion that ‖L‖₁ approximates.
//!
//! `row_counts` computes nnz of every row of L without numeric work by
//! traversing row subtrees of the elimination tree (the skeleton of
//! Gilbert–Ng–Peyton). Cost is O(nnz(L)) with the marker trick, which is as
//! fast as the counts themselves.

use crate::factor::etree::{self, NONE};
use crate::sparse::Csr;

/// Result of symbolic analysis.
#[derive(Clone, Debug)]
pub struct Symbolic {
    /// etree parent pointers.
    pub parent: Vec<usize>,
    /// nnz of each row of L (including the diagonal).
    pub row_nnz: Vec<usize>,
    /// total nnz(L) including the diagonal.
    pub lnnz: usize,
}

/// Run symbolic analysis on a symmetric matrix.
pub fn analyze(a: &Csr) -> Symbolic {
    let n = a.nrows();
    let parent = etree::etree(a);
    let mut row_nnz = vec![1usize; n]; // diagonal always present
    let mut mark = vec![NONE; n]; // mark[j] == i ⇒ j already counted for row i
    for i in 0..n {
        mark[i] = i;
        let (cols, _) = a.row(i);
        for &j in cols {
            if j >= i {
                break;
            }
            // walk from j toward the root, stopping at marked nodes;
            // every new node is a nonzero l_ij' in row i of L
            let mut node = j;
            while mark[node] != i {
                mark[node] = i;
                row_nnz[i] += 1;
                if parent[node] == NONE || parent[node] >= i {
                    break;
                }
                node = parent[node];
            }
        }
    }
    let lnnz = row_nnz.iter().sum();
    Symbolic { parent, row_nnz, lnnz }
}

/// Exact number of fill-ins: new nonzero *positions* created by the
/// factorization. With U = Lᵀ, LU stores each off-diagonal pattern entry
/// twice and the diagonal twice (L's unit diagonal + U's pivot), while A
/// stores the diagonal once — so
/// `nnz(L) + nnz(U) − n − nnz(A) = 2·lnnz − n − nnz(A)`,
/// which is exactly 0 for a no-fill factorization (e.g. tridiagonal).
pub fn fill_in_count(a: &Csr, sym: &Symbolic) -> usize {
    2 * sym.lnnz - a.nrows() - a.nnz()
}

/// The paper's Eq. (15): fill-ins normalized by nnz(A).
pub fn fill_ratio(a: &Csr, sym: &Symbolic) -> f64 {
    fill_in_count(a, sym) as f64 / a.nnz() as f64
}

/// Convenience: fill ratio of A under ordering `order` (order[k] = original
/// index eliminated k-th).
pub fn fill_ratio_of_order(a: &Csr, order: &[usize]) -> f64 {
    let pap = a.permute_sym(order);
    let sym = analyze(&pap);
    fill_ratio(&pap, &sym)
}

/// Number of floating-point operations the numeric factorization will
/// perform: Σ_j nnz_col(L_j)² (standard flop count for LLᵀ). Used by the
/// benchmark harness as a machine-independent cost proxy.
pub fn factor_flops(sym: &Symbolic) -> u64 {
    // col counts from row patterns: recompute via the etree-based relation
    // col_count[j] = 1 + #descendants contributing. We derive them cheaply
    // from row subtree sizes: every row-i entry in column j contributes one
    // multiply-add pass of length ~col nnz; use Σ row_nnz² as an upper-bound
    // proxy consistent across orderings.
    sym.row_nnz.iter().map(|&r| (r as u64) * (r as u64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::sparse::{Coo, Dense};
    use crate::util::rng::Pcg64;

    /// Dense-Cholesky oracle: factor PAPᵀ densely and count nnz of L.
    fn dense_lnnz(a: &Csr) -> usize {
        let d = Dense::from_rows(&a.to_dense());
        let l = d.cholesky().expect("SPD");
        l.tril_nnz(1e-11)
    }

    #[test]
    fn tridiagonal_has_no_fill() {
        let mut coo = Coo::square(6);
        for i in 0..5 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..6 {
            coo.push(i, i, 2.5);
        }
        let a = coo.to_csr();
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, 6 + 5); // diag + subdiagonal
        // tridiagonal factors with zero fill
        assert_eq!(fill_in_count(&a, &sym), 0);
        assert_eq!(fill_ratio(&a, &sym), 0.0);
    }

    #[test]
    fn arrow_natural_order_fills_nothing_reversed_fills_all() {
        // Arrow pointing down-right (hub last) has NO fill;
        // hub-first ordering fills completely.
        let n = 8;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 8.0);
        }
        let a = coo.to_csr();
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, n + (n - 1)); // no fill

        // reverse order: hub first → dense L
        let rev: Vec<usize> = (0..n).rev().collect();
        let b = a.permute_sym(&rev);
        let symb = analyze(&b);
        assert_eq!(symb.lnnz, n * (n + 1) / 2); // completely dense
    }

    #[test]
    fn counts_match_dense_oracle_on_grid() {
        let a = laplacian_2d(6, 5);
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, dense_lnnz(&a), "2d grid");

        let a = laplacian_3d(3, 3, 3);
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, dense_lnnz(&a), "3d grid");
    }

    #[test]
    fn counts_match_dense_oracle_on_random_spd() {
        // random sparse SPD matrices: symbolic count must equal the dense
        // oracle's nonzero count (exact cancellation is measure-zero)
        let mut rng = Pcg64::new(99);
        for trial in 0..10 {
            let n = 12 + rng.next_below(20);
            let mut coo = Coo::square(n);
            let mut diag = vec![1.0; n];
            for _ in 0..(2 * n) {
                let i = rng.next_below(n);
                let j = rng.next_below(n);
                if i == j {
                    continue;
                }
                let w = 0.1 + rng.next_f64();
                coo.push_sym(i, j, -w);
                diag[i] += w;
                diag[j] += w;
            }
            for (i, d) in diag.iter().enumerate() {
                coo.push(i, i, *d + 0.5);
            }
            let a = coo.to_csr();
            let sym = analyze(&a);
            assert_eq!(sym.lnnz, dense_lnnz(&a), "trial {trial} n={n}");
        }
    }

    #[test]
    fn fill_ratio_of_order_identity_matches_direct() {
        let a = laplacian_2d(8, 8);
        let sym = analyze(&a);
        let direct = fill_ratio(&a, &sym);
        let via_order = fill_ratio_of_order(&a, &(0..64).collect::<Vec<_>>());
        assert!((direct - via_order).abs() < 1e-12);
    }

    #[test]
    fn flops_positive_and_ordering_sensitive() {
        let n = 10;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 8.0);
        }
        let a = coo.to_csr();
        let good = factor_flops(&analyze(&a));
        let rev: Vec<usize> = (0..n).rev().collect();
        let bad = factor_flops(&analyze(&a.permute_sym(&rev)));
        assert!(bad > 2 * good, "bad {bad} vs good {good}");
    }
}
