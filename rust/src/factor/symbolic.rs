//! Symbolic Cholesky analysis: the exact fill-in count — the paper's golden
//! criterion that ‖L‖₁ approximates.
//!
//! `row_counts` computes nnz of every row of L without numeric work by
//! traversing row subtrees of the elimination tree (the skeleton of
//! Gilbert–Ng–Peyton). Cost is O(nnz(L)) with the marker trick, which is as
//! fast as the counts themselves.

use crate::factor::etree::{self, NONE};
use crate::sparse::Csr;

/// Result of symbolic analysis.
#[derive(Clone, Debug)]
pub struct Symbolic {
    /// etree parent pointers.
    pub parent: Vec<usize>,
    /// nnz of each row of L (including the diagonal).
    pub row_nnz: Vec<usize>,
    /// nnz of each column of L (including the diagonal) — the exact
    /// Gilbert–Ng–Peyton column counts.
    pub col_nnz: Vec<usize>,
    /// total nnz(L) including the diagonal.
    pub lnnz: usize,
}

/// Run symbolic analysis on a symmetric matrix.
///
/// Row counts and column counts come out of the same row-subtree
/// traversal: when row i's walk discovers node j (⇔ l_ij ≠ 0, j < i), it is
/// one new entry of row i *and* one new sub-diagonal entry of column j, so
/// both counters advance together and both are exact in O(nnz(L)).
pub fn analyze(a: &Csr) -> Symbolic {
    let n = a.nrows();
    let parent = etree::etree(a);
    let mut row_nnz = vec![1usize; n]; // diagonal always present
    let mut col_nnz = vec![1usize; n]; // ditto for columns
    let mut mark = vec![NONE; n]; // mark[j] == i ⇒ j already counted for row i
    for i in 0..n {
        mark[i] = i;
        let (cols, _) = a.row(i);
        for &j in cols {
            if j >= i {
                break;
            }
            // walk from j toward the root, stopping at marked nodes;
            // every new node is a nonzero l_ij' in row i of L
            let mut node = j;
            while mark[node] != i {
                mark[node] = i;
                row_nnz[i] += 1;
                col_nnz[node] += 1;
                if parent[node] == NONE || parent[node] >= i {
                    break;
                }
                node = parent[node];
            }
        }
    }
    let lnnz = row_nnz.iter().sum();
    Symbolic { parent, row_nnz, col_nnz, lnnz }
}

/// Exact number of fill-ins: new nonzero *positions* created by the
/// factorization. With U = Lᵀ, LU stores each off-diagonal pattern entry
/// twice and the diagonal twice (L's unit diagonal + U's pivot), while A
/// stores the diagonal once — so
/// `nnz(L) + nnz(U) − n − nnz(A) = 2·lnnz − n − nnz(A)`,
/// which is exactly 0 for a no-fill factorization (e.g. tridiagonal).
pub fn fill_in_count(a: &Csr, sym: &Symbolic) -> usize {
    2 * sym.lnnz - a.nrows() - a.nnz()
}

/// The paper's Eq. (15): fill-ins normalized by nnz(A).
pub fn fill_ratio(a: &Csr, sym: &Symbolic) -> f64 {
    fill_in_count(a, sym) as f64 / a.nnz() as f64
}

/// Convenience: fill ratio of A under ordering `order` (order[k] = original
/// index eliminated k-th).
pub fn fill_ratio_of_order(a: &Csr, order: &[usize]) -> f64 {
    let pap = a.permute_sym(order);
    let sym = analyze(&pap);
    fill_ratio(&pap, &sym)
}

/// Number of floating-point operations the numeric factorization will
/// perform: the exact Σ_j col_nnz(L_j)² (standard flop count for LLᵀ —
/// column j costs one sqrt, col_nnz−1 divides, and a rank-1 update over the
/// col_nnz×col_nnz lower block, which Σ cⱼ² counts to leading order).
/// Used by the benchmark harness as a machine-independent cost measure.
pub fn factor_flops(sym: &Symbolic) -> u64 {
    sym.col_nnz.iter().map(|&c| (c as u64) * (c as u64)).sum()
}

/// Cap on supernode panel width. Wider runs are split: a prefix of a
/// nested-pattern run is still a valid supernode, and bounding the width
/// keeps the dense panels inside L1/L2 during the rank-k updates.
pub const MAX_SUPERNODE_WIDTH: usize = 32;

/// Partition the columns into fundamental supernodes: maximal runs of
/// columns with identical sub-diagonal pattern, detected with the exact
/// column counts via
/// `parent[j] == j+1 && col_nnz[j] == col_nnz[j+1] + 1`
/// (the parent relation gives Struct(L₍ⱼ₎)∖{j} ⊆ Struct(L₍ⱼ₊₁₎); equal
/// cardinality upgrades the inclusion to equality). Returns CSR-style
/// boundaries: `sn_ptr[s]..sn_ptr[s+1]` are the columns of supernode s,
/// `sn_ptr.len() == nsuper + 1`, `sn_ptr[nsuper] == n`.
pub fn fundamental_supernodes(sym: &Symbolic) -> Vec<usize> {
    let n = sym.parent.len();
    let mut sn_ptr = Vec::with_capacity(n / 2 + 2);
    sn_ptr.push(0);
    let mut start = 0usize;
    for j in 0..n {
        let merge_next = j + 1 < n
            && sym.parent[j] == j + 1
            && sym.col_nnz[j] == sym.col_nnz[j + 1] + 1
            && (j + 1 - start) < MAX_SUPERNODE_WIDTH;
        if !merge_next {
            sn_ptr.push(j + 1);
            start = j + 1;
        }
    }
    sn_ptr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::sparse::{Coo, Dense};
    use crate::util::rng::Pcg64;

    /// Dense-Cholesky oracle: factor PAPᵀ densely and count nnz of L.
    fn dense_lnnz(a: &Csr) -> usize {
        let d = Dense::from_rows(&a.to_dense());
        let l = d.cholesky().expect("SPD");
        l.tril_nnz(1e-11)
    }

    #[test]
    fn tridiagonal_has_no_fill() {
        let mut coo = Coo::square(6);
        for i in 0..5 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..6 {
            coo.push(i, i, 2.5);
        }
        let a = coo.to_csr();
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, 6 + 5); // diag + subdiagonal
        // tridiagonal factors with zero fill
        assert_eq!(fill_in_count(&a, &sym), 0);
        assert_eq!(fill_ratio(&a, &sym), 0.0);
    }

    #[test]
    fn arrow_natural_order_fills_nothing_reversed_fills_all() {
        // Arrow pointing down-right (hub last) has NO fill;
        // hub-first ordering fills completely.
        let n = 8;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 8.0);
        }
        let a = coo.to_csr();
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, n + (n - 1)); // no fill

        // reverse order: hub first → dense L
        let rev: Vec<usize> = (0..n).rev().collect();
        let b = a.permute_sym(&rev);
        let symb = analyze(&b);
        assert_eq!(symb.lnnz, n * (n + 1) / 2); // completely dense
    }

    #[test]
    fn counts_match_dense_oracle_on_grid() {
        let a = laplacian_2d(6, 5);
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, dense_lnnz(&a), "2d grid");

        let a = laplacian_3d(3, 3, 3);
        let sym = analyze(&a);
        assert_eq!(sym.lnnz, dense_lnnz(&a), "3d grid");
    }

    #[test]
    fn counts_match_dense_oracle_on_random_spd() {
        // random sparse SPD matrices: symbolic count must equal the dense
        // oracle's nonzero count (exact cancellation is measure-zero)
        let mut rng = Pcg64::new(99);
        for trial in 0..10 {
            let n = 12 + rng.next_below(20);
            let mut coo = Coo::square(n);
            let mut diag = vec![1.0; n];
            for _ in 0..(2 * n) {
                let i = rng.next_below(n);
                let j = rng.next_below(n);
                if i == j {
                    continue;
                }
                let w = 0.1 + rng.next_f64();
                coo.push_sym(i, j, -w);
                diag[i] += w;
                diag[j] += w;
            }
            for (i, d) in diag.iter().enumerate() {
                coo.push(i, i, *d + 0.5);
            }
            let a = coo.to_csr();
            let sym = analyze(&a);
            assert_eq!(sym.lnnz, dense_lnnz(&a), "trial {trial} n={n}");
        }
    }

    #[test]
    fn fill_ratio_of_order_identity_matches_direct() {
        let a = laplacian_2d(8, 8);
        let sym = analyze(&a);
        let direct = fill_ratio(&a, &sym);
        let via_order = fill_ratio_of_order(&a, &(0..64).collect::<Vec<_>>());
        assert!((direct - via_order).abs() < 1e-12);
    }

    #[test]
    fn flops_positive_and_ordering_sensitive() {
        let n = 10;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 8.0);
        }
        let a = coo.to_csr();
        let good = factor_flops(&analyze(&a));
        // exact counts: hub-last columns are {j, hub} (c=2) except the hub
        // itself (c=1) → 9·4 + 1
        assert_eq!(good, 37);
        let rev: Vec<usize> = (0..n).rev().collect();
        let bad = factor_flops(&analyze(&a.permute_sym(&rev)));
        // hub-first is dense: Σ_{k=1..10} k² = 385
        assert_eq!(bad, 385);
        assert!(bad > 2 * good, "bad {bad} vs good {good}");
    }

    /// Dense-Cholesky oracle for per-column counts of L.
    fn dense_col_counts(a: &Csr) -> Vec<usize> {
        let d = Dense::from_rows(&a.to_dense());
        let l = d.cholesky().expect("SPD");
        let n = a.nrows();
        (0..n)
            .map(|j| (j..n).filter(|&i| l.get(i, j).abs() > 1e-11).count())
            .collect()
    }

    #[test]
    fn col_counts_match_dense_oracle() {
        let a = laplacian_2d(6, 5);
        let sym = analyze(&a);
        assert_eq!(sym.col_nnz, dense_col_counts(&a), "2d grid");
        assert_eq!(sym.col_nnz.iter().sum::<usize>(), sym.lnnz);

        let mut rng = Pcg64::new(5);
        for trial in 0..8 {
            let n = 12 + rng.next_below(20);
            let mut coo = Coo::square(n);
            let mut diag = vec![1.0; n];
            for _ in 0..(2 * n) {
                let i = rng.next_below(n);
                let j = rng.next_below(n);
                if i == j {
                    continue;
                }
                let w = 0.1 + rng.next_f64();
                coo.push_sym(i, j, -w);
                diag[i] += w;
                diag[j] += w;
            }
            for (i, d) in diag.iter().enumerate() {
                coo.push(i, i, *d + 0.5);
            }
            let a = coo.to_csr();
            let sym = analyze(&a);
            assert_eq!(sym.col_nnz, dense_col_counts(&a), "trial {trial} n={n}");
            assert_eq!(sym.col_nnz.iter().sum::<usize>(), sym.lnnz);
        }
    }

    #[test]
    fn supernodes_on_canonical_shapes() {
        // tridiagonal: no two adjacent columns share a sub-pattern → all
        // singleton supernodes
        let mut coo = Coo::square(6);
        for i in 0..5 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..6 {
            coo.push(i, i, 2.5);
        }
        let sym = analyze(&coo.to_csr());
        assert_eq!(fundamental_supernodes(&sym), vec![0, 1, 2, 3, 4, 5, 6]);

        // hub-last arrow: only the last two columns fuse
        let n = 8;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 8.0);
        }
        let a = coo.to_csr();
        let sym = analyze(&a);
        assert_eq!(fundamental_supernodes(&sym), vec![0, 1, 2, 3, 4, 5, 6, 8]);

        // hub-first arrow: L is completely dense → one supernode
        let rev: Vec<usize> = (0..n).rev().collect();
        let symr = analyze(&a.permute_sym(&rev));
        assert_eq!(fundamental_supernodes(&symr), vec![0, 8]);
    }

    #[test]
    fn supernode_width_is_capped() {
        // dense L on n=40 → split at MAX_SUPERNODE_WIDTH
        let n = 40;
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 64.0);
        }
        let a = coo.to_csr();
        let rev: Vec<usize> = (0..n).rev().collect();
        let sym = analyze(&a.permute_sym(&rev));
        let sn = fundamental_supernodes(&sym);
        assert_eq!(sn, vec![0, MAX_SUPERNODE_WIDTH, n]);
        for w in sn.windows(2) {
            assert!(w[1] - w[0] <= MAX_SUPERNODE_WIDTH);
        }
    }
}
