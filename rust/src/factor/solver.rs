//! End-to-end direct solver: reorder → factor → solve, with the fill-in and
//! timing bookkeeping the experiments report. This is the "downstream user"
//! API — what a simulation code would call.
//!
//! The solver is **kind-generic**: symmetric matrices take the Cholesky
//! engine (supernodal or up-looking per pattern — see
//! `factor::supernodal::profitable`), unsymmetric ones the Gilbert–Peierls
//! LU engine with threshold partial pivoting. [`FactorKind::for_matrix`]
//! makes the call from `Csr::is_symmetric`; callers with out-of-band
//! knowledge can pin the kind via [`DirectSolver::prepare_kind_with`].
//!
//! The [`FactorContext`]-taking entry points make the serving steady state
//! cheap for both kinds: a repeated pattern hits the symbolic cache (zero
//! re-analysis), the shared workspace (zero scratch allocation), and
//! [`DirectSolver::refactor`] rewrites the factor values in place.

use std::sync::Arc;
use std::time::Instant;

use crate::factor::lu::{self, LuFactor, LuOptions, LuSymbolic};
use crate::factor::numeric::{self, CholFactor, FactorError};
use crate::factor::sched::{self, Schedule};
use crate::factor::supernodal::{self, SupernodalFactor};
use crate::factor::symbolic::{factor_flops, fill_ratio};
use crate::factor::workspace::{FactorContext, FactorWorkspace, PatternAnalysis};
use crate::sparse::Csr;
use crate::util::sync::effective_threads;

/// Tolerance used when auto-detecting matrix symmetry for kind dispatch.
pub const SYMMETRY_TOL: f64 = 1e-12;

/// Which factorization a matrix calls for: LLᵀ on symmetric inputs, LU
/// with threshold partial pivoting on general ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FactorKind {
    Cholesky,
    Lu,
}

impl FactorKind {
    /// Short label used in CSV columns and metrics.
    pub fn label(&self) -> &'static str {
        match self {
            FactorKind::Cholesky => "cholesky",
            FactorKind::Lu => "lu",
        }
    }

    /// Pick the kind for a matrix: Cholesky iff symmetric (pattern and
    /// values, tolerance [`SYMMETRY_TOL`]).
    pub fn for_matrix(a: &Csr) -> FactorKind {
        if a.is_symmetric(SYMMETRY_TOL) {
            FactorKind::Cholesky
        } else {
            FactorKind::Lu
        }
    }
}

/// The factor produced by whichever engine/kernel the matrix selected.
pub enum Factorization {
    /// Scalar up-looking Cholesky factor.
    CholUpLooking(CholFactor),
    /// Blocked supernodal Cholesky factor.
    CholSupernodal(SupernodalFactor),
    /// Gilbert–Peierls LU factor (unit-lower L, U, row pivoting).
    Lu(LuFactor),
}

impl Factorization {
    /// Which factorization kind produced this factor.
    pub fn kind(&self) -> FactorKind {
        match self {
            Factorization::CholUpLooking(_) | Factorization::CholSupernodal(_) => {
                FactorKind::Cholesky
            }
            Factorization::Lu(_) => FactorKind::Lu,
        }
    }

    /// Structural nonzeros of the factor(s): nnz(L) for Cholesky,
    /// nnz(L+U) with the diagonal counted once for LU (the two coincide
    /// as fill measures: both equal the golden-criterion numerator).
    pub fn factor_nnz(&self) -> usize {
        match self {
            Factorization::CholUpLooking(f) => f.lnnz(),
            Factorization::CholSupernodal(f) => f.lnnz(),
            Factorization::Lu(f) => f.lu_nnz(),
        }
    }

    /// Entrywise ℓ₁ norm of the factor(s) — the paper's surrogate
    /// objective ‖L‖₁ (‖L+U‖₁ for LU).
    pub fn l1_norm(&self) -> f64 {
        match self {
            Factorization::CholUpLooking(f) => f.l1_norm(),
            Factorization::CholSupernodal(f) => f.l1_norm(),
            Factorization::Lu(f) => f.l1_norm(),
        }
    }

    /// Solve A·x = b through the factor (the LU arm applies its pivoting
    /// row permutation internally).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        match self {
            Factorization::CholUpLooking(f) => f.solve(b),
            Factorization::CholSupernodal(f) => f.solve(b),
            Factorization::Lu(f) => f.solve(b),
        }
    }

    /// Which numeric kernel produced this factor.
    pub fn kernel(&self) -> &'static str {
        match self {
            Factorization::CholUpLooking(_) => "up-looking",
            Factorization::CholSupernodal(_) => "supernodal",
            Factorization::Lu(_) => "lu-gp",
        }
    }

    /// Row-compressed view of L for the Cholesky kinds (clones for the
    /// up-looking kernel, converts panels for the supernodal one);
    /// `None` for LU.
    pub fn to_chol(&self) -> Option<CholFactor> {
        match self {
            Factorization::CholUpLooking(f) => Some(f.clone()),
            Factorization::CholSupernodal(f) => Some(f.to_chol()),
            Factorization::Lu(_) => None,
        }
    }
}

/// The symbolic analysis retained for refactorization, per kind.
enum Analysis {
    Chol(PatternAnalysis),
    Lu(Arc<LuSymbolic>),
}

/// A factorized, permuted system ready for repeated solves.
pub struct DirectSolver {
    order: Vec<usize>,
    analysis: Analysis,
    factor: Factorization,
    /// Task-DAG schedule for parallel supernodal (re)factorization —
    /// `Some` iff this solver was prepared with `factor_threads > 1` AND
    /// the pattern has enough subtree parallelism (`Schedule::build`).
    sched: Option<Arc<Schedule>>,
    /// Statistics gathered during `prepare`.
    pub stats: SolveStats,
}

/// Bookkeeping the experiments report (paper Table 2 / Figure 4 columns).
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub n: usize,
    pub nnz_a: usize,
    /// structural factor nnz: nnz(L) for Cholesky, nnz(L+U) for LU
    pub lnnz: usize,
    /// Cholesky: the paper's Eq. 15 (fill-ins / nnz(A));
    /// LU: nnz(L+U) / nnz(A)
    pub fill_ratio: f64,
    pub ordering_time: f64,
    pub symbolic_time: f64,
    pub factor_time: f64,
    /// exact LLᵀ flop count for Cholesky (Σⱼ col_nnz(L)ⱼ²); for LU, the
    /// structural estimate 2·Σⱼ col_nnz(chol(A+Aᵀ))ⱼ² — LU does twice
    /// the Cholesky work to leading order (dense limit 2n³/3 vs n³/3),
    /// exact absent pivoting on pattern-symmetric inputs
    pub flops: u64,
    /// numeric kernel used ("up-looking" | "supernodal" | "lu-gp")
    pub kernel: &'static str,
    /// factorization kind ("cholesky" | "lu")
    pub factor_kind: &'static str,
}

impl DirectSolver {
    /// Reorder A with `order` (precomputed permutation; `order[k]` = original
    /// index eliminated k-th), then factorize. The kind is auto-detected
    /// from matrix symmetry. `ordering_time` is supplied by the caller
    /// since the ordering was computed outside.
    pub fn prepare(a: &Csr, order: Vec<usize>, ordering_time: f64) -> Result<Self, FactorError> {
        DirectSolver::prepare_with(a, order, ordering_time, &mut FactorContext::new())
    }

    /// Like [`prepare`](Self::prepare), but reusing a long-lived
    /// [`FactorContext`]: a previously-seen permuted pattern skips symbolic
    /// analysis (cache hit) and performs no scratch allocation.
    pub fn prepare_with(
        a: &Csr,
        order: Vec<usize>,
        ordering_time: f64,
        ctx: &mut FactorContext,
    ) -> Result<Self, FactorError> {
        let kind = FactorKind::for_matrix(a);
        DirectSolver::prepare_kind_with(a, order, kind, ordering_time, ctx)
    }

    /// Fully explicit entry point: factorize `a` under `order` with the
    /// given [`FactorKind`] through a shared context. Note a Cholesky
    /// request on an unsymmetric matrix will fail (or silently use only
    /// the lower triangle); prefer [`prepare_with`](Self::prepare_with)
    /// unless the kind is known out of band.
    pub fn prepare_kind_with(
        a: &Csr,
        order: Vec<usize>,
        kind: FactorKind,
        ordering_time: f64,
        ctx: &mut FactorContext,
    ) -> Result<Self, FactorError> {
        DirectSolver::prepare_kind_threaded(a, order, kind, ordering_time, ctx, 1)
    }

    /// [`prepare_kind_with`](Self::prepare_kind_with) plus a
    /// `factor_threads` knob: with more than one (effective) thread and a
    /// pattern with usable subtree parallelism, the supernodal numeric
    /// phase runs through the task-DAG scheduler (`factor::sched`) —
    /// bit-identical factor, and [`refactor`](Self::refactor) reuses the
    /// same schedule. The request is clamped by the machine's available
    /// parallelism; patterns the scheduler declines (small, path-etree)
    /// factor sequentially with no threads spawned.
    pub fn prepare_kind_threaded(
        a: &Csr,
        order: Vec<usize>,
        kind: FactorKind,
        ordering_time: f64,
        ctx: &mut FactorContext,
        factor_threads: usize,
    ) -> Result<Self, FactorError> {
        let threads = effective_threads(factor_threads);
        let t0 = Instant::now();
        let pap = a.permute_sym(&order);
        let mut sched = None;
        let (analysis, symbolic_time, factor, factor_time, lnnz, fr, flops) = match kind {
            FactorKind::Cholesky => {
                let analysis = ctx.cache.analyze(&pap);
                if threads > 1 {
                    if let Some(ssym) = &analysis.ssym {
                        sched = Schedule::build(ssym, threads).map(Arc::new);
                    }
                }
                let symbolic_time = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let factor = match (&analysis.ssym, &sched) {
                    (Some(ssym), Some(sched)) => {
                        Factorization::CholSupernodal(sched::factorize_parallel(
                            &pap,
                            ssym.clone(),
                            &mut ctx.workspace,
                            sched,
                        )?)
                    }
                    (Some(ssym), None) => Factorization::CholSupernodal(supernodal::factorize(
                        &pap,
                        ssym.clone(),
                        &mut ctx.workspace,
                    )?),
                    (None, _) => Factorization::CholUpLooking(numeric::cholesky_with_ws(
                        &pap,
                        &analysis.sym,
                        &mut ctx.workspace,
                    )?),
                };
                let factor_time = t1.elapsed().as_secs_f64();
                let lnnz = analysis.sym.lnnz;
                let fr = fill_ratio(&pap, &analysis.sym);
                let flops = factor_flops(&analysis.sym);
                (Analysis::Chol(analysis), symbolic_time, factor, factor_time, lnnz, fr, flops)
            }
            FactorKind::Lu => {
                let lsym = ctx.cache.analyze_lu(&pap);
                let symbolic_time = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let f = lu::factorize(&pap, &lsym, LuOptions::default(), &mut ctx.workspace)?;
                let factor_time = t1.elapsed().as_secs_f64();
                let lnnz = f.lu_nnz();
                let fr = lu::lu_fill_ratio(&pap, &f);
                // LU ≈ 2× the Cholesky flop count of the A+Aᵀ pattern
                // (see the `flops` field docs)
                let flops = 2 * factor_flops(&lsym.sym);
                (
                    Analysis::Lu(lsym),
                    symbolic_time,
                    Factorization::Lu(f),
                    factor_time,
                    lnnz,
                    fr,
                    flops,
                )
            }
        };

        let stats = SolveStats {
            n: a.nrows(),
            nnz_a: a.nnz(),
            lnnz,
            fill_ratio: fr,
            ordering_time,
            symbolic_time,
            factor_time,
            flops,
            kernel: factor.kernel(),
            factor_kind: kind.label(),
        };
        Ok(DirectSolver { order, analysis, factor, sched, stats })
    }

    /// Is the task-DAG parallel factorization path active for this
    /// solver (schedule built and used by prepare/refactor)?
    pub fn parallel_factor_active(&self) -> bool {
        self.sched.is_some()
    }

    /// Numeric re-factorization for a matrix with the **same pattern** as
    /// the one this solver was prepared on but (possibly) new values — the
    /// serving steady state. Performs zero symbolic analysis (the stored
    /// analysis is reused) and zero scratch allocation (given a warm
    /// workspace); the factor values are rewritten in place. The LU arm
    /// may re-pivot under the new values (its fill can change); the
    /// stored factor buffers are still reused.
    pub fn refactor(&mut self, a: &Csr, ws: &mut FactorWorkspace) -> Result<(), FactorError> {
        let t1 = Instant::now();
        let pap = a.permute_sym(&self.order);
        match (&mut self.factor, &self.analysis) {
            (Factorization::CholUpLooking(f), Analysis::Chol(an)) => {
                numeric::refactor_into(&pap, &an.sym, f, ws)?
            }
            (Factorization::CholSupernodal(f), Analysis::Chol(_)) => match &self.sched {
                Some(sched) => f.refactor_parallel(&pap, ws, sched)?,
                None => f.refactor(&pap, ws)?,
            },
            (Factorization::Lu(f), Analysis::Lu(_)) => {
                lu::refactor_into(&pap, LuOptions::default(), f, ws)?;
                self.stats.lnnz = f.lu_nnz();
                self.stats.fill_ratio = lu::lu_fill_ratio(&pap, f);
            }
            _ => unreachable!("factor/analysis kind mismatch"),
        }
        self.stats.factor_time = t1.elapsed().as_secs_f64();
        Ok(())
    }

    /// Solve A·x = b (handles the permutation internally).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = b.len();
        assert_eq!(n, self.order.len());
        let pb: Vec<f64> = self.order.iter().map(|&o| b[o]).collect();
        let px = self.factor.solve(&pb);
        let mut x = vec![0.0; n];
        for (k, &o) in self.order.iter().enumerate() {
            x[o] = px[k];
        }
        x
    }

    /// Relative residual ‖Ax − b‖₂ / ‖b‖₂.
    pub fn residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
        let ax = a.matvec(x);
        let num: f64 = ax
            .iter()
            .zip(b)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        let den: f64 = b.iter().map(|q| q * q).sum::<f64>().sqrt().max(1e-300);
        num / den
    }

    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn factor(&self) -> &Factorization {
        &self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{convection_diffusion_2d, laplacian_2d, laplacian_3d};
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_with_identity_order() {
        let a = laplacian_2d(6, 6);
        let n = a.nrows();
        let solver = DirectSolver::prepare(&a, (0..n).collect(), 0.0).unwrap();
        let mut rng = Pcg64::new(1);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn solves_with_random_order() {
        let a = laplacian_2d(5, 7);
        let n = a.nrows();
        let mut rng = Pcg64::new(2);
        let order = rng.permutation(n);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn stats_are_populated() {
        let a = laplacian_2d(8, 8);
        let solver = DirectSolver::prepare(&a, (0..64).collect(), 0.125).unwrap();
        let s = &solver.stats;
        assert_eq!(s.n, 64);
        assert_eq!(s.nnz_a, a.nnz());
        assert!(s.lnnz >= (a.nnz() + 64) / 2);
        assert!(s.fill_ratio >= 0.0);
        assert_eq!(s.ordering_time, 0.125);
        assert!(s.factor_time >= 0.0);
        assert!(s.flops > 0);
        assert!(!s.kernel.is_empty());
        assert_eq!(s.factor_kind, "cholesky");
    }

    #[test]
    fn supernodal_path_selected_and_solves() {
        let a = laplacian_3d(6, 6, 6);
        let order = crate::order::amd(&a);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        assert_eq!(solver.stats.kernel, "supernodal");
        let n = a.nrows();
        let mut rng = Pcg64::new(4);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn unsymmetric_matrix_dispatches_to_lu_and_solves() {
        let mut rng = Pcg64::new(6);
        let a = convection_diffusion_2d(9, 8, 2.0, &mut rng);
        assert!(!a.is_symmetric(1e-12), "generator must be value-unsymmetric");
        assert_eq!(FactorKind::for_matrix(&a), FactorKind::Lu);
        let n = a.nrows();
        let order = crate::order::amd(&a);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        assert_eq!(solver.stats.kernel, "lu-gp");
        assert_eq!(solver.stats.factor_kind, "lu");
        assert!(solver.stats.fill_ratio >= 1.0, "nnz(L+U) ≥ nnz(A) on this class");
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn steady_state_skips_symbolic_and_allocations() {
        // the acceptance criterion: repeated factorizations with an
        // unchanged pattern → zero symbolic re-analysis, zero scratch
        // re-allocation
        let a = laplacian_3d(5, 5, 5);
        let order = crate::order::amd(&a);
        let mut ctx = FactorContext::new();
        let _ = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
        assert_eq!(ctx.cache.misses(), 1);
        let grows = ctx.workspace.grow_events();
        for _ in 0..5 {
            let s = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
            assert!(s.stats.lnnz > 0);
        }
        assert_eq!(ctx.cache.misses(), 1, "no symbolic re-analysis");
        assert_eq!(ctx.cache.hits(), 5);
        assert_eq!(ctx.workspace.grow_events(), grows, "no scratch re-allocation");
    }

    #[test]
    fn lu_steady_state_skips_symbolic_and_allocations() {
        // the same contract on the LU path
        let mut rng = Pcg64::new(8);
        let a = convection_diffusion_2d(10, 10, 1.5, &mut rng);
        let order = crate::order::amd(&a);
        let mut ctx = FactorContext::new();
        let first = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
        assert_eq!(first.stats.factor_kind, "lu");
        assert_eq!(ctx.cache.misses(), 1);
        let grows = ctx.workspace.grow_events();
        for _ in 0..4 {
            let s = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
            assert_eq!(s.stats.lnnz, first.stats.lnnz);
        }
        assert_eq!(ctx.cache.misses(), 1, "no LU symbolic re-analysis");
        assert_eq!(ctx.cache.hits(), 4);
        assert_eq!(ctx.workspace.grow_events(), grows, "no scratch re-allocation");
    }

    #[test]
    fn threaded_prepare_is_bit_identical_and_allocation_free() {
        // the tentpole contract at the solver layer: factor_threads > 1
        // yields the same factor bit for bit, and the steady state
        // (threaded refactor) performs zero scratch allocations
        let a = laplacian_3d(12, 12, 12);
        let order = crate::order::amd(&a);
        let mut ctx_seq = FactorContext::new();
        let base = DirectSolver::prepare_kind_threaded(
            &a, order.clone(), FactorKind::Cholesky, 0.0, &mut ctx_seq, 1,
        )
        .unwrap();
        assert!(!base.parallel_factor_active());
        let base_chol = base.factor().to_chol().unwrap();
        for threads in [2, 4] {
            let mut ctx = FactorContext::new();
            let mut solver = DirectSolver::prepare_kind_threaded(
                &a, order.clone(), FactorKind::Cholesky, 0.0, &mut ctx, threads,
            )
            .unwrap();
            assert_eq!(solver.stats.kernel, "supernodal");
            let chol = solver.factor().to_chol().unwrap();
            for i in 0..a.nrows() {
                assert_eq!(base_chol.row(i).0, chol.row(i).0);
                let same = base_chol
                    .row(i)
                    .1
                    .iter()
                    .zip(chol.row(i).1)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "threads={threads} row {i}: factor must be bit-identical");
            }
            let grows = ctx.workspace.grow_events();
            for _ in 0..3 {
                solver.refactor(&a, &mut ctx.workspace).unwrap();
            }
            assert_eq!(
                ctx.workspace.grow_events(),
                grows,
                "threaded refactor must not allocate"
            );
        }
    }

    #[test]
    fn small_matrices_never_build_a_schedule() {
        // the spawn-cost guard: a serving-sized matrix with a large
        // factor_threads request still factors sequentially
        let a = laplacian_2d(8, 8);
        let order = crate::order::amd(&a);
        let mut ctx = FactorContext::new();
        let solver = DirectSolver::prepare_kind_threaded(
            &a, order, FactorKind::Cholesky, 0.0, &mut ctx, 8,
        )
        .unwrap();
        assert!(!solver.parallel_factor_active(), "below cutoff: no schedule");
    }

    #[test]
    fn refactor_updates_values_in_place() {
        let a = laplacian_2d(9, 9);
        let n = a.nrows();
        let mut ctx = FactorContext::new();
        let mut solver =
            DirectSolver::prepare_with(&a, (0..n).collect(), 0.0, &mut ctx).unwrap();
        // same pattern, scaled values
        let scaled = crate::sparse::Csr::from_parts(
            n,
            n,
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.data().iter().map(|v| v * 3.0).collect(),
        );
        let misses = ctx.cache.misses();
        let grows = ctx.workspace.grow_events();
        solver.refactor(&scaled, &mut ctx.workspace).unwrap();
        assert_eq!(ctx.cache.misses(), misses, "refactor must not re-analyze");
        assert_eq!(ctx.workspace.grow_events(), grows);
        let mut rng = Pcg64::new(9);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = scaled.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&scaled, &x, &b) < 1e-10);
    }

    #[test]
    fn lu_refactor_updates_values_in_place() {
        let mut rng = Pcg64::new(11);
        let a = convection_diffusion_2d(8, 9, 3.0, &mut rng);
        let n = a.nrows();
        let order = crate::order::amd(&a);
        let mut ctx = FactorContext::new();
        let mut solver = DirectSolver::prepare_with(&a, order, 0.0, &mut ctx).unwrap();
        assert_eq!(solver.stats.factor_kind, "lu");
        let scaled = crate::sparse::Csr::from_parts(
            n,
            n,
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.data().iter().map(|v| v * 2.0).collect(),
        );
        let misses = ctx.cache.misses();
        let grows = ctx.workspace.grow_events();
        solver.refactor(&scaled, &mut ctx.workspace).unwrap();
        assert_eq!(ctx.cache.misses(), misses, "LU refactor must not re-analyze");
        assert_eq!(ctx.workspace.grow_events(), grows, "LU refactor must not grow scratch");
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = scaled.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&scaled, &x, &b) < 1e-9);
    }
}
