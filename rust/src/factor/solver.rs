//! End-to-end direct solver: reorder → factor → solve, with the fill-in and
//! timing bookkeeping the experiments report. This is the "downstream user"
//! API — what a simulation code would call.
//!
//! The solver picks the numeric kernel per pattern (supernodal for
//! fill-heavy matrices, up-looking otherwise — see `factor::supernodal::
//! profitable`), and the [`FactorContext`]-taking entry points make the
//! serving steady state cheap: a repeated pattern hits the symbolic cache
//! (zero re-analysis) and the shared workspace (zero scratch allocation),
//! and [`DirectSolver::refactor`] rewrites the factor values in place.

use std::time::Instant;

use crate::factor::numeric::{self, CholFactor, FactorError};
use crate::factor::supernodal::{self, SupernodalFactor};
use crate::factor::symbolic::{factor_flops, fill_ratio};
use crate::factor::workspace::{FactorContext, FactorWorkspace, PatternAnalysis};
use crate::sparse::Csr;

/// The factor produced by whichever numeric kernel the pattern selected.
pub enum FactorKind {
    UpLooking(CholFactor),
    Supernodal(SupernodalFactor),
}

impl FactorKind {
    /// nnz(L) including the diagonal.
    pub fn lnnz(&self) -> usize {
        match self {
            FactorKind::UpLooking(f) => f.lnnz(),
            FactorKind::Supernodal(f) => f.lnnz(),
        }
    }

    /// Entrywise ℓ₁ norm of L — the paper's surrogate objective ‖L‖₁.
    pub fn l1_norm(&self) -> f64 {
        match self {
            FactorKind::UpLooking(f) => f.l1_norm(),
            FactorKind::Supernodal(f) => f.l1_norm(),
        }
    }

    /// Solve L·y = b.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        match self {
            FactorKind::UpLooking(f) => f.solve_lower(b),
            FactorKind::Supernodal(f) => f.solve_lower(b),
        }
    }

    /// Solve Lᵀ·x = y.
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        match self {
            FactorKind::UpLooking(f) => f.solve_upper(y),
            FactorKind::Supernodal(f) => f.solve_upper(y),
        }
    }

    /// Solve A·x = b given A = L·Lᵀ.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Which kernel produced this factor.
    pub fn kernel(&self) -> &'static str {
        match self {
            FactorKind::UpLooking(_) => "up-looking",
            FactorKind::Supernodal(_) => "supernodal",
        }
    }

    /// Row-compressed view of L (clones for the up-looking kernel,
    /// converts panels for the supernodal one).
    pub fn to_chol(&self) -> CholFactor {
        match self {
            FactorKind::UpLooking(f) => f.clone(),
            FactorKind::Supernodal(f) => f.to_chol(),
        }
    }
}

/// A factorized, permuted system ready for repeated solves.
pub struct DirectSolver {
    order: Vec<usize>,
    analysis: PatternAnalysis,
    factor: FactorKind,
    /// Statistics gathered during `prepare`.
    pub stats: SolveStats,
}

/// Bookkeeping the experiments report (paper Table 2 / Figure 4 columns).
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub n: usize,
    pub nnz_a: usize,
    pub lnnz: usize,
    pub fill_ratio: f64,
    pub ordering_time: f64,
    pub symbolic_time: f64,
    pub factor_time: f64,
    /// exact LLᵀ flop count (Σⱼ col_nnz(L)ⱼ²)
    pub flops: u64,
    /// numeric kernel used ("up-looking" | "supernodal")
    pub kernel: &'static str,
}

impl DirectSolver {
    /// Reorder A with `order` (precomputed permutation; `order[k]` = original
    /// index eliminated k-th), then factorize. `ordering_time` is supplied by
    /// the caller since the ordering was computed outside.
    pub fn prepare(a: &Csr, order: Vec<usize>, ordering_time: f64) -> Result<Self, FactorError> {
        DirectSolver::prepare_with(a, order, ordering_time, &mut FactorContext::new())
    }

    /// Like [`prepare`](Self::prepare), but reusing a long-lived
    /// [`FactorContext`]: a previously-seen permuted pattern skips symbolic
    /// analysis (cache hit) and performs no scratch allocation.
    pub fn prepare_with(
        a: &Csr,
        order: Vec<usize>,
        ordering_time: f64,
        ctx: &mut FactorContext,
    ) -> Result<Self, FactorError> {
        let t0 = Instant::now();
        let pap = a.permute_sym(&order);
        let analysis = ctx.cache.analyze(&pap);
        let symbolic_time = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let factor = match &analysis.ssym {
            Some(ssym) => FactorKind::Supernodal(supernodal::factorize(
                &pap,
                ssym.clone(),
                &mut ctx.workspace,
            )?),
            None => FactorKind::UpLooking(numeric::cholesky_with_ws(
                &pap,
                &analysis.sym,
                &mut ctx.workspace,
            )?),
        };
        let factor_time = t1.elapsed().as_secs_f64();

        let stats = SolveStats {
            n: a.nrows(),
            nnz_a: a.nnz(),
            lnnz: analysis.sym.lnnz,
            fill_ratio: fill_ratio(&pap, &analysis.sym),
            ordering_time,
            symbolic_time,
            factor_time,
            flops: factor_flops(&analysis.sym),
            kernel: factor.kernel(),
        };
        Ok(DirectSolver { order, analysis, factor, stats })
    }

    /// Numeric re-factorization for a matrix with the **same pattern** as
    /// the one this solver was prepared on but (possibly) new values — the
    /// serving steady state. Performs zero symbolic analysis (the stored
    /// analysis is reused) and zero scratch allocation (given a warm
    /// workspace); the factor values are rewritten in place.
    pub fn refactor(&mut self, a: &Csr, ws: &mut FactorWorkspace) -> Result<(), FactorError> {
        let t1 = Instant::now();
        let pap = a.permute_sym(&self.order);
        match &mut self.factor {
            FactorKind::UpLooking(f) => numeric::refactor_into(&pap, &self.analysis.sym, f, ws)?,
            FactorKind::Supernodal(f) => f.refactor(&pap, ws)?,
        }
        self.stats.factor_time = t1.elapsed().as_secs_f64();
        Ok(())
    }

    /// Solve A·x = b (handles the permutation internally).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = b.len();
        assert_eq!(n, self.order.len());
        let pb: Vec<f64> = self.order.iter().map(|&o| b[o]).collect();
        let px = self.factor.solve(&pb);
        let mut x = vec![0.0; n];
        for (k, &o) in self.order.iter().enumerate() {
            x[o] = px[k];
        }
        x
    }

    /// Relative residual ‖Ax − b‖₂ / ‖b‖₂.
    pub fn residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
        let ax = a.matvec(x);
        let num: f64 = ax
            .iter()
            .zip(b)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        let den: f64 = b.iter().map(|q| q * q).sum::<f64>().sqrt().max(1e-300);
        num / den
    }

    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn factor(&self) -> &FactorKind {
        &self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_with_identity_order() {
        let a = laplacian_2d(6, 6);
        let n = a.nrows();
        let solver = DirectSolver::prepare(&a, (0..n).collect(), 0.0).unwrap();
        let mut rng = Pcg64::new(1);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn solves_with_random_order() {
        let a = laplacian_2d(5, 7);
        let n = a.nrows();
        let mut rng = Pcg64::new(2);
        let order = rng.permutation(n);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn stats_are_populated() {
        let a = laplacian_2d(8, 8);
        let solver = DirectSolver::prepare(&a, (0..64).collect(), 0.125).unwrap();
        let s = &solver.stats;
        assert_eq!(s.n, 64);
        assert_eq!(s.nnz_a, a.nnz());
        assert!(s.lnnz >= (a.nnz() + 64) / 2);
        assert!(s.fill_ratio >= 0.0);
        assert_eq!(s.ordering_time, 0.125);
        assert!(s.factor_time >= 0.0);
        assert!(s.flops > 0);
        assert!(!s.kernel.is_empty());
    }

    #[test]
    fn supernodal_path_selected_and_solves() {
        let a = laplacian_3d(6, 6, 6);
        let order = crate::order::amd(&a);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        assert_eq!(solver.stats.kernel, "supernodal");
        let n = a.nrows();
        let mut rng = Pcg64::new(4);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn steady_state_skips_symbolic_and_allocations() {
        // the acceptance criterion: repeated factorizations with an
        // unchanged pattern → zero symbolic re-analysis, zero scratch
        // re-allocation
        let a = laplacian_3d(5, 5, 5);
        let order = crate::order::amd(&a);
        let mut ctx = FactorContext::new();
        let _ = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
        assert_eq!(ctx.cache.misses(), 1);
        let grows = ctx.workspace.grow_events();
        for _ in 0..5 {
            let s = DirectSolver::prepare_with(&a, order.clone(), 0.0, &mut ctx).unwrap();
            assert!(s.stats.lnnz > 0);
        }
        assert_eq!(ctx.cache.misses(), 1, "no symbolic re-analysis");
        assert_eq!(ctx.cache.hits(), 5);
        assert_eq!(ctx.workspace.grow_events(), grows, "no scratch re-allocation");
    }

    #[test]
    fn refactor_updates_values_in_place() {
        let a = laplacian_2d(9, 9);
        let n = a.nrows();
        let mut ctx = FactorContext::new();
        let mut solver =
            DirectSolver::prepare_with(&a, (0..n).collect(), 0.0, &mut ctx).unwrap();
        // same pattern, scaled values
        let scaled = crate::sparse::Csr::from_parts(
            n,
            n,
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.data().iter().map(|v| v * 3.0).collect(),
        );
        let misses = ctx.cache.misses();
        let grows = ctx.workspace.grow_events();
        solver.refactor(&scaled, &mut ctx.workspace).unwrap();
        assert_eq!(ctx.cache.misses(), misses, "refactor must not re-analyze");
        assert_eq!(ctx.workspace.grow_events(), grows);
        let mut rng = Pcg64::new(9);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = scaled.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&scaled, &x, &b) < 1e-10);
    }
}
