//! End-to-end direct solver: reorder → factor → solve, with the fill-in and
//! timing bookkeeping the experiments report. This is the "downstream user"
//! API — what a simulation code would call.

use std::time::Instant;

use crate::factor::numeric::{cholesky_with, CholFactor, FactorError};
use crate::factor::symbolic::{analyze, fill_ratio, Symbolic};
use crate::sparse::Csr;

/// A factorized, permuted system ready for repeated solves.
pub struct DirectSolver {
    order: Vec<usize>,
    factor: CholFactor,
    /// Statistics gathered during `prepare`.
    pub stats: SolveStats,
}

/// Bookkeeping the experiments report (paper Table 2 / Figure 4 columns).
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub n: usize,
    pub nnz_a: usize,
    pub lnnz: usize,
    pub fill_ratio: f64,
    pub ordering_time: f64,
    pub symbolic_time: f64,
    pub factor_time: f64,
}

impl DirectSolver {
    /// Reorder A with `order` (precomputed permutation; `order[k]` = original
    /// index eliminated k-th), then factorize. `ordering_time` is supplied by
    /// the caller since the ordering was computed outside.
    pub fn prepare(a: &Csr, order: Vec<usize>, ordering_time: f64) -> Result<Self, FactorError> {
        let t0 = Instant::now();
        let pap = a.permute_sym(&order);
        let sym: Symbolic = analyze(&pap);
        let symbolic_time = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let factor = cholesky_with(&pap, &sym)?;
        let factor_time = t1.elapsed().as_secs_f64();

        let stats = SolveStats {
            n: a.nrows(),
            nnz_a: a.nnz(),
            lnnz: sym.lnnz,
            fill_ratio: fill_ratio(&pap, &sym),
            ordering_time,
            symbolic_time,
            factor_time,
        };
        Ok(DirectSolver { order, factor, stats })
    }

    /// Solve A·x = b (handles the permutation internally).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = b.len();
        assert_eq!(n, self.order.len());
        let pb: Vec<f64> = self.order.iter().map(|&o| b[o]).collect();
        let px = self.factor.solve(&pb);
        let mut x = vec![0.0; n];
        for (k, &o) in self.order.iter().enumerate() {
            x[o] = px[k];
        }
        x
    }

    /// Relative residual ‖Ax − b‖₂ / ‖b‖₂.
    pub fn residual(a: &Csr, x: &[f64], b: &[f64]) -> f64 {
        let ax = a.matvec(x);
        let num: f64 = ax
            .iter()
            .zip(b)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        let den: f64 = b.iter().map(|q| q * q).sum::<f64>().sqrt().max(1e-300);
        num / den
    }

    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn factor(&self) -> &CholFactor {
        &self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_with_identity_order() {
        let a = laplacian_2d(6, 6);
        let n = a.nrows();
        let solver = DirectSolver::prepare(&a, (0..n).collect(), 0.0).unwrap();
        let mut rng = Pcg64::new(1);
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn solves_with_random_order() {
        let a = laplacian_2d(5, 7);
        let n = a.nrows();
        let mut rng = Pcg64::new(2);
        let order = rng.permutation(n);
        let solver = DirectSolver::prepare(&a, order, 0.0).unwrap();
        let xt: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xt);
        let x = solver.solve(&b);
        assert!(DirectSolver::residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn stats_are_populated() {
        let a = laplacian_2d(8, 8);
        let solver = DirectSolver::prepare(&a, (0..64).collect(), 0.125).unwrap();
        let s = &solver.stats;
        assert_eq!(s.n, 64);
        assert_eq!(s.nnz_a, a.nnz());
        assert!(s.lnnz >= (a.nnz() + 64) / 2);
        assert!(s.fill_ratio >= 0.0);
        assert_eq!(s.ordering_time, 0.125);
        assert!(s.factor_time >= 0.0);
    }
}
