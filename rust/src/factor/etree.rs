//! Elimination tree of a sparse symmetric matrix.
//!
//! The etree is the dependency skeleton of Cholesky factorization: node j's
//! parent is the smallest row index i > j with l_ij ≠ 0. Both the symbolic
//! analysis (fill-in counts) and the numeric up-looking factorization are
//! driven by it (Liu, "The role of elimination trees in sparse
//! factorization", 1990).

use crate::sparse::Csr;

/// Sentinel for "no parent" (tree root).
pub const NONE: usize = usize::MAX;

/// Compute the elimination tree. `parent[j] = NONE` marks a root.
/// Uses the classic path-compression construction: O(nnz · α(n)).
pub fn etree(a: &Csr) -> Vec<usize> {
    let n = a.nrows();
    let mut parent = vec![NONE; n];
    let mut ancestor = vec![NONE; n]; // path-compressed ancestors
    for i in 0..n {
        let (cols, _) = a.row(i);
        for &j in cols {
            if j >= i {
                break; // only strict lower triangle drives the tree
            }
            // follow ancestors of j up to (but below) i, compressing
            let mut node = j;
            while node != NONE && node < i {
                let next = ancestor[node];
                ancestor[node] = i; // compress
                if next == NONE {
                    parent[node] = i;
                    break;
                }
                node = next;
            }
        }
    }
    parent
}

/// Postorder traversal of the etree (children before parents). Stable:
/// children are visited in ascending order. Returns the permutation
/// `post` with `post[k]` = k-th node in postorder.
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    // build child lists (ascending by construction)
    let mut head = vec![NONE; n];
    let mut next = vec![NONE; n];
    for j in (0..n).rev() {
        let p = parent[j];
        if p != NONE {
            next[j] = head[p];
            head[p] = j;
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack = Vec::new();
    for root in 0..n {
        if parent[root] != NONE {
            continue;
        }
        // iterative DFS emitting postorder
        stack.push((root, false));
        while let Some((node, expanded)) = stack.pop() {
            if expanded {
                post.push(node);
                continue;
            }
            stack.push((node, true));
            // push children (reversed so ascending pops first)
            let mut kids = Vec::new();
            let mut c = head[node];
            while c != NONE {
                kids.push(c);
                c = next[c];
            }
            for &k in kids.iter().rev() {
                stack.push((k, false));
            }
        }
    }
    post
}

/// Depth of each node in the etree (roots at depth 0).
pub fn depths(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut depth = vec![NONE; n];
    for mut j in 0..n {
        // walk up collecting the path, then assign
        let mut path = Vec::new();
        while depth[j] == NONE {
            path.push(j);
            if parent[j] == NONE {
                depth[j] = 0;
                break;
            }
            j = parent[j];
        }
        let mut d = depth[j];
        for &p in path.iter().rev() {
            if depth[p] == NONE {
                d += 1;
                depth[p] = d;
            } else {
                d = depth[p];
            }
        }
    }
    depth
}

/// Height of the etree (longest root-to-leaf path + 1): a proxy for the
/// parallelism of the triangular solves.
pub fn height(parent: &[usize]) -> usize {
    depths(parent).iter().map(|&d| d + 1).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::sparse::Coo;

    /// Arrow matrix pointing down-right: every node couples to the last.
    fn arrow(n: usize) -> Csr {
        let mut coo = Coo::square(n);
        for i in 0..n - 1 {
            coo.push_sym(i, n - 1, -1.0);
        }
        for i in 0..n {
            coo.push(i, i, 4.0);
        }
        coo.to_csr()
    }

    #[test]
    fn etree_of_arrow_is_star() {
        let parent = etree(&arrow(5));
        assert_eq!(parent, vec![4, 4, 4, 4, NONE]);
    }

    #[test]
    fn etree_of_tridiagonal_is_path() {
        let mut coo = Coo::square(5);
        for i in 0..4 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..5 {
            coo.push(i, i, 2.0);
        }
        let parent = etree(&coo.to_csr());
        assert_eq!(parent, vec![1, 2, 3, 4, NONE]);
    }

    #[test]
    fn postorder_children_first() {
        let a = laplacian_2d(5, 5);
        let parent = etree(&a);
        let post = postorder(&parent);
        assert_eq!(post.len(), 25);
        // position of each node in the postorder
        let mut pos = vec![0usize; 25];
        for (k, &v) in post.iter().enumerate() {
            pos[v] = k;
        }
        for j in 0..25 {
            if parent[j] != NONE {
                assert!(pos[j] < pos[parent[j]], "child {j} after parent");
            }
        }
    }

    #[test]
    fn depths_and_height() {
        let parent = vec![1, 2, NONE]; // path 0→1→2
        assert_eq!(depths(&parent), vec![2, 1, 0]);
        assert_eq!(height(&parent), 3);
    }

    #[test]
    fn forest_posts_all_roots() {
        // two separate 2-node trees: 0→1, 2→3
        let parent = vec![1, NONE, 3, NONE];
        let post = postorder(&parent);
        assert_eq!(post.len(), 4);
        let mut sorted = post.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }
}
