//! Numeric sparse Cholesky factorization (up-looking, etree-driven) plus
//! triangular solves. This is the in-repo replacement for the paper's
//! SuperLU `splu` call: the benchmark harness times *this* factorizer under
//! each candidate ordering, so method-vs-method time ratios are measured on
//! identical code.

use crate::factor::etree::NONE;
use crate::factor::symbolic::{analyze, Symbolic};
use crate::factor::workspace::FactorWorkspace;
use crate::sparse::Csr;

/// Lower-triangular Cholesky factor stored row-compressed (columns sorted
/// ascending; the diagonal is each row's last entry).
#[derive(Clone, Debug)]
pub struct CholFactor {
    n: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    data: Vec<f64>,
}

/// Factorization failure.
#[derive(Debug)]
pub enum FactorError {
    NotPositiveDefinite { row: usize, pivot: f64 },
    NotSquare { nrows: usize, ncols: usize },
    /// LU found no usable pivot in this column (structurally or
    /// numerically singular).
    Singular { col: usize },
}

impl std::fmt::Display for FactorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FactorError::NotPositiveDefinite { row, pivot } => {
                write!(f, "matrix is not positive definite: pivot {pivot} at row {row}")
            }
            FactorError::NotSquare { nrows, ncols } => {
                write!(f, "matrix is not square: {nrows}x{ncols}")
            }
            FactorError::Singular { col } => {
                write!(f, "matrix is singular: no usable pivot in column {col}")
            }
        }
    }
}

impl std::error::Error for FactorError {}

impl CholFactor {
    pub fn n(&self) -> usize {
        self.n
    }

    /// nnz(L) including the diagonal.
    pub fn lnnz(&self) -> usize {
        self.indices.len()
    }

    /// Row i of L: (columns, values), diagonal last.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// Entrywise ℓ₁ norm of L — the paper's surrogate objective ‖L‖₁.
    pub fn l1_norm(&self) -> f64 {
        self.data.iter().map(|v| v.abs()).sum()
    }

    /// Count |l_ij| > tol (numeric nnz; equals structural lnnz absent
    /// exact cancellation).
    pub fn nnz_above(&self, tol: f64) -> usize {
        self.data.iter().filter(|v| v.abs() > tol).count()
    }

    /// Solve L·y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let mut y = b.to_vec();
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            let mut acc = y[i];
            // all entries except the diagonal (last)
            for k in 0..cols.len() - 1 {
                acc -= vals[k] * y[cols[k]];
            }
            y[i] = acc / vals[cols.len() - 1];
        }
        y
    }

    /// Solve Lᵀ·x = y (backward substitution on the row-stored factor).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.n);
        let mut x = y.to_vec();
        for i in (0..self.n).rev() {
            let (cols, vals) = self.row(i);
            let d = vals[cols.len() - 1];
            x[i] /= d;
            let xi = x[i];
            for k in 0..cols.len() - 1 {
                x[cols[k]] -= vals[k] * xi;
            }
        }
        x
    }

    /// Solve A·x = b given A = L·Lᵀ.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Materialize L as a CSR matrix (tests / inspection).
    pub fn to_csr(&self) -> Csr {
        Csr::from_parts(
            self.n,
            self.n,
            self.indptr.clone(),
            self.indices.clone(),
            self.data.clone(),
        )
    }

    /// Assemble from raw row-compressed parts (used by the supernodal
    /// kernel's `to_chol` conversion). Caller guarantees the layout
    /// invariants: sorted columns, diagonal last per row.
    pub(crate) fn from_parts_unchecked(
        n: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        data: Vec<f64>,
    ) -> CholFactor {
        debug_assert_eq!(indptr.len(), n + 1);
        debug_assert_eq!(indices.len(), data.len());
        CholFactor { n, indptr, indices, data }
    }
}

/// Up-looking sparse Cholesky: A = L·Lᵀ.
///
/// Runs symbolic analysis internally; use [`cholesky_with`] to reuse an
/// existing [`Symbolic`] (the benchmark harness separates the two phases).
pub fn cholesky(a: &Csr) -> Result<CholFactor, FactorError> {
    let sym = analyze(a);
    cholesky_with(a, &sym)
}

/// Up-looking numeric factorization with a precomputed symbolic analysis.
/// Allocates a throwaway workspace; long-lived callers should hold a
/// [`FactorWorkspace`] and use [`cholesky_with_ws`] instead.
pub fn cholesky_with(a: &Csr, sym: &Symbolic) -> Result<CholFactor, FactorError> {
    cholesky_with_ws(a, sym, &mut FactorWorkspace::new())
}

/// Up-looking numeric factorization with caller-owned scratch buffers.
/// Repeated calls with same-size (or smaller) matrices perform zero
/// scratch allocations (the factor's own storage is still fresh; use
/// [`refactor_into`] to reuse that too).
pub fn cholesky_with_ws(
    a: &Csr,
    sym: &Symbolic,
    ws: &mut FactorWorkspace,
) -> Result<CholFactor, FactorError> {
    let mut indptr = Vec::new();
    let mut indices = Vec::new();
    let mut data = Vec::new();
    factor_core(a, sym, &mut indptr, &mut indices, &mut data, ws)?;
    Ok(CholFactor { n: a.nrows(), indptr, indices, data })
}

/// Numeric re-factorization in place: `f` must come from a previous
/// factorization of a matrix with the same sparsity pattern as `a`. The
/// factor's buffers are reused (no allocation), so the serving steady
/// state — same pattern, new values — touches the allocator not at all.
pub fn refactor_into(
    a: &Csr,
    sym: &Symbolic,
    f: &mut CholFactor,
    ws: &mut FactorWorkspace,
) -> Result<(), FactorError> {
    assert_eq!(f.n, a.nrows(), "refactor_into: factor/matrix size mismatch");
    let CholFactor { indptr, indices, data, .. } = f;
    factor_core(a, sym, indptr, indices, data, ws)
}

/// Shared numeric core writing into caller-owned factor storage. The
/// output vectors are cleared and resized (capacity is reused when the
/// caller passes previously-filled buffers of the same pattern).
fn factor_core(
    a: &Csr,
    sym: &Symbolic,
    indptr: &mut Vec<usize>,
    indices: &mut Vec<usize>,
    data: &mut Vec<f64>,
    ws: &mut FactorWorkspace,
) -> Result<(), FactorError> {
    if a.nrows() != a.ncols() {
        return Err(FactorError::NotSquare { nrows: a.nrows(), ncols: a.ncols() });
    }
    let n = a.nrows();
    ws.acquire(n);
    let (x, mark, pattern) = ws.uplooking_buffers();
    indptr.clear();
    indptr.resize(n + 1, 0);
    for i in 0..n {
        indptr[i + 1] = indptr[i] + sym.row_nnz[i];
    }
    let lnnz = indptr[n];
    indices.clear();
    indices.resize(lnnz, 0);
    data.clear();
    data.resize(lnnz, 0.0);

    // Quick diagonal lookup for each already-factored row: position of the
    // diagonal is indptr[r+1]-1 by construction.
    for i in 0..n {
        // ----- symbolic: pattern of row i via etree row subtrees -----
        pattern.clear();
        mark[i] = i;
        let (acols, avals) = a.row(i);
        let mut diag_a = 0.0;
        for (&j, &v) in acols.iter().zip(avals) {
            if j > i {
                break;
            }
            if j == i {
                diag_a = v;
                continue;
            }
            x[j] = v;
            let mut node = j;
            while mark[node] != i {
                mark[node] = i;
                pattern.push(node);
                if sym.parent[node] == NONE || sym.parent[node] >= i {
                    break;
                }
                node = sym.parent[node];
            }
        }
        // ascending column order gives a valid elimination order (deps j'<j)
        pattern.sort_unstable();

        // ----- numeric: sparse triangular solve L[0..i,0..i]·lᵢᵀ = aᵢ -----
        // Process pattern columns ascending; when column j is reached, every
        // x[k] with k < j already holds the final l_ik (zero off-pattern), so
        //   l_ij = (a_ij − Σ_{k<j} l_jk·l_ik) / l_jj
        // is a gather over row j of L against the dense scratch x.
        let mut diag = diag_a;
        for &j in pattern.iter() {
            let (jcols, jvals) = (
                &indices[indptr[j]..indptr[j + 1]],
                &data[indptr[j]..indptr[j + 1]],
            );
            let mut sum = 0.0;
            for t in 0..jcols.len() - 1 {
                sum += jvals[t] * x[jcols[t]];
            }
            let djj = jvals[jcols.len() - 1];
            let lij = (x[j] - sum) / djj;
            x[j] = lij;
            diag -= lij * lij;
        }
        if diag <= 0.0 {
            return Err(FactorError::NotPositiveDefinite { row: i, pivot: diag });
        }

        // write row i
        let s = indptr[i];
        debug_assert_eq!(pattern.len() + 1, sym.row_nnz[i]);
        for (k, &j) in pattern.iter().enumerate() {
            indices[s + k] = j;
            data[s + k] = x[j];
            x[j] = 0.0; // reset scratch
        }
        indices[s + pattern.len()] = i;
        data[s + pattern.len()] = diag.sqrt();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::{laplacian_2d, laplacian_3d};
    use crate::sparse::{Coo, Dense};
    use crate::util::check::assert_vec_close;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut coo = Coo::square(n);
        let mut diag = vec![1.0; n];
        for _ in 0..(3 * n) {
            let i = rng.next_below(n);
            let j = rng.next_below(n);
            if i == j {
                continue;
            }
            let w = 0.1 + rng.next_f64();
            coo.push_sym(i, j, -w);
            diag[i] += w;
            diag[j] += w;
        }
        for (i, d) in diag.iter().enumerate() {
            coo.push(i, i, *d + 0.5);
        }
        coo.to_csr()
    }

    fn check_reconstruction(a: &Csr, tol: f64) {
        let f = cholesky(a).expect("factorization");
        let l = f.to_csr();
        let lt = l.transpose();
        // (L·Lᵀ)_ij = Σ_k l_ik l_jk — compare against A densely (small n)
        let ld = l.to_dense();
        let ltd = lt.to_dense();
        let n = a.nrows();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += ld[i][k] * ltd[k][j];
                }
                let aij = a.get(i, j);
                assert!(
                    (s - aij).abs() <= tol * 1.0_f64.max(aij.abs()),
                    "LLᵀ mismatch at ({i},{j}): {s} vs {aij}"
                );
            }
        }
    }

    #[test]
    fn reconstructs_small_grid() {
        check_reconstruction(&laplacian_2d(4, 4), 1e-10);
        check_reconstruction(&laplacian_3d(3, 3, 2), 1e-10);
    }

    #[test]
    fn reconstructs_random_spd() {
        for seed in 0..8 {
            check_reconstruction(&random_spd(25, seed), 1e-9);
        }
    }

    #[test]
    fn matches_dense_cholesky() {
        let a = random_spd(20, 42);
        let f = cholesky(&a).unwrap();
        let dense_l = Dense::from_rows(&a.to_dense()).cholesky().unwrap();
        for i in 0..20 {
            let (cols, vals) = f.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                assert!(
                    (v - dense_l.get(i, c)).abs() < 1e-9,
                    "L[{i}][{c}] {v} vs {}",
                    dense_l.get(i, c)
                );
            }
        }
    }

    #[test]
    fn structural_nnz_matches_symbolic() {
        let a = laplacian_2d(7, 6);
        let sym = analyze(&a);
        let f = cholesky_with(&a, &sym).unwrap();
        assert_eq!(f.lnnz(), sym.lnnz);
    }

    #[test]
    fn solve_roundtrip() {
        let a = random_spd(40, 7);
        let f = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(8);
        let xtrue: Vec<f64> = (0..40).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xtrue);
        let x = f.solve(&b);
        assert_vec_close(&x, &xtrue, 1e-8);
    }

    #[test]
    fn workspace_reuse_allocates_once() {
        let a = laplacian_2d(9, 8);
        let sym = analyze(&a);
        let mut ws = FactorWorkspace::new();
        let f1 = cholesky_with_ws(&a, &sym, &mut ws).unwrap();
        let grows = ws.grow_events();
        assert_eq!(grows, 1);
        for _ in 0..3 {
            let f = cholesky_with_ws(&a, &sym, &mut ws).unwrap();
            assert_eq!(f.lnnz(), f1.lnnz());
        }
        assert_eq!(ws.grow_events(), grows, "steady state must not grow scratch");
        assert_eq!(ws.factorizations(), 4);
    }

    #[test]
    fn refactor_into_matches_fresh_factorization() {
        let a = random_spd(35, 3);
        let sym = analyze(&a);
        let mut ws = FactorWorkspace::new();
        let mut f = cholesky_with_ws(&a, &sym, &mut ws).unwrap();
        // scale the values, keep the pattern
        let scaled = Csr::from_parts(
            a.nrows(),
            a.ncols(),
            a.indptr().to_vec(),
            a.indices().to_vec(),
            a.data().iter().map(|v| v * 2.0).collect(),
        );
        let grows = ws.grow_events();
        refactor_into(&scaled, &sym, &mut f, &mut ws).unwrap();
        assert_eq!(ws.grow_events(), grows);
        let fresh = cholesky(&scaled).unwrap();
        assert_eq!(f.lnnz(), fresh.lnnz());
        for i in 0..a.nrows() {
            assert_eq!(f.row(i).0, fresh.row(i).0);
            assert_vec_close(f.row(i).1, fresh.row(i).1, 1e-14);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut coo = Coo::square(2);
        coo.push(0, 0, 1.0);
        coo.push_sym(0, 1, 2.0);
        coo.push(1, 1, 1.0);
        let res = cholesky(&coo.to_csr());
        assert!(matches!(res, Err(FactorError::NotPositiveDefinite { .. })));
    }

    #[test]
    fn l1_norm_positive() {
        let a = laplacian_2d(5, 5);
        let f = cholesky(&a).unwrap();
        assert!(f.l1_norm() > 0.0);
        assert!(f.nnz_above(1e-12) <= f.lnnz());
    }

    #[test]
    fn permuted_factorization_still_solves_original() {
        // factor PAPᵀ, solve via permuted rhs — standard direct-solver path
        let a = random_spd(30, 9);
        let order: Vec<usize> = {
            let mut rng = Pcg64::new(10);
            rng.permutation(30)
        };
        let pap = a.permute_sym(&order);
        let f = cholesky(&pap).unwrap();
        let mut rng = Pcg64::new(11);
        let xtrue: Vec<f64> = (0..30).map(|_| rng.next_gaussian()).collect();
        let b = a.matvec(&xtrue);
        // permute b, solve, un-permute x
        let pb: Vec<f64> = order.iter().map(|&o| b[o]).collect();
        let px = f.solve(&pb);
        let mut x = vec![0.0; 30];
        for (k, &o) in order.iter().enumerate() {
            x[o] = px[k];
        }
        assert_vec_close(&x, &xtrue, 1e-8);
    }
}
