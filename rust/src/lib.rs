//! # pfm-reorder
//!
//! Reproduction of **"Factorization-in-Loop: Proximal Fill-in Minimization
//! for Sparse Matrix Reordering"** (AAAI 2026). A three-layer system:
//!
//! * **L3 (this crate)** — sparse-matrix substrates, baseline reordering
//!   algorithms, symbolic + numeric Cholesky, the native in-Rust PFM
//!   optimizer (`pfm`: instance-wise ADMM + proximal fill-in
//!   minimization), a PJRT runtime that executes the AOT-compiled PFM
//!   network, an async reordering service, and a framed TCP gateway that
//!   puts the service on the wire.
//! * **L2 (python/compile)** — the PFM reordering network in JAX, trained
//!   with ADMM + proximal gradient at build time.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the network's hot
//!   spots (Sinkhorn normalization, SAGE aggregation, soft-threshold).
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.
pub mod coordinator;
pub mod factor;
pub mod gateway;
pub mod gen;
pub mod harness;
pub mod graph;
pub mod obs;
pub mod order;
pub mod persist;
pub mod pfm;
pub mod runtime;
pub mod sparse;
pub mod util;
