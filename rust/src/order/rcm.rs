//! Cuthill–McKee and Reverse Cuthill–McKee bandwidth-reducing orderings
//! (Cuthill & McKee 1969; George 1971).

use crate::graph::Graph;
use crate::sparse::Csr;

/// Cuthill–McKee: BFS from a pseudo-peripheral node, visiting neighbours in
/// ascending-degree order. Handles disconnected graphs by restarting from
/// the lowest-degree unvisited node.
pub fn cm(a: &Csr) -> Vec<usize> {
    let g = Graph::from_matrix(a);
    let n = g.n();
    let mut order = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();

    // component seeds in ascending degree order
    let mut seeds: Vec<usize> = (0..n).collect();
    seeds.sort_by_key(|&u| g.degree(u));

    for &seed in &seeds {
        if visited[seed] {
            continue;
        }
        let root = g.pseudo_peripheral(seed);
        visited[root] = true;
        queue.push_back(root);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            let mut nbrs: Vec<usize> =
                g.neighbors(u).iter().copied().filter(|&v| !visited[v]).collect();
            nbrs.sort_by_key(|&v| (g.degree(v), v));
            for v in nbrs {
                visited[v] = true;
                queue.push_back(v);
            }
        }
    }
    order
}

/// Reverse Cuthill–McKee: CM reversed; reduces the profile/fill of the
/// factorization rather than just the bandwidth.
pub fn rcm(a: &Csr) -> Vec<usize> {
    let mut order = cm(a);
    order.reverse();
    order
}

/// Matrix bandwidth under an ordering: max |pos(i) − pos(j)| over nonzeros.
pub fn bandwidth(a: &Csr, order: &[usize]) -> usize {
    let n = a.nrows();
    let mut pos = vec![0usize; n];
    for (k, &o) in order.iter().enumerate() {
        pos[o] = k;
    }
    let mut bw = 0usize;
    for i in 0..n {
        let (cols, _) = a.row(i);
        for &j in cols {
            let d = pos[i].abs_diff(pos[j]);
            bw = bw.max(d);
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::util::check::check_permutation;
    use crate::util::rng::Pcg64;

    #[test]
    fn cm_and_rcm_are_permutations() {
        let a = laplacian_2d(7, 5);
        check_permutation(&cm(&a)).unwrap();
        check_permutation(&rcm(&a)).unwrap();
    }

    #[test]
    fn rcm_reduces_bandwidth_of_shuffled_grid() {
        let a = laplacian_2d(10, 10);
        let mut rng = Pcg64::new(3);
        let shuffle = rng.permutation(100);
        let b = a.permute_sym(&shuffle);
        let natural_bw = bandwidth(&b, &(0..100).collect::<Vec<_>>());
        let rcm_bw = bandwidth(&b, &rcm(&b));
        assert!(
            rcm_bw < natural_bw / 2,
            "rcm bw {rcm_bw} vs natural {natural_bw}"
        );
    }

    #[test]
    fn rcm_reduces_fill_on_grid() {
        use crate::factor::fill_ratio_of_order;
        let a = laplacian_2d(12, 12);
        let mut rng = Pcg64::new(4);
        let shuffled_order = rng.permutation(144);
        let shuffled_fill = fill_ratio_of_order(&a, &shuffled_order);
        let rcm_fill = fill_ratio_of_order(&a, &rcm(&a));
        assert!(
            rcm_fill < shuffled_fill,
            "rcm {rcm_fill} vs shuffled {shuffled_fill}"
        );
    }

    #[test]
    fn handles_disconnected() {
        let mut coo = crate::sparse::Coo::square(6);
        coo.push_sym(0, 1, -1.0);
        coo.push_sym(3, 4, -1.0);
        for i in 0..6 {
            coo.push(i, i, 2.0);
        }
        let a = coo.to_csr();
        check_permutation(&rcm(&a)).unwrap();
    }

    #[test]
    fn path_graph_cm_is_linear() {
        let mut coo = crate::sparse::Coo::square(8);
        for i in 0..7 {
            coo.push_sym(i, i + 1, -1.0);
        }
        for i in 0..8 {
            coo.push(i, i, 2.0);
        }
        let a = coo.to_csr();
        let order = cm(&a);
        // path visited end-to-end → bandwidth 1
        assert_eq!(bandwidth(&a, &order), 1);
    }
}
