//! Matrix reordering algorithms: every baseline row of the paper's Table 2
//! plus the score-sorting inference path shared by all learned methods
//! (S_e, GPCE, UDNO, PFM).

pub mod amd;
pub mod nd;
pub mod rcm;
pub mod score;
pub mod spectral;

pub use amd::amd;
pub use nd::{nested_dissection, nested_dissection_with};
pub use rcm::{cm, rcm};
pub use score::{order_from_scores, order_from_scores_f32, ranks_from_scores};
pub use spectral::{fiedler_order, fiedler_order_with};

use crate::sparse::Csr;

/// The classical (non-learned) ordering methods, i.e. everything computable
/// without network artifacts. Learned methods are provided by
/// `runtime::pfm` (they need a compiled HLO artifact).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Classical {
    /// No reordering (identity).
    Natural,
    /// Reverse Cuthill–McKee.
    Rcm,
    /// Approximate minimum degree.
    Amd,
    /// Multilevel nested dissection (METIS-class).
    Metis,
    /// Fiedler-vector spectral ordering.
    Fiedler,
}

impl Classical {
    pub const ALL: [Classical; 5] = [
        Classical::Natural,
        Classical::Rcm,
        Classical::Amd,
        Classical::Metis,
        Classical::Fiedler,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Classical::Natural => "Natural",
            Classical::Rcm => "RCM",
            Classical::Amd => "AMD",
            Classical::Metis => "Metis",
            Classical::Fiedler => "Fiedler",
        }
    }

    /// Parse from the table label (case-insensitive; accepts the `nd` and
    /// `spectral` CLI aliases). Inverse of [`label`](Self::label) — the
    /// label strings live only there.
    pub fn from_label(s: &str) -> Option<Classical> {
        Classical::ALL
            .into_iter()
            .find(|c| c.label().eq_ignore_ascii_case(s))
            .or_else(|| {
                if s.eq_ignore_ascii_case("nd") {
                    Some(Classical::Metis)
                } else if s.eq_ignore_ascii_case("spectral") {
                    Some(Classical::Fiedler)
                } else {
                    None
                }
            })
    }

    /// Compute the elimination order for `a`.
    pub fn order(&self, a: &Csr) -> Vec<usize> {
        match self {
            Classical::Natural => (0..a.nrows()).collect(),
            Classical::Rcm => rcm(a),
            Classical::Amd => amd(a),
            Classical::Metis => nested_dissection(a),
            Classical::Fiedler => fiedler_order(a),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::grid::laplacian_2d;
    use crate::util::check::check_permutation;

    #[test]
    fn all_classical_methods_produce_permutations() {
        let a = laplacian_2d(10, 9);
        for m in Classical::ALL {
            let order = m.order(&a);
            check_permutation(&order)
                .unwrap_or_else(|e| panic!("{}: {e}", m.label()));
        }
    }

    #[test]
    fn natural_is_identity() {
        let a = laplacian_2d(4, 4);
        assert_eq!(Classical::Natural.order(&a), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn fill_ranking_matches_paper_shape() {
        // Paper Table 2 shape: Natural ≫ {AMD, Metis, Fiedler} on 2D3D.
        use crate::factor::fill_ratio_of_order;
        let a = laplacian_2d(20, 20);
        let fill = |m: Classical| fill_ratio_of_order(&a, &m.order(&a));
        let nat = fill(Classical::Natural);
        for m in [Classical::Amd, Classical::Metis, Classical::Fiedler] {
            let f = fill(m);
            assert!(f < nat, "{} fill {f} not below natural {nat}", m.label());
        }
    }
}
